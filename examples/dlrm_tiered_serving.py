"""End-to-end driver (the paper's deployment): serve a DLRM with batched
inference requests where embedding lookups run through the tiered-memory
buffer, comparing production LRU against RecMG (caching + prefetch models,
trained on the fly and pipelined one batch ahead).

    PYTHONPATH=src python examples/dlrm_tiered_serving.py [--accesses 120000]

Prints the paper's Fig.16-style per-batch latency breakdown and the
end-to-end inference-time reduction.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=120_000)
    ap.add_argument("--capacity-frac", type=float, default=0.18)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--batch-queries", type=int, default=32)
    ap.add_argument("--multi-table", action="store_true",
                    help="serve through the per-table facade (one batched "
                         "store per sparse feature, shared row budget)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.belady import belady_labels
    from repro.core.caching_model import (CachingModelConfig,
                                          evaluate_caching_model,
                                          train_caching_model)
    from repro.core.features import make_windows, split_train_eval
    from repro.core.prefetch_model import (PrefetchModelConfig,
                                           make_prefetch_data,
                                           train_prefetch_model)
    from repro.core.recmg import precompute_outputs
    from repro.core.trace import TraceGenConfig, generate_trace
    from repro.launch.serve import serve_trace
    from repro.models.dlrm import init_dlrm

    import dataclasses

    # CPU-sized DLRM with enough unique vectors (65K) that the access
    # distribution keeps production-like skew (same geometry as the bench).
    cfg = dataclasses.replace(get_config("dlrm-recmg").reduced(),
                              n_tables=16, rows_per_table=4096, multi_hot=4,
                              emb_dim=16)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    trace = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=args.accesses, drift_every=10**9))
    cap = int(args.capacity_frac * trace.unique_count())
    print(f"trace: {len(trace)} accesses, {trace.unique_count()} unique "
          f"vectors; buffer = {cap} ({args.capacity_frac:.0%})")

    # Offline training exactly as in the paper §VI-A.
    print("\n[1/3] Belady/optgen ground truth + model training...")
    labels, opt_hits, _ = belady_labels(trace.global_id, cap)
    mcfg = CachingModelConfig(n_tables=cfg.n_tables)
    data = make_windows(trace, labels=labels)
    trd, evd = split_train_eval(data)
    cparams, _ = train_caching_model(trd, mcfg, epochs=args.epochs,
                                     batch_size=512, log=print)
    print(f"  caching-model accuracy vs Belady: "
          f"{evaluate_caching_model(cparams, evd):.1%} (paper: ~83%)")
    pcfg = PrefetchModelConfig(n_tables=cfg.n_tables)
    pparams, _ = train_prefetch_model(make_prefetch_data(trace, stride=10),
                                      pcfg, epochs=args.epochs,
                                      batch_size=512, log=print)
    outputs = precompute_outputs(trace, caching=(cparams, mcfg),
                                 prefetch=(pparams, pcfg))

    print("\n[2/3] serving with production LRU...")
    lru = serve_trace(cfg, params, trace, cap, "lru", None,
                      batch_queries=args.batch_queries,
                      multi_table=args.multi_table)
    print("\n[3/3] serving with RecMG (pipelined models)...")
    rec = serve_trace(cfg, params, trace, cap, "recmg", outputs,
                      batch_queries=args.batch_queries,
                      multi_table=args.multi_table)

    # Paper §VII-F decomposition: device compute + slow-tier model
    # (python slot bookkeeping excluded; TorchRec does it in C++).  The
    # dense forward is policy-independent, so both sides share one
    # measured compute figure — otherwise run-to-run wall-clock noise in
    # this container's tiny CPU forward can swamp the fetch difference
    # and even flip the sign of the reduction.
    compute_ms = (lru["compute_ms"] + rec["compute_ms"]) / 2

    def total_ms(r):
        return compute_ms + r["modeled_fetch_ms_per_batch"]

    print(f"\n{'':14s}{'LRU':>12s}{'RecMG':>12s}")
    for k, fmt in (("hit_rate", "{:.3f}"), ("prefetch_hits", "{}"),
                   ("on_demand_rows", "{}")):
        print(f"{k:14s}{fmt.format(lru[k]):>12s}{fmt.format(rec[k]):>12s}")
    print(f"{'batch ms':14s}{total_ms(lru):>12.2f}{total_ms(rec):>12.2f}")
    print(f"\nend-to-end inference-time reduction: "
          f"{1 - total_ms(rec) / total_ms(lru):.1%} "
          "(paper: 31% avg, up to 43%)")


if __name__ == "__main__":
    main()
