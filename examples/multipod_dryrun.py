"""Multi-pod dry-run for one (arch x shape) cell + its roofline terms.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch qwen3-14b \
        --shape decode_32k

Runs in a subprocess so the 512 placeholder devices never leak into the
calling process.  For the full 40-cell sweep use
``python -m repro.launch.dryrun --all``.
"""
import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="both")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as td:
        env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", args.arch, "--shape", args.shape,
               "--mesh", args.mesh, "--out", td, "--tag", "x"]
        subprocess.run(cmd, env=env, check=True)

        from repro.launch.roofline import fmt_s, roofline_row

        for f in sorted(Path(td, "x").glob("*.json")):
            cell = json.loads(f.read_text())
            r = roofline_row(cell)
            if not r:
                print(f.name, cell.get("status"), cell.get("reason", ""))
                continue
            print(f"\n{r['arch']} / {r['shape']} / {r['mesh']}  "
                  f"({cell['devices']} chips)")
            print(f"  compute  term: {fmt_s(r['compute_s'])}")
            print(f"  memory   term: {fmt_s(r['memory_s'])}")
            print(f"  collective  : {fmt_s(r['collective_s'])}")
            print(f"  bottleneck  : {r['dominant']}  "
                  f"(roofline fraction {r['roofline_fraction']:.1%}, "
                  f"useful-FLOP ratio {r['useful_ratio']:.1%})")


if __name__ == "__main__":
    main()
