"""Drift-adaptive serving on a shifting workload — the scenario subsystem
plus the online adaptation loop, end to end.

    PYTHONPATH=src python examples/drift_adaptive_serving.py

Serves the ``diurnal`` hot-set-rotation scenario three ways through the
model-free scenario harness (same serving semantics as the launcher,
no training): LRU, recmg with its model outputs *frozen* on the first
phase's distribution, and the same frozen recmg with ``adapt`` on (drift
detector + online feature refresh).  Prints the per-phase steady-state
hit rates — the frozen model decays after every hot-set rotation, the
adaptive run recovers — and the drift-detector telemetry.  Doubles as
the CI scenario smoke: it exits non-zero if adaptation fails to recover
to within 15% of the pre-switch steady state (the test-suite bar is the
stricter 10% at a pinned seed).
"""
from __future__ import annotations

import argparse

from repro.runtime.drift import DriftConfig
from repro.workloads import phase_steady_hit_rates, replay_scenario, scenario


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=16384)
    ap.add_argument("--phases", type=int, default=4)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--capacity-frac", type=float, default=0.12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    spec = scenario("diurnal", n_tables=4, rows_per_table=512,
                    n_accesses=args.accesses, seed=args.seed,
                    n_phases=args.phases)
    kw = dict(batch=args.batch, capacity_frac=args.capacity_frac)
    dc = DriftConfig(window=max(512, args.accesses // 16), hot_k=128)

    print(f"[1/3] lru baseline ({args.phases}-phase diurnal, "
          f"{args.accesses} accesses)...")
    lru = replay_scenario(spec, policy="lru", **kw)
    print("[2/3] recmg, model outputs frozen on phase 1...")
    frozen = replay_scenario(spec, policy="recmg",
                             profile_frac=1 / args.phases, **kw)
    print("[3/3] recmg frozen + drift adaptation...")
    adapt = replay_scenario(spec, policy="recmg", adapt=True, adapt_cfg=dc,
                            profile_frac=1 / args.phases, **kw)

    rows = {"lru": lru, "recmg (frozen)": frozen, "recmg (adapt)": adapt}
    print(f"\n{'steady hit rate':24s}"
          + "".join(f"phase {p:<5d}" for p in range(args.phases)))
    for name, res in rows.items():
        ph = phase_steady_hit_rates(res, args.phases)
        print(f"{name:24s}" + "".join(f"{v:<11.3f}" for v in ph))
    print(f"{'aggregate':24s}"
          + "  ".join(f"{n}: {r['hit_rate']:.3f}" for n, r in rows.items()))

    d = adapt["drift"]
    print(f"\ndrift telemetry: {d['windows']} windows, {d['triggers']} "
          f"triggers (jaccard {d['jaccard_triggers']} / hit-rate "
          f"{d['hitrate_triggers']}), min jaccard {d['min_jaccard']}, "
          f"{d['refreshes']} feature refreshes, {d['refresh_pf_rows']} "
          f"prefetched rows, {d['rerank_rows']} re-ranked")

    pre = phase_steady_hit_rates(adapt, args.phases)[0]
    post = phase_steady_hit_rates(adapt, args.phases)[1:].mean()
    post_frozen = phase_steady_hit_rates(frozen, args.phases)[1:].mean()
    print(f"\npre-switch steady {pre:.3f}; post-switch steady: "
          f"adapt {post:.3f} vs frozen {post_frozen:.3f} "
          f"(recovery {post / max(pre, 1e-9):.1%})")
    if post < 0.85 * pre:
        raise SystemExit("adaptation failed to recover the hit rate "
                         f"({post:.3f} < 0.85 * {pre:.3f})")
    if adapt["drift"]["triggers"] < 1:
        raise SystemExit("drift detector never triggered on the rotation")
    return rows


if __name__ == "__main__":
    main()
