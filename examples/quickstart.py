"""Quickstart: train a reduced smollm-135m for a few hundred steps on CPU
with checkpointing, deterministic data, and straggler monitoring.

    PYTHONPATH=src python examples/quickstart.py [--steps 300]

The same launcher drives full-size runs on real pods (see
src/repro/launch/train.py and the multi-pod dry-run).
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--seq-len", "256", "--batch", "8",
        "--ckpt", "runs/quickstart", "--ckpt-every", "100",
        "--log-every", "20",
    ])
    print(f"\nquickstart: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps (resume with the same command)")
