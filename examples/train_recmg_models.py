"""The paper's §V/VI-A workflow in isolation: generate an access trace,
derive Belady/optgen ground truth, train the caching + prefetch models,
and report the paper's quality metrics (accuracy, correctness, coverage)
against the rule-based baselines.

    PYTHONPATH=src python examples/train_recmg_models.py [--accesses 200000]
"""
import argparse
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=200_000)
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()

    from repro.core.belady import belady_labels
    from repro.core.caching_model import (CachingModelConfig,
                                          evaluate_caching_model,
                                          train_caching_model)
    from repro.core.features import make_windows, split_train_eval
    from repro.core.lstm import n_params
    from repro.core.prefetch_model import (
        PrefetchData, PrefetchModelConfig, decode_to_ids, make_prefetch_data,
        predict_sequences, sequence_metrics, train_prefetch_model)
    from repro.core.prefetchers import make_prefetcher, prediction_metrics
    from repro.core.trace import TraceGenConfig, generate_trace

    tr = generate_trace(TraceGenConfig(n_tables=24, rows_per_table=20_000,
                                       n_accesses=args.accesses,
                                       drift_every=10**9))
    cap = int(0.2 * tr.unique_count())
    labels, opt_hits, miss = belady_labels(tr.global_id, cap)
    print(f"trace: {len(tr)} accesses, OPT hit rate {opt_hits.mean():.3f}")

    # ---- caching model ----
    mcfg = CachingModelConfig(n_tables=tr.n_tables)
    data = make_windows(tr, labels=labels)
    trd, evd = split_train_eval(data)
    cparams, _ = train_caching_model(trd, mcfg, epochs=args.epochs,
                                     batch_size=512, log=print)

    print(f"caching model: {n_params(cparams)} params (paper ~37K); "
          f"accuracy {evaluate_caching_model(cparams, evd):.1%} (paper ~83%)")

    # ---- prefetch model ----
    pcfg = PrefetchModelConfig(n_tables=tr.n_tables)
    pdata = make_prefetch_data(tr, stride=10)
    n_ev = len(pdata) // 5
    ptr = PrefetchData(pdata.base.batch(np.arange(len(pdata) - n_ev)),
                       {k: v[:-n_ev] for k, v in pdata.w_feats.items()})
    pev = PrefetchData(pdata.base.batch(np.arange(len(pdata) - n_ev, len(pdata))),
                       {k: v[-n_ev:] for k, v in pdata.w_feats.items()})
    pparams, _ = train_prefetch_model(ptr, pcfg, epochs=args.epochs,
                                      batch_size=512, log=print)
    print(f"prefetch model: {n_params(pparams)} params (paper ~74K)")

    po = predict_sequences(pparams, pcfg, pev)
    freq = Counter(tr.global_id[: int(len(tr) * 0.8)].tolist())
    cand = np.array(sorted(k for k, _ in freq.most_common(2000)))
    ids = decode_to_ids(pparams, pcfg, po, cand, tr)
    gt = np.round(pev.w_feats["wn"] * tr.n_vectors).astype(np.int64)
    m = sequence_metrics(ids, gt)
    print(f"prefetch correctness {m['correctness']:.1%} "
          f"coverage {m['coverage']:.1%}  (paper: ~37% correctness)")

    keys = tr.global_id[:60_000]
    for name in ("bingo", "domino", "bop"):
        mb = prediction_metrics(keys, make_prefetcher(name), window=15)
        print(f"  baseline {name:7s}: correctness {mb['correctness']:.2%} "
              f"coverage {mb['coverage']:.2%}")


if __name__ == "__main__":
    main()
