"""The paper's §V/VI-A workflow in isolation: generate an access trace,
train the caching + prefetch duo through the serving runtime's single
entry point (:meth:`LearnedRecMGModel.train_from_trace` — Belady ground
truth, window featurization, both training loops, candidate pool), and
report the paper's quality metrics (accuracy, correctness, coverage) on
a held-out trace suffix against the rule-based baselines.  Evaluation
inference runs the same jitted shape-bucketed path serving uses.

    PYTHONPATH=src python examples/train_recmg_models.py [--accesses 200000]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=200_000)
    ap.add_argument("--epochs", type=int, default=6)
    args = ap.parse_args()

    from repro.core.belady import belady_labels
    from repro.core.caching_model import evaluate_caching_model
    from repro.core.features import make_windows
    from repro.core.lstm import n_params
    from repro.core.model_runtime import (LearnedModelConfig,
                                          LearnedRecMGModel)
    from repro.core.prefetch_model import make_prefetch_data, sequence_metrics
    from repro.core.prefetchers import make_prefetcher, prediction_metrics
    from repro.core.trace import TraceGenConfig, generate_trace

    tr = generate_trace(TraceGenConfig(n_tables=24, rows_per_table=20_000,
                                       n_accesses=args.accesses,
                                       drift_every=10**9))
    cap = int(0.2 * tr.unique_count())
    _, opt_hits, _ = belady_labels(tr.global_id, cap)
    print(f"trace: {len(tr)} accesses, OPT hit rate {opt_hits.mean():.3f}")

    # Train on the first 80%, evaluate on the held-out suffix.
    split = int(0.8 * len(tr))
    lcfg = LearnedModelConfig(hidden=40, caching_epochs=args.epochs,
                              prefetch_epochs=args.epochs, batch_size=512,
                              lr=3e-3, train_stride=10, n_candidates=2000)
    model = LearnedRecMGModel.train_from_trace(tr, cap, lcfg,
                                               profile_upto=split, log=print)

    ev = tr.slice(split, len(tr))
    ev_labels, _, _ = belady_labels(ev.global_id, cap)
    evd = make_windows(ev, in_len=lcfg.in_len, labels=ev_labels)
    acc = evaluate_caching_model(model.cparams, evd)
    print(f"caching model: {n_params(model.cparams)} params (paper ~37K); "
          f"held-out accuracy {acc:.1%} (paper ~83%)")

    pev = make_prefetch_data(ev, in_len=lcfg.in_len, stride=10)
    print(f"prefetch model: {n_params(model.pparams)} params (paper ~74K)")
    ids = model.decode_points(model.predict_points(pev.base))
    gt = np.round(pev.w_feats["wn"] * tr.n_vectors).astype(np.int64)
    m = sequence_metrics(ids, gt)
    print(f"prefetch correctness {m['correctness']:.1%} "
          f"coverage {m['coverage']:.1%}  (paper: ~37% correctness)")

    keys = tr.global_id[:60_000]
    for name in ("bingo", "domino", "bop"):
        mb = prediction_metrics(keys, make_prefetcher(name), window=15)
        print(f"  baseline {name:7s}: correctness {mb['correctness']:.2%} "
              f"coverage {mb['coverage']:.2%}")


if __name__ == "__main__":
    main()
