"""Sharded multi-worker tiered serving: placement policies side by side.

    PYTHONPATH=src python examples/sharded_serving.py [--accesses 40000]

Partitions the embedding tables of one DLRM trace across N simulated
workers (per-shard tiered store + inline prefetch engine each) under each
placement policy — table-wise bin-pack, row-wise round-robin, keyed hash,
and the frequency-aware (RecShard-style) planner — and prints hit rate,
load imbalance (max shard load / mean), and the modeled slow-tier fetch
per batch in both the sum view and the parallel critical-path view
(workers fetch concurrently; the batch pays the slowest shard).

Doubles as the CI smoke: it asserts the sharding equivalence contract —
with one shard every placement reproduces the single-store counters
byte-for-byte, and with any N the gathered vectors match the monolithic
store exactly.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=40_000)
    ap.add_argument("--capacity-frac", type=float, default=0.15)
    ap.add_argument("--batch-queries", type=int, default=32)
    ap.add_argument("--shards", type=int, default=4)
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config
    from repro.core.sharded_serving import ShardedTieredStore
    from repro.core.tiered import TieredEmbeddingStore
    from repro.core.trace import TraceGenConfig, generate_trace
    from repro.launch.serve import serve_trace
    from repro.models.dlrm import init_dlrm
    from repro.sharding.embedding_shard import PLACEMENTS

    cfg = dataclasses.replace(get_config("dlrm-recmg").reduced(),
                              n_tables=16, rows_per_table=4096, multi_hot=4,
                              emb_dim=16)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    trace = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=args.accesses, drift_every=10**9))
    cap = int(args.capacity_frac * trace.unique_count())
    print(f"trace: {len(trace)} accesses, {trace.unique_count()} unique; "
          f"{cap} fast-tier rows across {args.shards} workers")

    print("[1/3] single-worker baseline...")
    base = serve_trace(cfg, params, trace, cap, "lru", None,
                       batch_queries=args.batch_queries)

    print(f"[2/3] {len(PLACEMENTS)} placements x {args.shards} workers...")
    runs = {}
    for placement in PLACEMENTS:
        runs[placement] = serve_trace(
            cfg, params, trace, cap, "lru", None,
            batch_queries=args.batch_queries, shards=args.shards,
            placement=placement)

    hdr = f"{'placement':12s}{'hit_rate':>10s}{'imbalance':>11s}" \
          f"{'fetch(sum)':>12s}{'fetch(crit)':>12s}{'speedup':>9s}"
    print(f"\n{hdr}")
    print(f"{'mono':12s}{base['hit_rate']:>10.4f}{1.0:>11.3f}"
          f"{base['modeled_fetch_ms_per_batch']:>12.3f}"
          f"{base['modeled_fetch_ms_per_batch']:>12.3f}{1.0:>9.2f}")
    for placement, r in runs.items():
        sh = r["shard"]
        crit = sh["modeled_fetch_ms_critical"] / max(r["batches"], 1)
        print(f"{placement:12s}{r['hit_rate']:>10.4f}"
              f"{sh['load_imbalance']:>11.3f}"
              f"{r['modeled_fetch_ms_per_batch']:>12.3f}{crit:>12.3f}"
              f"{sh['parallel_fetch_speedup']:>9.2f}")

    # ---- equivalence contract (the CI smoke assertion) ----
    print("\n[3/3] equivalence contract...")
    counters = ("hits", "lookups", "prefetch_hits", "on_demand_rows",
                "evictions")
    one = serve_trace(cfg, params, trace, cap, "lru", None,
                      batch_queries=args.batch_queries, shards=1,
                      placement="row")
    bad = [c for c in counters if one[c] != base[c]]
    if bad:
        raise SystemExit(f"N=1 sharded != single store on {bad}: "
                         f"{[(one[c], base[c]) for c in bad]}")
    print(f"  1-shard counters == single store on {counters}: OK")

    # Gathered vectors: any placement, any N — exact match.
    import numpy as np

    host_rows = int(trace.rows_per_table.sum())
    host = np.random.default_rng(0).normal(
        size=(host_rows, cfg.emb_dim)).astype(np.float32)
    mono = TieredEmbeddingStore(host, cap)
    sharded = ShardedTieredStore.build(host, trace.rows_per_table,
                                       args.shards, "freq", capacity=cap,
                                       profile_ids=trace.global_id)
    ids = trace.global_id[: 4 * 1024]
    for lo in range(0, len(ids), 512):
        a = np.asarray(mono.lookup(ids[lo: lo + 512]))
        b = np.asarray(sharded.lookup(ids[lo: lo + 512]))
        if not np.array_equal(a, b):
            raise SystemExit("sharded gather diverged from the single store")
    print(f"  gathered vectors identical across {len(ids)} lookups: OK")
    return base, runs


if __name__ == "__main__":
    main()
