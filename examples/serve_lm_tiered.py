"""Batched LM serving with the vocab embedding on tiered memory.

The paper's technique applied to an LM (DESIGN.md §4 arch-applicability):
the token-embedding table lives on the host tier; a small device buffer
serves decode-time rows, managed by LRU or the RecMG priority buffer.

    PYTHONPATH=src python examples/serve_lm_tiered.py --steps 48
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps", type=int, default=48)
    ap.add_argument("--capacity-frac", type=float, default=0.1)
    args = ap.parse_args()

    from repro.configs import RunConfig, get_config
    from repro.core.tiered import TieredEmbeddingStore
    from repro.models.model_api import build
    from repro.models.transformer import decode_step_embeds

    cfg = get_config(args.arch).reduced()
    run = RunConfig(attn_block_q=32, attn_block_kv=32)
    bundle = build(cfg, run)
    params = bundle.init(jax.random.PRNGKey(0))

    # Host tier: the full vocab table.  Fast tier: a small device buffer.
    host_vocab = np.asarray(params["embed"], np.float32)
    cap = max(16, int(args.capacity_frac * cfg.vocab))
    store = TieredEmbeddingStore(host_vocab, cap, policy="lru")
    print(f"{args.arch}: vocab {cfg.vocab} rows on host tier, "
          f"{cap}-row device buffer ({args.capacity_frac:.0%})")

    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0, cfg.vocab)
    _, cache = bundle.prefill(params, {"tokens": prompt},
                              cache_len=8 + args.steps)
    step = jax.jit(lambda p, x, c: decode_step_embeds(p, cfg, run, x, c))

    tok = prompt[:, -1:]
    t0 = time.perf_counter()
    for i in range(args.steps):
        rows = store.lookup(np.asarray(tok[:, 0]))  # fast-tier vocab rows
        logits, cache = step(params, jnp.asarray(rows)[:, None, :], cache)
        tok = jnp.argmax(logits, axis=-1)[:, None]  # greedy decode
    dt = time.perf_counter() - t0
    st = store.stats
    print(f"decoded {args.steps} steps x {B} streams in {dt:.2f}s "
          f"({args.steps * B / dt:.0f} tok/s)")
    print(f"vocab-buffer hit rate: {st.hit_rate:.1%} "
          f"(on-demand rows: {st.on_demand_rows})")
    print("greedy decode concentrates on hot tokens -> the buffer converges "
          "to the hot vocabulary, exactly the paper's power-law regime.")


if __name__ == "__main__":
    main()
