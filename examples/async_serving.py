"""Asynchronous pipelined serving: background prefetch engine +
micro-batching request pipeline vs. the synchronous serving loop.

    PYTHONPATH=src python examples/async_serving.py [--accesses 40000]

Serves the same DLRM trace twice through the tiered store — once with the
synchronous loop (every on-demand fetch on the critical path) and once
through `repro.runtime`'s pipelined runtime, where batch k's slow-tier
fetch overlaps batch k-1's dense forward and prefetch predictions are
applied by the background engine.  Predictions come from a rule-based
BOP prefetcher packaged as a prediction stream (no training step), so
this doubles as the CI runtime smoke.

With the default deterministic `inline` scheduler the two runs produce
*identical* hit/miss/eviction counters; only the stall accounting —
how much fetch time the device actually waits for — changes.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--accesses", type=int, default=40_000)
    ap.add_argument("--capacity-frac", type=float, default=0.15)
    ap.add_argument("--batch-queries", type=int, default=32)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--scheduler", default="inline",
                    choices=["inline", "thread"])
    ap.add_argument("--multi-table", action="store_true")
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config
    from repro.core.prefetchers import make_prefetcher
    from repro.core.trace import TraceGenConfig, generate_trace
    from repro.launch.serve import serve_trace
    from repro.models.dlrm import init_dlrm
    from repro.runtime import heuristic_prediction_stream

    cfg = dataclasses.replace(get_config("dlrm-recmg").reduced(),
                              n_tables=16, rows_per_table=4096, multi_hot=4,
                              emb_dim=16)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    trace = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=args.accesses, drift_every=10**9))
    cap = int(args.capacity_frac * trace.unique_count())
    print(f"trace: {len(trace)} accesses, {trace.unique_count()} unique; "
          f"buffer = {cap} rows")

    print("[1/3] packaging BOP prefetcher issues as a prediction stream...")
    outputs = heuristic_prediction_stream(trace.global_id,
                                          make_prefetcher("bop"))

    print("[2/3] synchronous serving (fetches on the critical path)...")
    sync = serve_trace(cfg, params, trace, cap, "lru", outputs,
                       batch_queries=args.batch_queries,
                       multi_table=args.multi_table)
    print("[3/3] pipelined serving (runtime: engine + micro-batcher)...")
    pipe = serve_trace(cfg, params, trace, cap, "lru", outputs,
                       batch_queries=args.batch_queries,
                       multi_table=args.multi_table, async_prefetch=True,
                       pipeline_depth=args.pipeline_depth,
                       scheduler=args.scheduler)

    print(f"\n{'':24s}{'sync':>12s}{'pipelined':>12s}")
    for k in ("hit_rate", "prefetch_hits", "on_demand_rows", "evictions"):
        print(f"{k:24s}{sync[k]:>12}{pipe[k]:>12}")
    print(f"{'on_demand_stall_ms':24s}{sync['on_demand_stall_ms']:>12.1f}"
          f"{pipe['on_demand_stall_ms']:>12.1f}")
    rt = pipe["runtime"]
    counters_equal = all(sync[k] == pipe[k] for k in
                         ("hit_rate", "prefetch_hits", "on_demand_rows",
                          "evictions"))
    red = 1 - pipe["on_demand_stall_ms"] / max(sync["on_demand_stall_ms"],
                                               1e-9)
    print(f"\ncounters identical: {counters_equal} "
          f"({args.scheduler} scheduler)")
    print(f"fetch stall hidden by the pipeline: {rt['hidden_ms']:.1f} ms "
          f"({red:.1%} lower stall)")
    print(f"prefetch: issued {rt['pf_issued']} rows in "
          f"{rt['pf_populate_calls']} coalesced populates, "
          f"deduped {rt['pf_deduped']}, "
          f"cancelled-resident {rt['pf_cancelled_resident']}, "
          f"timeliness {rt['pf_timeliness']:.2f}")
    print(f"request latency (modeled): p50 {rt['req_p50_ms']:.2f} ms / "
          f"p95 {rt['req_p95_ms']:.2f} ms / p99 {rt['req_p99_ms']:.2f} ms")
    if args.scheduler == "inline" and not counters_equal:
        raise SystemExit("determinism contract violated")
    return sync, pipe


if __name__ == "__main__":
    main()
