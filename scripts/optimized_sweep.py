#!/usr/bin/env python
"""Optimized dry-run sweep: per-cell best-known settings (§Perf).

    PYTHONPATH=src python scripts/optimized_sweep.py [--out DIR]

Resumable: cells whose result JSON already exists under ``--out`` are
skipped, so an interrupted sweep continues where it left off.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import traceback
from pathlib import Path

from repro.configs import ALL_ARCHS, RunConfig, get_config, shapes_for
from repro.launch.dryrun import lower_cell


def run_cfg_for(cfg, shape):
    kw = {}
    if cfg.family == "dlrm":
        kw.update(emb_rows="model", dlrm_sharded_lookup=True)
    elif shape.kind == "prefill" and cfg.family in ("dense", "vlm", "audio"):
        # (hybrid regressed under fsdp_seq: the mamba branch scans a sharded
        #  sequence -> cross-shard exchanges; measured in EXPERIMENTS.md)
        kw.update(sharding="fsdp_seq")
    return RunConfig(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="runs/dryrun/optimized",
                    help="result directory (one JSON per sweep cell)")
    args = ap.parse_args(argv)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = 0
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for sname, shape in shapes_for(cfg).items():
            for multi in (False, True):
                mesh = "2x16x16" if multi else "16x16"
                f = out / f"{arch}__{sname}__{mesh}.json"
                if f.exists():
                    continue
                try:
                    res = lower_cell(arch, sname, multi,
                                     run_cfg_for(cfg, shape))
                except Exception as e:
                    res = {"arch": arch, "shape": sname, "mesh": mesh,
                           "status": "error", "error": str(e),
                           "traceback": traceback.format_exc()}
                f.write_text(json.dumps(res, indent=2))
                st = res["status"]
                n_ok += st == "ok"
                n_fail += st == "error"
                print({"ok": "PASS", "skipped": "SKIP",
                       "error": "FAIL"}[st],
                      arch, sname, mesh, res.get("t_compile_s", "-"),
                      flush=True)
    print(f"optimized sweep: {n_ok} ok, {n_fail} failed")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
