#!/usr/bin/env python
"""Perf regression gate: compare ``runs/bench_results.json`` against the
checked-in baseline (``scripts/bench_baseline.json``).

Two metrics guard the serving hot path:

* ``batched_lookup_rows_per_s`` (bench ``tentpole``) — absolute batched
  lookup throughput; a floor metric (machine-dependent, so the baseline
  is deliberately conservative and the tolerance generous).
* ``recmg_lru_p50_ratio`` (bench ``fig16``) — measured p50 batch latency
  of the recmg policy relative to LRU; a ceiling metric (machine-
  independent: both sides run on the same box, so this is the true guard
  against the ML policy's bookkeeping creeping back onto the hot path).

Two more guard the workload-scenario matrix (bench ``scenario``; both
counter-derived, hence machine-independent):

* ``recmg_lru_on_demand_ratio_worst`` — worst-case recmg/LRU on-demand
  fetch ratio over the paper-target scenarios; a ceiling metric (the ML
  policy must keep fetching less than LRU on the regimes the paper's
  claim covers).
* ``adapt_recovery`` — drift-adaptive recmg's post-switch steady-state
  hit rate relative to pre-switch on the diurnal regime; a floor metric
  (adaptation must keep recovering after a hot-set rotation).

One guards the learned serving path (bench ``learned``; counter-derived):

* ``recmg_vs_voyager_on_demand_ratio`` — worst on-demand fetch ratio of
  the learned dual-model RecMG vs the Voyager-class prefetch-only
  baseline; a ceiling metric with an *absolute cap of 1.0* (the paper's
  §VII-C claim is directional — RecMG must fetch less than Voyager — so
  no tolerance may push the ceiling past parity).

One guards the observability layer (bench ``obs``):

* ``tracing_on_lookup_slowdown`` — batched-lookup throughput with a
  ``SpanTracer`` installed relative to the default ``NullTracer``; a
  ceiling metric (span emission must stay a few percent of the hot
  path; the tracing-*off* cost is already guarded by the two hot-path
  gates above, which run with tracing off).

One guards overload behavior (bench ``overload``; counter-derived,
deterministic on the VirtualClock):

* ``overload_goodput_4x_vs_1x`` — goodput (full-quality served requests
  per modeled second) at 4x offered load relative to 1x, through the
  SLO-aware admission path; a floor metric with an *absolute floor of
  0.7* (graceful degradation means shedding and degraded answers absorb
  the excess — goodput must not collapse as load quadruples).

Two guard the quantized fast tier (bench ``beyond``; counter-derived
fixed-byte-budget cells plus a deterministic fidelity probe):

* ``quantized_hit_rate_gain_at_fixed_bytes`` — worst-case quantized/fp32
  hit-rate ratio over the paper-target scenarios at the same byte
  budget; a floor metric with an *absolute floor of 1.0* (the acceptance
  bar is directional — at fixed bytes the quantized tier must improve
  the hit rate on every paper-target cell, so no tolerance may push the
  floor below parity).
* ``quantized_dequant_max_abs_err`` — max per-row dequantization error
  in units of the acceptance bound ``max|row|/127``; a ceiling metric
  with an *absolute cap of 1.0* (round-half-even sits at ~0.5; 1.0 is
  the hard fidelity bar).

One guards fault tolerance (bench ``failover``; counter-derived,
deterministic on the VirtualClock):

* ``failover_goodput_kill_vs_clean`` — goodput (exact-answer rows per
  modeled second) under a deterministic mid-run shard kill relative to
  the same workload with no faults; a floor metric with an *absolute
  floor of 0.8* (hot-row replication + the degraded contract must keep
  the service exact-or-zero and near full speed through a shard loss).

A metric regresses when it moves more than ``tolerance`` (default 30%)
past its baseline in the bad direction.  Exit 1 on any regression —
wired into the CI bench-smoke lane after the bench_e2e smoke.

    PYTHONPATH=src python scripts/check_bench_regression.py \
        [--results runs/bench_results.json] \
        [--baseline scripts/bench_baseline.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def load_rows(path: Path) -> dict:
    rows = json.loads(path.read_text())
    return {(r["bench"], r["name"]): r["value"] for r in rows}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="runs/bench_results.json")
    ap.add_argument("--baseline",
                    default=str(Path(__file__).parent / "bench_baseline.json"))
    args = ap.parse_args(argv)

    results = load_rows(Path(args.results))
    base = json.loads(Path(args.baseline).read_text())
    tol = float(base.get("tolerance", 0.30))

    failures = []

    def check_floor(key, name, floor=None):
        """Floor metric; ``floor`` is an optional *absolute* bound that
        tightens the tolerance-derived floor (for ratios with a hard
        semantic threshold — e.g. "no congestion collapse" means goodput
        at 4x must stay >= 0.7x of 1x no matter how generous the
        tolerance)."""
        want = base.get(name)
        got = results.get(key)
        if want is None or got is None:
            print(f"SKIP {name}: baseline={want} measured={got}")
            return
        lo = want * (1.0 - tol)
        if floor is not None:
            lo = max(lo, floor)
        status = "OK" if got >= lo else "REGRESSION"
        print(f"{status} {name}: measured {got:g} vs floor {lo:g} "
              f"(baseline {want}, tolerance {tol:.0%})")
        if got < lo:
            failures.append(name)

    def check_ceiling(key, name, cap=None):
        """Ceiling metric; ``cap`` is an optional *absolute* bound that
        tightens the tolerance-derived ceiling (for ratios with a hard
        semantic threshold — e.g. "learned must beat voyager" means the
        ratio must stay < 1.0 no matter how generous the tolerance)."""
        want = base.get(name)
        got = results.get(key)
        if want is None or got is None:
            print(f"SKIP {name}: baseline={want} measured={got}")
            return
        ceil = want * (1.0 + tol)
        if cap is not None:
            ceil = min(ceil, cap)
        status = "OK" if got <= ceil else "REGRESSION"
        print(f"{status} {name}: measured {got:.3f} vs ceiling {ceil:.3f} "
              f"(baseline {want}, tolerance {tol:.0%})")
        if got > ceil:
            failures.append(name)

    check_floor(("tentpole", "batched_lookup_rows_per_s"),
                "batched_lookup_rows_per_s")
    check_ceiling(("fig16", "recmg_lru_p50_ratio"), "recmg_lru_p50_ratio")
    check_ceiling(("scenario", "recmg_lru_on_demand_ratio_worst"),
                  "recmg_lru_on_demand_ratio_worst")
    check_floor(("scenario", "adapt_recovery"), "adapt_recovery")
    check_ceiling(("learned", "recmg_vs_voyager_on_demand_ratio"),
                  "recmg_vs_voyager_on_demand_ratio", cap=1.0)
    check_ceiling(("obs", "tracing_on_lookup_slowdown"),
                  "tracing_on_lookup_slowdown")
    check_floor(("overload", "overload_goodput_4x_vs_1x"),
                "overload_goodput_4x_vs_1x", floor=0.7)
    check_floor(("failover", "failover_goodput_kill_vs_clean"),
                "failover_goodput_kill_vs_clean", floor=0.8)
    check_floor(("beyond", "quantized_hit_rate_gain_at_fixed_bytes"),
                "quantized_hit_rate_gain_at_fixed_bytes", floor=1.0)
    check_ceiling(("beyond", "quantized_dequant_max_abs_err"),
                  "quantized_dequant_max_abs_err", cap=1.0)

    if failures:
        print(f"perf gate FAILED: {', '.join(failures)}", file=sys.stderr)
        return 1
    print("perf gate passed")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
