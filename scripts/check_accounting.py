#!/usr/bin/env python
"""Accounting-identity gate: assert the serving stack's counters
reconcile, standalone.

Reads a metrics snapshot (``serve --metrics-out``, or the ``metrics``
entry of a bench artifact) and optionally the matching Chrome trace
(``serve --trace-out``), then runs every identity in
``repro.obs.reconcile``:

* ``store.fast.hits + store.fast.misses == store.lookups``
* ``rt.pf.submitted == deduped + cancelled_resident + issued + queued``
* ``rt.pf.channel_scheduled == timely + late + unused + eta_overwritten
  + eta_pending``
* ``0 <= rt.stall_ms <= rt.demand_fetch_ms`` with ``stall + hidden ==
  demand_fetch``
* sharded aggregate ``store.*`` == sum over ``shard.<i>.store.*``
* admission fates: ``adm.admitted == served + shed + degraded``, both in
  total and per priority class (``adm.class.<name>.*`` sums to totals)
* trace cross-check: span args summed over the trace == the counters.

Exit 1 on any violation.  ``--selftest`` serves a tiny traced scenario
in-process and checks it end to end (no files needed) — the CI fast
lane runs this.

    PYTHONPATH=src python scripts/check_accounting.py \
        --metrics runs/metrics.json [--trace runs/trace.json]
    PYTHONPATH=src python scripts/check_accounting.py --selftest
"""
from __future__ import annotations

import argparse
import json
import sys


def _load(path):
    with open(path) as f:
        return json.load(f)


def selftest() -> int:
    """Serve a tiny traced scenario in-process; every identity must hold
    and the deliberate-violation probes must be caught."""
    from repro.obs import (check_all, reconcile, validate_chrome_trace)
    from repro.obs.tracing import SpanTracer, install_tracer
    from repro.workloads import parse_workload
    from repro.workloads.harness import replay_scenario

    tr = SpanTracer(ring_batches=8)
    install_tracer(tr)
    try:
        res = replay_scenario(parse_workload("zipf_hot:n_accesses=6000"),
                              policy="recmg", adapt=True)
    finally:
        install_tracer(None)
    trace = tr.chrome_trace()
    problems = validate_chrome_trace(trace)
    problems += reconcile(metrics=res["metrics"], trace=trace, strict=False)
    if problems:
        print("selftest: traced scenario does NOT reconcile:")
        for p in problems:
            print(f"  {p}")
        return 1

    # The checker must also *catch* broken books: a dropped hit and an
    # unaccounted prefetch fate are both violations by construction.
    broken = {"store.lookups": 100, "store.fast.hits": 60,
              "store.fast.misses": 39}
    if not check_all(broken):
        print("selftest: checker missed a hits+misses!=lookups violation")
        return 1
    broken_pf = {"rt.pf.submitted": 10, "rt.pf.deduped": 1,
                 "rt.pf.cancelled_resident": 1, "rt.pf.issued": 7,
                 "rt.pf.queued": 0}
    if not check_all(broken_pf):
        print("selftest: checker missed a prefetch-fate violation")
        return 1

    # Admission accounting (PR 8): the overload replay publishes the
    # ``adm.*`` namespace and must reconcile (admitted == served + shed
    # + degraded, totals and per class) on every serving surface —
    # synchronous (depth=1), pipelined (depth=2), and sharded.
    from repro.workloads import make_spec, replay_overload
    spec = make_spec("sustained_overload", n_accesses=6000)
    surfaces = [
        ("sync", dict(pipeline_depth=1, prefetch=False)),
        ("pipelined", dict(pipeline_depth=2)),
        ("sharded", dict(shards=2)),
    ]
    for name, kw in surfaces:
        res = replay_overload(spec, load_x=4.0, **kw)  # check=True reconciles
        flat = res["metrics"]["counters"]  # registry snapshot form
        if flat.get("adm.admitted", 0) <= 0:
            print(f"selftest: overload/{name} published no adm.admitted")
            return 1
        if flat["adm.admitted"] != (flat["adm.served"] + flat["adm.shed"]
                                    + flat["adm.degraded"]):
            print(f"selftest: overload/{name} admission identity broken")
            return 1

    # And the checker must catch cooked admission books: a shed request
    # that vanished from the fate sum, and a per-class sum that drifts
    # from the total.
    broken_adm = {"adm.admitted": 100, "adm.served": 80, "adm.shed": 10,
                  "adm.degraded": 5}
    if not check_all(broken_adm):
        print("selftest: checker missed an admission-fate violation")
        return 1
    broken_cls = {"adm.admitted": 10, "adm.served": 10, "adm.shed": 0,
                  "adm.degraded": 0,
                  "adm.class.gold.admitted": 6, "adm.class.gold.served": 6,
                  "adm.class.gold.shed": 0, "adm.class.gold.degraded": 0}
    if not check_all(broken_cls):
        print("selftest: checker missed a per-class vs total drift")
        return 1
    # Fault-tolerance accounting (PR 9): a chaos replay with a mid-run
    # shard kill must reconcile end to end (ft.* identities included in
    # check_all via the published snapshot) with zero wrong answers.
    from repro.workloads import replay_chaos
    res = replay_chaos(make_spec("shard_failure", n_accesses=6000,
                                 n_tables=4, rows_per_table=256),
                       batch=128, shards=4,
                       fault_plan="kill:1@mid,recover:1@75%")
    if res["wrong_rows"] != 0:
        print(f"selftest: chaos replay served {res['wrong_rows']} wrong rows")
        return 1
    flat = res["metrics"]["counters"]
    if flat.get("ft.kills", 0) != 1 or flat.get("ft.recoveries", 0) != 1:
        print("selftest: chaos replay published no kill/recovery counters")
        return 1

    # And the checker must catch cooked ft books: a failover row whose
    # source vanished, and a retry episode with no outcome.
    broken_ft = {"ft.served": 100, "ft.primary": 90,
                 "ft.failover_replica": 5, "ft.failover_degraded": 4,
                 "ft.degraded_default": 0,
                 "ft.retries": 0, "ft.retry_succeeded": 0,
                 "ft.retry_exhausted": 0}
    if not check_all(broken_ft):
        print("selftest: checker missed an ft answer-source violation")
        return 1
    broken_retry = {"ft.served": 10, "ft.primary": 10,
                    "ft.failover_replica": 0, "ft.failover_degraded": 0,
                    "ft.degraded_default": 0,
                    "ft.retries": 3, "ft.retry_succeeded": 1,
                    "ft.retry_exhausted": 1}
    if not check_all(broken_retry):
        print("selftest: checker missed a retry-outcome violation")
        return 1

    print("selftest: traced scenario + overload surfaces + chaos replay "
          "reconcile; violations are caught")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", default="",
                    help="metrics snapshot JSON (serve --metrics-out)")
    ap.add_argument("--trace", default="",
                    help="Chrome trace JSON (serve --trace-out); also "
                         "schema/monotonicity-validated")
    ap.add_argument("--selftest", action="store_true",
                    help="serve a tiny traced scenario in-process and "
                         "check it (no files needed)")
    args = ap.parse_args(argv)

    if args.selftest:
        return selftest()
    if not args.metrics and not args.trace:
        ap.error("pass --metrics and/or --trace (or --selftest)")

    from repro.obs import reconcile, validate_chrome_trace

    problems = []
    trace = _load(args.trace) if args.trace else None
    if trace is not None:
        problems += validate_chrome_trace(trace)
    metrics = _load(args.metrics) if args.metrics else None
    problems += reconcile(metrics=metrics, trace=trace, strict=False)
    if problems:
        print("ACCOUNTING VIOLATIONS:")
        for p in problems:
            print(f"  {p}")
        return 1
    checked = [s for s, on in (("metrics", metrics is not None),
                               ("trace", trace is not None)) if on]
    print(f"accounting OK ({' + '.join(checked)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
