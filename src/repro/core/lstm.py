"""Seq2seq LSTM stacks with Luong attention, in pure JAX.

The paper deliberately uses small LSTMs (not transformers) because the
models run on *CPU* alongside DLRM inference (§V): the caching model is one
encoder/decoder stack (~37K params), the prefetch model two stacks (~74K).
These are the building blocks; the two models live in caching_model.py /
prefetch_model.py.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def lstm_init(key, in_dim: int, hidden: int):
    k1, _ = jax.random.split(key)
    scale = 1.0 / math.sqrt(in_dim + hidden)
    w = jax.random.normal(k1, (in_dim + hidden, 4 * hidden)) * scale
    b = jnp.zeros((4 * hidden,))
    # Forget-gate bias 1.0 (standard stabilization).
    b = b.at[hidden : 2 * hidden].set(1.0)
    return {"w": w, "b": b}


def lstm_step(p, carry, x):
    h, c = carry
    z = jnp.concatenate([x, h], axis=-1) @ p["w"] + p["b"]
    hid = h.shape[-1]
    i, f, g, o = (z[..., :hid], z[..., hid:2*hid], z[..., 2*hid:3*hid],
                  z[..., 3*hid:])
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def lstm_seq(p, xs, h0=None):
    """xs: (T, in_dim) -> hs (T, hidden); returns (hs, (h_T, c_T))."""
    hid = p["w"].shape[1] // 4
    if h0 is None:
        h0 = (jnp.zeros((hid,)), jnp.zeros((hid,)))
    (hT, cT), hs = lax.scan(lambda c, x: lstm_step(p, c, x), h0, xs)
    return hs, (hT, cT)


def attn_init(key, hidden: int):
    return {"wa": jax.random.normal(key, (hidden, hidden)) / math.sqrt(hidden)}


def attend(p, h_dec, enc_hs):
    """Luong general attention.  h_dec: (H,), enc_hs: (T, H) -> ctx (H,)."""
    scores = enc_hs @ (p["wa"] @ h_dec)  # (T,)
    w = jax.nn.softmax(scores)
    return w @ enc_hs


def n_params(tree) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(tree))
