"""RecMG caching model (paper §V-A).

One seq2seq LSTM stack + attention, ~37K params.  Input: a chunk of prior
accesses; output: a *binary* priority per input element (1 = keep in buffer
with high priority) — the paper's key labeling trick that collapses the
billion-way placement problem to two labels.  Trained with cross-entropy
against Belady/optgen keep bits.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import lstm as LS
from repro.core.features import ROW_BUCKETS, WindowData


@dataclass(frozen=True)
class CachingModelConfig:
    n_tables: int = 856
    table_emb: int = 8
    row_emb: int = 8
    hidden: int = 40
    in_len: int = 15
    n_scalar: int = 3  # normalized id + online log-freq + log-recency


def init_caching_model(key, cfg: CachingModelConfig):
    ks = jax.random.split(key, 8)
    f = cfg.table_emb + 2 * cfg.row_emb + cfg.n_scalar
    H = cfg.hidden
    return {
        "table_emb": jax.random.normal(ks[0], (cfg.n_tables, cfg.table_emb)) * 0.1,
        "row_emb1": jax.random.normal(ks[1], (ROW_BUCKETS[0], cfg.row_emb)) * 0.1,
        "row_emb2": jax.random.normal(ks[2], (ROW_BUCKETS[1], cfg.row_emb)) * 0.1,
        "enc": LS.lstm_init(ks[3], f, H),
        "dec": LS.lstm_init(ks[4], 2 * H, H),
        "attn": LS.attn_init(ks[5], H),
        "w_out": jax.random.normal(ks[6], (2 * H,)) / math.sqrt(2 * H),
        "b_out": jnp.zeros(()),
    }


def _featurize(params, xt, xr1, xr2, xn, xf, xrc):
    """Per-window embeddings.  xt/xr1/xr2: (T,) int; xn/xf/xrc: (T,) f32."""
    return jnp.concatenate(
        [
            params["table_emb"][xt],
            params["row_emb1"][xr1],
            params["row_emb2"][xr2],
            xn[:, None],
            xf[:, None],
            xrc[:, None],
        ],
        axis=-1,
    )


def caching_logits(params, xt, xr1, xr2, xn, xf, xrc):
    """One window -> per-element keep logits (T,)."""
    feats = _featurize(params, xt, xr1, xr2, xn, xf, xrc)  # (T, f)
    enc_hs, (hT, cT) = LS.lstm_seq(params["enc"], feats)

    def dec_step(carry, enc_h):
        (h, c) = carry
        ctx = LS.attend(params["attn"], h, enc_hs)
        (h, c), _ = LS.lstm_step(params["dec"], (h, c), jnp.concatenate([enc_h, ctx]))
        logit = jnp.concatenate([h, ctx]) @ params["w_out"] + params["b_out"]
        return (h, c), logit

    _, logits = lax.scan(dec_step, (hT, cT), enc_hs)
    return logits


caching_logits_batch = jax.vmap(caching_logits, in_axes=(None, 0, 0, 0, 0, 0, 0))


def bce_loss(params, batch: Dict[str, jnp.ndarray]):
    logits = caching_logits_batch(
        params, batch["xt"], batch["xr1"], batch["xr2"], batch["xn"],
        batch["xf"], batch["xrc"]
    )
    y = batch["y"]
    # Stable sigmoid BCE (the paper's cross-entropy over {keep, evict}).
    loss = jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    return loss.mean()


@partial(jax.jit, static_argnums=(3,))
def _train_step(params, opt, batch, opt_cfg):
    from repro.optim.adamw import apply_updates

    loss, grads = jax.value_and_grad(bce_loss)(params, batch)
    params, opt, _ = apply_updates(opt_cfg, params, opt, grads)
    return params, opt, loss


def _to_batches(data: WindowData, batch_size: int, rng: np.random.Generator):
    idx = rng.permutation(len(data))
    for i in range(0, len(idx) - batch_size + 1, batch_size):
        b = data.batch(idx[i : i + batch_size])
        yield {
            "xt": jnp.asarray(b.x_table), "xr1": jnp.asarray(b.x_row1),
            "xr2": jnp.asarray(b.x_row2), "xn": jnp.asarray(b.x_norm),
            "xf": jnp.asarray(b.x_freq), "xrc": jnp.asarray(b.x_rec),
            "y": jnp.asarray(b.y_keep),
        }


def train_caching_model(data: WindowData, cfg: CachingModelConfig,
                        epochs: int = 3, batch_size: int = 256,
                        lr: float = 3e-3, seed: int = 0, log=None):
    from repro.optim.adamw import OptConfig, init_opt

    key = jax.random.PRNGKey(seed)
    params = init_caching_model(key, cfg)
    total = max(2, epochs * (len(data) // batch_size))
    opt_cfg = OptConfig(lr=lr, weight_decay=0.0,
                        warmup_steps=max(1, min(50, total // 10)),
                        total_steps=total)
    opt = init_opt(opt_cfg, params)
    rng = np.random.default_rng(seed)
    losses = []
    for ep in range(epochs):
        for batch in _to_batches(data, batch_size, rng):
            params, opt, loss = _train_step(params, opt, batch, opt_cfg)
            losses.append(float(loss))
        if log:
            log(f"caching epoch {ep}: loss {np.mean(losses[-50:]):.4f}")
    return params, losses


def evaluate_caching_model(params, data: WindowData, batch_size: int = 1024):
    """Accuracy vs Belady labels (paper: ~83%)."""
    correct = total = 0
    for i in range(0, len(data), batch_size):
        b = data.batch(np.arange(i, min(i + batch_size, len(data))))
        logits = caching_logits_batch(
            params, jnp.asarray(b.x_table), jnp.asarray(b.x_row1),
            jnp.asarray(b.x_row2), jnp.asarray(b.x_norm),
            jnp.asarray(b.x_freq), jnp.asarray(b.x_rec)
        )
        pred = np.asarray(logits) > 0
        correct += (pred == (b.y_keep > 0.5)).sum()
        total += pred.size
    return correct / max(total, 1)


def predict_bits(params, data: WindowData, batch_size: int = 4096) -> np.ndarray:
    """Keep-bits for every window, vectorized (the CPU-side inference)."""
    outs = []
    for i in range(0, len(data), batch_size):
        b = data.batch(np.arange(i, min(i + batch_size, len(data))))
        logits = caching_logits_batch(
            params, jnp.asarray(b.x_table), jnp.asarray(b.x_row1),
            jnp.asarray(b.x_row2), jnp.asarray(b.x_norm),
            jnp.asarray(b.x_freq), jnp.asarray(b.x_rec)
        )
        outs.append(np.asarray(logits) > 0)
    return np.concatenate(outs, axis=0)
