"""Input featurization + training-window extraction for the RecMG models.

Per the paper (§V-A): the model input is a fixed-length *chunk* of prior
accesses — (row id, table id) pairs — possibly spanning query boundaries (so
cross-query correlations are learnable).  Delta/one-hot labelings don't work
at embedding scale (§I), so features are small learned embeddings of the
table id and hashed row id, plus the normalized global index (the continuous
coordinate the prefetch model regresses in).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.belady import belady_labels
from repro.core.trace import Trace

ROW_BUCKETS = (256, 256)  # two-level hash of the row id


@dataclass
class WindowData:
    """Vectorized training windows."""

    x_table: np.ndarray  # (N, T_in) int32
    x_row1: np.ndarray  # (N, T_in) int32  row % B1
    x_row2: np.ndarray  # (N, T_in) int32  (row // B1) % B2
    x_norm: np.ndarray  # (N, T_in) f32    global id / n_vectors
    x_freq: np.ndarray = None  # (N, T_in) f32  online log-frequency
    x_rec: np.ndarray = None  # (N, T_in) f32   online log-recency
    y_keep: Optional[np.ndarray] = None  # (N, T_in) f32  Belady labels
    y_window: Optional[np.ndarray] = None  # (N, W) f32   future norm ids

    def __len__(self):
        return len(self.x_table)

    def batch(self, idx):
        return WindowData(
            self.x_table[idx], self.x_row1[idx], self.x_row2[idx],
            self.x_norm[idx], self.x_freq[idx], self.x_rec[idx],
            None if self.y_keep is None else self.y_keep[idx],
            None if self.y_window is None else self.y_window[idx],
        )


def access_stats(gid: np.ndarray):
    """Per-access online statistics, causally computable at deployment:
    log2-frequency-so-far and log2-recency (accesses since last use of the
    same vector), both normalized to ~[0, 1]."""
    n = len(gid)
    freq = np.zeros(n, dtype=np.float32)
    rec = np.ones(n, dtype=np.float32)
    counts: dict = {}
    last: dict = {}
    logn = max(np.log2(n + 1), 1.0)
    for i in range(n):
        k = gid[i]
        c = counts.get(k, 0)
        freq[i] = np.log2(c + 1) / logn
        j = last.get(k)
        if j is not None:
            rec[i] = np.log2(i - j + 1) / logn
        counts[k] = c + 1
        last[k] = i
    return freq, rec


def _stack_windows(a: np.ndarray, starts: np.ndarray, length: int):
    return a[starts[:, None] + np.arange(length)[None, :]]


def make_windows(trace: Trace, in_len: int = 15, out_window: int = 15,
                 stride: int = 15, capacity: Optional[int] = None,
                 labels: Optional[np.ndarray] = None,
                 stats=None) -> WindowData:
    """Extract (input chunk, Belady keep labels, future window) triples.

    ``capacity`` (or precomputed ``labels``) enables caching-model labels;
    the future window of normalized ids is the prefetch ground truth W.
    """
    gid = trace.global_id
    n = len(gid)
    norm = gid.astype(np.float64) / max(trace.n_vectors, 1)

    starts = np.arange(in_len, n - out_window - 1, stride, dtype=np.int64)
    starts_in = starts - in_len  # input chunk = [p-in_len, p)

    x_table = _stack_windows(trace.table_id.astype(np.int32), starts_in, in_len)
    row = trace.row_id
    x_row1 = _stack_windows((row % ROW_BUCKETS[0]).astype(np.int32),
                            starts_in, in_len)
    x_row2 = _stack_windows(((row // ROW_BUCKETS[0]) % ROW_BUCKETS[1]).astype(np.int32),
                            starts_in, in_len)
    x_norm = _stack_windows(norm.astype(np.float32), starts_in, in_len)
    freq, rec = stats if stats is not None else access_stats(gid)
    x_freq = _stack_windows(freq, starts_in, in_len)
    x_rec = _stack_windows(rec, starts_in, in_len)

    y_keep = None
    if labels is None and capacity:
        labels, _, _ = belady_labels(gid, capacity)
    if labels is not None:
        y_keep = _stack_windows(labels.astype(np.float32), starts_in, in_len)

    y_window = _stack_windows(norm.astype(np.float32), starts, out_window)
    return WindowData(x_table, x_row1, x_row2, x_norm, x_freq, x_rec,
                      y_keep, y_window)


def split_train_eval(data: WindowData, eval_frac: float = 0.2):
    n = len(data)
    cut = int(n * (1 - eval_frac))
    idx_tr = np.arange(0, cut)
    idx_ev = np.arange(cut, n)
    return data.batch(idx_tr), data.batch(idx_ev)
