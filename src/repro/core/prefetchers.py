"""Rule-based prefetcher baselines, at embedding-vector granularity.

The paper compares RecMG against a temporal prefetcher (Domino [8]), a
spatial prefetcher (Bingo [10]), and offset/delta prefetchers (BOP [52],
Berti [55]).  All of those are hardware cache-line prefetchers; per the
paper's methodology (§VII-A) we treat each embedding-vector index as a
memory address and the table id as the PC/IP proxy.

Interface: ``on_access(key, hit) -> list[key]`` of prefetch candidates.
"""
from __future__ import annotations

from collections import OrderedDict, defaultdict, deque
from typing import Dict, List

import numpy as np


class Prefetcher:
    name = "none"

    def on_access(self, key: int, hit: bool) -> List[int]:
        return []


class DominoLite(Prefetcher):
    """Temporal prefetching: record miss-history correlations
    (addr, next-addr) with a two-deep history (Domino's (a,b)->c scheme) and
    replay chains on re-occurrence."""

    name = "domino"

    def __init__(self, metadata_entries: int = 200_000, degree: int = 4):
        self.pair: "OrderedDict[tuple, int]" = OrderedDict()
        self.single: "OrderedDict[int, int]" = OrderedDict()
        self.meta = metadata_entries
        self.degree = degree
        self.hist = deque(maxlen=2)

    def _put(self, table, k, v):
        if k in table:
            table.move_to_end(k)
        table[k] = v
        if len(table) > self.meta:
            table.popitem(last=False)

    def on_access(self, key, hit):
        out = []
        h = tuple(self.hist)
        if len(h) == 2:
            self._put(self.pair, h, key)
        if self.hist:
            self._put(self.single, self.hist[-1], key)
        self.hist.append(key)

        # Predict a chain starting from the current context.
        ctx2 = (self.hist[0], self.hist[-1]) if len(self.hist) == 2 else None
        nxt = self.pair.get(ctx2) if ctx2 else None
        if nxt is None:
            nxt = self.single.get(key)
        depth = 0
        seen = set()
        while nxt is not None and depth < self.degree and nxt not in seen:
            out.append(nxt)
            seen.add(nxt)
            nxt = self.single.get(nxt)
            depth += 1
        return out


class BingoLite(Prefetcher):
    """Spatial footprint prefetching: regions of the (table-major) index
    space; on region re-entry, replay the recorded footprint keyed by
    (PC=table-proxy, trigger offset)."""

    name = "bingo"

    def __init__(self, region: int = 64, table_entries: int = 100_000,
                 pc_of=None):
        self.region = region
        self.hist: "OrderedDict[tuple, set]" = OrderedDict()
        self.active: Dict[int, set] = {}
        self.active_order = deque()
        self.table_entries = table_entries
        self.pc_of = pc_of or (lambda k: k >> 40)

    def on_access(self, key, hit):
        r, off = divmod(key, self.region)
        pc = self.pc_of(key)
        out = []
        if r not in self.active:
            # Region entry: replay footprint if we've seen this trigger.
            fp = self.hist.get((pc, off))
            if fp:
                base = r * self.region
                out = [base + o for o in fp if o != off]
            self.active[r] = (off, set())
            self.active_order.append(r)
            if len(self.active_order) > 16:
                old_r = self.active_order.popleft()
                self.active.pop(old_r, None)
        trigger, foot = self.active[r]
        foot.add(off)
        # Continuously publish the footprint (Bingo's history table update).
        self.hist[(pc, trigger)] = foot
        if len(self.hist) > self.table_entries:
            self.hist.popitem(last=False)
        return out


class BOP(Prefetcher):
    """Best-Offset Prefetcher [52]: score candidate offsets by whether
    (addr - offset) was recently requested; prefetch addr + best offset."""

    name = "bop"

    OFFSETS = [1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64,
               -1, -2, -3, -4, -8, -16, -32]

    def __init__(self, rr_size: int = 4096, rounds: int = 32,
                 bad_score: int = 1):
        self.rr: "OrderedDict[int, bool]" = OrderedDict()
        self.rr_size = rr_size
        self.scores = {o: 0 for o in self.OFFSETS}
        self.best = 1
        self.tests = 0
        self.round_len = rounds * len(self.OFFSETS)
        self.idx = 0
        self.bad = bad_score

    def _rr_add(self, key):
        self.rr[key] = True
        if len(self.rr) > self.rr_size:
            self.rr.popitem(last=False)

    def on_access(self, key, hit):
        # Learning phase: test one offset per access round-robin.
        o = self.OFFSETS[self.idx % len(self.OFFSETS)]
        self.idx += 1
        if key - o in self.rr:
            self.scores[o] += 1
        self.tests += 1
        if self.tests >= self.round_len:
            self.best, s = max(self.scores.items(), key=lambda kv: kv[1])
            self.scores = {k: 0 for k in self.scores}
            self.tests = 0
            if s <= self.bad:
                self.best = 0  # too noisy: stop prefetching this round
        self._rr_add(key)
        if self.best:
            return [key + self.best]
        return []


class BertiLite(Prefetcher):
    """Berti-style local-delta prefetcher: per-PC (table) best recent delta
    learned from timely hits."""

    name = "berti"

    def __init__(self, pc_of=None, hist_per_pc: int = 16):
        self.pc_of = pc_of or (lambda k: k >> 40)
        self.last: Dict[int, deque] = defaultdict(
            lambda: deque(maxlen=hist_per_pc)
        )
        self.delta_score: Dict[int, defaultdict] = defaultdict(
            lambda: defaultdict(int)
        )

    def on_access(self, key, hit):
        pc = self.pc_of(key)
        hist = self.last[pc]
        for prev in hist:
            d = key - prev
            if d != 0 and abs(d) < 512:
                self.delta_score[pc][d] += 1
        hist.append(key)
        scores = self.delta_score[pc]
        if not scores:
            return []
        best, s = max(scores.items(), key=lambda kv: kv[1])
        if len(scores) > 256:
            self.delta_score[pc] = defaultdict(
                int, dict(sorted(scores.items(), key=lambda kv: -kv[1])[:64])
            )
        return [key + best] if s >= 4 else []


class MABLite(Prefetcher):
    """Micro-Armed-Bandit [30]: epsilon-greedy coordinator that picks among
    simple prefetchers per epoch based on observed usefulness."""

    name = "mab"

    def __init__(self, seed=0, epoch=2048, eps=0.1):
        self.arms = [Prefetcher(), BOP(), BertiLite(), DominoLite(50_000, 2)]
        self.rng = np.random.default_rng(seed)
        self.q = np.zeros(len(self.arms))
        self.n = np.zeros(len(self.arms)) + 1e-6
        self.eps = eps
        self.epoch = epoch
        self.t = 0
        self.cur = 1
        self.issued_by_cur = 0
        self.hits_in_epoch = 0

    def on_access(self, key, hit):
        self.t += 1
        self.hits_in_epoch += hit
        if self.t % self.epoch == 0:
            reward = self.hits_in_epoch / self.epoch
            self.q[self.cur] += (reward - self.q[self.cur]) / (
                self.n[self.cur] + 1
            )
            self.n[self.cur] += 1
            self.hits_in_epoch = 0
            if self.rng.random() < self.eps:
                self.cur = int(self.rng.integers(len(self.arms)))
            else:
                self.cur = int(np.argmax(self.q))
        outs = []
        for i, arm in enumerate(self.arms):
            o = arm.on_access(key, hit)
            if i == self.cur:
                outs = o
        return outs


PREFETCHERS = {
    "none": Prefetcher,
    "domino": DominoLite,
    "bingo": BingoLite,
    "bop": BOP,
    "berti": BertiLite,
    "mab": MABLite,
}


def make_prefetcher(name: str, **kw) -> Prefetcher:
    return PREFETCHERS[name](**kw)


# ---------------------------------------------------------------------------
# Sequence-prediction metrics (paper Figs. 9/10)
# ---------------------------------------------------------------------------


def prediction_metrics(keys: np.ndarray, prefetcher: Prefetcher,
                       window: int = 15) -> dict:
    """Correctness = frac of issued prefetches that appear in the next
    `window` accesses; coverage per Eq. (2) over those windows."""
    n = len(keys)
    issued = 0
    correct = 0
    covered = 0
    gt_total = 0
    step = window
    for i in range(0, n - window, step):
        future = set(int(k) for k in keys[i + 1 : i + 1 + window])
        preds = []
        # Feed the window's accesses one at a time (online).
        for j in range(i, min(i + step, n)):
            preds.extend(prefetcher.on_access(int(keys[j]), True))
        preds = preds[:window]
        issued += len(preds)
        correct += sum(p in future for p in preds)
        covered += len(set(preds) & future)
        gt_total += len(future)
    return {
        "issued": issued,
        "correctness": correct / max(issued, 1),
        "coverage": covered / max(gt_total, 1),
    }
