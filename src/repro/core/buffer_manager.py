"""The RecMG buffer manager — Algorithms 1 & 2 of the paper, with the RRIP
semantics the paper cites.

Each buffer entry carries an integer priority (``eviction_speed = 4``):
the caching model's keep-bit puts just-accessed vectors in the
cache-friendly class (priority = eviction_speed) or the cache-averse class
(priority = 0, evict-next) — Hawkeye-style insertion; prefetched vectors
enter at eviction_speed.  ``populate`` (Algorithm 2) evicts the minimum-
priority entry, aging everyone *on demand* — only as far as needed to bring
that minimum to zero, which is the RRIP scan the paper says it builds on.
(The pseudocode's literal decay-by-1-per-eviction with priorities in
{ev, ev+1} degenerates to LRU under buffer-scale eviction pressure; see
EXPERIMENTS.md §Faithfulness notes — both readings are implemented and
tested.)

Since PR 4 the priority order lives in the **array-backed engine** of
:mod:`repro.core.priority_engine` instead of a Python min-heap: dense
``key -> (score, seq)`` NumPy state with lazy epoch aging and batched
victim selection, so the bulk surface — ``set_priorities``, ``fetch_many``,
``populate_many``, ``access_chunk``, ``load_embeddings`` — runs as O(chunk)
vectorized passes with no per-key heap operations.  Eviction-interleaved
chunks (``access_chunk``/``fetch_many``/``load_embeddings`` at capacity)
take an optimistic vectorized plan and fall back to an exact per-key
replay only when a victim is re-accessed inside the same chunk (rare:
victims are the lowest-priority entries).  The original heap
implementation is preserved verbatim in
:mod:`repro.core.buffer_manager_reference`; the property suite proves
victim-for-victim identical eviction order and identical hit masks
against it and against ``SlowRecMGBuffer`` (the literal O(capacity)
transcription below).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

import numpy as np

from repro.core.priority_engine import ArrayPriorityEngine


def _as_int_array(keys: Iterable[int]) -> np.ndarray:
    if isinstance(keys, np.ndarray):
        return keys.astype(np.int64, copy=False).ravel()
    return np.asarray(list(keys), np.int64).ravel()


class RecMGBuffer:
    def __init__(self, capacity: int, eviction_speed: int = 4,
                 n_keys_hint: int = 1024):
        self.capacity = max(1, int(capacity))
        self.ev = int(eviction_speed)
        self.engine = ArrayPriorityEngine(n_keys_hint)

    # ---------------- introspection (seed-compatible surface) ----------

    @property
    def epoch(self) -> int:
        return self.engine.epoch

    @property
    def seq(self) -> int:
        return self.engine.seq

    @property
    def score(self) -> Dict[int, int]:
        """Dict view of ``key -> stored_priority + epoch_at_set`` (the
        heap's ``score`` map; rebuilt from the dense arrays — tests and
        debugging only, O(key space))."""
        eng = self.engine
        live = np.flatnonzero(eng._live)
        return {int(k): int(s) for k, s in zip(live, eng._score[live])}

    def __len__(self):
        return self.engine.count

    def contains(self, key: int) -> bool:
        return self.engine.contains(int(key))

    # ---------------- single-key API ----------------

    def set_priority(self, key: int, priority: int):
        """Insert ``key`` or refresh its priority (public single-key API)."""
        self.engine.set_one(key, priority)

    # Backwards-compatible alias; callers should use ``set_priority``.
    _set_priority = set_priority

    def populate(self) -> Optional[int]:
        """Algorithm 2 with RRIP aging semantics: evict the minimum-priority
        entry; decay everyone only as far as needed to bring that minimum to
        zero (the RRIP "age until a victim exists" scan, via the epoch).

        The paper's pseudocode decays by exactly 1 per call; under buffer-
        sized eviction pressure that makes the recency epoch swamp the 0..5
        priority range and the policy degenerates to LRU (±0.4% in our
        measurements).  Age-on-demand keeps the caching model's bit decisive
        — which is the behavior of the RRIP family the paper says it builds
        on, and the only reading that reproduces its Fig. 8 gains.  See
        EXPERIMENTS.md §Faithfulness notes.
        """
        return self.engine.pop_min()

    def _make_room(self):
        eng = self.engine
        while eng.count >= self.capacity:
            if eng.pop_min() is None:
                break

    def fetch(self, key: int, priority: int):
        """Insert (or re-prioritize) a vector."""
        if not self.engine.contains(int(key)):
            self._make_room()
        self.set_priority(key, priority)

    # ---------------- bulk (chunk-at-a-time) API ----------------

    def set_priorities(self, keys: Iterable[int], priority: int,
                       only_new: bool = False):
        """Batched :meth:`set_priority` over a chunk of keys — one
        vectorized engine pass.

        ``only_new=True`` skips keys that already hold an entry (the
        admission-time insert of the tiered store, which must not demote a
        key the caching model just ranked)."""
        self.engine.set_many(_as_int_array(keys), int(priority),
                             only_new=only_new)

    def _fits_without_eviction(self, keys: np.ndarray) -> bool:
        """True when inserting ``keys`` cannot trigger an eviction.  The
        distinct new-key count is upper-bounded first (duplicate dead keys
        counted twice — cheap) and deduped only when the bound is tight."""
        eng = self.engine
        n_new = int(np.count_nonzero(~eng._live[keys]))
        if n_new and eng.count + n_new > self.capacity:
            n_new = int(np.unique(keys[~eng._live[keys]]).size)
        return eng.count + n_new <= self.capacity

    def fetch_many(self, keys: Iterable[int], priority: int):
        """Batched :meth:`fetch`: insert a chunk, evicting as needed.
        Fully vectorized when the chunk fits without eviction; otherwise
        an exact per-key replay (evictions interleave with refreshes that
        can change the victim order mid-chunk)."""
        keys = _as_int_array(keys)
        if not keys.size:
            return
        self.engine._ensure(int(keys.max()))
        if self._fits_without_eviction(keys):
            self.engine.set_many(keys, int(priority))
            return
        for k in keys.tolist():
            self.fetch(k, priority)

    def populate_many(self, n: int) -> List[int]:
        """Evict up to ``n`` victims in one call (Algorithm 2, batched —
        vectorized prefix pops instead of n heap scans)."""
        return self.engine.pop_min_many(int(n))

    def access_chunk(self, keys: np.ndarray, priority: int) -> np.ndarray:
        """Serve a chunk of demand accesses; returns a per-access hit mask.

        A miss fetches the key at ``priority`` (the tiered runtime's
        on-demand insert).  Vectorized: hit/miss partition in one pass;
        misses admit through the engine's interleaved batched eviction.
        The optimistic plan assumes no victim is re-accessed later in the
        same span — when one is (the only case where an eviction changes
        a later hit), the plan is undone and the longest conflict-free
        prefix commits instead, restarting from the re-access.  Each span
        is one vectorized pass, so a chunk costs O(1 + conflicts)
        passes."""
        keys = np.asarray(keys, np.int64).ravel()
        n = keys.size
        hits = np.empty(n, dtype=bool)
        if n == 0:
            return hits
        eng = self.engine
        eng._ensure(int(keys.max()))
        if n <= 16:
            # Tiny chunks (the simulators' 15-access segments): the exact
            # per-key replay through the engine's scalar fast path beats
            # the fixed cost of the vectorized plan.
            at_cap = self.capacity <= eng.count + n
            pr = int(priority)
            for i, k in enumerate(keys.tolist()):
                h = eng.contains(k)
                hits[i] = h
                if not h:
                    if at_cap:
                        self._make_room()
                    eng.set_one(k, pr)
            return hits
        lo = 0
        while lo < n:
            lo += self._access_span(keys[lo:], int(priority), hits[lo:])
        return hits

    def _access_span(self, keys: np.ndarray, priority: int,
                     hits: np.ndarray) -> int:
        """Optimistically plan the whole span, commit the longest
        conflict-free prefix; fill ``hits`` for it and return its
        length (>= 1)."""
        eng = self.engine
        n = keys.size
        at_cap = self.capacity <= eng.count + n  # may need room
        live0 = eng._live[keys].copy()
        u, first = np.unique(keys, return_index=True)
        is_first = np.zeros(n, bool)
        is_first[first] = True
        miss_first_pos = np.flatnonzero(is_first & ~live0)
        miss_keys = keys[miss_first_pos]
        if not at_cap:
            eng.set_many(miss_keys, priority)
            hits[:n] = live0 | ~is_first
            return n
        n_no_evict = max(0, self.capacity - eng.count)
        # Refresh-only APIs never evict, so replay can run over capacity;
        # the first miss's _make_room then drains the whole overflow.
        pre_drain = max(0, eng.count - self.capacity) if miss_keys.size else 0
        victims, own, kept, token = eng.admit_interleaved(
            miss_keys, priority, n_no_evict, undoable=True,
            pre_drain=pre_drain)
        if victims.size:
            # Conflict check: drained victims fall at the first miss;
            # interleaved eviction t is triggered by the miss at span
            # position miss_first_pos[n_no_evict + t].  A victim whose key
            # re-appears later than that invalidates the optimistic hits
            # from that re-access on.
            vpos = np.empty(victims.size, np.int64)
            vpos[:pre_drain] = miss_first_pos[0]
            vpos[pre_drain:] = miss_first_pos[
                n_no_evict + np.arange(victims.size - pre_drain)]
            last_rev = np.unique(keys[::-1], return_index=True)[1]
            last_occ = n - 1 - last_rev  # aligned with sorted-unique u
            pos_u = np.searchsorted(u, victims)
            pos_c = np.minimum(pos_u, u.size - 1)
            confl = (u[pos_c] == victims) & (last_occ[pos_c] > vpos)
            if np.any(confl):
                # Earliest re-access of any victim after its eviction: the
                # plan is exact strictly before it.  (A victim's eviction
                # position precedes any of its re-accesses, so q_star >= 1
                # and the restart always makes progress.)
                order = np.argsort(keys, kind="stable")
                ks = keys[order]
                left = np.searchsorted(ks, victims, side="left")
                right = np.searchsorted(ks, victims, side="right")
                q_star = n
                for i in np.flatnonzero(confl).tolist():
                    span = order[left[i]:right[i]]
                    j = int(np.searchsorted(span, vpos[i], side="right"))
                    if j < span.size:
                        q_star = min(q_star, int(span[j]))
                eng.undo(token)
                # The victim sequence of the shorter prefix is a prefix of
                # this plan's, so the re-run is conflict-free by q_star's
                # minimality and commits in one pass.
                return self._access_span(keys[:q_star], priority,
                                         hits[:q_star])
        hits[:n] = live0 | ~is_first
        return n

    def load_embeddings(self, trunk: Iterable[int], caching_bits: Iterable[int],
                        prefetch_keys: Iterable[int],
                        scaled_bits: bool = True):
        """Algorithm 1.  ``trunk`` = the most recently accessed chunk (already
        fetched on demand); caching_bits = the caching model's output C.

        ``scaled_bits=True`` gives the keep/evict classes RRIP-separated
        priorities (keep -> eviction_speed, evict -> 0/evict-next — Hawkeye's
        cache-friendly/averse insertion, which the paper builds on).  The
        paper's literal ``C[i] + eviction_speed`` keeps both classes within
        1 of each other and measures within noise of LRU; see EXPERIMENTS.md
        §Faithfulness notes.

        Vectorized whenever the chunk fits without eviction — which is
        always the case in the tiered store, whose ranking buffer is
        unbounded; the at-capacity simulator path replays per key because
        refreshes there can re-order victims mid-chunk."""
        trunk = _as_int_array(trunk)
        bits = (caching_bits if isinstance(caching_bits, np.ndarray)
                else np.asarray(list(caching_bits)))
        bits = bits.astype(np.int64, copy=False).ravel()
        pf = _as_int_array(prefetch_keys)
        m = min(trunk.size, bits.size)  # zip semantics: shorter side wins
        trunk, bits = trunk[:m], bits[:m]
        prs = bits * self.ev if scaled_bits else bits + self.ev
        eng = self.engine
        both = np.concatenate((trunk, pf))
        if both.size:
            eng._ensure(int(both.max()))
        if not both.size or self._fits_without_eviction(both):
            if trunk.size:
                eng.set_many(trunk, prs)
            if pf.size:
                eng.set_many(pf, self.ev, only_new=True)
            return
        for k, pr in zip(trunk.tolist(), prs.tolist()):
            if eng.contains(k):
                self.set_priority(k, pr)
            else:
                self.fetch(k, pr)
        for k in pf.tolist():
            if not eng.contains(k):
                self.fetch(k, self.ev)
                # paper: priority[P[i]] = eviction_speed ("high" so the
                # prefetch survives until its use)


class SlowRecMGBuffer:
    """Literal transcription of Algorithms 1 & 2 (O(capacity) eviction) —
    used to validate RecMGBuffer in tests.

    ``clamp`` is the paper's ``max(0, p-1)``; it only compresses ties among
    long-decayed entries (the paper doesn't specify tie order).  The O(log n)
    epoch formulation is order-identical to ``clamp=False``."""

    def __init__(self, capacity: int, eviction_speed: int = 4,
                 clamp: bool = True):
        self.capacity = max(1, int(capacity))
        self.ev = int(eviction_speed)
        self.clamp = clamp
        self.priority: Dict[int, int] = {}
        self.order: Dict[int, int] = {}
        self.seq = 0

    def __len__(self):
        return len(self.priority)

    def contains(self, key):
        return key in self.priority

    def populate(self):
        victim = min(
            self.priority, key=lambda k: (self.priority[k], self.order[k])
        )
        # RRIP aging: decay everyone by the victim's priority (age until a
        # zero-priority victim exists), then evict it.
        dec = max(0, self.priority[victim])
        lo = 0 if self.clamp else -(1 << 60)
        if dec:
            for k in self.priority:
                self.priority[k] = max(lo, self.priority[k] - dec)
        del self.priority[victim]
        del self.order[victim]
        return victim

    def fetch(self, key, priority):
        if key not in self.priority:
            while len(self.priority) >= self.capacity:
                self.populate()
        self.priority[key] = priority
        self.seq += 1
        self.order[key] = self.seq

    def load_embeddings(self, trunk, caching_bits, prefetch_keys,
                        scaled_bits: bool = True):
        for key, c in zip(trunk, caching_bits):
            pr = int(c) * self.ev if scaled_bits else int(c) + self.ev
            self.fetch(key, pr)
        for key in prefetch_keys:
            if key not in self.priority:
                self.fetch(key, self.ev)
