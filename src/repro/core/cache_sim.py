"""Cache-policy simulators at embedding-vector granularity.

The paper evaluates LRU/LFU (fully- and 32-way set-associative), SRRIP,
DRRIP, Hawkeye, Mockingjay-style reuse predictors, and Belady's OPT, all
treating an embedding vector as the atomic replacement unit (ChampSim in the
paper; reimplemented natively here — see DESIGN.md §7).

All policies implement ``access(key) -> bool`` (True = hit),
``insert_prefetch(key)``, and a bulk ``access_many(keys) -> hit mask`` used
for chunk-at-a-time replay; a unified ``simulate`` driver attributes hits
to {caching policy, prefetcher} and counts on-demand fetches, reproducing
the paper's Figure 14 breakdown.
"""
from __future__ import annotations

from collections import Counter, OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.belady import belady_sim

INF = np.iinfo(np.int64).max


class CacheBase:
    name = "base"

    def __init__(self, capacity: int):
        self.capacity = max(1, int(capacity))

    def access(self, key) -> bool:  # demand access
        raise NotImplementedError

    def contains(self, key) -> bool:
        raise NotImplementedError

    def insert_prefetch(self, key) -> None:
        """Default: prefetch inserts like a demand miss (no touch)."""
        if not self.contains(key):
            self.access(key)

    def access_many(self, keys: np.ndarray) -> np.ndarray:
        """Bulk demand-access path: serve a chunk of keys, return a hit
        mask.  Policies override this with a tighter loop; the default just
        removes per-access driver dispatch."""
        access = self.access
        return np.fromiter((access(int(k)) for k in keys), dtype=bool,
                           count=len(keys))


class FALRU(CacheBase):
    """Fully-associative LRU."""

    name = "lru_fa"

    def __init__(self, capacity):
        super().__init__(capacity)
        self.od = OrderedDict()

    def contains(self, key):
        return key in self.od

    def access(self, key):
        hit = key in self.od
        if hit:
            self.od.move_to_end(key)
        else:
            if len(self.od) >= self.capacity:
                self.od.popitem(last=False)
            self.od[key] = True
        return hit

    def access_many(self, keys):
        # Tight chunk loop: bound methods hoisted, no per-access dispatch.
        od, cap = self.od, self.capacity
        move, pop = od.move_to_end, od.popitem
        out = np.empty(len(keys), dtype=bool)
        for i, k in enumerate(keys.tolist() if isinstance(keys, np.ndarray)
                              else keys):
            if k in od:
                move(k)
                out[i] = True
            else:
                if len(od) >= cap:
                    pop(last=False)
                od[k] = True
                out[i] = False
        return out


class SetAssoc(CacheBase):
    """k-way set-associative base; subclasses define victim choice."""

    def __init__(self, capacity, ways: int = 32):
        super().__init__(capacity)
        self.ways = min(ways, self.capacity)  # never exceed total capacity
        self.n_sets = max(1, self.capacity // self.ways)
        self.sets: List[Dict] = [dict() for _ in range(self.n_sets)]

    def _set(self, key):
        return self.sets[hash(key) % self.n_sets]

    def contains(self, key):
        return key in self._set(key)

    def on_hit(self, s, key):
        raise NotImplementedError

    def on_fill(self, s, key):
        raise NotImplementedError

    def victim(self, s):
        raise NotImplementedError

    def access(self, key):
        s = self._set(key)
        if key in s:
            self.on_hit(s, key)
            return True
        if len(s) >= self.ways:
            del s[self.victim(s)]
        self.on_fill(s, key)
        return False


class SALRU(SetAssoc):
    name = "lru_32w"

    def __init__(self, capacity, ways=32):
        super().__init__(capacity, ways)
        self.clock = 0

    def on_hit(self, s, key):
        self.clock += 1
        s[key] = self.clock

    on_fill = on_hit

    def victim(self, s):
        return min(s, key=s.get)


class SALFU(SetAssoc):
    name = "lfu_32w"

    def on_hit(self, s, key):
        s[key] = s.get(key, 0) + 1

    def on_fill(self, s, key):
        s[key] = 1

    def victim(self, s):
        return min(s, key=s.get)


class SRRIP(SetAssoc):
    """Static RRIP [38]: 2-bit re-reference interval prediction."""

    name = "srrip"
    MAX = 3
    insert_rrpv = 2

    def on_hit(self, s, key):
        s[key] = 0

    def on_fill(self, s, key):
        s[key] = self.insert_rrpv

    def victim(self, s):
        while True:
            for k, v in s.items():
                if v >= self.MAX:
                    return k
            for k in s:
                s[k] += 1


class BRRIP(SRRIP):
    """Bimodal RRIP: mostly distant (MAX), occasionally long (MAX-1)."""

    name = "brrip"

    def __init__(self, capacity, ways=32, seed=0):
        super().__init__(capacity, ways)
        self.rng = np.random.default_rng(seed)

    def on_fill(self, s, key):
        s[key] = self.MAX - 1 if self.rng.random() < 1 / 32 else self.MAX


class DRRIP(SetAssoc):
    """Dynamic RRIP via set dueling between SRRIP and BRRIP inserts."""

    name = "drrip"
    MAX = 3

    def __init__(self, capacity, ways=32, seed=0):
        super().__init__(capacity, ways)
        self.rng = np.random.default_rng(seed)
        n = self.n_sets
        self.leader_s = set(range(0, n, 32))
        self.leader_b = set(range(1, n, 32))
        self.psel = 512

    def _set_idx(self, key):
        return hash(key) % self.n_sets

    def access(self, key):
        idx = self._set_idx(key)
        s = self.sets[idx]
        if key in s:
            s[key] = 0
            return True
        # PSEL bookkeeping: leader-set misses move the selector.
        if idx in self.leader_s:
            self.psel = min(1023, self.psel + 1)
        elif idx in self.leader_b:
            self.psel = max(0, self.psel - 1)
        if len(s) >= self.ways:
            while True:
                vic = next((k for k, v in s.items() if v >= self.MAX), None)
                if vic is not None:
                    del s[vic]
                    break
                for k in s:
                    s[k] += 1
        use_brrip = (
            idx in self.leader_b
            or (idx not in self.leader_s and self.psel >= 512)
        )
        if use_brrip:
            s[key] = self.MAX - 1 if self.rng.random() < 1 / 32 else self.MAX
        else:
            s[key] = 2
        return False

    def contains(self, key):
        return key in self.sets[self._set_idx(key)]


class HawkeyeLite(SetAssoc):
    """Hawkeye [36] adapted to embedding traces: the PC proxy is the table
    id (paper §VII-A); an online Belady emulation over a sampled window
    trains a per-table cache-friendly/averse predictor that drives
    RRIP-style insertion."""

    name = "hawkeye"
    MAX = 7

    def __init__(self, capacity, ways=32, table_of=None):
        super().__init__(capacity, ways)
        self.table_of = table_of or (lambda k: k >> 40)
        self.pred: Counter = Counter()
        self.last_use: Dict = {}
        self.occ = 0  # crude occupancy proxy for the sampled OPT emulation
        self.window = 8 * self.capacity

    def access(self, key):
        s = self._set(key)
        t = self.table_of(key)
        # OPTgen-lite: if the key was used within `capacity` distinct-ish
        # accesses, OPT would have hit -> the table is cache-friendly.
        self.occ += 1
        lu = self.last_use.get(key)
        if lu is not None:
            if self.occ - lu <= self.capacity:
                self.pred[t] = min(7, self.pred[t] + 1)
            else:
                self.pred[t] = max(-8, self.pred[t] - 1)
        self.last_use[key] = self.occ
        if len(self.last_use) > 4 * self.capacity:
            # Bound metadata: drop oldest half.
            items = sorted(self.last_use.items(), key=lambda kv: kv[1])
            self.last_use = dict(items[len(items) // 2:])

        if key in s:
            s[key] = 0 if self.pred[t] >= 0 else self.MAX
            return True
        if len(s) >= self.ways:
            vic = max(s.items(), key=lambda kv: kv[1])[0]
            del s[vic]
        s[key] = 0 if self.pred[t] >= 0 else self.MAX
        for k in list(s):
            if k != key and s[k] < self.MAX:
                s[k] += 1
        return False


class MockingjayLite(SetAssoc):
    """Mockingjay [69] adapted to embedding traces: predict each line's
    reuse distance from a sampled per-(table, row-bucket) history and evict
    the line with the largest predicted time-to-reuse.  The paper finds this
    class of PC-keyed predictors underperforms on user-driven embedding
    accesses — reproduced in fig15."""

    name = "mockingjay"

    def __init__(self, capacity, ways=32, table_of=None, bucket: int = 512):
        super().__init__(capacity, ways)
        self.table_of = table_of or (lambda k: k >> 40)
        self.bucket = bucket
        self.ewma: Dict = {}  # signature -> predicted reuse distance
        self.last_use: Dict = {}
        self.clock = 0

    def _sig(self, key):
        return (self.table_of(key), key % self.bucket)

    def _observe(self, key):
        self.clock += 1
        lu = self.last_use.get(key)
        if lu is not None:
            d = self.clock - lu
            sig = self._sig(key)
            prev = self.ewma.get(sig, d)
            self.ewma[sig] = 0.8 * prev + 0.2 * d
        self.last_use[key] = self.clock
        if len(self.last_use) > 8 * self.capacity:
            items = sorted(self.last_use.items(), key=lambda kv: kv[1])
            self.last_use = dict(items[len(items) // 2:])

    def _predicted_next_use(self, key):
        return self.last_use.get(key, self.clock) + self.ewma.get(
            self._sig(key), 4 * self.capacity)

    def on_hit(self, s, key):
        s[key] = self._predicted_next_use(key)

    on_fill = on_hit

    def access(self, key):
        self._observe(key)
        return super().access(key)

    def victim(self, s):
        return max(s, key=s.get)  # farthest predicted reuse


class BeladyCache(CacheBase):
    """OPT replay (needs the whole key stream up front)."""

    name = "belady"

    def __init__(self, capacity, keys: np.ndarray):
        super().__init__(capacity)
        self.hits, _ = belady_sim(keys, capacity)
        self.i = 0

    def contains(self, key):
        return bool(self.hits[self.i])

    def access(self, key):
        h = bool(self.hits[self.i])
        self.i += 1
        return h


POLICIES = {
    "lru_fa": FALRU,
    "lru_32w": SALRU,
    "lfu_32w": SALFU,
    "srrip": SRRIP,
    "brrip": BRRIP,
    "drrip": DRRIP,
    "hawkeye": HawkeyeLite,
    "mockingjay": MockingjayLite,
}


def make_cache(name: str, capacity: int, keys: Optional[np.ndarray] = None):
    if name == "belady":
        return BeladyCache(capacity, keys)
    return POLICIES[name](capacity)


# ---------------------------------------------------------------------------
# Unified simulation with prefetch attribution (paper Fig. 14 breakdown)
# ---------------------------------------------------------------------------


def attribute_prefetch_hits(seg: np.ndarray, hits: np.ndarray,
                            prefetched: set) -> int:
    """Vectorized first-touch prefetch attribution over one replayed chunk.

    For every key of ``seg`` that sits in ``prefetched``, its *first*
    occurrence decides (hit -> one attributed prefetch hit) and the key is
    retired from the set — identical to the per-key loop the replay
    drivers used, but as one ``searchsorted`` membership pass against the
    sorted prefetched ids.  Returns the number of attributed hits and
    mutates ``prefetched`` in place."""
    if not prefetched:
        return 0
    pf = np.fromiter(prefetched, np.int64, len(prefetched))
    pf.sort()
    present = np.flatnonzero(isin_sorted(pf, seg))
    if present.size == 0:
        return 0
    u, first = np.unique(seg[present], return_index=True)
    n_hit = int(np.count_nonzero(hits[present[first]]))
    prefetched.difference_update(u.tolist())
    return n_hit


def top_ids_by_count(ids: np.ndarray, k: int) -> np.ndarray:
    """The ``k`` most frequent ids of a stream, heat-ordered (hottest
    first) with a deterministic tie-break on the id — the shared "what is
    hot" definition used by the drift detector, the adaptation
    controller's pool refresh and the frequency-heuristic model
    (:func:`repro.core.recmg.frequency_outputs`); they must agree or the
    detector and the refresh silently diverge."""
    vals, counts = np.unique(np.asarray(ids, np.int64).ravel(),
                             return_counts=True)
    order = np.lexsort((vals, -counts))
    return vals[order[: max(int(k), 0)]]


def isin_sorted(sorted_vals: np.ndarray, keys: np.ndarray) -> np.ndarray:
    """Vectorized membership of ``keys`` in an already-sorted id array
    (one ``searchsorted`` pass; empty-safe)."""
    keys = np.asarray(keys, np.int64)
    if sorted_vals.size == 0:
        return np.zeros(keys.shape, bool)
    pos = np.minimum(np.searchsorted(sorted_vals, keys),
                     sorted_vals.size - 1)
    return sorted_vals[pos] == keys


@dataclass
class SimResult:
    accesses: int = 0
    hits: int = 0  # total buffer hits
    prefetch_hits: int = 0  # first-touch hits on prefetched entries
    on_demand: int = 0  # misses -> on-demand fetches from slow tier
    prefetch_issued: int = 0
    prefetch_useful: int = 0  # prefetched entries demanded before eviction

    @property
    def hit_rate(self):
        return self.hits / max(self.accesses, 1)

    @property
    def cache_hits(self):
        return self.hits - self.prefetch_hits

    @property
    def prefetch_accuracy(self):
        return self.prefetch_useful / max(self.prefetch_issued, 1)

    def as_dict(self):
        return {
            "accesses": self.accesses, "hits": self.hits,
            "cache_hits": self.cache_hits, "prefetch_hits": self.prefetch_hits,
            "on_demand": self.on_demand, "hit_rate": round(self.hit_rate, 4),
            "prefetch_issued": self.prefetch_issued,
            "prefetch_accuracy": round(self.prefetch_accuracy, 4),
        }


def simulate(keys: np.ndarray, cache: CacheBase, prefetcher=None,
             max_inflight_per_access: int = 8) -> SimResult:
    """Drive a key stream through (cache, prefetcher).

    Without a prefetcher the whole trace replays through the cache's bulk
    ``access_many`` (chunk-at-a-time); prefetchers need per-access candidate
    generation, so that path stays access-at-a-time."""
    if prefetcher is None:
        hits = cache.access_many(np.asarray(keys))
        res = SimResult()
        res.accesses = len(keys)
        res.hits = int(np.count_nonzero(hits))
        res.on_demand = res.accesses - res.hits
        return res
    res = SimResult()
    prefetched = set()  # resident-and-not-yet-demanded prefetch fills
    for key in keys:
        key = int(key)
        hit = cache.access(key)
        res.accesses += 1
        if hit:
            res.hits += 1
            if key in prefetched:
                res.prefetch_hits += 1
                res.prefetch_useful += 1
                prefetched.discard(key)
        else:
            res.on_demand += 1
            prefetched.discard(key)
        if prefetcher is not None:
            cands = prefetcher.on_access(key, hit)
            for c in cands[:max_inflight_per_access]:
                c = int(c)
                if not cache.contains(c):
                    cache.insert_prefetch(c)
                    prefetched.add(c)
                    res.prefetch_issued += 1
    return res
