"""RecMG end-to-end policy: the two models co-managing the buffer.

The buffer-state never feeds back into the *models* (they condition only on
the access history), so model inference over a whole trace is vectorized in
one jitted pass — exactly the paper's CPU-side pipelined deployment, where
predictions for chunk t are computed while the accelerator serves chunk t-1
(``pipelined=True`` applies outputs one chunk late to model that skew).

``run_recmg`` produces the Figure-14-style access breakdown: buffer hits due
to the caching policy, hits due to prefetch, and on-demand fetches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core.buffer_manager import RecMGBuffer
from repro.core.cache_sim import FALRU, SimResult
from repro.core.caching_model import (CachingModelConfig, predict_bits)
from repro.core.features import WindowData, make_windows
from repro.core.prefetch_model import (PrefetchData, PrefetchModelConfig,
                                       decode_to_ids, make_prefetch_data,
                                       predict_sequences)
from repro.core.trace import Trace


@dataclass
class RecMGOutputs:
    """Precomputed model outputs for every chunk of a trace."""

    chunk_starts: np.ndarray  # (C,) index of first access of each chunk
    caching_bits: Optional[np.ndarray]  # (C, in_len) bool
    prefetch_ids: Optional[np.ndarray]  # (C, out_len) int64


def precompute_outputs(trace: Trace, caching=None, prefetch=None,
                       in_len: int = 15, out_len: int = 5,
                       n_candidates: int = 5000) -> RecMGOutputs:
    """Vectorized model inference over all chunks (stride = in_len).

    Prefetch decode snaps predicted representation points to the nearest of
    the ``n_candidates`` most-frequent vectors (the deployment's candidate
    pool — cold vectors aren't worth prefetching)."""
    data = make_windows(trace, in_len=in_len, out_window=out_len,
                        stride=in_len)
    starts = np.arange(in_len, len(trace) - out_len - 1, in_len)[: len(data)]

    bits = None
    if caching is not None:
        params, _cfg = caching
        bits = predict_bits(params, data)

    ids = None
    if prefetch is not None:
        params, pcfg = prefetch
        po = predict_sequences(params, pcfg, data)
        gid = trace.global_id
        vals, counts = np.unique(gid, return_counts=True)
        top = np.argsort(counts)[::-1][:n_candidates]
        cand = np.sort(vals[top])
        ids = decode_to_ids(params, pcfg, po, cand, trace)
    return RecMGOutputs(starts, bits, ids)


def run_recmg(trace: Trace, capacity: int, outputs: RecMGOutputs,
              eviction_speed: int = 4, pipelined: bool = True,
              use_caching: bool = True, use_prefetch: bool = True,
              oracle_bits: Optional[np.ndarray] = None) -> SimResult:
    """Replay a trace through the RecMG-managed buffer.

    oracle_bits: per-access Belady keep labels — upper-bound variant used by
    benchmarks ("what if the caching model were perfect").
    """
    keys = trace.global_id
    n = len(keys)
    buf = RecMGBuffer(capacity, eviction_speed)
    res = SimResult()
    prefetched = set()

    in_len = (
        outputs.caching_bits.shape[1]
        if outputs.caching_bits is not None
        else 15
    )
    chunk_of = {int(s): i for i, s in enumerate(outputs.chunk_starts)}

    pending = None  # (trunk, bits, prefetch) applied at next chunk boundary

    for i in range(n):
        k = int(keys[i])
        hit = buf.contains(k)
        res.accesses += 1
        if hit:
            res.hits += 1
            if k in prefetched:
                res.prefetch_hits += 1
                res.prefetch_useful += 1
                prefetched.discard(k)
        else:
            res.on_demand += 1
            prefetched.discard(k)
            # On-demand fetch: enters the buffer at base priority; the
            # caching model's bit arrives with load_embeddings below.
            buf.fetch(k, eviction_speed)

        ci = chunk_of.get(i)
        if ci is None:
            continue
        # Chunk boundary: run Algorithm 1 for the *previous* chunk.
        trunk = keys[max(0, i - in_len): i].astype(np.int64)
        if oracle_bits is not None:
            bits = oracle_bits[max(0, i - in_len): i]
        elif outputs.caching_bits is not None and use_caching:
            bits = outputs.caching_bits[ci]
        else:
            bits = np.zeros(len(trunk), dtype=np.int64)
        pf = (
            outputs.prefetch_ids[ci]
            if (outputs.prefetch_ids is not None and use_prefetch)
            else []
        )
        item = (trunk.tolist(), list(np.asarray(bits).astype(int)),
                [int(p) for p in pf])
        if pipelined:
            item, pending = pending, item
            if item is None:
                continue
        t_, b_, p_ = item
        for p in p_:
            if not buf.contains(p):
                prefetched.add(p)
                res.prefetch_issued += 1
        buf.load_embeddings(t_, b_, p_)
    return res


def run_lru_pf(trace: Trace, capacity: int, outputs: RecMGOutputs) -> SimResult:
    """LRU + our prefetch model (the paper's single-model ablation LRU+PF)."""
    keys = trace.global_id
    cache = FALRU(capacity)
    res = SimResult()
    prefetched = set()
    chunk_of = {int(s): i for i, s in enumerate(outputs.chunk_starts)}
    for i in range(len(keys)):
        k = int(keys[i])
        hit = cache.access(k)
        res.accesses += 1
        if hit:
            res.hits += 1
            if k in prefetched:
                res.prefetch_hits += 1
                res.prefetch_useful += 1
                prefetched.discard(k)
        else:
            res.on_demand += 1
            prefetched.discard(k)
        ci = chunk_of.get(i)
        if ci is not None and outputs.prefetch_ids is not None:
            for p in outputs.prefetch_ids[ci]:
                p = int(p)
                if not cache.contains(p):
                    cache.insert_prefetch(p)
                    prefetched.add(p)
                    res.prefetch_issued += 1
    return res
