"""RecMG end-to-end policy: the two models co-managing the buffer.

The buffer-state never feeds back into the *models* (they condition only on
the access history), so model inference over a whole trace is vectorized in
one jitted pass — exactly the paper's CPU-side pipelined deployment, where
predictions for chunk t are computed while the accelerator serves chunk t-1
(``pipelined=True`` applies outputs one chunk late to model that skew).

Trace replay goes **chunk-at-a-time**: accesses between two chunk
boundaries are served in one ``RecMGBuffer.access_chunk`` /
``FALRU.access_many`` call (the bulk API), and Algorithm 1 is applied once
per boundary — same semantics as the per-access loop, without per-access
driver dispatch.

``run_recmg`` produces the Figure-14-style access breakdown: buffer hits due
to the caching policy, hits due to prefetch, and on-demand fetches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core.buffer_manager import RecMGBuffer
from repro.core.cache_sim import FALRU, SimResult, attribute_prefetch_hits
from repro.core.caching_model import predict_bits
from repro.core.features import make_windows
from repro.core.prefetch_model import decode_to_ids, predict_sequences
from repro.core.trace import Trace


@dataclass
class RecMGOutputs:
    """Precomputed model outputs for every chunk of a trace."""

    chunk_starts: np.ndarray  # (C,) index of first access of each chunk
    caching_bits: Optional[np.ndarray]  # (C, in_len) bool
    prefetch_ids: Optional[np.ndarray]  # (C, out_len) int64


def precompute_outputs(trace: Trace, caching=None, prefetch=None,
                       in_len: int = 15, out_len: int = 5,
                       n_candidates: int = 5000) -> RecMGOutputs:
    """Vectorized model inference over all chunks (stride = in_len).

    Prefetch decode snaps predicted representation points to the nearest of
    the ``n_candidates`` most-frequent vectors (the deployment's candidate
    pool — cold vectors aren't worth prefetching)."""
    data = make_windows(trace, in_len=in_len, out_window=out_len,
                        stride=in_len)
    starts = np.arange(in_len, len(trace) - out_len - 1, in_len)[: len(data)]

    bits = None
    if caching is not None:
        params, _cfg = caching
        bits = predict_bits(params, data)

    ids = None
    if prefetch is not None:
        params, pcfg = prefetch
        po = predict_sequences(params, pcfg, data)
        gid = trace.global_id
        vals, counts = np.unique(gid, return_counts=True)
        top = np.argsort(counts)[::-1][:n_candidates]
        cand = np.sort(vals[top])
        ids = decode_to_ids(params, pcfg, po, cand, trace)
    return RecMGOutputs(starts, bits, ids)


def frequency_outputs(trace: Trace, capacity: int, in_len: int = 15,
                      out_len: int = 5, *,
                      profile_upto: Optional[int] = None) -> RecMGOutputs:
    """Frequency-heuristic RecMG outputs — a stand-in for the trained
    models that needs no training and is fully deterministic.

    The "model" is the access-frequency profile of the trace prefix up to
    ``profile_upto`` (default: the whole trace): keep-bits mark trunk keys
    that sit in the profile's ``capacity`` hottest ids, and each chunk
    prefetches the next ``out_len`` ids of the hot list in heat order
    (round-robin, so the hottest are re-prefetched most often).

    Two jobs: (a) the scenario matrix's cheap recmg arm — on stationary
    skewed regimes this protects the power-law head and beats LRU, like
    the paper's trained caching model does; (b) the drift experiments'
    *frozen phase-1 model* — profile only the pre-switch prefix
    (``profile_upto``; 0 means an *empty* profile, i.e. a model that has
    seen nothing) and the outputs keep ranking/prefetching stale rows
    after the regime switches, reproducing the decay ``--adapt`` must
    recover from.

    ``profile_upto`` is keyword-only: a positional mixup with ``out_len``
    would silently profile past the freeze point (i.e. train on
    post-switch data) instead of failing loudly."""
    from repro.core.cache_sim import isin_sorted, top_ids_by_count

    keys = trace.global_id.astype(np.int64)
    n = len(keys)
    prof = keys if profile_upto is None else keys[: profile_upto]
    hot = top_ids_by_count(prof, max(1, int(capacity)))
    hot_sorted = np.sort(hot)

    # Only chunks whose trunk window fits entirely inside the trace (same
    # chunk grid as precompute_outputs); a trace shorter than in_len has
    # zero chunks rather than a ragged first one.  The stride equals the
    # window, so chunk ci's trunk is exactly keys[ci*in_len:(ci+1)*in_len]
    # and all bits come out of one membership pass.
    starts = np.arange(in_len, n - out_len - 1, in_len)
    c = len(starts)
    bits = isin_sorted(hot_sorted, keys[: c * in_len].reshape(c, in_len))
    if hot.size == 0:  # empty profile: nothing to rank or prefetch
        return RecMGOutputs(starts, bits, np.zeros((c, 0), np.int64))
    pf_idx = (np.arange(c)[:, None] * out_len
              + np.arange(out_len)[None, :]) % hot.size
    return RecMGOutputs(starts, bits, hot[pf_idx])


def _replay_segment(access, seg: np.ndarray, res: SimResult,
                    prefetched: set):
    """Serve one chunk of demand accesses through a bulk-access callable
    (``seg -> hit mask``), attributing hits/misses and first-touch
    prefetch hits (vectorized ``searchsorted`` membership — the per-key
    set-walk was the last Python loop in the replay drivers)."""
    if not len(seg):
        return
    hits = access(seg)
    nh = int(np.count_nonzero(hits))
    res.accesses += len(seg)
    res.hits += nh
    res.on_demand += len(seg) - nh
    if prefetched:  # only non-empty between a prefetch issue and first use
        n_pf = attribute_prefetch_hits(seg, hits, prefetched)
        res.prefetch_hits += n_pf
        res.prefetch_useful += n_pf


def run_recmg(trace: Trace, capacity: int, outputs: RecMGOutputs,
              eviction_speed: int = 4, pipelined: bool = True,
              use_caching: bool = True, use_prefetch: bool = True,
              oracle_bits: Optional[np.ndarray] = None) -> SimResult:
    """Replay a trace through the RecMG-managed buffer, chunk at a time.

    Accesses between two chunk boundaries are served in one
    ``RecMGBuffer.access_chunk`` call (the bulk path); Algorithm 1 for the
    chunk ending at each boundary is applied right after its segment, one
    chunk late when ``pipelined`` (the paper's CPU-side skew).

    oracle_bits: per-access Belady keep labels — upper-bound variant used by
    benchmarks ("what if the caching model were perfect").
    """
    keys = trace.global_id.astype(np.int64)
    n = len(keys)
    buf = RecMGBuffer(capacity, eviction_speed)
    res = SimResult()
    prefetched = set()

    in_len = (
        outputs.caching_bits.shape[1]
        if outputs.caching_bits is not None
        else 15
    )

    access = lambda seg: buf.access_chunk(seg, eviction_speed)  # noqa: E731
    pending = None  # (trunk, bits, prefetch) applied at next chunk boundary
    seg_start = 0
    for ci, s in enumerate(np.asarray(outputs.chunk_starts,
                                      np.int64).tolist()):
        if s >= n:
            break
        # Segment = accesses up to and including the boundary access s.
        _replay_segment(access, keys[seg_start: s + 1], res, prefetched)
        seg_start = s + 1
        # Chunk boundary: run Algorithm 1 for the *previous* chunk.
        trunk = keys[max(0, s - in_len): s]
        if oracle_bits is not None:
            bits = oracle_bits[max(0, s - in_len): s]
        elif outputs.caching_bits is not None and use_caching:
            bits = outputs.caching_bits[ci]
        else:
            bits = np.zeros(len(trunk), dtype=np.int64)
        pf = (
            outputs.prefetch_ids[ci]
            if (outputs.prefetch_ids is not None and use_prefetch)
            else np.empty(0, np.int64)
        )
        item = (trunk, np.asarray(bits).astype(np.int64),
                np.asarray(pf, np.int64))
        if pipelined:
            item, pending = pending, item
            if item is None:
                continue
        t_, b_, p_ = item
        for p in p_.tolist():
            if not buf.contains(p):
                prefetched.add(p)
                res.prefetch_issued += 1
        buf.load_embeddings(t_, b_, p_)
    _replay_segment(access, keys[seg_start:], res, prefetched)
    return res


def run_lru_pf(trace: Trace, capacity: int, outputs: RecMGOutputs) -> SimResult:
    """LRU + our prefetch model (the paper's single-model ablation LRU+PF),
    replayed chunk-at-a-time through the cache's bulk ``access_many``."""
    keys = trace.global_id.astype(np.int64)
    n = len(keys)
    cache = FALRU(capacity)
    res = SimResult()
    prefetched = set()
    seg_start = 0
    for ci, s in enumerate(np.asarray(outputs.chunk_starts,
                                      np.int64).tolist()):
        if s >= n:
            break
        _replay_segment(cache.access_many, keys[seg_start: s + 1],
                        res, prefetched)
        seg_start = s + 1
        if outputs.prefetch_ids is not None:
            for p in outputs.prefetch_ids[ci]:
                p = int(p)
                if not cache.contains(p):
                    cache.insert_prefetch(p)
                    prefetched.add(p)
                    res.prefetch_issued += 1
    _replay_segment(cache.access_many, keys[seg_start:], res, prefetched)
    return res
