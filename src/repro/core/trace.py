"""Embedding-access traces: containers, synthetic generation, locality stats.

The paper evaluates on Meta production traces (dlrm_datasets): 856 sparse
features, 62M unique vectors, >400M accesses, with (a) power-law popularity
(~20% of vectors take ~80% of accesses), (b) a heavy long-reuse-distance tail
(20% of accesses with reuse distance > 2^20), (c) pooling factors from 1 to
hundreds, and (d) cross-query user-behavior correlation that makes accesses
*learnable*.  The generator below reproduces those properties at configurable
scale (offline container -> synthetic, calibrated to the published stats; the
interface accepts real traces unchanged).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class Trace:
    """A flat sequence of embedding-vector accesses."""

    table_id: np.ndarray  # (N,) int32
    row_id: np.ndarray  # (N,) int64  (row within table)
    rows_per_table: np.ndarray  # (T,) int64
    query_id: Optional[np.ndarray] = None  # (N,) int32 — inference query

    def __len__(self):
        return len(self.table_id)

    @property
    def n_tables(self) -> int:
        return len(self.rows_per_table)

    @property
    def table_offsets(self) -> np.ndarray:
        return np.concatenate([[0], np.cumsum(self.rows_per_table)[:-1]])

    @property
    def global_id(self) -> np.ndarray:
        """Unique vector id across all tables."""
        return self.table_offsets[self.table_id] + self.row_id

    @property
    def n_vectors(self) -> int:
        return int(self.rows_per_table.sum())

    def unique_count(self) -> int:
        return len(np.unique(self.global_id))

    def slice(self, start: int, stop: int) -> "Trace":
        q = self.query_id[start:stop] if self.query_id is not None else None
        return Trace(self.table_id[start:stop], self.row_id[start:stop],
                     self.rows_per_table, q)


# ---------------------------------------------------------------------------
# Synthetic generation
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceGenConfig:
    n_tables: int = 24
    rows_per_table: int = 100_000
    n_accesses: int = 500_000
    seed: int = 0
    # Popularity: per-table zipf over rows; mix of components per access.
    zipf_a: float = 1.05
    table_zipf_a: float = 1.1
    p_popular: float = 0.40  # global power-law draws (high temporal locality)
    p_cluster: float = 0.25  # user-cluster correlated draws (learnable)
    p_markov: float = 0.20  # successor-item correlations (consecutive-access
    #   structure: learnable by sequence models, invisible to spatial/offset
    #   prefetchers because the per-table jumps are large)
    p_stream: float = 0.15  # advancing streams (few reuses / long distance)
    n_clusters: int = 64
    cluster_size: int = 256  # correlated rows per (cluster, table)
    # Queries: pooling factor distribution (1..hundreds, lognormal).
    pool_mu: float = 2.2
    pool_sigma: float = 0.9
    pool_max: int = 300
    drift_every: int = 200_000  # popularity drift period (content drift)


def _zipf_ranks(rng, a: float, n: int, size: int) -> np.ndarray:
    """Zipf-distributed ranks in [0, n) via inverse-CDF on a truncated zipf."""
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-a)
    cdf = np.cumsum(w)
    cdf /= cdf[-1]
    u = rng.random(size)
    return np.searchsorted(cdf, u).astype(np.int64)


def generate_trace(cfg: TraceGenConfig) -> Trace:
    rng = np.random.default_rng(cfg.seed)
    T, R, N = cfg.n_tables, cfg.rows_per_table, cfg.n_accesses

    # Per-table popularity permutation (which rows are "hot") + drift.
    n_epochs = max(1, N // cfg.drift_every)
    perm_seed = rng.integers(0, 2**31, size=(n_epochs, T))

    # Cluster profiles: correlated row sets shared by users with the same
    # interests — this is what makes the access stream *learnable*.
    cluster_rows = rng.integers(0, R, size=(cfg.n_clusters, T, cfg.cluster_size))

    # 1) Build per-access query structure.
    pool = np.clip(
        np.round(rng.lognormal(cfg.pool_mu, cfg.pool_sigma, size=N // 4)),
        1, cfg.pool_max,
    ).astype(np.int64)
    table_of_q = _zipf_ranks(rng, cfg.table_zipf_a, T, len(pool)) % T
    csum = np.cumsum(pool)
    n_q = int(np.searchsorted(csum, N))
    pool = pool[: n_q + 1]
    csum = csum[: n_q + 1]
    total = int(csum[-1])

    table_id = np.repeat(table_of_q[: n_q + 1], pool).astype(np.int32)
    query_id = np.repeat(np.arange(n_q + 1, dtype=np.int32), pool)
    epoch = np.minimum(
        np.arange(total, dtype=np.int64) // cfg.drift_every, n_epochs - 1
    )

    # Session-level cluster choice: each query belongs to a user cluster, and
    # consecutive queries are often from the same session.
    q_cluster = _zipf_ranks(rng, 1.2, cfg.n_clusters, n_q + 1) % cfg.n_clusters
    same = rng.random(n_q + 1) < 0.6
    for i in range(1, n_q + 1):  # cheap session smoothing
        if same[i]:
            q_cluster[i] = q_cluster[i - 1]
    cluster_of_access = q_cluster[query_id]

    # 2) Draw rows per access as a mixture of components.
    u = rng.random(total)
    p1 = cfg.p_popular
    p2 = p1 + cfg.p_cluster
    p3 = p2 + cfg.p_markov
    comp = np.where(u < p1, 0, np.where(u < p2, 1, np.where(u < p3, 3, 2)))

    row_id = np.empty(total, dtype=np.int64)

    # Popular: zipf rank -> permuted row (drift rotates the permutation).
    pop_mask = comp == 0
    ranks = _zipf_ranks(rng, cfg.zipf_a, R, int(pop_mask.sum()))
    salt = perm_seed[epoch[pop_mask], table_id[pop_mask].astype(np.int64)]
    # Cheap keyed permutation: (rank * odd + salt) % R.
    row_id[pop_mask] = (ranks * 2654435761 + salt) % R

    # Cluster-correlated: pick from the (cluster, table) profile.
    cl_mask = comp == 1
    idx = rng.integers(0, cfg.cluster_size, size=int(cl_mask.sum()))
    row_id[cl_mask] = cluster_rows[
        cluster_of_access[cl_mask], table_id[cl_mask].astype(np.int64), idx
    ]

    # Streams: slowly advancing fronts per table — long reuse distance / few
    # reuses (the component LRU cannot hold).
    st_mask = comp == 2
    front = (np.arange(total, dtype=np.int64) * 7) % R
    jitter = rng.integers(0, 64, size=int(st_mask.sum()))
    row_id[st_mask] = (front[st_mask] + jitter) % R

    # Markov successors: "users who touched item r next touch succ_t(r)" —
    # the consecutive-access correlation the paper's LSTM exploits.  The
    # per-table jump is large (R/11..R/5), so no spatial/delta prefetcher
    # sees it, but it is a deterministic (hence learnable) function of the
    # previous access.
    jumps = rng.integers(R // 11, R // 5, size=T)
    mk_idx = np.nonzero(comp == 3)[0]
    for i in mk_idx:
        if i == 0:
            row_id[i] = 0
        else:
            row_id[i] = (row_id[i - 1] + jumps[table_id[i]]) % R

    tr = Trace(
        table_id=table_id[:N],
        row_id=row_id[:N],
        rows_per_table=np.full(T, R, dtype=np.int64),
        query_id=query_id[:N],
    )
    return tr


# ---------------------------------------------------------------------------
# Trace serialization: npz (exact dtypes) and csv (interoperable)
# ---------------------------------------------------------------------------


def save_trace(trace: Trace, path) -> None:
    """Write a trace to ``path`` (format by suffix: ``.npz`` or ``.csv``).

    Both formats round-trip byte-identically through :func:`load_trace`
    (same arrays, same dtypes) — the contract the ``replay`` workload
    regime and its property test rely on.  CSV carries one access per
    line (``table_id,row_id[,query_id]``) with the per-table row counts
    in a ``# rows_per_table=`` header comment, so external traces can be
    dropped in from any tool that can write a text file.
    """
    from pathlib import Path

    path = Path(path)
    if path.suffix == ".npz":
        payload = {"table_id": trace.table_id, "row_id": trace.row_id,
                   "rows_per_table": trace.rows_per_table}
        if trace.query_id is not None:
            payload["query_id"] = trace.query_id
        np.savez(path, **payload)
        return
    if path.suffix == ".csv":
        rpt = ",".join(str(int(r)) for r in trace.rows_per_table)
        cols = [trace.table_id, trace.row_id]
        header = "table_id,row_id"
        if trace.query_id is not None:
            cols.append(trace.query_id)
            header += ",query_id"
        body = np.stack([c.astype(np.int64) for c in cols], axis=1)
        with open(path, "w") as f:
            f.write(f"# rows_per_table={rpt}\n{header}\n")
            np.savetxt(f, body, fmt="%d", delimiter=",")
        return
    raise ValueError(f"unsupported trace format {path.suffix!r} "
                     "(use .npz or .csv)")


def load_trace(path) -> Trace:
    """Read a trace written by :func:`save_trace` (or any external file in
    the same layout).  Dtypes are restored exactly: ``table_id`` int32,
    ``row_id`` int64, ``rows_per_table`` int64, ``query_id`` int32."""
    from pathlib import Path

    path = Path(path)
    if path.suffix == ".npz":
        with np.load(path) as z:
            q = z["query_id"] if "query_id" in z.files else None
            return Trace(z["table_id"].astype(np.int32),
                         z["row_id"].astype(np.int64),
                         z["rows_per_table"].astype(np.int64),
                         None if q is None else q.astype(np.int32))
    if path.suffix == ".csv":
        with open(path) as f:
            first = f.readline().strip()
            if not first.startswith("# rows_per_table="):
                raise ValueError(f"{path}: missing rows_per_table header")
            rpt = np.asarray([int(x) for x in
                              first.split("=", 1)[1].split(",")], np.int64)
            header = f.readline().strip().split(",")
            body = np.loadtxt(f, dtype=np.int64, delimiter=",", ndmin=2)
        if body.size == 0:
            body = body.reshape(0, len(header))
        cols = {name: body[:, i] for i, name in enumerate(header)}
        q = cols.get("query_id")
        return Trace(cols["table_id"].astype(np.int32),
                     cols["row_id"].astype(np.int64), rpt,
                     None if q is None else q.astype(np.int32))
    raise ValueError(f"unsupported trace format {path.suffix!r} "
                     "(use .npz or .csv)")


# ---------------------------------------------------------------------------
# Locality statistics (paper §III)
# ---------------------------------------------------------------------------


def reuse_distances(keys: np.ndarray) -> np.ndarray:
    """Exact reuse distance per access (#distinct keys between consecutive
    uses of the same key); -1 for first-ever accesses.

    Fenwick-tree algorithm, O(N log N).
    """
    n = len(keys)
    out = np.full(n, -1, dtype=np.int64)
    tree = np.zeros(n + 2, dtype=np.int64)

    def update(i, v):
        i += 1
        while i <= n + 1:
            tree[i] += v
            i += i & (-i)

    def query(i):  # sum of [0, i]
        i += 1
        s = 0
        while i > 0:
            s += tree[i]
            i -= i & (-i)
        return s

    last = {}
    for i in range(n):
        k = keys[i]
        j = last.get(k)
        if j is not None:
            # #distinct keys accessed in (j, i) = count of "last occurrence"
            # markers in that range.
            out[i] = query(i - 1) - query(j)
            update(j, -1)
        update(i, 1)
        last[k] = i
    return out


def reuse_distance_cdf(keys: np.ndarray, max_pow: int = 24):
    """(bucket_edges, frac_of_accesses_with_rd >= edge) for log2 buckets."""
    rd = reuse_distances(keys)
    seen = rd[rd >= 0]
    edges = [2**p for p in range(0, max_pow + 1)]
    frac = [float((seen >= e).mean()) if len(seen) else 0.0 for e in edges]
    return np.array(edges), np.array(frac)
