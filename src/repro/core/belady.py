"""Belady's MIN (OPT) cache simulator + optgen-style label generation.

The paper trains its caching model on ground-truth labels from optgen [35]
(Hawkeye's liveness-interval implementation of Belady).  We implement the
exact MIN policy directly with a lazy max-heap over next-use times — same
decisions, simpler code — including *bypass* (if the incoming line's next use
is farther than everything cached, OPT doesn't insert it), which is required
for true optimality.

Label semantics (paper §VI-A): the "caching trace" marks, per access, whether
the vector should stay in the buffer — i.e. whether its NEXT use hits under
OPT.  ``belady_labels`` returns exactly that bit per access, plus the
hit/miss outcome stream.  The "prefetch trace" is derived as the accesses
that miss under OPT (vectors OPT could not keep).
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

INF = np.iinfo(np.int64).max


def next_use_times(keys: np.ndarray) -> np.ndarray:
    """next_use[i] = index of next access to keys[i] (INF if none)."""
    n = len(keys)
    nxt = np.full(n, INF, dtype=np.int64)
    last = {}
    for i in range(n - 1, -1, -1):
        k = keys[i]
        j = last.get(k)
        if j is not None:
            nxt[i] = j
        last[k] = i
    return nxt


def belady_sim(keys: np.ndarray, capacity: int,
               bypass: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """Exact MIN.  Returns (hits bool (N,), kept bool (N,)).

    ``kept[i]`` is True iff the vector stays in cache from access i until its
    next use (equivalently: its next use is a hit *because of* access i) —
    this is the optgen caching-trace label.
    """
    n = len(keys)
    nxt = next_use_times(keys)
    hits = np.zeros(n, dtype=bool)
    kept = np.zeros(n, dtype=bool)

    cache = {}  # key -> current next-use time
    prev_idx = {}  # key -> index of the access that (re)inserted/touched it
    heap = []  # (-next_use, key, next_use) lazy entries

    for i in range(n):
        k = int(keys[i])
        cur = cache.get(k)
        if cur is not None and cur == i:
            hits[i] = True
            kept[prev_idx[k]] = True
            cache[k] = int(nxt[i])
            prev_idx[k] = i
            heapq.heappush(heap, (-nxt[i], k))
            continue

        # Miss.
        if capacity <= 0:
            continue
        if len(cache) >= capacity:
            if bypass and nxt[i] == INF:
                continue  # never reused: OPT bypasses
            # Find the valid cached key with the farthest next use.
            while heap:
                negnu, kk = heap[0]
                if cache.get(kk) == -negnu:
                    break
                heapq.heappop(heap)
            if heap and bypass and -heap[0][0] <= nxt[i]:
                continue  # incoming is the farthest: bypass
            if len(cache) >= capacity:
                negnu, kk = heapq.heappop(heap)
                del cache[kk]
                prev_idx.pop(kk, None)
        cache[k] = int(nxt[i])
        prev_idx[k] = i
        heapq.heappush(heap, (-nxt[i], k))
    return hits, kept


def belady_labels(keys: np.ndarray, capacity: int):
    """(caching_labels (N,) uint8, hits (N,) bool, prefetch_mask (N,) bool).

    caching_labels: 1 -> keep with high priority (next use hits under OPT).
    prefetch_mask: accesses that miss under OPT — the prefetch model's
    ground-truth targets (paper: "embedding vectors leading to cache
    misses").
    """
    hits, kept = belady_sim(keys, capacity)
    return kept.astype(np.uint8), hits, ~hits
