"""Batched tiered-memory embedding serving runtime (paper §VI).

Fast tier: a device-resident buffer of embedding vectors (on TPU this is the
HBM software-managed buffer; gathers go through the Pallas row-gather kernel
when available).  Slow tier: the full embedding tables in host memory.  A
miss triggers an on-demand host->device fetch (O(10us) per the paper).

The residency engine is **array-backed and batched** — the hot path does no
per-key Python work:

* ``_slot_map``  (N,) int32 — key -> slot, -1 when not resident (the dense
  inverse of the old ``slot_of`` dict; host tables are materialised arrays,
  so the key space is exactly ``range(N)``).
* ``_slot_key``  (C,) int64 — slot -> key, -1 when free (with ``_slot_map``
  this forms the two-way residency invariant checked in tests).
* ``_last_use``  (C,) int64 — LRU ranks from a global clock; batched
  eviction ranks all victims in one ``argpartition`` pass.
* ``_admit_seq`` (C,) int64 — admission order (the eviction fallback the
  dict insertion order used to provide).
* ``_pf_flag``   (C,) bool — prefetched-and-not-yet-demanded, for the
  Fig. 14 hit attribution.

``lookup`` partitions a batch into hits/misses with one vectorized gather on
``_slot_map``, admits all misses at once (single fused scatter into the
device buffer), and serves working sets larger than the buffer straight from
the host tier.  The per-key seed implementation is preserved verbatim in
:mod:`repro.core.tiered_reference`; ``tests/test_tiered_equivalence.py``
proves both produce identical counters on a recorded trace.

Under ``policy="recmg"`` eviction is driven by the **array-backed priority
engine** (:mod:`repro.core.priority_engine`): the whole miss batch admits
through one ``admit_interleaved`` call that ranks every victim in a single
vectorized pass and resolves own-batch evictions (a just-admitted key
evicted by a later key of the same batch) without per-key Python.  The
seed-faithful per-key loop survives as ``_admit_recmg_sequential`` — the
equivalence oracle, also the safety net should the engine ever desync from
residency (checked per batch in O(1)).

The gather path is **device-resident end-to-end**: one jitted
``buf[idx][inv]`` fused gather per batch (both index vectors padded to
power-of-two shape buckets), overflow rows folded in through a jitted
``where``-select over staged host rows instead of a device->host->device
bounce, and no intermediate ``block_until_ready`` between the miss-path
scatter and the gather — fetch and gather pipeline inside one device sync
(``fetch_s`` therefore measures host-side admit + dispatch; execution time
lands in ``gather_s``).  ``warmup(batch_hint)`` (or the ``warmup_batch``
constructor argument) eagerly compiles every shape bucket a batch can hit,
so XLA compiles land at construction instead of inside measured batches;
the jitted functions are module-level, so all stores of one process share
one compile cache.

The buffer is co-managed by the RecMG models exactly as in Algorithms 1 & 2:
the caching model's bits set priorities of the just-accessed chunk, the
prefetch model's predictions are inserted ahead of use, both computed one
batch ahead (pipelined) on the CPU.  ``stage_model_outputs`` double-buffers
those outputs so they land at the next batch boundary without blocking an
in-flight ``lookup``.

Besides wall-clock measurement, the runtime reports an analytic latency
decomposition using the slow-tier cost model (fetch_us per missing row +
fixed per-batch overhead) so results transfer to the real two-tier hardware
this container lacks; the linear performance model of §VII-F (Fig. 18) is
fitted from these runs.  See ``docs/architecture.md`` for the full state
layout and invariants.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.buffer_manager import RecMGBuffer
from repro.obs.tracing import get_tracer


# Quantized fast-tier row formats: storage dtype per format (scale stays
# fp32 either way).  Mirrors repro.kernels.embedding_gather.ROW_FORMATS —
# kept local so the store's constructor-time validation doesn't import the
# Pallas stack.
_QDTYPE = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}


def fast_row_bytes(d: int, host_dtype, quantize: bool,
                   row_format: str = "int8") -> int:
    """Per-row fast-tier footprint in bytes: ``d * itemsize`` for fp32
    rows, ``d * 1 + 4`` for the quantized formats (1-byte elements + one
    fp32 scale) — the accounting the byte-budget facades split on."""
    if quantize:
        if row_format not in _QDTYPE:
            raise ValueError(f"unknown row_format {row_format!r} "
                             f"(expected one of {sorted(_QDTYPE)})")
        return d + 4
    return d * np.dtype(host_dtype).itemsize


def _bucket(n: int) -> int:
    """Round up to a power of two (>= 16): the shape-bucketing that keeps
    the jitted scatter/gather from recompiling for every working-set size."""
    return max(16, 1 << (int(n) - 1).bit_length())


# ---------------------------------------------------------------------------
# Module-level jitted scatter/gather: one compile cache per process, shared
# by every store instance (per-instance lambdas would recompile the same
# shape buckets once per table/shard).  ``inv`` folds the unique->request
# expansion into the same fused program, so the result never leaves the
# device; the ``_OV`` variants where-select staged host rows for overflow
# (working set larger than the buffer) without a host round-trip.
# ---------------------------------------------------------------------------

# ``iv`` packs both index vectors — row 0 the unique slots, row 1 the
# unique->request inverse — into one operand, so each gather costs a
# single host->device transfer.
_JIT_GATHER = jax.jit(lambda buf, iv: buf[iv[0]][iv[1]])
_JIT_GATHER_OV = jax.jit(
    lambda buf, iv, ov, hr: jnp.where(ov[:, None], hr, buf[iv[0]])[iv[1]])
_JIT_GATHER_Q = jax.jit(
    lambda buf, sc, iv:
    (buf[iv[0]].astype(jnp.float32) * sc[iv[0]][:, None])[iv[1]])
_JIT_GATHER_Q_OV = jax.jit(
    lambda buf, sc, iv, ov, hr:
    jnp.where(ov[:, None], hr,
              buf[iv[0]].astype(jnp.float32) * sc[iv[0]][:, None])[iv[1]])
_JIT_SCATTER = jax.jit(lambda buf, idx, rows: buf.at[idx].set(rows),
                       donate_argnums=(0,))
_JIT_SCATTER_SC = jax.jit(lambda sc, idx, s: sc.at[idx].set(s),
                          donate_argnums=(0,))


def _scatter_quant(buf, sc, idx, rows, row_format):
    """Fused device-side quantize + scatter: per-row scale derivation,
    round/clip and both buffer writes trace into ONE jitted program, so
    the quantized admit keeps the fp32 path's single-dispatch /
    one-sync-per-batch property (the old host NumPy quantizer serialized
    a round-trip per admit)."""
    from repro.kernels.embedding_gather import quantize_rows_ref

    q, s = quantize_rows_ref(rows, row_format)
    return buf.at[idx].set(q), sc.at[idx].set(s)


_JIT_SCATTER_Q = jax.jit(_scatter_quant, static_argnums=(4,),
                         donate_argnums=(0, 1))

_KERNEL_JITS: Dict[tuple, object] = {}


def _kernel_gathers(quantized: bool = False, interpret: bool = False):
    """Pallas row-gather variants, built lazily (TPU backend, or any
    backend under ``interpret=True``).  ``quantized=True`` returns the
    fused dequantizing pair (int8/fp8 row + per-row scale DMA'd HBM->VMEM,
    dequantized in-kernel) with the overflow where-select folded in."""
    key = ("gq" if quantized else "g", interpret)
    if key not in _KERNEL_JITS:
        from repro.kernels import embedding_gather as eg

        if quantized:
            def g(buf, sc, iv, _i=interpret):
                return eg.gather_rows_dequant(buf, sc, iv[0],
                                              interpret=_i)[iv[1]]

            def gov(buf, sc, iv, ov, hr, _i=interpret):
                return jnp.where(
                    ov[:, None], hr,
                    eg.gather_rows_dequant(buf, sc, iv[0],
                                           interpret=_i))[iv[1]]
        else:
            def g(buf, iv, _i=interpret):
                return eg.gather_rows(buf, iv[0], interpret=_i)[iv[1]]

            def gov(buf, iv, ov, hr, _i=interpret):
                return jnp.where(ov[:, None], hr,
                                 eg.gather_rows(buf, iv[0],
                                                interpret=_i))[iv[1]]
        _KERNEL_JITS[key] = (jax.jit(g), jax.jit(gov))
    return _KERNEL_JITS[key]


def _kernel_scatter_q(row_format: str, interpret: bool = False):
    """Fused Pallas quantize + scatter for the kernel path: admitted fp32
    rows are quantized by the :func:`~repro.kernels.embedding_gather.
    quantize_rows` kernel and scattered into the quantized buffer + scale
    vector inside one jitted program (single dispatch, donated buffers)."""
    key = ("qs", row_format, interpret)
    if key not in _KERNEL_JITS:
        from repro.kernels import embedding_gather as eg

        def qs(buf, sc, idx, rows, _rf=row_format, _i=interpret):
            q, s = eg.quantize_rows(rows, row_format=_rf, interpret=_i)
            return buf.at[idx].set(q), sc.at[idx].set(s)

        _KERNEL_JITS[key] = jax.jit(qs, donate_argnums=(0, 1))
    return _KERNEL_JITS[key]


@dataclass
class TierStats:
    batches: int = 0
    lookups: int = 0
    hits: int = 0
    misses: int = 0  # request-level fast-tier misses (hits + misses == lookups)
    prefetch_hits: int = 0
    on_demand_rows: int = 0
    evictions: int = 0
    fetch_s: float = 0.0  # measured host->device copy time
    gather_s: float = 0.0  # device gather time
    model_s: float = 0.0  # CPU-side model inference time (off critical path)
    modeled_fetch_s: float = 0.0  # analytic slow-tier penalty

    @property
    def hit_rate(self):
        return self.hits / max(self.lookups, 1)

    def as_dict(self):
        # ``hits`` is emitted raw alongside the rounded ``hit_rate``:
        # serve/bench JSON must stay lossless for cross-run aggregation
        # (summing rounded rates across runs is meaningless).
        return {
            "batches": self.batches, "lookups": self.lookups,
            "hits": self.hits, "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
            "prefetch_hits": self.prefetch_hits,
            "on_demand_rows": self.on_demand_rows,
            "evictions": self.evictions,
            "fetch_s": round(self.fetch_s, 4),
            "gather_s": round(self.gather_s, 4),
            "model_s": round(self.model_s, 4),
            "modeled_fetch_s": round(self.modeled_fetch_s, 4),
        }

    def merge(self, other: "TierStats") -> "TierStats":
        """Aggregate (for the multi-table facade)."""
        for f in ("batches", "lookups", "hits", "misses", "prefetch_hits",
                  "on_demand_rows", "evictions"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("fetch_s", "gather_s", "model_s", "modeled_fetch_s"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self

    def publish(self, reg, prefix: str = "store"):
        """Publish into a :class:`repro.obs.MetricsRegistry` under the
        ``store.*`` namespace (see docs/architecture.md)."""
        for key, val in (
            ("batches", self.batches), ("lookups", self.lookups),
            ("fast.hits", self.hits), ("fast.misses", self.misses),
            ("fast.prefetch_hits", self.prefetch_hits),
            ("fast.on_demand_rows", self.on_demand_rows),
            ("fast.evictions", self.evictions),
            ("time.fetch_s", self.fetch_s),
            ("time.gather_s", self.gather_s),
            ("time.model_s", self.model_s),
            ("time.modeled_fetch_s", self.modeled_fetch_s),
        ):
            reg.counter(f"{prefix}.{key}").inc(val)
        reg.gauge(f"{prefix}.fast.hit_rate").set(self.hit_rate)
        return reg


class TieredEmbeddingStore:
    """Host table (N, D) + device buffer (C, D) with pluggable policy."""

    def __init__(self, host_table: np.ndarray, capacity: int,
                 policy: str = "lru", eviction_speed: int = 4,
                 fetch_us_per_row: float = 10.0, fetch_us_fixed: float = 30.0,
                 quantize: bool = False, row_format: Optional[str] = None,
                 use_kernel: Optional[bool] = None,
                 kernel_interpret: bool = False,
                 warmup_batch: Optional[int] = None):
        """``quantize=True``: quantized rows + per-row fp32 scale in the
        fast tier — the mixed-precision-embedding trick the paper cites
        ([90]): ``D + 4`` bytes per resident row instead of ``D *
        itemsize``, so at a fixed byte budget the buffer holds ~2-4x the
        rows and the hit rate rises (gated fixed-byte-budget cells in
        benchmarks/bench_e2e.py).  ``row_format`` picks the storage format
        (``"int8"`` default, or ``"fp8"`` = float8_e4m3fn); passing it
        without ``quantize=True`` is an error.

        ``use_kernel``: route the device gather (and, under quantize, the
        admit-side quantizer) through the fused Pallas kernels.  Default
        auto: TPU backend with a lane-aligned D.  An *explicit*
        ``use_kernel=True`` is validated, never silently downgraded: off
        the TPU backend it needs ``kernel_interpret=True`` (the Pallas
        interpreter — the CPU test lane), and D must be a multiple of 128
        on the compiled path.

        ``warmup_batch``: eagerly compile the jitted scatter/gather for
        every power-of-two shape bucket a batch of up to this many ids can
        hit (see :meth:`warmup`); None skips the warmup."""
        self.host = host_table
        n, d = host_table.shape
        self.capacity = max(1, int(capacity))  # same clamp as RecMGBuffer
        self.quantize = quantize
        if row_format is not None and not quantize:
            raise ValueError("row_format requires quantize=True "
                             "(fp32 rows have no storage format knob)")
        self.row_format = row_format or "int8"
        if self.row_format not in _QDTYPE:
            raise ValueError(f"unknown row_format {self.row_format!r} "
                             f"(expected one of {sorted(_QDTYPE)})")
        if quantize:
            self.buffer = jnp.zeros((self.capacity, d),
                                    _QDTYPE[self.row_format])
            self.scales = jnp.zeros((self.capacity,), jnp.float32)
        else:
            self.buffer = jnp.zeros((self.capacity, d), host_table.dtype)
        # -------- array-backed residency state (see module docstring) -----
        self._slot_map = np.full(n, -1, np.int32)
        self._slot_key = np.full(self.capacity, -1, np.int64)
        self._free = np.arange(self.capacity - 1, -1, -1, dtype=np.int32)
        self._n_free = self.capacity
        self._last_use = np.zeros(self.capacity, np.int64)
        self._admit_seq = np.zeros(self.capacity, np.int64)
        self._pf_flag = np.zeros(self.capacity, bool)
        self._clock = 1
        self.policy = policy
        # The store owns RESIDENCY (_slot_map); the RecMG structure only
        # ranks priorities, so it gets unbounded capacity and never
        # self-evicts — under recmg its live set mirrors the resident set
        # exactly (checked in check_invariants), which is what lets
        # ``_admit`` rank a whole victim batch in one engine pass.
        self.recmg = RecMGBuffer(1 << 40, eviction_speed, n_keys_hint=n)
        self.fetch_us_per_row = fetch_us_per_row
        self.fetch_us_fixed = fetch_us_fixed
        self.stats = TierStats()
        self._staged: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self.kernel_interpret = bool(kernel_interpret)
        on_tpu = jax.default_backend() == "tpu"
        if use_kernel is None:
            # Auto mode may downgrade: the kernel path only engages when
            # the backend can actually compile it for this table shape.
            use_kernel = on_tpu and d % 128 == 0
        elif use_kernel:
            # An explicit request is a contract — validate, never
            # silently drop (the old ``and not quantize`` downgrade hid
            # exactly this class of misconfiguration).
            if not on_tpu and not self.kernel_interpret:
                raise ValueError(
                    "use_kernel=True requires the TPU backend; pass "
                    "kernel_interpret=True to run the Pallas kernels in "
                    "interpret mode (the CPU test lane)")
            if not self.kernel_interpret and d % 128:
                raise ValueError(
                    f"use_kernel=True requires D % 128 == 0 (got D={d}): "
                    "the compiled kernels stream rows through the 128-lane "
                    "layout — pad the table or pass kernel_interpret=True")
        self.use_kernel = bool(use_kernel)
        if self.use_kernel:
            self._gather_inv, self._gather_ov = _kernel_gathers(
                quantized=quantize, interpret=self.kernel_interpret)
        elif quantize:
            self._gather_inv, self._gather_ov = _JIT_GATHER_Q, _JIT_GATHER_Q_OV
        else:
            self._gather_inv, self._gather_ov = _JIT_GATHER, _JIT_GATHER_OV
        self._out_np_dtype = np.dtype(
            np.float32 if quantize else self.buffer.dtype)
        if quantize:
            if self.use_kernel:
                self._scatter_q = _kernel_scatter_q(
                    self.row_format, interpret=self.kernel_interpret)
            else:
                rf = self.row_format
                self._scatter_q = lambda buf, sc, idx, rows: \
                    _JIT_SCATTER_Q(buf, sc, idx, rows, rf)
        if warmup_batch:
            self.warmup(warmup_batch)

    # ---------------- compat / introspection ----------------

    @property
    def slot_of(self) -> Dict[int, int]:
        """Dict view of key -> slot residency (seed-compatible read API)."""
        res = np.flatnonzero(self._slot_key >= 0)
        return {int(self._slot_key[s]): int(s) for s in res}

    @property
    def n_resident(self) -> int:
        return self.capacity - self._n_free

    def resident_mask(self, ids: np.ndarray) -> np.ndarray:
        """Vectorized residency probe: True where ``ids`` are in the fast
        tier right now (public API for the serving runtime's cancel-
        before-issue and for tests; does not touch recency state)."""
        return self._slot_map[np.asarray(ids, np.int64).ravel()] >= 0

    def lookup_resident(self, ids: np.ndarray):
        """Degraded read for over-deadline requests: ``(rows, n_default)``
        where resident ids get their current (possibly stale) fast-tier
        row and slow-tier misses get a zero default row — never a wrong
        shape, never a slow-tier fetch.  Pure read: no recency update, no
        admission/eviction, no stats mutation, so the main accounting
        identities are untouched."""
        ids = np.asarray(ids, np.int64).ravel()
        out = np.zeros((ids.size, self.host.shape[1]), self._out_np_dtype)
        slots = self._slot_map[ids]
        res = slots >= 0
        n_res = int(np.count_nonzero(res))
        if n_res:
            s = slots[res].astype(np.int64)
            rows = np.asarray(self.buffer)[s]
            if self.quantize:
                rows = rows.astype(np.float32) \
                    * np.asarray(self.scales)[s][:, None]
            out[res] = rows.astype(self._out_np_dtype, copy=False)
        return out, int(ids.size) - n_res

    def check_invariants(self):
        """Residency invariants (used by tests): the slot map and slot->key
        array are exact inverses, the free stack covers the rest, and under
        recmg the priority engine's live set mirrors residency exactly."""
        res = np.flatnonzero(self._slot_key >= 0)
        keys = self._slot_key[res]
        assert np.array_equal(self._slot_map[keys], res.astype(np.int32))
        assert len(res) == self.capacity - self._n_free
        assert np.count_nonzero(self._slot_map >= 0) == len(res)
        free = self._free[: self._n_free]
        assert np.all(self._slot_key[free] < 0)
        if self.policy == "recmg":
            # Every resident key holds a live ranking entry; the engine may
            # additionally hold *stale* entries for non-resident keys
            # (prefetch rankings that outlived their row — the seed's heap
            # had the same, drained lazily during victim selection).
            eng = self.recmg.engine
            live = eng.live_keys()
            assert eng.count == live.size
            assert np.all(np.isin(keys, live))

    def warmup(self, batch_hint: int):
        """Eagerly compile the jitted scatter/gather for every power-of-two
        shape bucket a batch of up to ``batch_hint`` ids can hit, so XLA
        compiles land at construction instead of inside measured batches
        (they showed up as ~600ms p99 spikes against a ~10ms p50).  The
        jitted functions are module-level: across tables/shards only the
        first store pays each compile."""
        bi = _bucket(int(batch_hint))
        d = self.host.shape[1]
        b = 16
        while b <= bi:
            iv = jnp.zeros((2, b), jnp.int32)
            ov = jnp.zeros(b, bool)
            hr = jnp.zeros((b, d), self._out_np_dtype)
            gather_args = (
                (self.buffer, self.scales) if self.quantize
                else (self.buffer,)
            )
            self._gather_inv(*gather_args, iv)
            self._gather_ov(*gather_args, iv, ov, hr)
            # Scatter warm-up must not clobber buffer contents: rewrite
            # slot 0 with its own current row (a no-op write).
            slots = jnp.zeros(b, jnp.int32)
            if self.quantize:
                # Warm the fused quantize+scatter with slot 0's own
                # dequantized row: re-quantizing a quantized row is
                # value-preserving (same scale derivation, round-half-even
                # maps each code back to itself), so resident contents
                # survive to within the format's quantization error.
                r0 = (np.asarray(self.buffer[0:1]).astype(np.float32)
                      * float(np.asarray(self.scales[0])))
                rows = jnp.asarray(np.repeat(r0, b, axis=0))
                self.buffer, self.scales = self._scatter_q(
                    self.buffer, self.scales, slots, rows)
            else:
                r0 = np.repeat(np.asarray(self.buffer[0:1]), b, axis=0)
                self.buffer = _JIT_SCATTER(self.buffer, slots,
                                           jnp.asarray(r0))
            b <<= 1
        jax.block_until_ready(self.buffer)

    # ---------------- slot allocation / eviction ----------------

    def _alloc(self, m: int) -> np.ndarray:
        slots = self._free[self._n_free - m: self._n_free][::-1].copy()
        self._n_free -= m
        return slots

    def _release(self, slots: np.ndarray):
        k = len(slots)
        self._free[self._n_free: self._n_free + k] = slots[::-1]
        self._n_free += k

    def _evict_slots(self, victim_slots: np.ndarray):
        """Batched eviction: clear residency + prefetch flags, free slots."""
        vk = self._slot_key[victim_slots]
        self._slot_map[vk] = -1
        self._slot_key[victim_slots] = -1
        self._pf_flag[victim_slots] = False
        self.stats.evictions += len(victim_slots)
        self._release(np.asarray(victim_slots, np.int32))

    def _pick_victim_recmg(self) -> int:
        victim = self.recmg.populate()
        while victim is not None and self._slot_map[victim] < 0:
            victim = self.recmg.populate()  # stale non-resident entry
        if victim is None:  # priorities exhausted: oldest-admitted resident
            res = np.flatnonzero(self._slot_key >= 0)
            victim = int(self._slot_key[res[np.argmin(self._admit_seq[res])]])
        return victim

    def _bind(self, keys: np.ndarray, slots: np.ndarray):
        """Point keys at slots and stamp admission order / recency."""
        m = len(keys)
        self._slot_map[keys] = slots
        self._slot_key[slots] = keys
        self._admit_seq[slots] = self._clock + np.arange(m)
        self._last_use[slots] = self._clock + np.arange(m)
        self._clock += m

    def _admit(self, missing: np.ndarray) -> np.ndarray:
        """Assign slots for all missing keys at once, evicting as needed.

        Returns a bool mask over ``missing``: True where the key is resident
        after the batch (False = overflow: the working set exceeded the
        buffer, so the row is served straight from the host tier).
        """
        m = len(missing)
        kept = np.ones(m, bool)
        if self.policy == "recmg":
            if m <= self._n_free:
                slots = self._alloc(m)
                self._bind(missing, slots)
                self.recmg.set_priorities(missing, self.recmg.ev,
                                          only_new=True)
            elif self.recmg.engine.contains_many(missing).any():
                # Resurrection: a missing key still holds a stale ranking
                # entry (it was prefetch-ranked after being evicted in its
                # own admission batch).  Re-admitting it must *keep* that
                # old entry (the seed's only_new semantics), and the old
                # entry can even be chosen as a victim mid-batch — exact
                # only in the per-key oracle.  Rare: requires a stale key
                # to be demand-missed while its entry survives.
                self._admit_recmg_sequential(missing, kept)
            else:
                self._admit_recmg_batched(missing, kept)
            return kept
        # ---- LRU: fully batched ----
        if m >= self.capacity:
            # Every old resident gets evicted, then the first m-C missing
            # keys are themselves evicted by later ones in admit order:
            # only the last C keys of the (sorted-unique) batch survive.
            old = np.flatnonzero(self._slot_key >= 0)
            if len(old):
                self._evict_slots(old)
            kept[: m - self.capacity] = False
            # The seed admitted those m-C keys and then evicted each one;
            # count them so the eviction stat matches the reference.
            self.stats.evictions += m - self.capacity
            new = missing[m - self.capacity:]
            self._bind(new, self._alloc(self.capacity))
            return kept
        need = m - self._n_free
        if need > 0:
            res = np.flatnonzero(self._slot_key >= 0)
            if need >= len(res):
                victims = res
            else:  # rank all victims in one pass
                victims = res[np.argpartition(self._last_use[res],
                                              need - 1)[:need]]
            self._evict_slots(victims)
        self._bind(missing, self._alloc(m))
        return kept

    def _admit_recmg_batched(self, missing: np.ndarray, kept: np.ndarray):
        """Fully batched recmg admission under eviction pressure: the
        engine ranks all victims in one vectorized pass
        (:meth:`~repro.core.priority_engine.ArrayPriorityEngine.
        admit_interleaved`), resolving own-batch evictions (a key of this
        batch evicted by a later one) vectorially.  Counter- and
        victim-identical to :meth:`_admit_recmg_sequential` (the property
        suite fuzzes both against the seed reference)."""
        m = len(missing)
        slot_map = self._slot_map
        victims, own, kept_eng = self.recmg.engine.admit_interleaved(
            missing, self.recmg.ev, self._n_free,
            resident_fn=lambda kk: slot_map[kk] >= 0)
        ext = victims[~own]
        if ext.size:
            vs = self._slot_map[ext]
            self._slot_map[ext] = -1
            self._slot_key[vs] = -1
            self._pf_flag[vs] = False
            self._release(vs.astype(np.int32, copy=False))
        # Own-batch victims were bound and then evicted by the sequential
        # loop; both count as evictions and both consumed a clock tick.
        self.stats.evictions += int(victims.size)
        kidx = np.flatnonzero(kept_eng)
        kk = missing[kidx]
        slots = self._alloc(kidx.size)
        self._slot_map[kk] = slots
        self._slot_key[slots] = kk
        self._admit_seq[slots] = self._clock + kidx
        self._last_use[slots] = self._clock + kidx
        self._clock += m
        kept[:] = kept_eng

    def _admit_recmg_sequential(self, missing: np.ndarray, kept: np.ndarray):
        """Seed-faithful per-key admission under recmg eviction pressure
        (the equivalence oracle for :meth:`_admit_recmg_batched`)."""
        slot_map, slot_key = self._slot_map, self._slot_key
        pos = {int(k): i for i, k in enumerate(missing.tolist())}
        for i, k in enumerate(missing.tolist()):
            if self._n_free == 0:
                v = self._pick_victim_recmg()
                vs = slot_map[v]
                slot_map[v] = -1
                slot_key[vs] = -1
                self._pf_flag[vs] = False
                self.stats.evictions += 1
                self._release(np.asarray([vs], np.int32))
                j = pos.get(v)
                if j is not None and j < i:
                    kept[j] = False  # own-batch key evicted mid-batch
            slot = int(self._alloc(1)[0])
            slot_map[k] = slot
            slot_key[slot] = k
            self._admit_seq[slot] = self._clock
            self._last_use[slot] = self._clock
            self._clock += 1
            if not self.recmg.contains(k):
                self.recmg.set_priority(k, self.recmg.ev)

    # ---------------- main path ----------------

    def lookup(self, ids: np.ndarray) -> jnp.ndarray:
        """ids: (M,) int64 -> (M, D) embeddings from the fast tier,
        fetching misses on demand.  One vectorized pass: hit/miss partition
        via the slot map, batched admission, single fused scatter + gather.
        The result stays on the device (feed it straight into the jitted
        forward); facades that merge sub-results host-side should use
        :meth:`lookup_host` instead, which saves the device-side slice.
        """
        out, m_ids, t0 = self._lookup_padded(ids)
        out = out[:m_ids]
        jax.block_until_ready(out)
        self.stats.gather_s += time.perf_counter() - t0
        return out

    def lookup_host(self, ids: np.ndarray) -> np.ndarray:
        """:meth:`lookup` materialized as a NumPy array in one transfer —
        the multi-table and sharded facades reassemble per-store results
        on the host, so slicing there is free.  Counters are identical to
        :meth:`lookup`."""
        out, m_ids, t0 = self._lookup_padded(ids)
        out = np.asarray(out)[:m_ids]
        self.stats.gather_s += time.perf_counter() - t0
        return out

    def _lookup_padded(self, ids: np.ndarray):
        """Shared lookup pipeline; returns (padded device rows, true batch
        size, gather timer start) — callers slice and sync."""
        self._drain_staged()
        tr = get_tracer()
        if tr.enabled:  # off cost: one global read + attr check per batch
            t_span = tr.clock.now()
            ev0 = self.stats.evictions
        ids = np.asarray(ids).ravel()
        self.stats.batches += 1
        self.stats.lookups += ids.size
        uniq, inv = np.unique(ids, return_inverse=True)
        slots_u = self._slot_map[uniq]
        miss_mask = slots_u < 0
        n_hit = int(np.count_nonzero(~miss_mask[inv]))
        self.stats.hits += n_hit
        self.stats.misses += int(ids.size) - n_hit
        hit_slots = slots_u[~miss_mask]
        pf = self._pf_flag[hit_slots]
        n_pf = int(np.count_nonzero(pf))
        if n_pf:  # first-touch prefetch attribution
            self.stats.prefetch_hits += n_pf
            self._pf_flag[hit_slots] = False

        missing = uniq[miss_mask]
        if missing.size:
            t0 = time.perf_counter()
            if tr.enabled:
                t_admit = tr.clock.now()
            rows = self.host[missing]
            kept = self._admit(missing)
            wkeys = missing[kept]
            self._write_rows(self._slot_map[wkeys], rows[kept])
            # No sync here: the scatter pipelines into the gather below and
            # both resolve in that single device sync (fetch_s is the
            # host-side admit + dispatch time; execution lands in gather_s).
            self.stats.fetch_s += time.perf_counter() - t0
            self.stats.on_demand_rows += int(missing.size)
            self.stats.modeled_fetch_s += (
                self.fetch_us_fixed + self.fetch_us_per_row * missing.size
            ) * 1e-6
            if tr.enabled:
                tr.add_span("store", "admit", t_admit,
                            tr.clock.now() - t_admit, track="store",
                            args={"miss_rows": int(missing.size)})
            slots_u = self._slot_map[uniq]  # refresh post-admission

        if self.policy == "lru":
            # Batched touch: every resident key of this batch moves to the
            # MRU end, ordered by sorted-unique position (seed order).
            res = slots_u >= 0
            rs = slots_u[res]
            self._last_use[rs] = self._clock + np.flatnonzero(res)
            self._clock += uniq.size

        t0 = time.perf_counter()
        if tr.enabled:
            t_gather = tr.clock.now()
        # Device-resident gather: one fused jitted pass does the slot
        # gather, the overflow where-select, and the unique->request
        # expansion, so the result never bounces through the host.  The
        # two index vectors are packed into one (2, bucket) operand — a
        # single transfer — and share ONE power-of-two bucket (u <= M
        # always): independent buckets would give O(log^2) compiled shape
        # combos, and per-table sub-batch sizes vary enough to hit them
        # all at runtime.  Buckets are warmed eagerly by :meth:`warmup`.
        gather_args = (
            (self.buffer, self.scales) if self.quantize else (self.buffer,)
        )
        u = uniq.size
        m_ids = ids.size
        bsz = _bucket(m_ids)
        iv = np.zeros((2, bsz), np.int32)
        np.maximum(slots_u, 0, out=iv[0, :u], casting="unsafe")
        iv[1, :m_ids] = inv
        overflow = slots_u < 0
        if overflow.any():
            # A batch whose unique working set exceeds the buffer can evict
            # rows admitted earlier in the same batch; stage those rows
            # from the host tier into the padded gather input and fold them
            # in with a jitted where-select (counted as on-demand already).
            ov = np.zeros(bsz, bool)
            ov[:u] = overflow
            hrows = np.zeros((bsz, self.host.shape[1]),
                             self._out_np_dtype)
            hrows[:u][overflow] = self.host[uniq[overflow]]
            out = self._gather_ov(*gather_args, jnp.asarray(iv),
                                  jnp.asarray(ov), jnp.asarray(hrows))
        else:
            out = self._gather_inv(*gather_args, jnp.asarray(iv))
        if tr.enabled:
            tr.add_span("store", "gather", t_gather,
                        tr.clock.now() - t_gather, track="store",
                        args={"uniq": int(u)})
            # Span args carry the batch's exact counter deltas — the trace
            # <-> metrics reconciliation sums these over all lookup spans.
            tr.add_span("store", "lookup", t_span, tr.clock.now() - t_span,
                        track="store", args={
                            "ids": m_ids, "uniq": int(u),
                            "hit_ids": n_hit, "miss_ids": m_ids - n_hit,
                            "miss_rows": int(missing.size),
                            "evictions": self.stats.evictions - ev0,
                        })
        return out, m_ids, t0

    def _write_rows(self, slots: np.ndarray, rows: np.ndarray):
        if not len(slots):
            return
        # Bucket-pad the scatter like the gather: repeat the last
        # (slot, row) pair — rewriting one slot with its own row is a
        # no-op, and the fixed shapes keep XLA from recompiling per batch.
        pad = _bucket(len(slots)) - len(slots)
        if pad:
            slots = np.concatenate((slots, np.repeat(slots[-1:], pad)))
            rows = np.concatenate((rows, np.repeat(rows[-1:], pad, axis=0)))
        if self.quantize:
            # Device-side quantize + scatter in one fused dispatch (Pallas
            # quantizer on the kernel path, jnp reference otherwise): no
            # host NumPy pass, and the write pipelines into the batch's
            # gather exactly like the fp32 scatter does.
            self.buffer, self.scales = self._scatter_q(
                self.buffer, self.scales, jnp.asarray(slots),
                jnp.asarray(rows, jnp.float32))
        else:
            self.buffer = _JIT_SCATTER(
                self.buffer, jnp.asarray(slots), jnp.asarray(rows))

    # ---------------- RecMG co-management hooks ----------------

    def stage_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Double-buffered Algorithm 1: queue the model outputs now, apply
        them at the next batch boundary, so the producer never blocks an
        in-flight lookup.  Serving loops should call :meth:`flush_staged`
        in the gap between batches (off the latency-measured path); the
        next ``lookup`` drains any remainder as a fallback."""
        self._staged.append((np.asarray(trunk), np.asarray(bits),
                             np.asarray(prefetch_ids)))

    def flush_staged(self):
        """Apply all staged model outputs now (the inter-batch gap)."""
        self._drain_staged()

    def _drain_staged(self):
        if self._staged:
            staged, self._staged = self._staged, []
            for trunk, bits, pf in staged:
                self.apply_model_outputs(trunk, bits, pf)

    def apply_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Algorithm 1, invoked between batches (pipelined)."""
        tr = get_tracer()
        if tr.enabled:
            t_pop = tr.clock.now()
            ev0 = self.stats.evictions
        trunk = np.asarray(trunk, np.int64).ravel()
        bits = np.asarray(bits).ravel()
        m = min(trunk.size, bits.size)  # zip semantics: shorter side wins
        trunk, bits = trunk[:m], bits[:m]
        pf_ids = np.asarray(prefetch_ids, np.int64).ravel()
        if self.policy != "recmg":
            # LRU+PF mode: only prefetch insertion applies.
            pf = self._new_prefetch_keys(pf_ids)
            if pf.size:
                self._fetch_prefetch(pf)
        else:
            t0 = time.perf_counter()
            # Only rank RESIDENT keys (pipelined outputs can reference
            # vectors already evicted; ranking them would desync
            # priorities/residency).
            res = self._slot_map[trunk] >= 0
            self.recmg.load_embeddings(trunk[res], bits[res], [])
            pf = self._new_prefetch_keys(pf_ids)
            if pf.size:
                self._fetch_prefetch(pf)
                self.recmg.set_priorities(pf, self.recmg.ev)
            self.stats.model_s += time.perf_counter() - t0
        if tr.enabled:
            tr.add_span("store", "populate", t_pop,
                        tr.clock.now() - t_pop, track="store", args={
                            "trunk": int(trunk.size), "pf_rows": int(pf.size),
                            "evictions": self.stats.evictions - ev0})

    def _new_prefetch_keys(self, pf_ids: np.ndarray) -> np.ndarray:
        """Non-resident prefetch targets, deduplicated, first-occurrence
        order preserved (the seed admitted duplicates twice, leaking a
        buffer slot per duplicate; the batched engine dedupes)."""
        if not pf_ids.size:
            return pf_ids
        pf = pf_ids[self._slot_map[pf_ids] < 0]
        if pf.size > 1:
            _, first = np.unique(pf, return_index=True)
            pf = pf[np.sort(first)]
        return pf

    def _fetch_prefetch(self, keys: np.ndarray):
        rows = self.host[keys]
        kept = self._admit(keys)
        wkeys = keys[kept]
        slots = self._slot_map[wkeys]
        self._write_rows(slots, rows[kept])
        self._pf_flag[slots] = True

    def modeled_batch_ms(self) -> float:
        """Analytic per-batch latency contribution of the slow tier."""
        return 1e3 * self.stats.modeled_fetch_s / max(self.stats.batches, 1)

    def publish_metrics(self, reg):
        """Publish this store's counters under ``store.*`` (uniform
        facade/store surface for the serving entry points)."""
        return self.stats.publish(reg, prefix="store")
