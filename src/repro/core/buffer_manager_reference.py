"""Heap-backed reference RecMG buffer manager (the seed implementation).

This is the original lazy-min-heap ``RecMGBuffer``, kept verbatim (same
pattern as :mod:`repro.core.tiered_reference`) for two jobs:

1. **Equivalence oracle** — the property suite replays fuzzed chunk
   sequences through this class, the array-backed engine in
   :mod:`repro.core.buffer_manager`, and ``SlowRecMGBuffer``, asserting
   victim-for-victim identical eviction order and identical hit masks.
2. **Speedup baseline** — per-key heap ops are what made the ``recmg``
   policy ~4.5x slower per serving batch than LRU before the engine.

Do not optimise this file; its value is that it stays slow and obviously
correct.  New behavior belongs in :mod:`repro.core.priority_engine` /
:mod:`repro.core.buffer_manager`.

Original module docstring follows.

The RecMG buffer manager — Algorithms 1 & 2 of the paper, with the RRIP
semantics the paper cites.

Each buffer entry carries an integer priority (``eviction_speed = 4``):
the caching model's keep-bit puts just-accessed vectors in the
cache-friendly class (priority = eviction_speed) or the cache-averse class
(priority = 0, evict-next) — Hawkeye-style insertion; prefetched vectors
enter at eviction_speed.  ``populate`` (Algorithm 2) evicts the minimum-
priority entry, aging everyone *on demand* — only as far as needed to bring
that minimum to zero, which is the RRIP scan the paper says it builds on.
(The pseudocode's literal decay-by-1-per-eviction with priorities in
{ev, ev+1} degenerates to LRU under buffer-scale eviction pressure; see
EXPERIMENTS.md §Faithfulness notes — both readings are implemented and
tested.)

Production buffers hold O(100K+) vectors, so eviction is O(log n): a global
decay epoch (age-by-d == epoch += d; effective priority = stored_priority +
stored_epoch - epoch preserves eviction order of the static key
stored_priority + stored_epoch) over a lazy min-heap whose entries are
validated by (score, seq) — ties broken by insertion age.
``SlowRecMGBuffer`` is the literal O(capacity) transcription used to
cross-check in tests.

Batched drivers use the chunk-at-a-time surface — ``set_priorities``,
``fetch_many``, ``populate_many``, and ``access_chunk`` (the replay inner
loop of ``run_recmg``) — instead of per-key calls; ``set_priority`` is the
public single-key form (``_set_priority`` remains as an alias).
"""
from __future__ import annotations

import heapq
from typing import Dict, Iterable, List, Optional

import numpy as np


class RecMGBuffer:
    def __init__(self, capacity: int, eviction_speed: int = 4):
        self.capacity = max(1, int(capacity))
        self.ev = int(eviction_speed)
        self.epoch = 0
        self.score: Dict[int, int] = {}  # key -> stored_priority + epoch
        self._seq_of: Dict[int, int] = {}  # key -> seq of its live entry
        self.heap: List = []  # (score, seq, key) lazy
        self.seq = 0

    def __len__(self):
        return len(self.score)

    def contains(self, key: int) -> bool:
        return key in self.score

    def set_priority(self, key: int, priority: int):
        """Insert ``key`` or refresh its priority (public single-key API)."""
        s = priority + self.epoch
        self.score[key] = s
        self.seq += 1
        self._seq_of[key] = self.seq
        heapq.heappush(self.heap, (s, self.seq, key))

    # Backwards-compatible alias; callers should use ``set_priority``.
    _set_priority = set_priority

    # ---------------- bulk (chunk-at-a-time) API ----------------

    def set_priorities(self, keys: Iterable[int], priority: int,
                       only_new: bool = False):
        """Batched :meth:`set_priority` over a chunk of keys.

        ``only_new=True`` skips keys that already hold an entry (the
        admission-time insert of the tiered store, which must not demote a
        key the caching model just ranked)."""
        score, seq_of, heap = self.score, self._seq_of, self.heap
        s = int(priority) + self.epoch
        seq = self.seq
        for k in keys:
            k = int(k)
            if only_new and k in score:
                continue
            seq += 1
            score[k] = s
            seq_of[k] = seq
            heapq.heappush(heap, (s, seq, k))
        self.seq = seq

    def fetch_many(self, keys: Iterable[int], priority: int):
        """Batched :meth:`fetch`: insert a chunk, evicting as needed."""
        for k in keys:
            self.fetch(int(k), priority)

    def populate_many(self, n: int) -> List[int]:
        """Evict up to ``n`` victims in one call (Algorithm 2, batched)."""
        out = []
        for _ in range(n):
            v = self.populate()
            if v is None:
                break
            out.append(v)
        return out

    def access_chunk(self, keys: np.ndarray, priority: int) -> np.ndarray:
        """Serve a chunk of demand accesses; returns a per-access hit mask.

        A miss fetches the key at ``priority`` (the tiered runtime's
        on-demand insert).  This is the replay inner loop hoisted out of
        ``run_recmg`` so drivers go chunk-at-a-time instead of paying
        per-access method dispatch."""
        score = self.score
        hits = np.empty(len(keys), dtype=bool)
        at_cap = self.capacity <= len(score) + len(keys)  # may need room
        for i, k in enumerate(keys.tolist()):
            h = k in score
            hits[i] = h
            if not h:
                if at_cap:
                    self._make_room()
                self.set_priority(k, priority)
        return hits

    def populate(self) -> Optional[int]:
        """Algorithm 2 with RRIP aging semantics: evict the minimum-priority
        entry; decay everyone only as far as needed to bring that minimum to
        zero (the RRIP "age until a victim exists" scan, via the epoch).

        The paper's pseudocode decays by exactly 1 per call; under buffer-
        sized eviction pressure that makes the recency epoch swamp the 0..5
        priority range and the policy degenerates to LRU (±0.4% in our
        measurements).  Age-on-demand keeps the caching model's bit decisive
        — which is the behavior of the RRIP family the paper says it builds
        on, and the only reading that reproduces its Fig. 8 gains.  See
        EXPERIMENTS.md §Faithfulness notes.
        """
        victim = None
        while self.heap:
            s, sq, k = self.heap[0]
            # An entry is live iff both score AND seq match (a refresh with
            # an equal score would otherwise leave the stale seq winning the
            # tie-break).
            if self.score.get(k) == s and self._seq_of.get(k) == sq:
                heapq.heappop(self.heap)
                del self.score[k]
                del self._seq_of[k]
                victim = k
                if s > self.epoch:
                    self.epoch = s  # age exactly until this victim hits 0
                break
            heapq.heappop(self.heap)
        return victim

    def _make_room(self):
        while len(self.score) >= self.capacity:
            self.populate()

    def fetch(self, key: int, priority: int):
        """Insert (or re-prioritize) a vector."""
        if key not in self.score:
            self._make_room()
        self._set_priority(key, priority)

    def load_embeddings(self, trunk: Iterable[int], caching_bits: Iterable[int],
                        prefetch_keys: Iterable[int],
                        scaled_bits: bool = True):
        """Algorithm 1.  ``trunk`` = the most recently accessed chunk (already
        fetched on demand); caching_bits = the caching model's output C.

        ``scaled_bits=True`` gives the keep/evict classes RRIP-separated
        priorities (keep -> eviction_speed, evict -> 0/evict-next — Hawkeye's
        cache-friendly/averse insertion, which the paper builds on).  The
        paper's literal ``C[i] + eviction_speed`` keeps both classes within
        1 of each other and measures within noise of LRU; see EXPERIMENTS.md
        §Faithfulness notes.

        Accepts plain iterables or NumPy arrays (arrays are the bulk
        chunk-at-a-time path used by the batched tiered store)."""
        if isinstance(trunk, np.ndarray):
            trunk = trunk.tolist()
        if isinstance(caching_bits, np.ndarray):
            caching_bits = caching_bits.tolist()
        if isinstance(prefetch_keys, np.ndarray):
            prefetch_keys = prefetch_keys.tolist()
        for key, c in zip(trunk, caching_bits):
            pr = int(c) * self.ev if scaled_bits else int(c) + self.ev
            if key in self.score:
                self.set_priority(key, pr)
            else:
                self.fetch(key, pr)
        for key in prefetch_keys:
            if key not in self.score:
                self.fetch(key, self.ev)
                # paper: priority[P[i]] = eviction_speed ("high" so the
                # prefetch survives until its use)


class SlowRecMGBuffer:
    """Literal transcription of Algorithms 1 & 2 (O(capacity) eviction) —
    used to validate RecMGBuffer in tests.

    ``clamp`` is the paper's ``max(0, p-1)``; it only compresses ties among
    long-decayed entries (the paper doesn't specify tie order).  The O(log n)
    epoch formulation is order-identical to ``clamp=False``."""

    def __init__(self, capacity: int, eviction_speed: int = 4,
                 clamp: bool = True):
        self.capacity = max(1, int(capacity))
        self.ev = int(eviction_speed)
        self.clamp = clamp
        self.priority: Dict[int, int] = {}
        self.order: Dict[int, int] = {}
        self.seq = 0

    def __len__(self):
        return len(self.priority)

    def contains(self, key):
        return key in self.priority

    def populate(self):
        victim = min(
            self.priority, key=lambda k: (self.priority[k], self.order[k])
        )
        # RRIP aging: decay everyone by the victim's priority (age until a
        # zero-priority victim exists), then evict it.
        dec = max(0, self.priority[victim])
        lo = 0 if self.clamp else -(1 << 60)
        if dec:
            for k in self.priority:
                self.priority[k] = max(lo, self.priority[k] - dec)
        del self.priority[victim]
        del self.order[victim]
        return victim

    def fetch(self, key, priority):
        if key not in self.priority:
            while len(self.priority) >= self.capacity:
                self.populate()
        self.priority[key] = priority
        self.seq += 1
        self.order[key] = self.seq

    def load_embeddings(self, trunk, caching_bits, prefetch_keys,
                        scaled_bits: bool = True):
        for key, c in zip(trunk, caching_bits):
            pr = int(c) * self.ev if scaled_bits else int(c) + self.ev
            self.fetch(key, pr)
        for key in prefetch_keys:
            if key not in self.priority:
                self.fetch(key, self.ev)
