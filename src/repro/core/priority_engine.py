"""Vectorized array-backed RRIP/priority engine — the structure behind
:class:`~repro.core.buffer_manager.RecMGBuffer`.

The seed kept the RecMG priority order in a lazy min-heap: every
insert/refresh/eviction was one Python ``heapq`` operation, which made the
paper's ML-guided policy ~4.5x slower per serving batch than plain LRU even
though the *modeled* fetch cost was near-identical — the bookkeeping, not
the slow tier, was the bottleneck.  This engine replaces the heap with
dense NumPy state so every bulk operation is an O(chunk) vectorized pass:

* ``_score``  (K,) int64 — ``stored_priority + epoch_at_set`` per key (the
  same epoch trick as the heap: age-by-d == ``epoch += d``; effective
  priority = ``_score[k] - epoch`` and eviction order is the *static* key
  ``(_score[k], _seq[k])``, so aging never rewrites per-key state).
* ``_seq``    (K,) int64 — insertion sequence of the key's live entry
  (admission-order tie-break, identical to the heap's ``seq``).
* ``_live``   (K,) bool  — membership.  ``K`` grows geometrically with the
  largest key seen (keys are embedding ids: dense non-negative ints).

Victim *order* is found through sorted **candidate runs** — a
log-structured merge hierarchy: ``set_many`` appends O(chunk) pending
``_dirty`` chunks (each born sorted: batch inserts share one score and
carry ascending seqs), which fold into a new run before any eviction
(``_consolidate``); runs then collapse binary-counter style (a run merges
with its predecessor whenever it has grown at least as large), so there
are O(log n) runs and every entry is merged O(log n) times total.
Entries are validated lazily against ``_seq`` — a refresh leaves its
stale older copies in the runs, and pops skip them exactly like the
heap's lazy invalidation.

Batched victim selection (``pop_min_many``, ``admit_interleaved``) pops
vectorized *prefixes*: the run holding the global minimum surrenders every
entry below the other runs' heads in one ``searchsorted`` pass, so a batch
of ``n`` evictions costs O(runs + n) instead of n heap pops.
``admit_interleaved`` additionally replays the tiered store's admission
loop — one eviction before each insert once the buffer is full — and
resolves **own-batch evictions** (an inserted key evicted by a later key
of the same batch) vectorially, by treating the batch itself as a third
sorted run whose scores are materialized incrementally as the epoch
evolves.  ``tests/test_property_equivalence.py`` proves victim-for-victim
equality against the heap reference
(:mod:`repro.core.buffer_manager_reference`) and the literal
``SlowRecMGBuffer`` transcription.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

_EMPTY = np.empty(0, np.int64)
_EMPTY_B = np.empty(0, bool)


class _Run:
    """One sorted candidate run: entries ordered by ``(score, seq)``,
    consumed from ``head``.  Stale entries (superseded by a refresh or
    already popped) are detected lazily via the dense ``_seq`` array."""

    __slots__ = ("keys", "scores", "seqs", "head")

    def __init__(self, keys: np.ndarray, scores: np.ndarray,
                 seqs: np.ndarray, head: int = 0):
        self.keys = keys
        self.scores = scores
        self.seqs = seqs
        self.head = head

    def __len__(self):
        return len(self.keys) - self.head


class ArrayPriorityEngine:
    """Dense ``key -> (score, seq)`` priority map with batched min-pops.

    Keys must be non-negative integers (embedding ids).  All mutating
    operations accept chunks; per-key Python appears only on the lazy
    stale-skip at run heads (amortized O(1) per superseded entry).
    """

    def __init__(self, n_keys_hint: int = 1024):
        n = max(16, int(n_keys_hint))
        self._score = np.zeros(n, np.int64)
        self._seq = np.zeros(n, np.int64)
        self._live = np.zeros(n, bool)
        self.epoch = 0
        self.seq = 0
        self.count = 0
        # Sorted candidate runs, largest first (binary-counter LSM: a
        # newly consolidated chunk merges with the previous run whenever
        # it has grown at least as large, so there are O(log n) runs and
        # every entry participates in O(log n) merges overall).
        self._runs: List[_Run] = []
        self._dirty: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
        self._n_dirty = 0
        # Scalar nursery: single-key sets append plain (key, score, seq)
        # tuples here — no per-key array allocation — and ``pop_min``
        # scans it directly, so the interleaved set/pop regime of the
        # trace simulators never pays a consolidation per pop.
        self._sdirty: List[Tuple[int, int, int]] = []

    # ---------------- dense state ----------------

    def _ensure(self, kmax: int):
        n = self._live.size
        if kmax < n:
            return
        new = 1 << int(kmax + 1).bit_length()
        for name in ("_score", "_seq"):
            a = np.zeros(new, np.int64)
            a[:n] = getattr(self, name)
            setattr(self, name, a)
        live = np.zeros(new, bool)
        live[:n] = self._live
        self._live = live

    def contains(self, key: int) -> bool:
        return 0 <= key < self._live.size and bool(self._live[key])

    def contains_many(self, keys: np.ndarray) -> np.ndarray:
        keys = np.asarray(keys, np.int64).ravel()
        if keys.size:
            self._ensure(int(keys.max()))
        return self._live[keys]

    def live_keys(self) -> np.ndarray:
        """All live keys (introspection; O(K))."""
        return np.flatnonzero(self._live)

    def _valid(self, keys: np.ndarray, seqs: np.ndarray) -> np.ndarray:
        return self._live[keys] & (self._seq[keys] == seqs)

    # ---------------- inserts / refreshes ----------------

    def set_one(self, key: int, priority: int):
        """Scalar insert/refresh — the no-array fast path for per-key
        callers (``set_priority``/``fetch`` and the simulators' exact
        replay segments)."""
        key = int(key)
        self._ensure(key)
        s = int(priority) + self.epoch
        self.seq += 1
        if not self._live[key]:
            self._live[key] = True
            self.count += 1
        self._score[key] = s
        self._seq[key] = self.seq
        self._sdirty.append((key, s, self.seq))
        if len(self._sdirty) > 64:
            self._consolidate()

    def set_many(self, keys, priorities, only_new: bool = False):
        """Batched insert/refresh: ``score = priority + epoch`` and a fresh
        seq per *occurrence* (duplicates: the last occurrence wins, exactly
        like the sequential loop).  ``only_new=True`` skips keys already
        live (and within-chunk re-occurrences), consuming no seq for them.
        ``priorities`` is a scalar or a per-key array."""
        keys = np.asarray(keys, np.int64).ravel()
        if keys.size == 0:
            return
        self._ensure(int(keys.max()))
        scalar = np.ndim(priorities) == 0
        if not scalar:
            priorities = np.asarray(priorities, np.int64).ravel()[:keys.size]
        owned = False  # the dirty queue must own its key arrays: a caller
        # may reuse/mutate its buffer after we return (mask/fancy indexing
        # below always produces a fresh array, so those paths are owned).
        if only_new:
            alive = self._live[keys]
            keys = keys[~alive]
            if not scalar:
                priorities = priorities[~alive]
            owned = True
            if keys.size > 1:
                u, first = np.unique(keys, return_index=True)
                if u.size < keys.size:
                    sel = np.sort(first)
                    keys = keys[sel]
                    if not scalar:
                        priorities = priorities[sel]
            if keys.size == 0:
                return
        m = keys.size
        if scalar:
            scores = np.full(m, int(priorities) + self.epoch, np.int64)
        else:
            scores = priorities + self.epoch
        seqs = np.arange(self.seq + 1, self.seq + 1 + m, dtype=np.int64)
        self.seq += m
        if only_new:
            self.count += m
        elif m == 1:
            self.count += 0 if self._live[keys[0]] else 1
        else:
            dead = keys[~self._live[keys]]
            if dead.size:  # dedup only the (typically tiny) dead subset
                self.count += (1 if dead.size == 1
                               else int(np.unique(dead).size))
        self._score[keys] = scores
        self._seq[keys] = seqs
        self._live[keys] = True
        if not owned:
            keys = keys.copy()  # dirty parts are re-sorted at consolidation
        self._dirty.append((keys, scores, seqs))
        self._n_dirty += m

    # ---------------- run maintenance ----------------

    def _sorted_run(self, parts) -> _Run:
        """Concatenate (keys, scores, seqs) parts, drop stale entries,
        and lexsort into one run."""
        k = np.concatenate([p[0] for p in parts])
        s = np.concatenate([p[1] for p in parts])
        q = np.concatenate([p[2] for p in parts])
        v = self._valid(k, q)
        k, s, q = k[v], s[v], q[v]
        order = np.lexsort((q, s))
        return _Run(k[order], s[order], q[order])

    def _append_run(self, new: _Run):
        """Append a sorted run, then cascade binary-counter merges: while
        the newest run has grown at least as large as its predecessor,
        the two collapse into one (with stale filtering).  Keeps the run
        count at O(log n) and amortizes every merge to O(log n) per
        entry — a per-chunk append never touches the big runs until
        enough small ones have piled up."""
        self._runs = runs = [r for r in self._runs if len(r)]
        if len(new):
            runs.append(new)
        while len(runs) > 1 and len(runs[-1]) >= len(runs[-2]):
            b, a = runs.pop(), runs.pop()
            merged = self._sorted_run([
                (a.keys[a.head:], a.scores[a.head:], a.seqs[a.head:]),
                (b.keys[b.head:], b.scores[b.head:], b.seqs[b.head:]),
            ])
            if len(merged):
                runs.append(merged)

    def _consolidate(self, scalars: bool = True):
        """Fold pending dirty chunks (and, by default, the scalar
        nursery) into the run hierarchy."""
        if scalars and self._sdirty:
            arr = np.array(self._sdirty, np.int64).reshape(-1, 3)
            self._dirty.append((arr[:, 0], arr[:, 1], arr[:, 2]))
            self._n_dirty += arr.shape[0]
            self._sdirty = []
        if not self._n_dirty:
            return
        parts, self._dirty = self._dirty, []
        self._n_dirty = 0
        self._append_run(self._sorted_run(parts))

    def _peek(self, r: _Run) -> Optional[Tuple[int, int]]:
        """Advance past stale entries; return the head's (score, seq)."""
        k, q = r.keys, r.seqs
        live, dseq = self._live, self._seq
        h, n = r.head, len(k)
        while h < n and not (live[k[h]] and dseq[k[h]] == q[h]):
            h += 1
        r.head = h
        if h >= n:
            return None
        return int(r.scores[h]), int(q[h])

    def _pop_prefix(self, r: _Run, thr: Optional[Tuple[int, int]],
                    cap_n: int,
                    incl_bound: Optional[int] = None,
                    resident_fn=None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Pop up to ``cap_n`` valid entries from ``r`` strictly below
        ``thr`` (a (score, seq) bound; None = unbounded) in one vectorized
        pass.  ``incl_bound`` additionally caps the stretch at entries with
        ``score <= incl_bound`` (inclusive — used by ``admit_interleaved``,
        where the first insert appended during the stretch competes with
        every later pop at exactly that score but a larger seq).

        ``resident_fn`` (keys -> bool mask) mirrors the seed store's
        ``_pick_victim_recmg`` skip-loop: live entries whose key is no
        longer resident are popped *and discarded* on the way to each
        victim — they don't count toward ``cap_n`` and are returned
        separately (third element) so the caller can fix up ``count``.

        Marks everything consumed dead and advances the head (stale
        entries inside the window are skipped forever).  Returns (victim
        keys, victim scores, discarded keys) in pop order."""
        h, k, s, q = r.head, r.keys, r.scores, r.seqs
        n = len(k)
        if thr is None:
            bound = n
        else:
            ts, tq = thr
            lo = h + int(np.searchsorted(s[h:], ts, side="left"))
            span = h + int(np.searchsorted(s[h:], ts, side="right"))
            bound = lo + int(np.searchsorted(q[lo:span], tq, side="left"))
        if incl_bound is not None:
            bound = min(bound, h + int(np.searchsorted(
                s[h:], incl_bound, side="right")))
        if bound <= h:  # caller guarantees head < thr; defensive single pop
            bound = h + 1
        vm = self._valid(k[h:bound], q[h:bound])
        if resident_fn is None:
            res_m = vm
        else:
            res_m = np.zeros(vm.size, bool)
            res_m[vm] = resident_fn(k[h:bound][vm])
        cnt = int(np.count_nonzero(res_m))
        # With a residency filter the stretch must stop AT the cap_n-th
        # victim: stales past it are only discarded en route to a *later*
        # victim (the seed pops them inside _pick_victim_recmg, which is
        # not called again once the batch has all its victims).
        if cnt > cap_n or (cnt == cap_n and resident_fn is not None):
            cut = h + int(np.searchsorted(np.cumsum(res_m), cap_n)) + 1
            vm = vm[: cut - h]
            res_m = res_m[: cut - h]
        else:
            cut = bound
        victims = k[h:cut][res_m]
        vscores = s[h:cut][res_m]
        discard = k[h:cut][vm & ~res_m] if resident_fn is not None else _EMPTY
        self._live[victims] = False
        if discard.size:
            self._live[discard] = False
        r.head = cut
        return victims, vscores, discard

    # ---------------- eviction ----------------

    def pop_min(self) -> Optional[int]:
        """Evict the live (score, seq) minimum; age the epoch up to its
        score (the heap's ``populate`` semantics).  None when empty.
        Scans the scalar nursery in place — the interleaved set/pop
        regime never consolidates."""
        if self._n_dirty:
            self._consolidate(scalars=False)
        best, br = None, None
        for r in self._runs:
            pk = self._peek(r)
            if pk is not None and (best is None or pk < best):
                best, br = pk, r
        sbest, sidx = None, -1
        live, dseq = self._live, self._seq
        for i, (k, s, q) in enumerate(self._sdirty):
            if live[k] and dseq[k] == q and (sbest is None or (s, q) < sbest):
                sbest, sidx = (s, q), i
        if sbest is not None and (best is None or sbest < best):
            key = self._sdirty.pop(sidx)[0]
            score = sbest[0]
        elif br is not None:
            key = int(br.keys[br.head])
            br.head += 1
            score = best[0]
        else:
            return None
        self._live[key] = False
        self.count -= 1
        if score > self.epoch:
            self.epoch = score
        return key

    def pop_min_many(self, n: int) -> List[int]:
        """Evict up to ``n`` victims in vectorized prefix stretches."""
        if n <= 0:
            return []
        if self._n_dirty or self._sdirty:
            self._consolidate()
        out: List[np.ndarray] = []
        got = 0
        while got < n:
            peeks = []
            for r in self._runs:
                pk = self._peek(r)
                if pk is not None:
                    peeks.append((pk, r))
            if not peeks:
                break
            peeks.sort(key=lambda x: x[0])
            br = peeks[0][1]
            thr = peeks[1][0] if len(peeks) > 1 else None
            victims, vscores, _ = self._pop_prefix(br, thr, n - got)
            if victims.size == 0:
                continue
            if int(vscores[-1]) > self.epoch:
                self.epoch = int(vscores[-1])
            out.append(victims)
            got += victims.size
        self.count -= got
        return [int(x) for a in out for x in a]

    def admit_interleaved(self, keys, priority: int, n_no_evict: int,
                          undoable: bool = False, pre_drain: int = 0,
                          resident_fn=None):
        """Replay the tiered store's admission loop in vectorized
        stretches: insert ``keys`` in order at ``priority``; before each
        insert past the first ``n_no_evict``, evict the live (score, seq)
        minimum.  The minimum may be a key inserted earlier in this very
        batch (own-batch eviction): the batch is treated as a third sorted
        run whose scores materialize as the epoch evolves.

        ``pre_drain`` pops that many extra victims *before* the first
        insert — the ``_make_room`` overflow drain when the structure
        holds more entries than its nominal capacity (priority refreshes
        never evict, so replay can run over).

        ``resident_fn`` (keys -> bool mask): live entries that are no
        longer resident in the caller's store are popped-and-discarded on
        the way to each victim, exactly like the seed's
        ``_pick_victim_recmg`` skip-loop (they consume no eviction).

        Returns ``(victims, own, kept)`` — victims in eviction order
        (drained first), ``own[i]`` True where victim ``i`` came from this
        batch, ``kept`` a mask over ``keys`` of the inserts still live at
        the end — plus an opaque undo token when ``undoable=True`` (see
        :meth:`undo`).  Every key must be absent (the store admits only
        non-resident keys); keys must be unique."""
        keys = np.asarray(keys, np.int64).ravel()
        m = keys.size
        pr = int(priority)
        n_no_evict = max(0, min(int(n_no_evict), m))
        need = m - n_no_evict
        if m:
            self._ensure(int(keys.max()))
        if need <= 0:
            self.set_many(keys, pr, only_new=True)
            res = (_EMPTY, _EMPTY_B, np.ones(m, bool))
            return res + (None,) if undoable else res  # token=None: no-op undo
        self._consolidate()
        assert not self._live[keys].any(), \
            "admit_interleaved requires absent keys (engine out of sync)"
        E = self.epoch
        epoch0, seq0, count0 = self.epoch, self.seq, self.count
        self.seq += m
        runs0 = list(self._runs)
        heads0 = [r.head for r in runs0]
        kept = np.ones(m, bool)
        vict_parts: List[np.ndarray] = []
        own_parts: List[np.ndarray] = []
        disc_parts: List[np.ndarray] = []
        drained = 0
        while drained < int(pre_drain):
            peeks = []
            for r in self._runs:
                pk = self._peek(r)
                if pk is not None:
                    peeks.append((pk, r))
            if not peeks:
                break
            peeks.sort(key=lambda x: x[0])
            thr = peeks[1][0] if len(peeks) > 1 else None
            victims, vscores, disc = self._pop_prefix(
                peeks[0][1], thr, int(pre_drain) - drained,
                resident_fn=resident_fn)
            if disc.size:
                disc_parts.append(disc)
            if victims.size == 0:
                continue
            vict_parts.append(victims)
            own_parts.append(np.zeros(victims.size, bool))
            E = max(E, int(vscores[-1]))
            drained += victims.size
        ins_scores = np.empty(m, np.int64)
        ins_scores[:n_no_evict] = pr + E
        n_ins = n_no_evict     # batch inserts materialized so far
        i_head = 0             # head of the own-batch run
        done = 0
        while done < need:
            peeks = []
            for r in self._runs:
                pk = self._peek(r)
                if pk is not None:
                    peeks.append((pk, r))
            peeks.sort(key=lambda x: x[0])
            best, br = peeks[0] if peeks else (None, None)
            second = peeks[1][0] if len(peeks) > 1 else None
            ih = ((int(ins_scores[i_head]), seq0 + 1 + i_head)
                  if i_head < n_ins else None)
            if ih is not None and (best is None or ih < best):
                # Own-batch stretch: inserted entries below the engine's
                # best head get evicted before it (scores ascending, and
                # their seqs are the largest, so ties go to the engine).
                if best is None:
                    hi = n_ins
                else:
                    hi = i_head + int(np.searchsorted(
                        ins_scores[i_head:n_ins], best[0], side="left"))
                c = max(1, min(hi - i_head, need - done))
                new_e = np.maximum(E, ins_scores[i_head:i_head + c])
                vict_parts.append(keys[i_head:i_head + c])
                own_parts.append(np.ones(c, bool))
                kept[i_head:i_head + c] = False
                ins_scores[n_ins:n_ins + c] = pr + new_e
                E = int(new_e[-1])
                i_head += c
                n_ins += c
                done += c
            elif br is not None:
                thr = second if ih is None else (
                    min(second, ih) if second is not None else ih)
                # The first insert appended during this stretch enters at
                # pr + max(E, head score) with the largest seq: engine
                # entries at exactly that score still pop first (smaller
                # seq), anything above waits — hence the inclusive cap.
                victims, vscores, disc = self._pop_prefix(
                    br, thr, need - done, incl_bound=pr + max(E, best[0]),
                    resident_fn=resident_fn)
                if disc.size:
                    disc_parts.append(disc)
                c = victims.size
                if c == 0:
                    continue
                new_e = np.maximum(E, vscores)
                vict_parts.append(victims)
                own_parts.append(np.zeros(c, bool))
                ins_scores[n_ins:n_ins + c] = pr + new_e
                E = int(new_e[-1])
                n_ins += c
                done += c
            else:
                raise RuntimeError(
                    "priority engine exhausted during admission")
        kidx = np.flatnonzero(kept)
        kk = keys[kidx]
        kscores = ins_scores[kidx]
        kseqs = seq0 + 1 + kidx
        self._score[kk] = kscores
        self._seq[kk] = kseqs
        self._live[kk] = True
        victims = np.concatenate(vict_parts) if vict_parts else _EMPTY
        own = np.concatenate(own_parts) if own_parts else _EMPTY_B
        discards = np.concatenate(disc_parts) if disc_parts else _EMPTY
        n_ext = int(np.count_nonzero(~own))
        self.count += int(kk.size) - n_ext - int(discards.size)
        self.epoch = E
        self._append_run(_Run(kk, kscores, kseqs))
        if undoable:
            token = (runs0, heads0, seq0, epoch0,
                     np.concatenate((victims[~own], discards)), kk, count0)
            return victims, own, kept, token
        return victims, own, kept

    def undo(self, token):
        """Revert one ``admit_interleaved(..., undoable=True)`` call.
        Only the admission is reverted; the consolidation it triggered is
        semantically neutral and stays.  Run arrays are immutable (pops
        only advance heads; merges build new runs), so restoring the
        pre-admit run list and head positions is a full rollback."""
        (runs0, heads0, seq0, epoch0, ext, kk, count0) = token
        self._live[kk] = False
        self._live[ext] = True
        for r, h in zip(runs0, heads0):
            r.head = h
        self._runs = runs0
        self.seq = seq0
        self.epoch = epoch0
        self.count = count0
