"""RecMG prefetch model (paper §V-B).

Two seq2seq LSTM stacks + attention (~74K params).  Input: the same access
chunk as the caching model.  Output: a *sequence* of |PO| = 5 predicted
embedding-vector coordinates in the model's dense representation space —
"the encoder/decoder pair naturally generates a dense representation of
embedding vectors in a continuous space" (§V) — which is how RecMG sidesteps
the million-way classification that OOMs Voyager-style one-hot labeling
(§VII-B).

Training: bidirectional Chamfer distance (Eq. 5, alpha=0.7) between the
predicted set PO and the representations of the decoupled evaluation window
W of the next |W| = 3*|PO| accesses.  Target representations are
stop-gradiented (prevents the trivial collapse the paper's reverse term also
guards against); the fixed normalized-index coordinate anchors the space.
At deployment the predicted points snap to the nearest candidate vector by
squared-L2 (a matmul), giving concrete indices to prefetch.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import lstm as LS
from repro.core.chamfer import chamfer_bidirectional_vec, l2_truncated_vec
from repro.core.features import ROW_BUCKETS, WindowData


@dataclass(frozen=True)
class PrefetchModelConfig:
    n_tables: int = 856
    table_emb: int = 8
    row_emb: int = 8
    hidden: int = 40
    in_len: int = 15
    out_len: int = 5  # |PO|
    window: int = 15  # |W| = 3 * |PO| (paper Fig. 12 sensitivity)
    alpha: float = 0.7
    n_stacks: int = 2
    backbone: str = "lstm"  # lstm (RecMG) | transformer (TransFetch-class
    #   baseline: same featurization/loss/decode, transformer encoder —
    #   reproduces the paper's TransFetch comparison incl. CPU cost)
    loss: str = "chamfer"  # chamfer | l2 (ablation baseline)
    norm_weight: float = 4.0  # weight of the fixed index coordinate
    stat_weight: float = 2.0  # weight of the online freq/recency coords
    diversity_weight: float = 0.1  # repulsion between predicted points
    diversity_tau: float = 0.5

    @property
    def rep_dim(self) -> int:
        # Output/decode representation space: stable per-id coordinates only.
        return self.table_emb + 2 * self.row_emb + 1

    @property
    def in_dim(self) -> int:
        # Encoder input: rep coords + online freq/recency.
        return self.rep_dim + 2


def init_prefetch_model(key, cfg: PrefetchModelConfig):
    ks = jax.random.split(key, 12)
    f = cfg.rep_dim
    fin = cfg.in_dim
    H = cfg.hidden
    p = {
        "table_emb": jax.random.normal(ks[0], (cfg.n_tables, cfg.table_emb)) * 0.3,
        "row_emb1": jax.random.normal(ks[1], (ROW_BUCKETS[0], cfg.row_emb)) * 0.3,
        "row_emb2": jax.random.normal(ks[2], (ROW_BUCKETS[1], cfg.row_emb)) * 0.3,
        # Stack 1: encoder/decoder refining the access sequence.
        "enc1": LS.lstm_init(ks[3], fin, H),
        "dec1": LS.lstm_init(ks[4], 2 * H, H),
        "attn1": LS.attn_init(ks[5], H),
        # Output embedding layer (paper Fig. 5b): FC + projection into the
        # representation space.
        "w_fc": jax.random.normal(ks[9], (2 * H, H)) / math.sqrt(2 * H),
        "b_fc": jnp.zeros((H,)),
        "w_proj": jax.random.normal(ks[10], (H, f)) / math.sqrt(H),
        "b_proj": jnp.zeros((f,)),
        "y_in": jax.random.normal(ks[11], (f, 8)) / math.sqrt(f),
    }
    if cfg.backbone == "transformer":
        # TransFetch-class encoder: replace the LSTM stacks with small
        # self-attention blocks over the chunk.
        del p["enc1"], p["dec1"], p["attn1"]
        p["in_proj"] = jax.random.normal(ks[3], (fin, H)) / math.sqrt(fin)
        p["pos_emb"] = jax.random.normal(ks[4], (cfg.in_len, H)) * 0.1
        blocks = []
        for i in range(2):
            kk = jax.random.split(ks[5], 8)[4 * i : 4 * i + 4]
            blocks.append({
                "wq": jax.random.normal(kk[0], (H, H)) / math.sqrt(H),
                "wk": jax.random.normal(kk[1], (H, H)) / math.sqrt(H),
                "wv": jax.random.normal(kk[2], (H, H)) / math.sqrt(H),
                "wo": jax.random.normal(kk[3], (H, H)) / math.sqrt(H),
                "w1": jax.random.normal(kk[0], (H, 2 * H)) / math.sqrt(H),
                "w2": jax.random.normal(kk[1], (2 * H, H)) / math.sqrt(2 * H),
            })
        p["tblocks"] = blocks
    elif cfg.n_stacks >= 2:
        p["enc2"] = LS.lstm_init(ks[6], H, H)
    p["dec2"] = LS.lstm_init(ks[7], 8 + H, H)
    p["attn2"] = LS.attn_init(ks[8], H)
    return p


def _transformer_encode(params, feats):
    """feats: (T, fin) -> hs (T, H) via 2 tiny self-attention blocks."""
    h = feats @ params["in_proj"] + params["pos_emb"][: feats.shape[0]]
    for blk in params["tblocks"]:
        q = h @ blk["wq"]
        k = h @ blk["wk"]
        v = h @ blk["wv"]
        s = q @ k.T / math.sqrt(q.shape[-1])
        h = h + jax.nn.softmax(s, axis=-1) @ v @ blk["wo"]
        h = h + jax.nn.gelu(h @ blk["w1"]) @ blk["w2"]
    return h


def access_reps(params, cfg: PrefetchModelConfig, xt, xr1, xr2, xn):
    """Stable representation-space coordinates of vector ids.
    (..., T) ints -> (..., T, F).  This is the space Chamfer compares in and
    nearest-neighbor decode searches in."""
    return jnp.concatenate(
        [
            params["table_emb"][xt],
            params["row_emb1"][xr1],
            params["row_emb2"][xr2],
            (xn * cfg.norm_weight)[..., None],
        ],
        axis=-1,
    )


def input_feats(params, cfg: PrefetchModelConfig, xt, xr1, xr2, xn, xf, xrc):
    """Encoder inputs: rep coords + online freq/recency scalars."""
    reps = access_reps(params, cfg, xt, xr1, xr2, xn)
    return jnp.concatenate(
        [reps, (xf * cfg.stat_weight)[..., None],
         (xrc * cfg.stat_weight)[..., None]], axis=-1,
    )


def prefetch_predict(params, cfg: PrefetchModelConfig, xt, xr1, xr2, xn, xf, xrc):
    """One window -> (out_len, F) predicted representation points."""
    feats = input_feats(params, cfg, xt, xr1, xr2, xn, xf, xrc)
    if cfg.backbone == "transformer":
        hs2 = _transformer_encode(params, feats)
        h = hs2[-1]
        c = jnp.zeros_like(h)
    else:
        hs1, (h, c) = LS.lstm_seq(params["enc1"], feats)

        def dec1_step(carry, enc_h):
            (h, c) = carry
            ctx = LS.attend(params["attn1"], h, hs1)
            (h, c), out = LS.lstm_step(params["dec1"], (h, c),
                                       jnp.concatenate([enc_h, ctx]))
            return (h, c), out

        (h, c), ds1 = lax.scan(dec1_step, (h, c), hs1)

        if "enc2" in params:
            hs2, (h, c) = LS.lstm_seq(params["enc2"], ds1)
        else:
            hs2 = ds1

    f = cfg.rep_dim

    def dec2_step(carry, _):
        (h, c), prev = carry
        ctx = LS.attend(params["attn2"], h, hs2)
        x = jnp.concatenate([prev @ params["y_in"], ctx])
        (h, c), _ = LS.lstm_step(params["dec2"], (h, c), x)
        feat = jnp.tanh(jnp.concatenate([h, ctx]) @ params["w_fc"] + params["b_fc"])
        y = feat @ params["w_proj"] + params["b_proj"]
        return ((h, c), y), y

    (_, _), ys = lax.scan(dec2_step, ((h, c), jnp.zeros((f,))),
                          None, length=cfg.out_len)
    return ys  # (out_len, F)


def prefetch_predict_batch(params, cfg, xt, xr1, xr2, xn, xf, xrc):
    return jax.vmap(
        lambda a, b, c_, d, e, f: prefetch_predict(params, cfg, a, b, c_, d, e, f)
    )(xt, xr1, xr2, xn, xf, xrc)


def prefetch_loss(params, cfg: PrefetchModelConfig, batch):
    po = prefetch_predict_batch(
        params, cfg, batch["xt"], batch["xr1"], batch["xr2"], batch["xn"],
        batch["xf"], batch["xrc"]
    )  # (B, P, F)
    wlen = cfg.window if cfg.loss == "chamfer" else cfg.out_len
    w = jax.lax.stop_gradient(
        access_reps(params, cfg, batch["wt"][:, :wlen], batch["wr1"][:, :wlen],
                    batch["wr2"][:, :wlen], batch["wn"][:, :wlen])
    )  # (B, W, F)
    if cfg.loss == "l2":
        return l2_truncated_vec(po, w).mean()
    loss = chamfer_bidirectional_vec(po, w, cfg.alpha).mean()
    if cfg.diversity_weight:
        # Repulsion between predicted points: counters the duplicate-output
        # collapse the paper's reverse Chamfer term fights (§V-B).
        d = po[:, :, None, :] - po[:, None, :, :]
        d2 = (d * d).sum(-1)
        P = po.shape[1]
        off = 1.0 - jnp.eye(P)
        rep = (jnp.exp(-d2 / cfg.diversity_tau) * off).sum(-1).sum(-1) / (P * (P - 1))
        loss = loss + cfg.diversity_weight * rep.mean()
    return loss


@partial(jax.jit, static_argnums=(3, 4))
def _train_step(params, opt, batch, cfg, opt_cfg):
    from repro.optim.adamw import apply_updates

    loss, grads = jax.value_and_grad(
        lambda p: prefetch_loss(p, cfg, batch)
    )(params)
    params, opt, _ = apply_updates(opt_cfg, params, opt, grads)
    return params, opt, loss


def window_int_features(trace, starts, wlen, stats=None):
    """Raw int features of the future window for target representations."""
    from repro.core.features import _stack_windows, access_stats

    row = trace.row_id
    freq, rec = stats if stats is not None else access_stats(trace.global_id)
    return {
        "wt": _stack_windows(trace.table_id.astype(np.int32), starts, wlen),
        "wr1": _stack_windows((row % ROW_BUCKETS[0]).astype(np.int32), starts, wlen),
        "wr2": _stack_windows(((row // ROW_BUCKETS[0]) % ROW_BUCKETS[1]).astype(np.int32),
                              starts, wlen),
        "wn": _stack_windows(
            (trace.global_id / max(trace.n_vectors, 1)).astype(np.float32),
            starts, wlen),
        "wf": _stack_windows(freq, starts, wlen),
        "wrc": _stack_windows(rec, starts, wlen),
    }


@dataclass
class PrefetchData:
    """WindowData + raw int features of each future window."""

    base: WindowData
    w_feats: Dict[str, np.ndarray]

    def __len__(self):
        return len(self.base)

    def batch_dict(self, idx) -> Dict[str, jnp.ndarray]:
        b = self.base.batch(idx)
        d = {
            "xt": jnp.asarray(b.x_table), "xr1": jnp.asarray(b.x_row1),
            "xr2": jnp.asarray(b.x_row2), "xn": jnp.asarray(b.x_norm),
            "xf": jnp.asarray(b.x_freq), "xrc": jnp.asarray(b.x_rec),
        }
        for k, v in self.w_feats.items():
            d[k] = jnp.asarray(v[idx])
        return d


def make_prefetch_data(trace, in_len=15, window=15, stride=5,
                       miss_mask: Optional[np.ndarray] = None) -> PrefetchData:
    """miss_mask: per-access OPT-miss bits — when given, the ground-truth
    window W is the next `window` *missing* accesses (the paper's prefetch
    trace: "embedding vectors leading to cache misses", §VI-A)."""
    from repro.core.features import access_stats, make_windows

    stats = access_stats(trace.global_id)
    base = make_windows(trace, in_len=in_len, out_window=window, stride=stride,
                        stats=stats)
    starts = np.arange(in_len, len(trace) - window - 1, stride,
                       dtype=np.int64)[: len(base)]
    if miss_mask is None:
        return PrefetchData(base, window_int_features(trace, starts, window, stats))

    # Gather the first `window` miss positions at/after each start.
    mpos = np.nonzero(miss_mask)[0]
    j = np.searchsorted(mpos, starts)
    keep = j < max(len(mpos) - window, 1)  # aligned with base rows
    j = j[keep]
    idx = np.minimum(j[:, None] + np.arange(window)[None, :], len(mpos) - 1)
    flat = mpos[idx]  # (N, window) absolute access positions of misses

    row = trace.row_id
    gid = trace.global_id
    freq, rec = stats
    w_feats = {
        "wt": trace.table_id.astype(np.int32)[flat],
        "wr1": (row % ROW_BUCKETS[0]).astype(np.int32)[flat],
        "wr2": ((row // ROW_BUCKETS[0]) % ROW_BUCKETS[1]).astype(np.int32)[flat],
        "wn": (gid / max(trace.n_vectors, 1)).astype(np.float32)[flat],
        "wf": freq[flat],
        "wrc": rec[flat],
    }
    base = base.batch(np.nonzero(keep)[0])
    return PrefetchData(base, w_feats)


def train_prefetch_model(data: PrefetchData, cfg: PrefetchModelConfig,
                         epochs: int = 3, batch_size: int = 256,
                         lr: float = 3e-3, seed: int = 0, log=None):
    from repro.optim.adamw import OptConfig, init_opt

    params = init_prefetch_model(jax.random.PRNGKey(seed), cfg)
    steps_per_epoch = max(1, len(data) // batch_size)
    total = max(2, epochs * steps_per_epoch)
    opt_cfg = OptConfig(lr=lr, weight_decay=0.0,
                        warmup_steps=max(1, min(50, total // 10)),
                        total_steps=total)
    opt = init_opt(opt_cfg, params)
    rng = np.random.default_rng(seed)
    losses = []
    for ep in range(epochs):
        idx = rng.permutation(len(data))
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            batch = data.batch_dict(idx[i : i + batch_size])
            params, opt, loss = _train_step(params, opt, batch, cfg, opt_cfg)
            losses.append(float(loss))
        if log:
            log(f"prefetch epoch {ep}: loss {np.mean(losses[-50:]):.5f}")
    return params, losses


# ---------------------------------------------------------------------------
# Deployment: snap predicted points to real vector ids + quality metrics
# ---------------------------------------------------------------------------


def candidate_reps(params, cfg: PrefetchModelConfig, cand_ids: np.ndarray,
                   trace) -> jnp.ndarray:
    """Representation matrix of candidate vector ids.  (C, F)."""
    offs = trace.table_offsets
    t = np.searchsorted(offs, cand_ids, side="right") - 1
    row = cand_ids - offs[t]
    xn = cand_ids / max(trace.n_vectors, 1)
    return access_reps(
        params, cfg, jnp.asarray(t.astype(np.int32)),
        jnp.asarray((row % ROW_BUCKETS[0]).astype(np.int32)),
        jnp.asarray(((row // ROW_BUCKETS[0]) % ROW_BUCKETS[1]).astype(np.int32)),
        jnp.asarray(xn.astype(np.float32)),
    )


@jax.jit
def _nn_decode(points, cand):
    """points: (N, F), cand: (C, F) -> (N,) argmin squared-L2 (via matmul)."""
    p2 = (points * points).sum(-1, keepdims=True)
    c2 = (cand * cand).sum(-1)
    d = p2 + c2[None, :] - 2.0 * points @ cand.T
    return jnp.argmin(d, axis=1)


def decode_to_ids(params, cfg: PrefetchModelConfig, po_points: np.ndarray,
                  cand_ids: np.ndarray, trace,
                  chunk: int = 65536) -> np.ndarray:
    """po_points: (N, P, F) -> (N, P) vector ids (nearest candidate)."""
    cand = candidate_reps(params, cfg, cand_ids, trace)
    flat = po_points.reshape(-1, po_points.shape[-1])
    outs = []
    for i in range(0, len(flat), chunk):
        idx = _nn_decode(jnp.asarray(flat[i : i + chunk]), cand)
        outs.append(np.asarray(idx))
    nn = np.concatenate(outs)
    return cand_ids[nn].reshape(po_points.shape[:-1])


def predict_sequences(params, cfg: PrefetchModelConfig, data,
                      batch_size: int = 4096) -> np.ndarray:
    """(N, P, F) predicted representation points for every window."""
    base = data.base if isinstance(data, PrefetchData) else data
    outs = []
    for i in range(0, len(base), batch_size):
        b = base.batch(np.arange(i, min(i + batch_size, len(base))))
        po = prefetch_predict_batch(
            params, cfg, jnp.asarray(b.x_table), jnp.asarray(b.x_row1),
            jnp.asarray(b.x_row2), jnp.asarray(b.x_norm),
            jnp.asarray(b.x_freq), jnp.asarray(b.x_rec)
        )
        outs.append(np.asarray(po))
    return np.concatenate(outs, axis=0)


def sequence_metrics(po_ids: np.ndarray, gt_windows: np.ndarray) -> dict:
    """Correctness (frac of PO appearing in the window) + coverage (Eq. 2)."""
    correct = 0
    covered = 0
    gt_unique_total = 0
    for po, w in zip(po_ids, gt_windows):
        ws = set(int(x) for x in w)
        correct += sum(int(p) in ws for p in po)
        covered += len(set(int(p) for p in po) & ws)
        gt_unique_total += len(ws)
    return {
        "correctness": correct / max(po_ids.size, 1),
        "coverage": covered / max(gt_unique_total, 1),
    }
