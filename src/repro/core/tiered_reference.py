"""Per-key reference implementation of the tiered embedding store.

This is the original (seed) ``TieredEmbeddingStore``: residency tracked in a
Python dict, LRU order in an ``OrderedDict``, admission/eviction/prefetch all
driven by per-key Python loops.  It is kept verbatim for two jobs:

1. **Equivalence oracle** — ``tests/test_tiered_equivalence.py`` replays the
   same trace through this class and the batched engine in
   :mod:`repro.core.tiered` and asserts identical hit/miss/on-demand/prefetch
   counters and identical returned rows.
2. **Speedup baseline** — ``benchmarks/bench_e2e.py`` measures batched lookup
   throughput against this implementation (the acceptance bar is >= 3x at
   batch >= 1024 under LRU).

Do not optimise this file; its value is that it stays slow and obviously
correct.  New behavior belongs in :mod:`repro.core.tiered`.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

# The reference store pairs with the *reference* (heap) buffer manager so
# the oracle chain stays fully independent of the array-backed engine.
from repro.core.buffer_manager_reference import RecMGBuffer
from repro.core.tiered import TierStats


class ReferenceTieredStore:
    """Host table (N, D) + device buffer (C, D), per-key bookkeeping."""

    def __init__(self, host_table: np.ndarray, capacity: int,
                 policy: str = "lru", eviction_speed: int = 4,
                 fetch_us_per_row: float = 10.0, fetch_us_fixed: float = 30.0,
                 quantize: bool = False):
        self.host = host_table
        n, d = host_table.shape
        self.capacity = int(capacity)
        self.quantize = quantize
        if quantize:
            self.buffer = jnp.zeros((self.capacity, d), jnp.int8)
            self.scales = jnp.zeros((self.capacity,), jnp.float32)
        else:
            self.buffer = jnp.zeros((self.capacity, d), host_table.dtype)
        self.slot_of: Dict[int, int] = {}
        self.free: List[int] = list(range(self.capacity - 1, -1, -1))
        self.policy = policy
        self.lru: "OrderedDict[int, bool]" = OrderedDict()
        self.recmg = RecMGBuffer(1 << 40, eviction_speed)
        self.prefetched: set = set()
        self.fetch_us_per_row = fetch_us_per_row
        self.fetch_us_fixed = fetch_us_fixed
        self.stats = TierStats()
        if quantize:
            self._gather = jax.jit(
                lambda buf, sc, idx: buf[idx].astype(jnp.float32)
                * sc[idx][:, None]
            )
        else:
            self._gather = jax.jit(lambda buf, idx: buf[idx])
        self._scatter = jax.jit(
            lambda buf, idx, rows: buf.at[idx].set(rows),
            donate_argnums=(0,),
        )
        self._scatter_sc = jax.jit(
            lambda sc, idx, s: sc.at[idx].set(s), donate_argnums=(0,)
        )

    def _write_rows(self, slots: np.ndarray, rows: np.ndarray):
        if self.quantize:
            scale = np.abs(rows).max(axis=1) / 127.0 + 1e-12
            q = np.clip(np.round(rows / scale[:, None]), -127, 127)
            self.buffer = self._scatter(
                self.buffer, jnp.asarray(slots), jnp.asarray(q, jnp.int8))
            self.scales = self._scatter_sc(
                self.scales, jnp.asarray(slots),
                jnp.asarray(scale, jnp.float32))
        else:
            self.buffer = self._scatter(
                self.buffer, jnp.asarray(slots), jnp.asarray(rows))

    # ---------------- policy plumbing ----------------

    def _evict_one(self) -> int:
        if self.policy == "recmg":
            victim = self.recmg.populate()
            while victim is not None and victim not in self.slot_of:
                victim = self.recmg.populate()  # stale non-resident entry
            if victim is None:  # priorities exhausted: fall back to any slot
                victim = next(iter(self.slot_of))
        else:
            victim, _ = self.lru.popitem(last=False)
        slot = self.slot_of.pop(victim)
        self.prefetched.discard(victim)
        self.stats.evictions += 1
        return slot

    def _touch(self, key: int):
        if self.policy == "lru" and key in self.lru:
            self.lru.move_to_end(key)

    def _admit(self, keys: List[int]) -> np.ndarray:
        """Assign slots for missing keys (evicting as needed)."""
        slots = np.empty(len(keys), dtype=np.int32)
        for i, k in enumerate(keys):
            if not self.free:
                self.free.append(self._evict_one())
            slot = self.free.pop()
            self.slot_of[k] = slot
            slots[i] = slot
            if self.policy == "recmg":
                if not self.recmg.contains(k):
                    self.recmg.set_priority(k, self.recmg.ev)
            else:
                self.lru[k] = True
        return slots

    # ---------------- main path ----------------

    def lookup(self, ids: np.ndarray) -> jnp.ndarray:
        """ids: (M,) int64 -> (M, D) embeddings from the fast tier,
        fetching misses on demand."""
        self.stats.batches += 1
        self.stats.lookups += len(ids)
        uniq, inv = np.unique(ids, return_inverse=True)
        missing = [int(k) for k in uniq if int(k) not in self.slot_of]
        n_hit = len(ids) - sum(
            1 for k in ids if int(k) in missing_set
        ) if (missing_set := set(missing)) else len(ids)
        self.stats.hits += n_hit
        self.stats.misses += len(ids) - n_hit
        for k in ids:
            k = int(k)
            if k in self.prefetched and k not in missing_set:
                self.stats.prefetch_hits += 1
                self.prefetched.discard(k)

        if missing:
            t0 = time.perf_counter()
            rows = self.host[np.asarray(missing)]
            slots = self._admit(missing)
            self._write_rows(slots, rows)
            jax.block_until_ready(self.buffer)
            self.stats.fetch_s += time.perf_counter() - t0
            self.stats.on_demand_rows += len(missing)
            self.stats.modeled_fetch_s += (
                self.fetch_us_fixed + self.fetch_us_per_row * len(missing)
            ) * 1e-6
        for k in uniq:
            k = int(k)
            if k in self.slot_of:
                self._touch(k)

        t0 = time.perf_counter()
        slot_arr = np.asarray(
            [self.slot_of.get(int(k), -1) for k in uniq], np.int32
        )
        gather_args = (
            (self.buffer, self.scales) if self.quantize else (self.buffer,)
        )
        out = np.array(self._gather(*gather_args, jnp.asarray(
            np.maximum(slot_arr, 0))))
        overflow = slot_arr < 0
        if overflow.any():
            out[overflow] = self.host[uniq[overflow]]
        out = jnp.asarray(out[inv])
        jax.block_until_ready(out)
        self.stats.gather_s += time.perf_counter() - t0
        return out

    # ---------------- RecMG co-management hooks ----------------

    def apply_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Algorithm 1, invoked between batches (pipelined)."""
        if self.policy != "recmg":
            pf = [int(p) for p in prefetch_ids if int(p) not in self.slot_of]
            if pf:
                self._fetch_prefetch(pf)
            return
        t0 = time.perf_counter()
        pairs = [(int(k), int(b)) for k, b in zip(trunk, bits)
                 if int(k) in self.slot_of]
        self.recmg.load_embeddings(
            [k for k, _ in pairs], [b for _, b in pairs], []
        )
        pf = [int(p) for p in prefetch_ids if int(p) not in self.slot_of]
        if pf:
            self._fetch_prefetch(pf)
            for p in pf:
                self.recmg.set_priority(p, self.recmg.ev)
        self.stats.model_s += time.perf_counter() - t0

    def _fetch_prefetch(self, keys: List[int]):
        rows = self.host[np.asarray(keys)]
        slots = self._admit(keys)
        self._write_rows(slots, rows)
        for k in keys:
            # Only keys still resident get the mark: at capacity ~ 1 a
            # later key of the same prefetch batch can evict an earlier
            # one mid-`_admit`, and marking the evicted key would leak a
            # phantom prefetch attribution onto its next residency (the
            # batched store's per-slot ``_pf_flag`` can't leak this way —
            # eviction clears the slot's flag by construction).
            if k in self.slot_of:
                self.prefetched.add(k)

    def modeled_batch_ms(self) -> float:
        """Analytic per-batch latency contribution of the slow tier."""
        return 1e3 * self.stats.modeled_fetch_s / max(self.stats.batches, 1)
