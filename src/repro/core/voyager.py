"""Voyager-style hierarchical classification prefetcher [71] — the paper's
other ML baseline, implemented to *demonstrate its scaling failure* on
embedding traces (paper §VII-B: one-hot labeling over millions of vectors
OOMs even on a 512GB host).

Voyager decomposes an address into (page, offset) and predicts each with a
softmax.  Mapped to embedding ids: page = gid // page_size, offset =
gid % page_size.  The output layers are (hidden x n_pages) and (hidden x
page_size): at production scale (62M vectors / 256 = 242K pages) the page
softmax alone is ~10M params and the training labels are one-hot over it —
`label_memory_bytes` quantifies the blow-up the paper reports.  At bench
scale it trains fine, which lets us also reproduce the cost comparison.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lstm as LS
from repro.core.features import ROW_BUCKETS, WindowData


@dataclass(frozen=True)
class VoyagerConfig:
    n_vectors: int = 480_000
    page_size: int = 256
    hidden: int = 40
    in_len: int = 15
    table_emb: int = 8
    row_emb: int = 8

    @property
    def n_pages(self) -> int:
        return (self.n_vectors + self.page_size - 1) // self.page_size


def label_memory_bytes(cfg: VoyagerConfig, n_samples: int,
                       one_hot: bool = True) -> int:
    """Training-label footprint — the quantity that OOMs at paper scale.

    Voyager's formulation stores one-hot page labels; 62M vectors ->
    242K-way one-hot per sample: 400M samples x 242K x 1B ~ 10^16 bytes.
    """
    per = cfg.n_pages + cfg.page_size if one_hot else 8
    return n_samples * per


def init_voyager(key, cfg: VoyagerConfig, n_tables: int):
    ks = jax.random.split(key, 8)
    f = cfg.table_emb + 2 * cfg.row_emb + 1
    H = cfg.hidden
    return {
        "table_emb": jax.random.normal(ks[0], (n_tables, cfg.table_emb)) * 0.1,
        "row_emb1": jax.random.normal(ks[1], (ROW_BUCKETS[0], cfg.row_emb)) * 0.1,
        "row_emb2": jax.random.normal(ks[2], (ROW_BUCKETS[1], cfg.row_emb)) * 0.1,
        "enc": LS.lstm_init(ks[3], f, H),
        # The two classification heads — the scaling bottleneck.
        "w_page": jax.random.normal(ks[4], (H, cfg.n_pages)) / math.sqrt(H),
        "w_off": jax.random.normal(ks[5], (H, cfg.page_size)) / math.sqrt(H),
    }


def _encode(params, cfg, xt, xr1, xr2, xn):
    feats = jnp.concatenate(
        [params["table_emb"][xt], params["row_emb1"][xr1],
         params["row_emb2"][xr2], xn[:, None]], axis=-1)
    _, (h, _) = LS.lstm_seq(params["enc"], feats)
    return h


def voyager_logits(params, cfg: VoyagerConfig, xt, xr1, xr2, xn):
    h = _encode(params, cfg, xt, xr1, xr2, xn)
    return h @ params["w_page"], h @ params["w_off"]


voyager_logits_batch = jax.vmap(voyager_logits,
                                in_axes=(None, None, 0, 0, 0, 0))


def voyager_loss(params, cfg: VoyagerConfig, batch):
    pl_, ol = voyager_logits_batch(
        params, cfg, batch["xt"], batch["xr1"], batch["xr2"], batch["xn"])
    lp = jax.nn.log_softmax(pl_, axis=-1)
    lo = jax.nn.log_softmax(ol, axis=-1)
    npage = jnp.take_along_axis(lp, batch["page"][:, None], 1)[:, 0]
    noff = jnp.take_along_axis(lo, batch["off"][:, None], 1)[:, 0]
    return -(npage + noff).mean()


@partial(jax.jit, static_argnums=(3, 4))
def _train_step(params, opt, batch, cfg, opt_cfg):
    from repro.optim.adamw import apply_updates

    loss, grads = jax.value_and_grad(
        lambda p: voyager_loss(p, cfg, batch))(params)
    params, opt, _ = apply_updates(opt_cfg, params, opt, grads)
    return params, opt, loss


def train_voyager(data: WindowData, cfg: VoyagerConfig, n_tables: int,
                  epochs: int = 3, batch_size: int = 512, lr: float = 5e-3,
                  seed: int = 0):
    """Targets: the NEXT access's (page, offset) after each window."""
    from repro.optim.adamw import OptConfig, init_opt

    params = init_voyager(jax.random.PRNGKey(seed), cfg, n_tables)
    total = max(2, epochs * (len(data) // batch_size))
    opt_cfg = OptConfig(lr=lr, weight_decay=0.0,
                        warmup_steps=max(1, min(50, total // 10)),
                        total_steps=total)
    opt = init_opt(opt_cfg, params)
    gid_next = np.round(data.y_window[:, 0] * cfg.n_vectors).astype(np.int64)
    pages = (gid_next // cfg.page_size).astype(np.int32)
    offs = (gid_next % cfg.page_size).astype(np.int32)
    rng = np.random.default_rng(seed)
    losses = []
    for _ in range(epochs):
        idx = rng.permutation(len(data))
        for i in range(0, len(idx) - batch_size + 1, batch_size):
            b = data.batch(idx[i : i + batch_size])
            batch = {
                "xt": jnp.asarray(b.x_table), "xr1": jnp.asarray(b.x_row1),
                "xr2": jnp.asarray(b.x_row2), "xn": jnp.asarray(b.x_norm),
                "page": jnp.asarray(pages[idx[i : i + batch_size]]),
                "off": jnp.asarray(offs[idx[i : i + batch_size]]),
            }
            params, opt, loss = _train_step(params, opt, batch, cfg, opt_cfg)
            losses.append(float(loss))
    return params, losses


def predict_next(params, cfg: VoyagerConfig, data: WindowData,
                 batch_size: int = 4096) -> np.ndarray:
    """Top-1 predicted next vector id per window."""
    outs = []
    for i in range(0, len(data), batch_size):
        b = data.batch(np.arange(i, min(i + batch_size, len(data))))
        pl_, ol = voyager_logits_batch(
            params, cfg, jnp.asarray(b.x_table), jnp.asarray(b.x_row1),
            jnp.asarray(b.x_row2), jnp.asarray(b.x_norm))
        page = np.asarray(jnp.argmax(pl_, -1))
        off = np.asarray(jnp.argmax(ol, -1))
        outs.append(page.astype(np.int64) * cfg.page_size + off)
    return np.concatenate(outs)
