"""Learned RecMG serving runtime: the trained dual models on the hot path.

This closes ROADMAP item 1: the caching + prefetch models trained with the
paper's differentiable losses (``bce_loss`` against Belady keep bits,
``prefetch_loss`` — bidirectional Chamfer in the learned representation
space) drive live serving instead of the frequency-heuristic stand-in.

Three pieces:

* :class:`LearnedRecMGModel` — owns both trained models and the candidate
  pool.  ``train_from_trace`` is the compact entry point (same internals as
  ``examples/train_recmg_models.py``: Belady ground truth on a trace
  prefix, window featurization, both training loops).  Inference runs
  through jitted **shape-bucketed** batched calls: batches are padded to
  the next power of two so XLA compiles one kernel per bucket instead of
  one per ragged length.  Padding is row-wise invariant for both models
  (the vmapped forward has no cross-row ops), so within a bucket the
  padded rows are bit-invisible; across buckets XLA's per-shape
  compilation drifts the raw floats at rounding level (~1e-7) but the
  serving-visible decisions — keep bits and decoded prefetch ids — are
  identical to per-window calls.  Both halves of that contract are
  pinned by ``tests/test_model_runtime.py``.
* :class:`LearnedController` — the adaptation loop.  Wraps the PR-5
  :class:`~repro.runtime.drift.AdaptiveController` (same ``BatchHook``
  signature, so both serving paths wire it unchanged); on every drift
  refresh it additionally fine-tunes the caching model on the live access
  window (bounded jitted steps, persistent optimizer state), refreshes the
  prefetch candidate pool from the same window, and recomputes the model
  outputs for the rest of the trace.  Everything is seeded and clock-free,
  so adaptive serving stays deterministic under ``VirtualClock``.
* :func:`voyager_outputs` — the Voyager-class ML-prefetcher baseline as a
  serving arm (LRU store + top-``out_len`` predicted prefetches per chunk),
  the comparator for the paper's headline 1.5x on-demand reduction
  (``recmg_vs_voyager_on_demand_ratio`` in ``benchmarks/bench_e2e.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.belady import belady_labels
from repro.core.cache_sim import top_ids_by_count
from repro.core.caching_model import (CachingModelConfig,
                                      caching_logits_batch,
                                      train_caching_model)
from repro.core.caching_model import _train_step as _caching_train_step
from repro.core.features import WindowData, make_windows
from repro.core.prefetch_model import (PrefetchModelConfig, _nn_decode,
                                       candidate_reps, make_prefetch_data,
                                       prefetch_predict_batch,
                                       train_prefetch_model)
from repro.core.recmg import RecMGOutputs
from repro.core.trace import Trace
from repro.obs.tracing import get_tracer
from repro.optim.adamw import OptConfig, init_opt
from repro.runtime.drift import AdaptiveController, DriftConfig

_EMPTY = np.empty(0, np.int64)


@dataclass(frozen=True)
class LearnedModelConfig:
    """Training + inference + online-finetune knobs for the learned policy.

    The defaults are tuned for the scenario-matrix scale (a few thousand
    vectors, ~8K accesses): small hidden size, many epochs over densely
    strided windows, candidate pool = the buffer capacity's hottest ids.
    At this setting the learned policy beats the frequency heuristic on
    on-demand fetches on every paper-target scenario (pinned by
    ``tests/test_scenario_matrix.py``)."""

    hidden: int = 32
    in_len: int = 15
    out_len: int = 5
    caching_epochs: int = 30
    prefetch_epochs: int = 15
    batch_size: int = 128
    lr: float = 1e-2
    train_stride: int = 2     # window stride over the training prefix
    seed: int = 0
    n_candidates: int = 0     # prefetch candidate pool size; 0 -> capacity
    infer_batch: int = 4096   # largest inference bucket
    # Online fine-tune (per drift refresh): bounded, seeded, jitted.
    finetune_steps: int = 8
    finetune_batch: int = 64
    finetune_lr: float = 2e-3
    finetune_stride: int = 4


def _bucket(n: int) -> int:
    """Next power of two >= n (the shape bucket a batch of n rows pads to)."""
    return 1 << max(0, int(n) - 1).bit_length()


def _pad_rows(a: np.ndarray, m: int) -> np.ndarray:
    """Pad axis 0 to m rows by repeating the last row (values are dropped
    after inference; repetition keeps every dtype/embedding index valid)."""
    if len(a) == m:
        return a
    return np.concatenate([a, np.repeat(a[-1:], m - len(a), axis=0)])


@jax.jit
def _caching_logits_jit(params, xt, xr1, xr2, xn, xf, xrc):
    return caching_logits_batch(params, xt, xr1, xr2, xn, xf, xrc)


@partial(jax.jit, static_argnums=(1,))
def _prefetch_points_jit(params, cfg, xt, xr1, xr2, xn, xf, xrc):
    return prefetch_predict_batch(params, cfg, xt, xr1, xr2, xn, xf, xrc)


class LearnedRecMGModel:
    """The trained caching + prefetch models behind one serving interface.

    ``predict_bits`` / ``predict_points`` / ``decode_points`` run jitted
    shape-bucketed batched inference; ``outputs_for`` packages a whole
    trace's chunk grid into :class:`RecMGOutputs` (the same grid
    ``frequency_outputs`` uses, so the serving loops are interchangeable);
    ``finetune`` takes one bounded online training pass on a live access
    window (the drift-adaptation hook)."""

    def __init__(self, cfg: LearnedModelConfig, mcfg: CachingModelConfig,
                 pcfg: PrefetchModelConfig, cparams, pparams,
                 cand_ids: np.ndarray, capacity: int, geom: Trace,
                 caching_losses=None, prefetch_losses=None):
        self.cfg = cfg
        self.mcfg = mcfg
        self.pcfg = pcfg
        self.cparams = cparams
        self.pparams = pparams
        self.cand_ids = np.asarray(cand_ids, np.int64)
        self.capacity = int(capacity)
        # Table geometry reference (table_offsets / rows_per_table /
        # n_vectors) for candidate featurization and window re-derivation.
        self.geom = geom
        self.caching_losses = list(caching_losses or [])
        self.prefetch_losses = list(prefetch_losses or [])
        # ---- online-finetune state + telemetry ----
        self._ft_opt = None
        self._ft_opt_cfg = None
        self.finetunes = 0
        self.finetune_steps_run = 0

    # ------------------------------------------------------------------
    # Training
    # ------------------------------------------------------------------

    @classmethod
    def train_from_trace(cls, trace: Trace, capacity: int,
                         cfg: Optional[LearnedModelConfig] = None, *,
                         profile_upto: Optional[int] = None,
                         log=None) -> "LearnedRecMGModel":
        """Train both models on a trace prefix (the paper's §VI-A offline
        workflow in one call): Belady keep bits on the prefix label the
        caching model, the prefix's future windows supervise the prefetch
        model, and the prefix's ``capacity`` hottest ids seed the prefetch
        candidate pool.  ``profile_upto`` freezes training on a prefix —
        the drift experiments' phase-1-only model."""
        cfg = cfg or LearnedModelConfig()
        prefix = (trace if profile_upto is None
                  else trace.slice(0, int(profile_upto)))
        capacity = max(1, int(capacity))
        labels, _, _ = belady_labels(prefix.global_id, capacity)
        mcfg = CachingModelConfig(n_tables=trace.n_tables, hidden=cfg.hidden,
                                  in_len=cfg.in_len)
        data = make_windows(prefix, in_len=cfg.in_len, labels=labels,
                            stride=cfg.train_stride)
        cparams, closs = train_caching_model(
            data, mcfg, epochs=cfg.caching_epochs, batch_size=cfg.batch_size,
            lr=cfg.lr, seed=cfg.seed, log=log)
        pcfg = PrefetchModelConfig(n_tables=trace.n_tables, hidden=cfg.hidden,
                                   in_len=cfg.in_len, out_len=cfg.out_len)
        pdata = make_prefetch_data(prefix, in_len=cfg.in_len,
                                   stride=cfg.train_stride)
        pparams, ploss = train_prefetch_model(
            pdata, pcfg, epochs=cfg.prefetch_epochs,
            batch_size=cfg.batch_size, lr=cfg.lr, seed=cfg.seed, log=log)
        n_cand = cfg.n_candidates or capacity
        cand = np.sort(top_ids_by_count(prefix.global_id, max(1, n_cand)))
        return cls(cfg, mcfg, pcfg, cparams, pparams, cand, capacity, trace,
                   closs, ploss)

    # ------------------------------------------------------------------
    # Jitted shape-bucketed inference
    # ------------------------------------------------------------------

    def _slices(self, n: int):
        for i in range(0, n, self.cfg.infer_batch):
            yield i, min(i + self.cfg.infer_batch, n)

    @staticmethod
    def _feed(b: WindowData, m: int):
        return [jnp.asarray(_pad_rows(np.asarray(a), m)) for a in
                (b.x_table, b.x_row1, b.x_row2, b.x_norm, b.x_freq, b.x_rec)]

    def predict_bits(self, data: WindowData) -> np.ndarray:
        """Keep-bits for every window: jitted, bucketed.  (N, in_len) bool."""
        n = len(data)
        if n == 0:
            return np.zeros((0, self.mcfg.in_len), bool)
        outs = []
        for lo, hi in self._slices(n):
            b = data.batch(np.arange(lo, hi))
            logits = _caching_logits_jit(
                self.cparams, *self._feed(b, _bucket(hi - lo)))
            outs.append(np.asarray(logits)[: hi - lo] > 0)
        return np.concatenate(outs, axis=0)

    def predict_points(self, data: WindowData) -> np.ndarray:
        """Predicted PO representation points, jitted + bucketed.
        (N, out_len, rep_dim) f32."""
        n = len(data)
        if n == 0:
            return np.zeros((0, self.pcfg.out_len, self.pcfg.rep_dim),
                            np.float32)
        outs = []
        for lo, hi in self._slices(n):
            b = data.batch(np.arange(lo, hi))
            po = _prefetch_points_jit(
                self.pparams, self.pcfg, *self._feed(b, _bucket(hi - lo)))
            outs.append(np.asarray(po)[: hi - lo])
        return np.concatenate(outs, axis=0)

    def decode_points(self, points: np.ndarray) -> np.ndarray:
        """Snap predicted points to candidate-pool ids.  (N, P) int64."""
        if points.size == 0:
            return np.zeros(points.shape[:-1], np.int64)
        cand = candidate_reps(self.pparams, self.pcfg, self.cand_ids,
                              self.geom)
        flat = np.asarray(points, np.float32).reshape(-1, points.shape[-1])
        outs = []
        for i in range(0, len(flat), self.cfg.infer_batch):
            seg = flat[i: i + self.cfg.infer_batch]
            idx = _nn_decode(jnp.asarray(_pad_rows(seg, _bucket(len(seg)))),
                             cand)
            outs.append(np.asarray(idx)[: len(seg)])
        nn = np.concatenate(outs)
        return self.cand_ids[nn].reshape(points.shape[:-1])

    def outputs_for(self, trace: Trace) -> RecMGOutputs:
        """Model outputs on the serving chunk grid (stride = in_len), the
        same grid ``precompute_outputs`` / ``frequency_outputs`` emit."""
        cfg = self.cfg
        data = make_windows(trace, in_len=cfg.in_len,
                            out_window=cfg.out_len, stride=cfg.in_len)
        starts = np.arange(cfg.in_len, len(trace) - cfg.out_len - 1,
                           cfg.in_len)[: len(data)]
        bits = self.predict_bits(data)
        ids = self.decode_points(self.predict_points(data))
        return RecMGOutputs(starts, bits, ids)

    # ------------------------------------------------------------------
    # Online adaptation
    # ------------------------------------------------------------------

    def refresh_candidates(self, ids: np.ndarray) -> None:
        """Re-derive the prefetch candidate pool from a live window."""
        ids = np.asarray(ids, np.int64).ravel()
        if ids.size:
            self.cand_ids = np.sort(
                top_ids_by_count(ids, max(1, len(self.cand_ids))))

    def finetune(self, recent_ids: np.ndarray) -> int:
        """One bounded online fine-tune pass of the caching model on the
        most recent accesses (<= ``finetune_steps`` jitted steps of
        ``finetune_batch`` windows at ``finetune_lr``; Adam state persists
        across calls).  Belady labels are re-derived on the window — the
        same supervision as offline training, just on live data.  Also
        refreshes the prefetch candidate pool.  Returns steps taken."""
        cfg = self.cfg
        recent = np.asarray(recent_ids, np.int64).ravel()
        self.finetunes += 1
        self.refresh_candidates(recent)
        if recent.size <= cfg.in_len * 2:
            return 0
        offs = self.geom.table_offsets
        t = np.searchsorted(offs, recent, side="right") - 1
        row = recent - offs[t]
        wtrace = Trace(t.astype(np.int32), row.astype(np.int64),
                       self.geom.rows_per_table)
        wlabels, _, _ = belady_labels(recent, self.capacity)
        wdata = make_windows(wtrace, in_len=cfg.in_len, labels=wlabels,
                             stride=cfg.finetune_stride)
        if len(wdata) < cfg.finetune_batch:
            return 0
        if self._ft_opt is None:
            self._ft_opt_cfg = OptConfig(lr=cfg.finetune_lr,
                                         weight_decay=0.0, warmup_steps=1,
                                         total_steps=10 ** 6)
            self._ft_opt = init_opt(self._ft_opt_cfg, self.cparams)
        rng = np.random.default_rng(1000 + cfg.seed + self.finetunes)
        idx = rng.permutation(len(wdata))[: cfg.finetune_steps
                                          * cfg.finetune_batch]
        steps = 0
        for i in range(0, len(idx) - cfg.finetune_batch + 1,
                       cfg.finetune_batch):
            b = wdata.batch(idx[i: i + cfg.finetune_batch])
            batch = {
                "xt": jnp.asarray(b.x_table), "xr1": jnp.asarray(b.x_row1),
                "xr2": jnp.asarray(b.x_row2), "xn": jnp.asarray(b.x_norm),
                "xf": jnp.asarray(b.x_freq), "xrc": jnp.asarray(b.x_rec),
                "y": jnp.asarray(b.y_keep),
            }
            self.cparams, self._ft_opt, _ = _caching_train_step(
                self.cparams, self._ft_opt, batch, self._ft_opt_cfg)
            steps += 1
        self.finetune_steps_run += steps
        return steps

    def telemetry(self) -> dict:
        return {
            "caching_loss": (round(float(np.mean(self.caching_losses[-20:])),
                                   4) if self.caching_losses else None),
            "prefetch_loss": (round(float(np.mean(self.prefetch_losses[-20:])),
                                    5) if self.prefetch_losses else None),
            "n_candidates": int(len(self.cand_ids)),
            "finetunes": self.finetunes,
            "finetune_steps": self.finetune_steps_run,
        }


@dataclass
class OutputsRef:
    """Mutable holder for the live :class:`RecMGOutputs` — the serving
    loops read through it so an online refresh swaps the outputs without
    re-wiring the loop (the chunk grid is identical, so the loop's chunk
    pointer stays valid)."""

    outputs: Optional[RecMGOutputs] = field(default=None)


class LearnedController:
    """Drift adaptation with model fine-tune: the PR-5 heuristic refresh
    (hot-pool rebuild + per-chunk re-rank + bounded prefetch) *plus*, on
    every pool refresh, a bounded fine-tune of the caching model on the
    live window and a full output recompute.  Exposes the same
    ``on_batch`` hook (:data:`~repro.runtime.drift.BatchHook`), so
    ``serve_trace``, the pipelined runtime and the scenario harness wire
    it exactly like :class:`AdaptiveController`."""

    def __init__(self, store, capacity: int, model: LearnedRecMGModel,
                 outputs_ref: OutputsRef, trace: Trace,
                 cfg: Optional[DriftConfig] = None):
        self.inner = AdaptiveController(store, capacity, cfg)
        self.model = model
        self.outputs_ref = outputs_ref
        self.trace = trace
        self._refreshes_seen = 0

    def on_batch(self, ids: np.ndarray, hits: int,
                 batch_index: int = 0) -> List[Tuple]:
        items = self.inner.on_batch(ids, hits, batch_index)
        if self.inner.refreshes > self._refreshes_seen:
            self._refreshes_seen = self.inner.refreshes
            tr = get_tracer()
            if tr.enabled:
                t0 = tr.clock.now()
            steps = self.model.finetune(self.inner.recent_ids())
            self.outputs_ref.outputs = self.model.outputs_for(self.trace)
            if tr.enabled:
                tr.add_span("model", "finetune", t0, tr.clock.now() - t0,
                            track="model", args={"steps": steps})
                tr.add_instant("model", "swap", track="model",
                               args={"finetunes": self.model.finetunes})
        return items

    def as_dict(self) -> dict:
        d = self.inner.as_dict()
        d.update(finetunes=self.model.finetunes,
                 finetune_steps=self.model.finetune_steps_run)
        return d

    def publish(self, reg, prefix: str = "model"):
        """Publish the drift counters plus the learned-model telemetry
        into a :class:`repro.obs.MetricsRegistry`."""
        self.inner.publish(reg)
        mt = self.model.telemetry()
        reg.counter(f"{prefix}.finetunes").inc(mt["finetunes"])
        reg.counter(f"{prefix}.finetune_steps").inc(mt["finetune_steps"])
        reg.gauge(f"{prefix}.n_candidates").set(mt["n_candidates"])
        if mt["caching_loss"] is not None:
            reg.gauge(f"{prefix}.caching_loss").set(mt["caching_loss"])
        if mt["prefetch_loss"] is not None:
            reg.gauge(f"{prefix}.prefetch_loss").set(mt["prefetch_loss"])
        return reg


def voyager_outputs(trace: Trace, capacity: int, in_len: int = 15,
                    out_len: int = 5, *,
                    profile_upto: Optional[int] = None, epochs: int = 8,
                    batch_size: int = 128, lr: float = 5e-3,
                    train_stride: int = 2, page_size: int = 64,
                    hidden: int = 32, seed: int = 0,
                    n_candidates: int = 0) -> RecMGOutputs:
    """Voyager-class ML-prefetcher serving arm (paper §VII-B baseline).

    Trains the hierarchical page/offset classifier on the trace prefix,
    then emits per-chunk top-``out_len`` prefetch ids by scoring the
    candidate pool with ``page_logit[page(c)] + offset_logit[offset(c)]``
    (the decomposed softmax read out over real ids).  No caching bits —
    Voyager only prefetches, so the serving arm is an LRU store + this
    prefetch stream (the LRU+PF mode of ``apply_model_outputs``)."""
    from repro.core.voyager import (VoyagerConfig, train_voyager,
                                    voyager_logits_batch)

    prefix = (trace if profile_upto is None
              else trace.slice(0, int(profile_upto)))
    vcfg = VoyagerConfig(n_vectors=trace.n_vectors, page_size=page_size,
                         hidden=hidden, in_len=in_len)
    data = make_windows(prefix, in_len=in_len, out_window=1,
                        stride=train_stride)
    vparams, _ = train_voyager(data, vcfg, trace.n_tables, epochs=epochs,
                               batch_size=batch_size, lr=lr, seed=seed)

    sdata = make_windows(trace, in_len=in_len, out_window=out_len,
                         stride=in_len)
    starts = np.arange(in_len, len(trace) - out_len - 1,
                       in_len)[: len(sdata)]
    cand = np.sort(top_ids_by_count(
        prefix.global_id, max(1, n_candidates or int(capacity))))
    pages = jnp.asarray((cand // page_size).astype(np.int32))
    offs = jnp.asarray((cand % page_size).astype(np.int32))
    k = min(out_len, len(cand))
    ids = np.zeros((len(sdata), out_len), np.int64)
    for i in range(0, len(sdata), 4096):
        b = sdata.batch(np.arange(i, min(i + 4096, len(sdata))))
        pl, ol = voyager_logits_batch(
            vparams, vcfg, jnp.asarray(b.x_table), jnp.asarray(b.x_row1),
            jnp.asarray(b.x_row2), jnp.asarray(b.x_norm))
        score = pl[:, pages] + ol[:, offs]  # (B, C) over the candidate pool
        top = np.asarray(jax.lax.top_k(score, k)[1])
        got = cand[top]
        if k < out_len:  # tiny pools: repeat to fill the grid
            got = np.pad(got, ((0, 0), (0, out_len - k)), mode="edge")
        ids[i: i + len(got)] = got
    return RecMGOutputs(starts, None, ids)
