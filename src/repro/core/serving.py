"""Multi-table tiered serving facade — one batched store per sparse feature.

Industrial DLRM serving (Software-Defined Memory, RecShard) manages
residency per embedding table: tables differ wildly in size and skew, so a
single global buffer lets one hot table starve the rest.  This facade owns
one :class:`~repro.core.tiered.TieredEmbeddingStore` per table under a
**shared byte budget**, split proportionally to table size (rows), and
routes batched lookups on *global* vector ids (the trace id space:
``global_id = table_offset + row_id``) to the right store with one
``searchsorted`` pass.

The facade mirrors the single-store API (``lookup``,
``apply_model_outputs``, ``stage_model_outputs``, ``stats``,
``modeled_batch_ms``) so ``launch/serve.py``, the examples, and the
benchmarks can swap it in with a flag.  Algorithm 1 outputs are routed per
table and, through ``stage_model_outputs``, land double-buffered at the
next batch boundary without blocking an in-flight lookup.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.tiered import (TierStats, TieredEmbeddingStore,
                               fast_row_bytes)


class MultiTableTieredStore:
    """Per-table batched stores under a shared byte budget.

    Parameters
    ----------
    host_tables: per-table host-tier arrays, each (N_t, D).
    capacity:    total fast-tier rows across all tables (mutually exclusive
                 with ``byte_budget``).
    byte_budget: total fast-tier bytes, split with *per-table* row
                 footprints (``D * itemsize`` for full-precision rows —
                 mixed-dtype table sets pay their own rate — or ``D + 4``
                 for the quantized tier).
    weights:     optional per-table split weights (default: table rows).
    """

    def __init__(self, host_tables: Sequence[np.ndarray],
                 capacity: Optional[int] = None,
                 byte_budget: Optional[int] = None,
                 policy: str = "lru", quantize: bool = False,
                 row_format: Optional[str] = None,
                 weights: Optional[Sequence[float]] = None,
                 min_capacity: int = 4, fetch_us_fixed: float = 30.0,
                 **store_kw):
        if (capacity is None) == (byte_budget is None):
            raise ValueError("pass exactly one of capacity / byte_budget")
        rows = np.array([t.shape[0] for t in host_tables], np.int64)
        d = host_tables[0].shape[1]
        # Budget split in the unit the caller budgeted in: bytes-per-row
        # per table under ``byte_budget`` (tables can differ in dtype, so
        # a shared scalar row size would over/under-run the budget), a
        # unit cost of 1 under row ``capacity`` (same algorithm, rows).
        rb = np.array([fast_row_bytes(t.shape[1], t.dtype, quantize,
                                      row_format or "int8")
                       for t in host_tables], np.int64)
        unit = rb if capacity is None else np.ones(len(rb), np.int64)
        budget = int(byte_budget) if capacity is None else int(capacity)
        if int((np.minimum(1, rows) * unit).sum()) > budget:
            # Below one row per store the budget cannot be honored (stores
            # clamp to capacity >= 1); fail loudly instead of overrunning.
            raise ValueError(
                f"budget of {budget} cannot give {len(host_tables)} "
                "tables one row each")
        w = np.asarray(weights if weights is not None else rows, np.float64)
        # The per-table floor must never be allowed to overrun the shared
        # budget: when the budget cannot afford ``min_capacity`` rows for
        # every table, the effective floor drops to an equal split (at
        # least one row — the irreducible store minimum).
        floor = max(1, min(int(min_capacity), budget // int(unit.sum())))
        caps = np.maximum(floor, np.floor(
            budget * (w / w.sum()) / unit)).astype(np.int64)
        caps = np.minimum(caps, rows)  # never exceed the table itself
        # Lifting small tables to the floor can still overrun the budget;
        # claw the excess back from the biggest spender (in budget units)
        # still above the floor, largest-first — deterministic, and since
        # every table at the floor fits the budget by construction, this
        # always converges to ``sum(caps * unit) <= budget``.
        excess = int((caps * unit).sum()) - budget
        while excess > 0:
            above = np.flatnonzero(caps > floor)
            if not above.size:
                break
            i = int(above[np.argmax((caps * unit)[above])])
            take = min(-(-excess // int(unit[i])), int(caps[i]) - floor)
            caps[i] -= take
            excess -= take * int(unit[i])
        self.offsets = np.concatenate(([0], np.cumsum(rows)))
        self.capacity = int(caps.sum())
        self.row_bytes_per_table = rb
        self.row_bytes = int(rb.max())  # worst-case scalar (back-compat)
        self.byte_budget = (int(byte_budget) if byte_budget is not None
                            else int((caps * rb).sum()))
        # Sub-stores model only the per-row slow-tier cost; the fixed
        # per-batch overhead is charged once per *facade* batch with a miss
        # (matching the monolithic store's accounting, so the bench
        # comparison measures policy quality, not aggregation artifacts).
        self.fetch_us_fixed = float(fetch_us_fixed)
        self._fixed_fetch_s = 0.0
        self.stores: List[TieredEmbeddingStore] = [
            TieredEmbeddingStore(t, int(c), policy=policy, quantize=quantize,
                                 row_format=row_format,
                                 fetch_us_fixed=0.0, **store_kw)
            for t, c in zip(host_tables, caps)
        ]
        self.emb_dim = d
        # Quantized stores dequantize to f32; otherwise the (jax-
        # canonicalized) buffer dtype flows through, matching what the
        # single-store lookup returns.
        self.out_dtype = (np.float32 if quantize
                          else self.stores[0].buffer.dtype)
        self.batches = 0

    @classmethod
    def from_global_table(cls, host: np.ndarray, rows_per_table: np.ndarray,
                          **kw) -> "MultiTableTieredStore":
        """Split a monolithic (sum_rows, D) host table laid out in
        global-id order into per-table views (zero-copy slices)."""
        offs = np.concatenate(([0], np.cumsum(rows_per_table)))
        tables = [host[offs[t]: offs[t + 1]] for t in
                  range(len(rows_per_table))]
        return cls(tables, **kw)

    # ---------------- routing ----------------

    def _route(self, global_ids: np.ndarray):
        gid = np.asarray(global_ids, np.int64).ravel()
        table = np.searchsorted(self.offsets, gid, side="right") - 1
        return gid, table, gid - self.offsets[table]

    def resident_mask(self, global_ids: np.ndarray) -> np.ndarray:
        """Vectorized residency probe across all per-table stores (the
        serving runtime's cancel-before-issue hook)."""
        gid, table, local = self._route(global_ids)
        mask = np.zeros(len(gid), bool)
        for t in np.unique(table).tolist():
            m = table == t
            mask[m] = self.stores[t].resident_mask(local[m])
        return mask

    def lookup_resident(self, global_ids: np.ndarray):
        """Degraded read (single-store API parity): ``(rows, n_default)``
        — stale-but-resident rows per table, zero default for misses; no
        stats mutation and no slow-tier traffic on any sub-store."""
        gid, table, local = self._route(global_ids)
        out = np.zeros((len(gid), self.emb_dim), self.out_dtype)
        n_default = 0
        for t in np.unique(table).tolist():
            m = table == t
            rows, nd = self.stores[t].lookup_resident(local[m])
            out[m] = rows.astype(self.out_dtype, copy=False)
            n_default += nd
        return out, n_default

    # ---------------- single-store-compatible API ----------------

    def lookup(self, global_ids: np.ndarray) -> jnp.ndarray:
        """(M,) global ids -> (M, D); one batched sub-lookup per table hit
        by this batch, reassembled in request order."""
        gid, table, local = self._route(global_ids)
        self.batches += 1
        out = np.empty((len(gid), self.emb_dim), self.out_dtype)
        missed = False
        for t in np.unique(table).tolist():
            m = table == t
            st = self.stores[t]
            od0 = st.stats.on_demand_rows
            # lookup_host: sub-results merge on the host anyway, so the
            # store materializes in one transfer (no device-side slice).
            out[m] = st.lookup_host(local[m])
            missed = missed or st.stats.on_demand_rows > od0
        if missed:
            self._fixed_fetch_s += self.fetch_us_fixed * 1e-6
        return jnp.asarray(out)

    def _route_outputs(self, trunk, bits, prefetch_ids, staged: bool):
        trunk, t_tab, t_loc = self._route(trunk)
        bits = np.asarray(bits).ravel()[: len(trunk)]  # zip truncation
        t_tab, t_loc = t_tab[: len(bits)], t_loc[: len(bits)]
        _, p_tab, p_loc = self._route(prefetch_ids)
        for t in np.unique(np.concatenate((t_tab, p_tab))).tolist():
            tm, pm = t_tab == t, p_tab == t
            store = self.stores[t]
            fn = store.stage_model_outputs if staged \
                else store.apply_model_outputs
            fn(t_loc[tm], bits[tm], p_loc[pm])

    def apply_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Route Algorithm 1 outputs (global-id keyed) to each table."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=False)

    def stage_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Double-buffered apply: route now, land at each store's next
        lookup boundary."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=True)

    def flush_staged(self):
        """Apply all staged outputs now (the inter-batch gap)."""
        for s in self.stores:
            s.flush_staged()

    def warmup(self, batch_hint: int):
        """Eagerly compile every scatter/gather shape bucket a batch of up
        to ``batch_hint`` global ids can hit (single-store API parity; the
        jitted functions are module-level, so across the per-table stores
        only the first pays each compile).  Alternatively pass
        ``warmup_batch=`` at construction — it flows to every sub-store."""
        for s in self.stores:
            s.warmup(batch_hint)

    # ---------------- aggregated accounting ----------------

    @property
    def stats(self) -> TierStats:
        agg = TierStats()
        for s in self.stores:
            agg.merge(s.stats)
        agg.batches = self.batches  # facade batches, not per-store sum
        agg.modeled_fetch_s += self._fixed_fetch_s
        return agg

    def modeled_batch_ms(self) -> float:
        return 1e3 * self.stats.modeled_fetch_s / max(self.batches, 1)

    def per_table_hit_rates(self) -> List[float]:
        return [s.stats.hit_rate for s in self.stores]

    def publish_metrics(self, reg):
        """Publish the aggregate ``store.*`` view plus one
        ``table.<t>.store.*`` namespace per sparse feature."""
        self.stats.publish(reg, prefix="store")
        reg.gauge("tables.n_tables").set(len(self.stores))
        for t, st in enumerate(self.stores):
            st.stats.publish(reg, prefix=f"table.{t}.store")
        return reg
