"""Sharded multi-worker tiered serving: N simulated workers, one batched
tiered store (+ inline prefetch engine) each, all-to-all-style gather.

:class:`ShardedTieredStore` executes a
:class:`~repro.sharding.embedding_shard.ShardPlan`: every worker owns the
host-tier rows the plan assigned to it (a zero-copy-ordered slice of the
global table) and a fast-tier buffer sized by the plan's per-shard budget.
A batch of global ids is routed shard-locally in one vectorized pass
(``plan.route``), each touched shard runs one batched
:class:`~repro.core.tiered.TieredEmbeddingStore` lookup on its local ids,
and the results merge back into request order — the simulated equivalent
of the all-to-all that follows per-worker embedding lookups in
distributed DLRM serving.

Model outputs (Algorithm 1 triples, global-id keyed) route the same way,
through one **per-shard inline** :class:`~repro.runtime.prefetch_engine.
PrefetchEngine` each: the engine dedups in-flight prefetch ids, cancels
ids that became resident before issue, models each worker's private
background fetch channel (timeliness), and applies synchronously — so
the sharded store remains byte-for-byte equivalent to the composition of
its per-shard single stores (the contract the property suite checks).

Telemetry goes beyond the merged :class:`~repro.core.tiered.TierStats`:

* **load / skew** — per-shard routed-id counts, aggregate and worst
  single-batch imbalance (``max shard load / mean shard load``);
* **stall** — per-shard modeled slow-tier time, plus the *critical-path*
  view: per batch, workers fetch in parallel, so the batch pays the max
  over shards, not the sum.  ``parallel_fetch_speedup`` is the ratio.

**Fault tolerance** (``arm_faults`` / ``fault_plan=``): a deterministic
:class:`~repro.runtime.faults.FaultInjector` drives per-shard health on
the shared virtual clock.  A dead shard's rows are answered from the
plan's hot-row replica set when replicated (exact bytes), else through
the degraded ``lookup_resident`` contract (stale-but-resident row or
zero default — never a wrong vector, never a hang); transient fetch
failures retry through a clock-driven deadline-aware wrapper; recovery
rebuilds the shard store and streams the lost resident set back in
bounded background chunks through the shard's prefetch channel (int8 on
the modeled wire, exact rows from the surviving host tier).  Everything
is accounted in the exactly-reconciled ``ft.*`` namespace
(:func:`repro.obs.reconcile.check_ft`).  With no plan armed, the serving
path is byte-identical to before this layer existed.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.tiered import (TierStats, TieredEmbeddingStore,
                               fast_row_bytes)
from repro.obs.tracing import get_tracer
from repro.sharding.embedding_shard import (ShardPlan, make_plan,
                                            trace_frequencies)


class ShardedTieredStore:
    """N per-shard batched stores behind one single-store-compatible API.

    Parameters
    ----------
    host:  (n_vectors, D) global host-tier table in global-id order.
    plan:  a :class:`ShardPlan` (see :func:`ShardedTieredStore.build` for
           the convenience constructor that makes one).
    with_engines: route ``apply_model_outputs`` through per-shard inline
           prefetch engines (dedup/cancel/timeliness telemetry).  The
           apply semantics are identical either way.
    """

    def __init__(self, host: np.ndarray, plan: ShardPlan,
                 policy: str = "lru", quantize: bool = False,
                 row_format: Optional[str] = None,
                 fetch_us_fixed: float = 30.0, with_engines: bool = True,
                 fault_plan=None, fault_horizon: Optional[int] = None,
                 **store_kw):
        if host.shape[0] != plan.n_vectors:
            raise ValueError(f"host has {host.shape[0]} rows, "
                             f"plan covers {plan.n_vectors}")
        self.plan = plan
        self.n_shards = plan.n_shards
        self.emb_dim = host.shape[1]
        # Kept for the fault layer: replica rows come from here, and a
        # recovered shard's replacement store is rebuilt over host[g].
        self._host = np.asarray(host)
        self._policy = policy
        self._quantize = quantize
        self._row_format = row_format
        self._store_kw = dict(store_kw)
        # Per-shard stores model the per-row slow-tier cost; the fixed
        # per-batch overhead is charged at the facade (once per batch with
        # a miss for the sum view, once per missing *shard* for the
        # critical-path view) so policy comparisons aren't aggregation
        # artifacts — same scheme as the multi-table facade.
        self.fetch_us_fixed = float(fetch_us_fixed)
        self.stores: List[TieredEmbeddingStore] = [
            TieredEmbeddingStore(host[g], int(c), policy=policy,
                                 quantize=quantize, row_format=row_format,
                                 fetch_us_fixed=0.0, **store_kw)
            for g, c in zip(plan.global_ids, plan.capacities)
        ]
        self.out_dtype = (np.float32 if quantize
                          else self.stores[0].buffer.dtype)
        self.batches = 0
        self._fixed_fetch_s = 0.0
        # ---- load / critical-path telemetry ----
        self._shard_lookups = np.zeros(self.n_shards, np.int64)
        self._max_batch_imbalance = 0.0
        self._critical_fetch_s = 0.0   # sum over batches of max-over-shards
        self._engines = None
        if with_engines:
            from repro.runtime.clock import VirtualClock
            from repro.runtime.prefetch_engine import PrefetchEngine
            from repro.runtime.telemetry import RuntimeTelemetry

            self.clock = VirtualClock()
            self.engine_telemetry = [RuntimeTelemetry()
                                     for _ in range(self.n_shards)]
            self._engines = [
                PrefetchEngine(st, telemetry=tel, clock=self.clock,
                               scheduler="inline",
                               fetch_us_per_row=st.fetch_us_per_row,
                               fetch_us_fixed=self.fetch_us_fixed,
                               trace_track=f"pf-shard-{s}")
                for s, (st, tel) in enumerate(zip(self.stores,
                                                  self.engine_telemetry))
            ]
        # ---- hot-row replication (exact failover answers) ----
        self._replica_index = None   # global id -> replica row (-1: none)
        self._replica_rows = None    # (k, D) exact host bytes
        rep = plan.replicated_ids
        if rep is not None and len(rep):
            rep = np.asarray(rep, np.int64)
            self._replica_index = np.full(plan.n_vectors, -1, np.int64)
            self._replica_index[rep] = np.arange(len(rep))
            self._replica_rows = self._host[rep].copy()
        # ---- fault layer (off by default: path byte-identical) ----
        self._injector = None
        self._ft = None
        self._lost_rows = {}    # shard -> local ids resident at kill time
        self._recovery = {}     # shard -> list of pending local-id chunks
        if fault_plan is not None:
            self.arm_faults(fault_plan, fault_horizon)

    @classmethod
    def build(cls, host: np.ndarray, rows_per_table: Sequence[int],
              n_shards: int, placement: str = "table",
              capacity: Optional[int] = None,
              byte_budget: Optional[int] = None,
              frequencies: Optional[np.ndarray] = None,
              fast_weights: Optional[Sequence[float]] = None,
              profile_ids: Optional[np.ndarray] = None,
              replicate_hot: int = 0,
              **kw) -> "ShardedTieredStore":
        """Plan + store in one call.  ``profile_ids`` (a trace sample)
        stands in for explicit ``frequencies`` under ``"freq"`` and for
        ``replicate_hot`` (top-k hot rows resident on every shard).
        ``byte_budget`` (mutually exclusive with ``capacity``) budgets the
        total fast tier in bytes, converted with the quantization-aware
        per-row footprint before the planner splits rows across shards."""
        if capacity is not None and byte_budget is not None:
            raise ValueError("pass at most one of capacity / byte_budget")
        if byte_budget is not None:
            rb = fast_row_bytes(host.shape[1], host.dtype,
                                kw.get("quantize", False),
                                kw.get("row_format") or "int8")
            capacity = int(byte_budget) // rb
        if capacity is None:
            raise ValueError("capacity (total fast-tier rows) or "
                             "byte_budget is required")
        if frequencies is None and profile_ids is not None:
            frequencies = trace_frequencies(profile_ids, host.shape[0])
        plan = make_plan(rows_per_table, n_shards, int(capacity),
                         placement, frequencies=frequencies,
                         fast_weights=fast_weights,
                         replicate_hot=replicate_hot)
        return cls(host, plan, **kw)

    def arm_faults(self, fault_plan, horizon_batches: Optional[int] = None,
                   seed: int = 0):
        """Arm deterministic fault injection (a :class:`~repro.runtime.
        faults.FaultPlan` or its CLI string form, e.g. ``"kill:1@mid,
        recover:1@75%"``).  ``horizon_batches`` resolves fractional event
        times.  Returns the :class:`~repro.runtime.faults.FaultInjector`."""
        from repro.runtime.faults import FaultInjector, FaultPlan, FtStats
        if self._engines is None:
            raise ValueError("fault injection needs with_engines=True "
                             "(the shared virtual clock drives the "
                             "fault timeline)")
        if isinstance(fault_plan, str):
            fault_plan = FaultPlan.parse(fault_plan, seed=seed)
        self._injector = FaultInjector(fault_plan, self.n_shards,
                                       horizon_batches)
        self._ft = FtStats(n_shards=self.n_shards)
        return self._injector

    @property
    def ft_stats(self):
        """The ``ft.*`` counters (None until :meth:`arm_faults`)."""
        return self._ft

    # ---------------- routing + merge (the all-to-all) ----------------

    def lookup(self, global_ids: np.ndarray) -> jnp.ndarray:
        """(M,) global ids -> (M, D): scatter ids shard-locally, one
        batched per-shard lookup each, gather back in request order."""
        inj = self._injector
        if inj is not None:
            # Fault timeline first: events scheduled for this batch index
            # fire before any routing, then each recovering shard streams
            # one bounded background chunk (serving never halts).
            self._poll_faults(self.batches)
            self._pump_recovery()
        gid, shard, local = self.plan.route(global_ids)
        self.batches += 1
        loads = np.bincount(shard, minlength=self.n_shards)
        self._shard_lookups += loads
        self._max_batch_imbalance = max(
            self._max_batch_imbalance,
            float(loads.max() / max(loads.mean(), 1e-12)))
        out = np.empty((len(gid), self.emb_dim), self.out_dtype)
        missed_any = False
        critical_us = 0.0
        tr = get_tracer()
        if inj is not None:
            self._ft.served += int(len(gid))
        for s in np.flatnonzero(loads).tolist():
            m = shard == s
            st = self.stores[s]
            if inj is not None and not inj.up[s]:
                # Dead shard: replicas / degraded contract, no slow-tier
                # work, zero critical-path contribution (bounded stall).
                self._serve_failover(s, gid[m], local[m], out, m)
                continue
            f0, od0 = st.stats.modeled_fetch_s, st.stats.on_demand_rows
            if tr.enabled:
                t_s = tr.clock.now()
            # Timeliness probe only when this shard's channel has fetches
            # in flight — skips the per-batch unique() on cold paths.
            if self._engines is not None and self._engines[s]._pf_eta:
                self._engines[s].observe_demand(np.unique(local[m]),
                                                self.clock.now())
            extra_us = 0.0
            if (inj is not None and inj.flaky[s] > 0.0
                    and bool((~st.resident_mask(local[m])).any())):
                # The slice needs the slow tier and the channel is flaky:
                # fetch through the clock-driven retry wrapper.  Exhausted
                # episodes fall back to the degraded contract for this
                # slice — the slow tier stays un-touched, never hung on.
                rows, extra_us, ok = self._fetch_with_retry(s, st, local[m])
                self._ft.retry_overhead_ms += extra_us * 1e-3
                if ok:
                    out[m] = rows
                    self._ft.primary += int(loads[s])
                else:
                    r, nd = st.lookup_resident(local[m])
                    out[m] = r.astype(self.out_dtype, copy=False)
                    self._ft.failover_degraded += int(loads[s])
                    self._ft.degraded_default += int(nd)
            else:
                # lookup_host: the all-to-all merge is host-side, so each
                # worker materializes in one transfer (no device-side
                # slice).
                out[m] = st.lookup_host(local[m])
                if inj is not None:
                    self._ft.primary += int(loads[s])
            d_us = (st.stats.modeled_fetch_s - f0) * 1e6 + extra_us
            if st.stats.on_demand_rows > od0:
                missed_any = True
                d_us += self.fetch_us_fixed
            if inj is not None and inj.slow[s] != 1.0:
                # Congested / throttled host: its fetch window stretches.
                self._ft.slow_ms += d_us * (inj.slow[s] - 1.0) * 1e-3
                d_us *= inj.slow[s]
            critical_us = max(critical_us, d_us)
            if tr.enabled:
                # Per-shard route+gather window on this worker's track.
                tr.add_span("shard", "lookup", t_s, tr.clock.now() - t_s,
                            track=f"shard-{s}", args={
                                "shard": s, "rows": int(loads[s]),
                                "miss_rows": st.stats.on_demand_rows - od0})
        if missed_any:
            self._fixed_fetch_s += self.fetch_us_fixed * 1e-6
        self._critical_fetch_s += critical_us * 1e-6
        if self._engines is not None:
            # Workers fetch in parallel; modeled time moves by the batch's
            # critical path (what timeliness is measured against).
            self.clock.advance(critical_us)
        return jnp.asarray(out)

    # ---------------- fault handling (armed via arm_faults) ----------------

    def _poll_faults(self, batch: int):
        """Fire the injector's due transitions and apply their store-side
        effects; every edge gets a span instant on the shard's track."""
        tr = get_tracer()
        for e, clear in self._injector.poll(batch, self.clock.now()):
            if tr.enabled:
                name = f"ft.{e.kind}" + ("_clear" if clear else "")
                tr.add_instant("ft", name, ts=self.clock.now(),
                               track=f"shard-{e.shard}",
                               args={"shard": e.shard, "batch": batch,
                                     "factor": e.factor})
            if e.kind == "kill" and not clear:
                self._on_kill(e.shard)
            elif e.kind == "recover" and not clear:
                self._on_recover(e.shard)

    def _on_kill(self, s: int):
        """The shard process dies.  Its store object survives only as a
        read-only stale standby snapshot (the facade's last-known-good
        view, what `lookup_resident` answers from); in-flight prefetch
        work is cancelled with the ``pf.shard_down`` fate and staged
        model outputs are discarded — nothing may mutate a dead shard."""
        self._ft.kills += 1
        st = self.stores[s]
        # The resident set at kill time is what recovery must restore.
        self._lost_rows[s] = np.flatnonzero(st._slot_map >= 0).astype(
            np.int64)
        for item in st._staged:
            self._ft.staged_dropped += int(np.asarray(item[2]).size)
        st._staged.clear()
        self._engines[s].set_down(True)

    def _on_recover(self, s: int):
        """A replacement worker comes up *empty*: rebuild the shard store
        fresh over the surviving host-tier slice (cumulative counters
        carry over — the shard's history did happen), re-open its
        prefetch engine, and queue the lost resident set for bounded
        background restoration."""
        inj, ft = self._injector, self._ft
        old = self.stores[s]
        kw = dict(self._store_kw)
        kw.pop("warmup_batch", None)  # shape buckets are already compiled
        g = self.plan.global_ids[s]
        new = TieredEmbeddingStore(self._host[g], int(old.capacity),
                                   policy=self._policy,
                                   quantize=self._quantize,
                                   row_format=self._row_format,
                                   fetch_us_fixed=0.0, **kw)
        new.stats = old.stats
        self.stores[s] = new
        self._engines[s].store = new
        self._engines[s].set_down(False)
        ft.down_us[s] += inj.close_downtime(s, self.clock.now())
        ft.recoveries += 1
        lost = self._lost_rows.pop(s, None)
        if lost is not None and lost.size:
            chunk = max(1, int(inj.plan.recovery_chunk))
            self._recovery[s] = [lost[i:i + chunk]
                                 for i in range(0, lost.size, chunk)]

    def _pump_recovery(self):
        """One bounded chunk per recovering shard per batch: the lost
        resident set streams back through the shard's prefetch channel as
        int8 row transfers (accounted on the modeled wire), with exact
        values re-materialized from the surviving host tier — recovery
        can never introduce a wrong vector."""
        if not self._recovery:
            return
        from repro.distributed.compression import quantize_int8
        ft, tr = self._ft, get_tracer()
        for s in sorted(self._recovery):
            chunks = self._recovery[s]
            loc = chunks.pop(0)
            rows = self.stores[s].host[loc]
            q, _scale = quantize_int8(jnp.asarray(rows))
            ft.recovery_bytes += int(q.size) + 4          # int8 + scale
            ft.recovery_bytes_raw += int(loc.size) * self.emb_dim * 4
            eng = self._engines[s]
            eng.submit(np.empty(0, np.int64), np.empty(0, np.int64), loc,
                       now_us=self.clock.now())
            eng.drain()
            ft.recovery_rows += int(loc.size)
            ft.recovery_chunks += 1
            if not chunks:
                del self._recovery[s]
                if tr.enabled:
                    tr.add_instant("ft", "ft.recovery_complete",
                                   ts=self.clock.now(), track=f"shard-{s}",
                                   args={"shard": s,
                                         "rows": ft.recovery_rows})

    def _serve_failover(self, s: int, g: np.ndarray, loc: np.ndarray,
                        out: np.ndarray, m: np.ndarray):
        """Answer a dead shard's slice: replicated rows exactly from the
        hot-row replica set, the rest via the degraded stale-resident /
        zero-default contract on the standby snapshot."""
        ft = self._ft
        idx = np.flatnonzero(m)
        if self._replica_index is not None:
            rep_loc = self._replica_index[g]
            is_rep = rep_loc >= 0
        else:
            is_rep = np.zeros(len(g), bool)
        if is_rep.any():
            out[idx[is_rep]] = self._replica_rows[
                rep_loc[is_rep]].astype(self.out_dtype, copy=False)
            ft.failover_replica += int(np.count_nonzero(is_rep))
        miss = ~is_rep
        if miss.any():
            rows, nd = self.stores[s].lookup_resident(loc[miss])
            out[idx[miss]] = rows.astype(self.out_dtype, copy=False)
            ft.failover_degraded += int(np.count_nonzero(miss))
            ft.degraded_default += int(nd)

    def _fetch_with_retry(self, s: int, st, loc: np.ndarray):
        """One retry *episode* around a flaky shard's fetch: each failed
        attempt costs the plan's timeout, backoffs charge modeled time
        (never a wall-clock sleep), and the whole episode is bounded by
        the retry deadline.  Returns ``(rows, extra_us, ok)``; the store
        mutates exactly once, on the successful attempt."""
        from repro.distributed.fault_tolerance import (RetryDeadlineExceeded,
                                                       retry_step)
        from repro.runtime.faults import TransientFetchError
        inj, ft = self._injector, self._ft
        fp = inj.plan
        extra = [0.0]
        failures = [0]

        def attempt():
            if inj.draw_failure(s):
                failures[0] += 1
                extra[0] += fp.retry_timeout_us
                raise TransientFetchError(
                    f"shard {s}: injected fetch timeout")
            return st.lookup_host(loc)

        try:
            rows = retry_step(
                attempt, retries=fp.max_retries,
                backoff_s=fp.retry_backoff_us * 1e-6,
                retryable=(TransientFetchError,),
                sleep=lambda sec: extra.__setitem__(0, extra[0] + sec * 1e6),
                now=lambda: extra[0] * 1e-6,
                deadline_s=fp.retry_deadline_us * 1e-6)
            if failures[0]:
                ft.retries += 1
                ft.retry_succeeded += 1
            return rows, extra[0], True
        except (TransientFetchError, RetryDeadlineExceeded):
            ft.retries += 1
            ft.retry_exhausted += 1
            return None, extra[0], False

    def resident_mask(self, global_ids: np.ndarray) -> np.ndarray:
        gid, shard, local = self.plan.route(global_ids)
        mask = np.zeros(len(gid), bool)
        for s in np.unique(shard).tolist():
            m = shard == s
            mask[m] = self.stores[s].resident_mask(local[m])
        return mask

    def lookup_resident(self, global_ids: np.ndarray):
        """Degraded read (single-store API parity): ``(rows, n_default)``
        routed shard-locally — stale-but-resident rows, zero default for
        misses; no stats mutation, no slow-tier traffic, and no load/
        imbalance accounting (this is the answer a shard gives when it is
        *not* allowed to do work)."""
        gid, shard, local = self.plan.route(global_ids)
        out = np.zeros((len(gid), self.emb_dim), self.out_dtype)
        n_default = 0
        for s in np.unique(shard).tolist():
            m = shard == s
            rows, nd = self.stores[s].lookup_resident(local[m])
            out[m] = rows.astype(self.out_dtype, copy=False)
            n_default += nd
        return out, n_default

    def _route_outputs(self, trunk, bits, prefetch_ids, staged: bool):
        trunk, t_shard, t_loc = self.plan.route(trunk)
        bits = np.asarray(bits).ravel()[: len(trunk)]  # zip truncation
        t_shard, t_loc = t_shard[: len(bits)], t_loc[: len(bits)]
        _, p_shard, p_loc = self.plan.route(prefetch_ids)
        for s in np.unique(np.concatenate((t_shard, p_shard))).tolist():
            tm, pm = t_shard == s, p_shard == s
            if (staged and self._injector is not None
                    and not self._injector.up[s]):
                # Dead shard, direct staging path (bypasses the engine):
                # discard with its own non-identity counter — these rows
                # were never pf.submitted, so they must not take a
                # pf-fate; the engine path below accounts its own drops
                # as pf.shard_down.
                self._ft.staged_dropped += int(np.count_nonzero(pm))
                continue
            if staged:
                self.stores[s].stage_model_outputs(t_loc[tm], bits[tm],
                                                   p_loc[pm])
            elif self._engines is not None:
                # Inline engine: dedup/cancel/channel accounting, then a
                # synchronous apply — store state matches a direct call.
                self._engines[s].submit(t_loc[tm], bits[tm], p_loc[pm],
                                        now_us=self.clock.now())
                self._engines[s].drain()
            else:
                self.stores[s].apply_model_outputs(t_loc[tm], bits[tm],
                                                   p_loc[pm])

    def apply_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Route Algorithm 1 outputs (global-id keyed) to each worker's
        engine (or store, with engines disabled)."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=False)

    def stage_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Double-buffered apply: route now, land at each shard store's
        next lookup boundary."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=True)

    def flush_staged(self):
        for s, st in enumerate(self.stores):
            if self._injector is not None and not self._injector.up[s]:
                continue  # a dead shard's standby snapshot must not mutate
            st.flush_staged()

    def warmup(self, batch_hint: int):
        """Eagerly compile every scatter/gather shape bucket a batch of up
        to ``batch_hint`` routed ids can hit on each worker (single-store
        API parity; module-level jits mean only the first shard pays each
        compile).  Alternatively pass ``warmup_batch=`` at construction —
        it flows to every per-shard store."""
        for st in self.stores:
            st.warmup(batch_hint)

    # ---------------- aggregated accounting ----------------

    @property
    def capacity(self) -> int:
        return int(sum(st.capacity for st in self.stores))

    @property
    def stats(self) -> TierStats:
        agg = TierStats()
        for st in self.stores:
            agg.merge(st.stats)
        agg.batches = self.batches  # facade batches, not per-shard sum
        agg.modeled_fetch_s += self._fixed_fetch_s
        return agg

    def modeled_batch_ms(self) -> float:
        """Sum view (comparable to the single store / facade)."""
        return 1e3 * self.stats.modeled_fetch_s / max(self.batches, 1)

    def critical_batch_ms(self) -> float:
        """Parallel view: per batch, the slowest shard's fetch."""
        return 1e3 * self._critical_fetch_s / max(self.batches, 1)

    def load_imbalance(self) -> float:
        """Aggregate max-shard load / mean-shard load (1.0 = perfect)."""
        total = self._shard_lookups
        return float(total.max() / max(total.mean(), 1e-12))

    def shard_telemetry(self) -> dict:
        """Per-shard load / skew / stall plus engine counters."""
        fetch_s = self.stats.modeled_fetch_s
        d = {
            "n_shards": self.n_shards,
            "placement": self.plan.placement,
            "per_shard_rows": self.plan.shard_rows.tolist(),
            "per_shard_capacity": [int(st.capacity) for st in self.stores],
            "per_shard_lookups": self._shard_lookups.tolist(),
            "per_shard_hit_rate": [round(st.stats.hit_rate, 4)
                                   for st in self.stores],
            "per_shard_evictions": [st.stats.evictions
                                    for st in self.stores],
            "per_shard_fetch_ms": [round(st.stats.modeled_fetch_s * 1e3, 3)
                                   for st in self.stores],
            "load_imbalance": round(self.load_imbalance(), 4),
            "max_batch_imbalance": round(self._max_batch_imbalance, 4),
            "modeled_fetch_ms_sum": round(fetch_s * 1e3, 3),
            "modeled_fetch_ms_critical": round(
                self._critical_fetch_s * 1e3, 3),
            "parallel_fetch_speedup": round(
                fetch_s / max(self._critical_fetch_s, 1e-12), 3),
        }
        if self._engines is not None:
            for k in ("pf_submitted", "pf_deduped", "pf_cancelled_resident",
                      "pf_shard_down", "pf_issued", "pf_timely", "pf_late"):
                d[f"per_shard_{k}"] = [getattr(t, k)
                                       for t in self.engine_telemetry]
        if self._injector is not None:
            d["shard_up"] = self._injector.up.tolist()
            d["ft"] = self._ft.as_dict()
        return d

    def per_shard_hit_rates(self) -> List[float]:
        return [st.stats.hit_rate for st in self.stores]

    def publish_metrics(self, reg):
        """Publish the aggregate ``store.*`` view, every worker's
        ``shard.<i>.store.*`` / ``shard.<i>.rt.*`` namespaces, and the
        facade load/skew gauges — the layout
        :func:`repro.obs.reconcile.check_sharded` reconciles (aggregate ==
        sum of shards)."""
        self.stats.publish(reg, prefix="store")
        reg.gauge("sharded.n_shards").set(self.n_shards)
        reg.gauge("sharded.load_imbalance").set(self.load_imbalance())
        reg.gauge("sharded.max_batch_imbalance").set(
            self._max_batch_imbalance)
        reg.counter("sharded.critical_fetch_ms").inc(
            self._critical_fetch_s * 1e3)
        mean_load = max(float(self._shard_lookups.mean()), 1e-12)
        for s, st in enumerate(self.stores):
            st.stats.publish(reg, prefix=f"shard.{s}.store")
            reg.gauge(f"shard.{s}.imbalance").set(
                float(self._shard_lookups[s]) / mean_load)
            if self._engines is not None:
                self._engines[s].publish(reg, prefix=f"shard.{s}.rt")
        if self._ft is not None:
            # Fold any still-open downtime window into the per-shard
            # gauges without mutating the accumulated counters.
            saved = self._ft.down_us
            self._ft.down_us = saved + np.asarray(
                [self._injector.down_time_us(s, self.clock.now())
                 for s in range(self.n_shards)])
            self._ft.publish(reg)
            self._ft.down_us = saved
        return reg
