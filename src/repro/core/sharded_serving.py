"""Sharded multi-worker tiered serving: N simulated workers, one batched
tiered store (+ inline prefetch engine) each, all-to-all-style gather.

:class:`ShardedTieredStore` executes a
:class:`~repro.sharding.embedding_shard.ShardPlan`: every worker owns the
host-tier rows the plan assigned to it (a zero-copy-ordered slice of the
global table) and a fast-tier buffer sized by the plan's per-shard budget.
A batch of global ids is routed shard-locally in one vectorized pass
(``plan.route``), each touched shard runs one batched
:class:`~repro.core.tiered.TieredEmbeddingStore` lookup on its local ids,
and the results merge back into request order — the simulated equivalent
of the all-to-all that follows per-worker embedding lookups in
distributed DLRM serving.

Model outputs (Algorithm 1 triples, global-id keyed) route the same way,
through one **per-shard inline** :class:`~repro.runtime.prefetch_engine.
PrefetchEngine` each: the engine dedups in-flight prefetch ids, cancels
ids that became resident before issue, models each worker's private
background fetch channel (timeliness), and applies synchronously — so
the sharded store remains byte-for-byte equivalent to the composition of
its per-shard single stores (the contract the property suite checks).

Telemetry goes beyond the merged :class:`~repro.core.tiered.TierStats`:

* **load / skew** — per-shard routed-id counts, aggregate and worst
  single-batch imbalance (``max shard load / mean shard load``);
* **stall** — per-shard modeled slow-tier time, plus the *critical-path*
  view: per batch, workers fetch in parallel, so the batch pays the max
  over shards, not the sum.  ``parallel_fetch_speedup`` is the ratio.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.tiered import TierStats, TieredEmbeddingStore
from repro.obs.tracing import get_tracer
from repro.sharding.embedding_shard import (ShardPlan, make_plan,
                                            trace_frequencies)


class ShardedTieredStore:
    """N per-shard batched stores behind one single-store-compatible API.

    Parameters
    ----------
    host:  (n_vectors, D) global host-tier table in global-id order.
    plan:  a :class:`ShardPlan` (see :func:`ShardedTieredStore.build` for
           the convenience constructor that makes one).
    with_engines: route ``apply_model_outputs`` through per-shard inline
           prefetch engines (dedup/cancel/timeliness telemetry).  The
           apply semantics are identical either way.
    """

    def __init__(self, host: np.ndarray, plan: ShardPlan,
                 policy: str = "lru", quantize: bool = False,
                 fetch_us_fixed: float = 30.0, with_engines: bool = True,
                 **store_kw):
        if host.shape[0] != plan.n_vectors:
            raise ValueError(f"host has {host.shape[0]} rows, "
                             f"plan covers {plan.n_vectors}")
        self.plan = plan
        self.n_shards = plan.n_shards
        self.emb_dim = host.shape[1]
        # Per-shard stores model the per-row slow-tier cost; the fixed
        # per-batch overhead is charged at the facade (once per batch with
        # a miss for the sum view, once per missing *shard* for the
        # critical-path view) so policy comparisons aren't aggregation
        # artifacts — same scheme as the multi-table facade.
        self.fetch_us_fixed = float(fetch_us_fixed)
        self.stores: List[TieredEmbeddingStore] = [
            TieredEmbeddingStore(host[g], int(c), policy=policy,
                                 quantize=quantize, fetch_us_fixed=0.0,
                                 **store_kw)
            for g, c in zip(plan.global_ids, plan.capacities)
        ]
        self.out_dtype = (np.float32 if quantize
                          else self.stores[0].buffer.dtype)
        self.batches = 0
        self._fixed_fetch_s = 0.0
        # ---- load / critical-path telemetry ----
        self._shard_lookups = np.zeros(self.n_shards, np.int64)
        self._max_batch_imbalance = 0.0
        self._critical_fetch_s = 0.0   # sum over batches of max-over-shards
        self._engines = None
        if with_engines:
            from repro.runtime.clock import VirtualClock
            from repro.runtime.prefetch_engine import PrefetchEngine
            from repro.runtime.telemetry import RuntimeTelemetry

            self.clock = VirtualClock()
            self.engine_telemetry = [RuntimeTelemetry()
                                     for _ in range(self.n_shards)]
            self._engines = [
                PrefetchEngine(st, telemetry=tel, clock=self.clock,
                               scheduler="inline",
                               fetch_us_per_row=st.fetch_us_per_row,
                               fetch_us_fixed=self.fetch_us_fixed,
                               trace_track=f"pf-shard-{s}")
                for s, (st, tel) in enumerate(zip(self.stores,
                                                  self.engine_telemetry))
            ]

    @classmethod
    def build(cls, host: np.ndarray, rows_per_table: Sequence[int],
              n_shards: int, placement: str = "table",
              capacity: Optional[int] = None,
              frequencies: Optional[np.ndarray] = None,
              fast_weights: Optional[Sequence[float]] = None,
              profile_ids: Optional[np.ndarray] = None,
              **kw) -> "ShardedTieredStore":
        """Plan + store in one call.  ``profile_ids`` (a trace sample)
        stands in for explicit ``frequencies`` under ``"freq"``."""
        if capacity is None:
            raise ValueError("capacity (total fast-tier rows) is required")
        if frequencies is None and profile_ids is not None:
            frequencies = trace_frequencies(profile_ids, host.shape[0])
        plan = make_plan(rows_per_table, n_shards, int(capacity),
                         placement, frequencies=frequencies,
                         fast_weights=fast_weights)
        return cls(host, plan, **kw)

    # ---------------- routing + merge (the all-to-all) ----------------

    def lookup(self, global_ids: np.ndarray) -> jnp.ndarray:
        """(M,) global ids -> (M, D): scatter ids shard-locally, one
        batched per-shard lookup each, gather back in request order."""
        gid, shard, local = self.plan.route(global_ids)
        self.batches += 1
        loads = np.bincount(shard, minlength=self.n_shards)
        self._shard_lookups += loads
        self._max_batch_imbalance = max(
            self._max_batch_imbalance,
            float(loads.max() / max(loads.mean(), 1e-12)))
        out = np.empty((len(gid), self.emb_dim), self.out_dtype)
        missed_any = False
        critical_us = 0.0
        tr = get_tracer()
        for s in np.flatnonzero(loads).tolist():
            m = shard == s
            st = self.stores[s]
            f0, od0 = st.stats.modeled_fetch_s, st.stats.on_demand_rows
            if tr.enabled:
                t_s = tr.clock.now()
            # Timeliness probe only when this shard's channel has fetches
            # in flight — skips the per-batch unique() on cold paths.
            if self._engines is not None and self._engines[s]._pf_eta:
                self._engines[s].observe_demand(np.unique(local[m]),
                                                self.clock.now())
            # lookup_host: the all-to-all merge is host-side, so each
            # worker materializes in one transfer (no device-side slice).
            out[m] = st.lookup_host(local[m])
            d_us = (st.stats.modeled_fetch_s - f0) * 1e6
            if st.stats.on_demand_rows > od0:
                missed_any = True
                d_us += self.fetch_us_fixed
            critical_us = max(critical_us, d_us)
            if tr.enabled:
                # Per-shard route+gather window on this worker's track.
                tr.add_span("shard", "lookup", t_s, tr.clock.now() - t_s,
                            track=f"shard-{s}", args={
                                "shard": s, "rows": int(loads[s]),
                                "miss_rows": st.stats.on_demand_rows - od0})
        if missed_any:
            self._fixed_fetch_s += self.fetch_us_fixed * 1e-6
        self._critical_fetch_s += critical_us * 1e-6
        if self._engines is not None:
            # Workers fetch in parallel; modeled time moves by the batch's
            # critical path (what timeliness is measured against).
            self.clock.advance(critical_us)
        return jnp.asarray(out)

    def resident_mask(self, global_ids: np.ndarray) -> np.ndarray:
        gid, shard, local = self.plan.route(global_ids)
        mask = np.zeros(len(gid), bool)
        for s in np.unique(shard).tolist():
            m = shard == s
            mask[m] = self.stores[s].resident_mask(local[m])
        return mask

    def lookup_resident(self, global_ids: np.ndarray):
        """Degraded read (single-store API parity): ``(rows, n_default)``
        routed shard-locally — stale-but-resident rows, zero default for
        misses; no stats mutation, no slow-tier traffic, and no load/
        imbalance accounting (this is the answer a shard gives when it is
        *not* allowed to do work)."""
        gid, shard, local = self.plan.route(global_ids)
        out = np.zeros((len(gid), self.emb_dim), self.out_dtype)
        n_default = 0
        for s in np.unique(shard).tolist():
            m = shard == s
            rows, nd = self.stores[s].lookup_resident(local[m])
            out[m] = rows.astype(self.out_dtype, copy=False)
            n_default += nd
        return out, n_default

    def _route_outputs(self, trunk, bits, prefetch_ids, staged: bool):
        trunk, t_shard, t_loc = self.plan.route(trunk)
        bits = np.asarray(bits).ravel()[: len(trunk)]  # zip truncation
        t_shard, t_loc = t_shard[: len(bits)], t_loc[: len(bits)]
        _, p_shard, p_loc = self.plan.route(prefetch_ids)
        for s in np.unique(np.concatenate((t_shard, p_shard))).tolist():
            tm, pm = t_shard == s, p_shard == s
            if staged:
                self.stores[s].stage_model_outputs(t_loc[tm], bits[tm],
                                                   p_loc[pm])
            elif self._engines is not None:
                # Inline engine: dedup/cancel/channel accounting, then a
                # synchronous apply — store state matches a direct call.
                self._engines[s].submit(t_loc[tm], bits[tm], p_loc[pm],
                                        now_us=self.clock.now())
                self._engines[s].drain()
            else:
                self.stores[s].apply_model_outputs(t_loc[tm], bits[tm],
                                                   p_loc[pm])

    def apply_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Route Algorithm 1 outputs (global-id keyed) to each worker's
        engine (or store, with engines disabled)."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=False)

    def stage_model_outputs(self, trunk: np.ndarray, bits: np.ndarray,
                            prefetch_ids: np.ndarray):
        """Double-buffered apply: route now, land at each shard store's
        next lookup boundary."""
        self._route_outputs(trunk, bits, prefetch_ids, staged=True)

    def flush_staged(self):
        for st in self.stores:
            st.flush_staged()

    def warmup(self, batch_hint: int):
        """Eagerly compile every scatter/gather shape bucket a batch of up
        to ``batch_hint`` routed ids can hit on each worker (single-store
        API parity; module-level jits mean only the first shard pays each
        compile).  Alternatively pass ``warmup_batch=`` at construction —
        it flows to every per-shard store."""
        for st in self.stores:
            st.warmup(batch_hint)

    # ---------------- aggregated accounting ----------------

    @property
    def capacity(self) -> int:
        return int(sum(st.capacity for st in self.stores))

    @property
    def stats(self) -> TierStats:
        agg = TierStats()
        for st in self.stores:
            agg.merge(st.stats)
        agg.batches = self.batches  # facade batches, not per-shard sum
        agg.modeled_fetch_s += self._fixed_fetch_s
        return agg

    def modeled_batch_ms(self) -> float:
        """Sum view (comparable to the single store / facade)."""
        return 1e3 * self.stats.modeled_fetch_s / max(self.batches, 1)

    def critical_batch_ms(self) -> float:
        """Parallel view: per batch, the slowest shard's fetch."""
        return 1e3 * self._critical_fetch_s / max(self.batches, 1)

    def load_imbalance(self) -> float:
        """Aggregate max-shard load / mean-shard load (1.0 = perfect)."""
        total = self._shard_lookups
        return float(total.max() / max(total.mean(), 1e-12))

    def shard_telemetry(self) -> dict:
        """Per-shard load / skew / stall plus engine counters."""
        fetch_s = self.stats.modeled_fetch_s
        d = {
            "n_shards": self.n_shards,
            "placement": self.plan.placement,
            "per_shard_rows": self.plan.shard_rows.tolist(),
            "per_shard_capacity": [int(st.capacity) for st in self.stores],
            "per_shard_lookups": self._shard_lookups.tolist(),
            "per_shard_hit_rate": [round(st.stats.hit_rate, 4)
                                   for st in self.stores],
            "per_shard_evictions": [st.stats.evictions
                                    for st in self.stores],
            "per_shard_fetch_ms": [round(st.stats.modeled_fetch_s * 1e3, 3)
                                   for st in self.stores],
            "load_imbalance": round(self.load_imbalance(), 4),
            "max_batch_imbalance": round(self._max_batch_imbalance, 4),
            "modeled_fetch_ms_sum": round(fetch_s * 1e3, 3),
            "modeled_fetch_ms_critical": round(
                self._critical_fetch_s * 1e3, 3),
            "parallel_fetch_speedup": round(
                fetch_s / max(self._critical_fetch_s, 1e-12), 3),
        }
        if self._engines is not None:
            for k in ("pf_submitted", "pf_deduped", "pf_cancelled_resident",
                      "pf_issued", "pf_timely", "pf_late"):
                d[f"per_shard_{k}"] = [getattr(t, k)
                                       for t in self.engine_telemetry]
        return d

    def per_shard_hit_rates(self) -> List[float]:
        return [st.stats.hit_rate for st in self.stores]

    def publish_metrics(self, reg):
        """Publish the aggregate ``store.*`` view, every worker's
        ``shard.<i>.store.*`` / ``shard.<i>.rt.*`` namespaces, and the
        facade load/skew gauges — the layout
        :func:`repro.obs.reconcile.check_sharded` reconciles (aggregate ==
        sum of shards)."""
        self.stats.publish(reg, prefix="store")
        reg.gauge("sharded.n_shards").set(self.n_shards)
        reg.gauge("sharded.load_imbalance").set(self.load_imbalance())
        reg.gauge("sharded.max_batch_imbalance").set(
            self._max_batch_imbalance)
        reg.counter("sharded.critical_fetch_ms").inc(
            self._critical_fetch_s * 1e3)
        mean_load = max(float(self._shard_lookups.mean()), 1e-12)
        for s, st in enumerate(self.stores):
            st.stats.publish(reg, prefix=f"shard.{s}.store")
            reg.gauge(f"shard.{s}.imbalance").set(
                float(self._shard_lookups[s]) / mean_load)
            if self._engines is not None:
                self._engines[s].publish(reg, prefix=f"shard.{s}.rt")
        return reg
