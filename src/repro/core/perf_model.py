"""Linear performance model (paper §VII-F, Fig. 18).

DLRM inference time is linear in the buffer hit rate: t = t0 - s * hit_rate
(equivalently t = a + b * misses), validated in the paper with RMSE < 3.75ms
(1.7%).  We fit it from measured (hit_rate, latency) points produced by the
tiered-memory runtime and use it to estimate end-to-end latency for every
caching/prefetching strategy from its simulated hit rate (Fig. 19).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass
class LinearPerfModel:
    intercept: float  # latency at hit rate 0
    slope: float  # d latency / d hit_rate (negative)
    rmse: float = 0.0

    def predict(self, hit_rate):
        return self.intercept + self.slope * np.asarray(hit_rate)

    def as_dict(self):
        return {"intercept_ms": self.intercept, "slope_ms_per_hit": self.slope,
                "rmse_ms": self.rmse}


def fit_perf_model(hit_rates: Sequence[float],
                   latencies_ms: Sequence[float]) -> LinearPerfModel:
    x = np.asarray(hit_rates, dtype=np.float64)
    y = np.asarray(latencies_ms, dtype=np.float64)
    A = np.stack([np.ones_like(x), x], axis=1)
    (b0, b1), *_ = np.linalg.lstsq(A, y, rcond=None)
    resid = y - (b0 + b1 * x)
    return LinearPerfModel(float(b0), float(b1),
                           float(np.sqrt((resid ** 2).mean())))
