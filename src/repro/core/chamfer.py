"""The paper's bidirectional Chamfer loss (Eq. 5).

dist(PO, W) = a * mean_{x in PO} min_{y in W} |x-y|
            + (1-a) * mean_{y in W} min_{x in PO} |x-y|

The reverse term prevents the mode-collapse shortcut of one-sided Chamfer
(all outputs predicting the single easiest target — the paper's {1,2,3} vs
{2,6,7,8} example).  alpha = 0.7 per the paper.

The pairwise |PO| x |W| distance matrix is tiny at model scale (5 x 15) but
is evaluated for millions of windows per training epoch — the Pallas kernel
in repro/kernels/chamfer.py fuses the batched pairwise-min reduction; this
module is the jnp reference used everywhere off-TPU.
"""
from __future__ import annotations

import jax.numpy as jnp


def pairwise_abs(po, w):
    """po: (..., P), w: (..., W) -> (..., P, W)."""
    return jnp.abs(po[..., :, None] - w[..., None, :])


def chamfer_forward(po, w):
    """One-sided d_CM(PO, W) (Eq. 4), mean over PO. Shapes (..., P), (..., W)."""
    return pairwise_abs(po, w).min(axis=-1).mean(axis=-1)


def chamfer_bidirectional(po, w, alpha: float = 0.7):
    """Eq. 5, already normalized by |PO| and |W|.  Returns (...,)."""
    d = pairwise_abs(po, w)
    fwd = d.min(axis=-1).mean(axis=-1)  # each PO point -> nearest W
    bwd = d.min(axis=-2).mean(axis=-1)  # each W point -> nearest PO
    return alpha * fwd + (1.0 - alpha) * bwd


def l2_truncated(po, w):
    """Ablation baseline (paper Fig. 11): elementwise L2 against the first
    |PO| ground-truth accesses (evaluation window == output length)."""
    wt = w[..., : po.shape[-1]]
    return ((po - wt) ** 2).mean(axis=-1)


# ---------------------------------------------------------------------------
# Vector-space (learned-representation) variants.
#
# The prefetch model predicts points in the encoder's dense representation
# space ("the encoder/decoder pair naturally generates a dense representation
# of embedding vectors in a continuous space", §V) and the Chamfer measure
# compares the predicted set against the window's representations.  Squared
# L2 keeps Eq. 4/5's structure and allows matmul-based nearest-neighbor
# decode at deployment.
# ---------------------------------------------------------------------------


def pairwise_sqdist(po, w):
    """po: (..., P, F), w: (..., W, F) -> (..., P, W) squared L2."""
    d = po[..., :, None, :] - w[..., None, :, :]
    return (d * d).sum(axis=-1)


def chamfer_bidirectional_vec(po, w, alpha: float = 0.7):
    """Eq. 5 over representation vectors."""
    d = pairwise_sqdist(po, w)
    fwd = d.min(axis=-1).mean(axis=-1)
    bwd = d.min(axis=-2).mean(axis=-1)
    return alpha * fwd + (1.0 - alpha) * bwd


def l2_truncated_vec(po, w):
    wt = w[..., : po.shape[-2], :]
    return ((po - wt) ** 2).sum(axis=-1).mean(axis=-1)
