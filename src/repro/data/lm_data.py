"""Deterministic, resumable synthetic LM token pipeline.

Fault-tolerance contract: batch(step) is a pure function of (seed, step,
shape), so restart-from-checkpoint replays the exact stream with no data
loss or duplication, and elastic restarts with a different data-parallel
layout still see the same global batch order.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class LMDataConfig:
    vocab: int = 49152
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0
    # Markov-ish synthetic text: makes loss meaningfully decrease.
    n_states: int = 64


def _batch_np(cfg: LMDataConfig, step: int) -> np.ndarray:
    rng = np.random.default_rng((cfg.seed << 20) ^ step)
    B, S = cfg.global_batch, cfg.seq_len
    # Low-order Markov structure so a real LM can learn something.
    trans = np.arange(cfg.n_states)
    state = rng.integers(0, cfg.n_states, size=B)
    toks = np.empty((B, S), dtype=np.int32)
    noise = rng.integers(0, cfg.vocab, size=(B, S))
    jump = rng.random((B, S)) < 0.15
    for t in range(S):
        state = (state * 31 + 17) % cfg.n_states
        toks[:, t] = state * (cfg.vocab // cfg.n_states)
    toks = np.where(jump, noise, toks).astype(np.int32)
    return toks % cfg.vocab


def batch_at(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    toks = _batch_np(cfg, step)
    labels = np.concatenate(
        [toks[:, 1:], np.full((toks.shape[0], 1), -1, np.int32)], axis=1
    )
    return {"tokens": toks, "labels": labels}


def stream(cfg: LMDataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
