"""DLRM inference-query generator: dense features + multi-hot sparse ids.

Query batches are derived from a ``repro.core.trace`` access stream so the
serving runtime, the cache simulators and the DLRM model all see the same
distribution; labels for training are a synthetic CTR function of the
features (deterministic, so loss decrease is meaningful).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.trace import Trace, TraceGenConfig, generate_trace


@dataclass(frozen=True)
class DLRMDataConfig:
    n_tables: int = 8
    rows_per_table: int = 4096
    multi_hot: int = 4
    dense_features: int = 13
    batch: int = 256
    seed: int = 0


def query_batches(cfg: DLRMDataConfig, trace: Optional[Trace] = None,
                  n_batches: int = 100,
                  workload=None) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {dense (B,F), sparse (B,T,P), label (B,)} batches.

    With a trace, sparse ids replay its access stream (query-aligned);
    ``workload`` (a :class:`~repro.workloads.spec.WorkloadSpec`) derives
    the trace from a named scenario regime at this config's geometry;
    otherwise ids come from the default calibrated generator.
    """
    rng = np.random.default_rng(cfg.seed)
    B, T, P = cfg.batch, cfg.n_tables, cfg.multi_hot
    per_batch = B * T * P

    if trace is None and workload is not None:
        from repro.workloads import make_trace

        trace = make_trace(workload.with_(
            n_tables=T, rows_per_table=cfg.rows_per_table,
            n_accesses=n_batches * per_batch, seed=cfg.seed))
    if trace is None:
        tr_cfg = TraceGenConfig(
            n_tables=T, rows_per_table=cfg.rows_per_table,
            n_accesses=n_batches * per_batch, seed=cfg.seed,
        )
        trace = generate_trace(tr_cfg)

    rows = trace.row_id
    tables = trace.table_id
    pos = 0
    for _ in range(n_batches):
        if pos + per_batch > len(rows):
            pos = 0
        # Reshape the flat stream into (B, T, P) respecting table ids as
        # best effort: use the row stream and assign tables round-robin (the
        # trace's own table marginals are preserved in expectation).
        sl = rows[pos : pos + per_batch]
        sparse = (sl % cfg.rows_per_table).reshape(B, T, P).astype(np.int32)
        pos += per_batch
        dense = rng.normal(size=(B, cfg.dense_features)).astype(np.float32)
        # Synthetic CTR: depends on dense features + id parity (learnable).
        logit = dense[:, 0] - 0.5 * dense[:, 1] + 0.1 * (
            (sparse.sum(axis=(1, 2)) % 7) - 3
        )
        label = (logit + 0.5 * rng.normal(size=B) > 0).astype(np.float32)
        yield {"dense": dense, "sparse": sparse, "label": label}
