"""Partitioning rules: logical intents -> physical PartitionSpecs.

Strategy (FSDP x TP, "fsdp_tp"):
  * every >=2D parameter shards its feature-out dim on ``model`` (tensor
    parallel) and one other large dim on the data axes (``("pod","data")``
    multi-pod, ``("data",)`` single-pod) — that is FSDP/ZeRO-3: weights are
    gathered per layer inside the ``lax.scan`` over layers;
  * the stacked layer dim (leading L under ``blocks``) is never sharded;
  * any assignment whose dim is not divisible by the mesh-axis product is
    dropped (progressively, for tuple assignments), so odd head counts
    (15H smollm) or 1500-frame caches still lower — they just replicate.

Variants: "tp" (no FSDP), "dp" (pure data parallel) — perf-loop knobs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks")

# ---------------------------------------------------------------------------
# Activation sharding context.
#
# Model code calls ``constrain_batch(x)`` at layer boundaries; under an
# ``activation_sharding(mesh)`` scope this pins the batch dim to the data
# axes (otherwise GSPMD is free to drift to feature-sharded/batch-replicated
# layouts once it passes through ops whose dims don't divide the mesh — at
# 512 devices that costs 16-32x activation memory).  Outside the scope (CPU
# tests, examples) it is a no-op.
# ---------------------------------------------------------------------------

_ACT_MESH = None
_ACT_VARIANT = "fsdp_tp"


class activation_sharding:
    def __init__(self, mesh, variant: str = "fsdp_tp"):
        self.mesh = mesh
        self.variant = variant

    def __enter__(self):
        global _ACT_MESH, _ACT_VARIANT
        self._prev = (_ACT_MESH, _ACT_VARIANT)
        _ACT_MESH = self.mesh
        _ACT_VARIANT = self.variant
        return self

    def __exit__(self, *exc):
        global _ACT_MESH, _ACT_VARIANT
        _ACT_MESH, _ACT_VARIANT = self._prev
        return False


def batch_entry(mesh, variant: Optional[str] = None):
    """Axes the batch dim of activations/inputs shards over."""
    variant = variant or _ACT_VARIANT
    dp = data_axes(mesh)
    if variant == "fsdp":
        return dp + ("model",)  # no TP: every axis is data-parallel
    return dp


def seq_entry(mesh, variant: Optional[str] = None):
    """Axes the sequence dim of activations shards over (sequence
    parallelism for small-batch prefill: 'fsdp_seq')."""
    variant = variant or _ACT_VARIANT
    return ("model",) if variant == "fsdp_seq" else None


def constrain_kv_gather(x, batch_dim: int = 0):
    """Under 'fsdp_seq': pin K/V to be sequence-REPLICATED (batch-sharded
    only), so attention gathers each layer's K/V once (cheap under GQA)
    while Q stays sequence-sharded and the score einsum partitions along
    Q's shards.  No-op outside the seq variant."""
    mesh = _ACT_MESH
    if mesh is None or not seq_entry(mesh):
        return x
    entries = [None] * x.ndim
    entries[batch_dim] = batch_entry(mesh)
    spec = fit_spec(x.shape, entries, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x


def constrain_batch(x, batch_dim: int = 0):
    mesh = _ACT_MESH
    if mesh is None:
        return x
    be = batch_entry(mesh)
    if not be:
        return x
    entries = [None] * x.ndim
    entries[batch_dim] = be
    se = seq_entry(mesh)
    if se and x.ndim >= 3 and batch_dim == 0:
        entries[1] = se  # (B, S, ...) activations: shard S too
    if se and x.ndim == 2 and batch_dim == 0:
        # Flattened (B*S, D) token tables (MoE dispatch): combined axes.
        entries[0] = tuple(be) + tuple(se)
    spec = fit_spec(x.shape, entries, mesh)
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        # Inside shard_map the mesh axes are manual: per-shard code is
        # already sharded by construction — the constraint is a no-op.
        return x


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fit_spec(shape, entries, mesh: Mesh) -> P:
    """Drop (progressively) axis assignments that don't divide the dim."""
    out = []
    for dim, ent in enumerate(entries):
        if ent is None or dim >= len(shape):
            out.append(None)
            continue
        cand = (ent,) if isinstance(ent, str) else tuple(ent)
        while cand and shape[dim] % _axis_size(mesh, cand) != 0:
            cand = cand[:-1]
        if not cand:
            out.append(None)
        elif len(cand) == 1:
            out.append(cand[0])
        else:
            out.append(cand)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# (model_dim, fsdp_dim) for each named parameter, relative to the UNSTACKED
# tensor.  Parent-qualified names ("moe/w1") take precedence.
_RULES = {
    "embed": (0, 1),
    "lm_head": (1, 0),
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0), "wo": (0, 1),
    "bq": (0, None), "bk": (0, None), "bv": (0, None),
    "w1": (1, 0), "w3": (1, 0), "w2": (0, 1),
    "router": (None, 0),
    "moe/w1": (2, 1), "moe/w3": (2, 1), "moe/w2": (1, 2),
    "in_proj": (1, 0),
    "conv_w": (1, None), "conv_b": (0, None),
    "x_proj": (0, None), "dt_proj": (1, None), "dt_bias": (0, None),
    "A_log": (0, None), "D_skip": (0, None),
    "out_proj": (0, 1),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return tuple(names)


def param_pspecs(param_struct, mesh: Mesh, sharding: str = "fsdp_tp",
                 emb_rows: str = "all"):
    """PartitionSpec pytree for a parameter pytree (of ShapeDtypeStructs).

    Variants: fsdp_tp (default), tp (no FSDP), dp (replicated params),
    fsdp / fsdp_seq (params FSDP-sharded over every axis, no TP).
    ``emb_rows``: "all" shards DLRM EMB rows over every axis; "model" keeps
    them on the model axis only (each data replica owns a full row shard —
    enables pool-before-reduce lookups; see models/dlrm.py).
    """
    dp = data_axes(mesh)
    use_tp = sharding in ("fsdp_tp", "tp") and "model" in mesh.axis_names
    use_fsdp = sharding == "fsdp_tp"
    fsdp_all = sharding in ("fsdp", "fsdp_seq")

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        parent = names[-2] if len(names) >= 2 else ""
        stacked = any(k in names for k in STACKED_KEYS)
        shape = leaf.shape

        if name == "emb" and len(shape) == 3:  # DLRM EMBs: row-sharded
            axes = ("model",) if emb_rows == "model" else dp + ("model",)
            return fit_spec(shape, [None, axes, None], mesh)

        rule = _RULES.get(f"{parent}/{name}") or _RULES.get(name)
        if rule is None or len(shape) < (2 if not stacked else 2):
            # norms, biases without rules, scalars: replicate (but strip the
            # stacked dim consideration — replication is always valid).
            return P()
        model_dim, fsdp_dim = rule
        off = 1 if stacked else 0
        entries = [None] * len(shape)
        if fsdp_all:
            # Pure FSDP: shard the largest rule dim over every mesh axis.
            cands = [d for d in (model_dim, fsdp_dim)
                     if d is not None and d + off < len(shape)]
            if cands:
                d = max(cands, key=lambda dd: shape[dd + off])
                entries[d + off] = dp + ("model",)
            return fit_spec(shape, entries, mesh)
        if use_tp and model_dim is not None and model_dim + off < len(shape):
            entries[model_dim + off] = "model"
        if use_fsdp and dp and fsdp_dim is not None and fsdp_dim + off < len(shape):
            entries[fsdp_dim + off] = dp
        return fit_spec(shape, entries, mesh)

    return jax.tree_util.tree_map_with_path(spec_for, param_struct)


def batch_pspecs(batch_struct, mesh: Mesh, sharding: str = "fsdp_tp"):
    """Shard dim 0 (global batch) of every batch leaf on the data axes
    (plus seq dim on model for the 'fsdp_seq' variant)."""
    be = batch_entry(mesh, sharding)
    se = seq_entry(mesh, sharding)

    def spec_for(leaf):
        if not leaf.shape:
            return P()
        entries = [be] + [None] * (len(leaf.shape) - 1)
        if se and len(leaf.shape) >= 2:
            entries[1] = se
        return fit_spec(leaf.shape, entries, mesh)

    return jax.tree_util.tree_map(spec_for, batch_struct)


def cache_pspecs(cache_struct, mesh: Mesh, shard_kv_seq: bool = True):
    """Decode-cache shardings.

    k/v (L, B, S, K, hd): batch on data; the cache length S on ``model`` when
    ``shard_kv_seq`` (GSPMD reduces the softmax across the sharded length),
    else KV heads on ``model`` when divisible.  SSM state (L, B, Di, N) and
    conv state (L, B, W, Di) shard Di on ``model``.
    """
    dp = data_axes(mesh)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        if name == "pos" or not shape:
            return P()
        if name in ("k", "v", "xk", "xv"):
            if shard_kv_seq:
                return fit_spec(shape, [None, dp, "model", None, None], mesh)
            return fit_spec(shape, [None, dp, None, "model", None], mesh)
        if name == "conv":
            return fit_spec(shape, [None, dp, None, "model"], mesh)
        if name == "h":
            return fit_spec(shape, [None, dp, "model", None], mesh)
        return fit_spec(shape, [None, dp], mesh)

    return jax.tree_util.tree_map_with_path(spec_for, cache_struct)


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )
