"""Shard placement planning for multi-worker tiered serving.

RecShard (Sethi et al.) and Software-Defined Memory (Ardestani et al.)
both show that *where* an embedding-table shard lands — across workers
and across memory tiers — dominates end-to-end DLRM latency.  This
module turns a (tables, rows, capacity) description into a
:class:`ShardPlan`: a dense, vectorized mapping from the trace's global
vector ids onto ``n_shards`` simulated workers, plus a fast-tier row
budget per shard.  :class:`~repro.core.sharded_serving.ShardedTieredStore`
executes the plan with one per-shard :class:`~repro.core.tiered.
TieredEmbeddingStore`.

Placement policies (``PLACEMENTS``):

* ``"table"`` — table-wise: whole tables land on one shard, packed by a
  greedy longest-processing-time bin-pack over row counts (the classic
  TorchRec/RecShard baseline; cheap routing, but a hot table skews one
  worker).
* ``"row"``   — row-wise round-robin: ``shard = global_id % n_shards``
  (fine-grained striping; near-perfect load balance, every batch touches
  every shard).
* ``"hash"``  — row-wise keyed hash (Knuth multiplicative): decorrelates
  shard choice from table layout and trace structure.
* ``"freq"``  — frequency-aware (RecShard-style): given per-row access
  frequencies from a profiling sample, the hottest ``sum(capacities)``
  rows are spread across shards by weighted round-robin **proportional to
  each shard's fast-tier budget** — hot rows pack onto fast-tier-rich
  shards and every hot row can be fast-tier resident — while cold rows
  are dealt out to equalize per-shard row counts.

Every placement numbers a shard's local rows in ascending global-id
order, so with ``n_shards=1`` each policy degenerates to the identity
mapping and the sharded store reproduces the single-store counters
byte-for-byte (the equivalence contract tested in
``tests/test_property_equivalence.py``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

PLACEMENTS = ("table", "row", "hash", "freq")

_KNUTH = 2654435761  # multiplicative hash constant (same as trace gen)


@dataclass
class ShardPlan:
    """A placement decision: global id -> (shard, local row) + budgets.

    ``global_ids[s]`` is sorted ascending, so ``local_of`` is the rank of
    a global id within its shard's set and ``host[global_ids[s]]`` is the
    shard's local host-tier table.
    """

    placement: str
    n_shards: int
    shard_of: np.ndarray        # (n_vectors,) int32: global id -> shard
    local_of: np.ndarray        # (n_vectors,) int64: global id -> local row
    global_ids: List[np.ndarray]  # per shard: local row -> global id
    capacities: np.ndarray      # (n_shards,) int64: fast-tier rows
    # Hot-row replication (RecShard's CDF lever): the top-k rows by
    # profiled frequency, resident on *every* shard in addition to their
    # home shard.  None/empty == no replication.  The facade serves a
    # dead shard's replicated rows from this set with exact bytes.
    replicated_ids: Optional[np.ndarray] = None

    @property
    def n_vectors(self) -> int:
        return len(self.shard_of)

    @property
    def shard_rows(self) -> np.ndarray:
        return np.asarray([len(g) for g in self.global_ids], np.int64)

    def route(self, global_ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray,
                                                     np.ndarray]:
        """Vectorized routing: (gid, shard, local) for a flat id batch."""
        gid = np.asarray(global_ids, np.int64).ravel()
        return gid, self.shard_of[gid], self.local_of[gid]

    def check(self):
        """Plan invariants (used by tests): the shard/local maps and the
        per-shard id lists are exact inverses, budgets are sane."""
        assert len(self.global_ids) == self.n_shards
        seen = 0
        for s, g in enumerate(self.global_ids):
            assert np.all(np.diff(g) > 0)  # sorted ascending, unique
            assert np.all(self.shard_of[g] == s)
            assert np.array_equal(self.local_of[g], np.arange(len(g)))
            assert 1 <= self.capacities[s] <= max(len(g), 1)
            seen += len(g)
        assert seen == self.n_vectors
        if self.replicated_ids is not None and len(self.replicated_ids):
            r = self.replicated_ids
            assert np.all(np.diff(r) > 0)  # sorted ascending, unique
            assert 0 <= r[0] and r[-1] < self.n_vectors

    def replica_mask(self) -> np.ndarray:
        """(n_vectors,) bool: True where the row is hot-replicated."""
        m = np.zeros(self.n_vectors, bool)
        if self.replicated_ids is not None:
            m[self.replicated_ids] = True
        return m


def trace_frequencies(global_ids: np.ndarray, n_vectors: int,
                      sample_frac: float = 0.25) -> np.ndarray:
    """Per-row access counts from a trace prefix (the profiling sample a
    frequency-aware planner would collect online)."""
    gid = np.asarray(global_ids, np.int64).ravel()
    n = max(1, int(len(gid) * sample_frac))
    return np.bincount(gid[:n], minlength=n_vectors).astype(np.int64)


def make_plan(rows_per_table: Sequence[int], n_shards: int, capacity: int,
              placement: str = "table",
              frequencies: Optional[np.ndarray] = None,
              fast_weights: Optional[Sequence[float]] = None,
              replicate_hot: int = 0) -> ShardPlan:
    """Build a :class:`ShardPlan`.

    ``capacity`` is the *total* fast-tier row budget across shards, split
    proportionally to ``fast_weights`` (default: assigned rows for
    table/row/hash, uniform for freq) with a one-row floor per shard.
    ``frequencies`` (required for ``"freq"``) are per-global-id access
    counts, e.g. from :func:`trace_frequencies`.

    ``replicate_hot`` marks the top-k rows by ``frequencies`` (required
    when k > 0) as replicated on every shard: RecShard's per-table CDFs
    show a tiny hot set covers most traffic, which is exactly the set
    that must stay answerable from survivors when a shard dies.  Routing
    is unchanged (each row keeps one home shard); ``replicated_ids`` is
    the failover layer's exact-answer set.
    """
    if placement not in PLACEMENTS:
        raise ValueError(f"unknown placement {placement!r}; "
                         f"expected one of {PLACEMENTS}")
    rows = np.asarray(rows_per_table, np.int64)
    n_vectors = int(rows.sum())
    n_shards = int(n_shards)
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n_vectors < n_shards:
        raise ValueError(f"{n_vectors} vectors cannot span {n_shards} shards")
    capacity = max(n_shards, min(int(capacity), n_vectors))

    if placement == "freq":
        if frequencies is None:
            raise ValueError('placement "freq" needs per-row frequencies '
                             "(see trace_frequencies)")
        freq = np.asarray(frequencies, np.float64).ravel()
        if len(freq) != n_vectors:
            raise ValueError(f"frequencies cover {len(freq)} rows, "
                             f"tables hold {n_vectors}")
        caps = _split_budget(capacity,
                             np.asarray(fast_weights, np.float64)
                             if fast_weights is not None
                             else np.ones(n_shards),
                             np.full(n_shards, n_vectors, np.int64))
        shard_of = _assign_freq(freq, caps, n_shards)
    else:
        if placement == "table":
            shard_of = np.repeat(_pack_tables(rows, n_shards), rows)
        elif placement == "row":
            shard_of = (np.arange(n_vectors, dtype=np.int64)
                        % n_shards).astype(np.int32)
        else:  # hash
            gid = np.arange(n_vectors, dtype=np.uint64)
            h = (gid * np.uint64(_KNUTH)) % np.uint64(1 << 32)
            # High bits: the multiplicative hash's low bits pass the id
            # through (K is odd), which would degenerate to round-robin
            # for power-of-two shard counts.
            shard_of = ((h >> np.uint64(16))
                        % np.uint64(n_shards)).astype(np.int32)
            # Tiny tables can leave a shard hashless; rebalance by moving
            # the fullest shard's highest ids (deterministic, and only
            # ever triggers when n_vectors is within a few x of n_shards).
            counts = np.bincount(shard_of, minlength=n_shards)
            for s in np.flatnonzero(counts == 0).tolist():
                big = int(np.argmax(counts))
                shard_of[np.flatnonzero(shard_of == big)[-1]] = s
                counts[big] -= 1
                counts[s] += 1
        shard_rows = np.bincount(shard_of, minlength=n_shards)
        if shard_rows.min() == 0:
            raise ValueError(
                f"placement {placement!r} left a shard empty: table-wise "
                f"placement cannot use more shards ({n_shards}) than "
                f"tables ({len(rows)})")
        caps = _split_budget(capacity,
                             np.asarray(fast_weights, np.float64)
                             if fast_weights is not None
                             else shard_rows.astype(np.float64),
                             shard_rows)

    # Local numbering: rank within the shard's ascending global-id set
    # (flatnonzero returns sorted indices), so n_shards=1 is the identity.
    local_of = np.empty(n_vectors, np.int64)
    global_ids = []
    for s in range(n_shards):
        g = np.flatnonzero(shard_of == s)
        local_of[g] = np.arange(len(g))
        global_ids.append(g)
    caps = np.minimum(caps, np.asarray([max(len(g), 1)
                                        for g in global_ids], np.int64))

    replicated = None
    if replicate_hot > 0:
        if frequencies is None:
            raise ValueError("replicate_hot needs per-row frequencies "
                             "(see trace_frequencies)")
        freq = np.asarray(frequencies, np.float64).ravel()
        if len(freq) != n_vectors:
            raise ValueError(f"frequencies cover {len(freq)} rows, "
                             f"tables hold {n_vectors}")
        k = min(int(replicate_hot), n_vectors)
        # Same stable hotness order as _assign_freq: frequency descending,
        # global id ascending — the replica set is deterministic.
        hot_order = np.lexsort((np.arange(n_vectors), -freq))
        replicated = np.sort(hot_order[:k]).astype(np.int64)
    return ShardPlan(placement, n_shards, shard_of.astype(np.int32),
                     local_of, global_ids, caps, replicated_ids=replicated)


def _pack_tables(rows: np.ndarray, n_shards: int) -> np.ndarray:
    """Greedy LPT bin-pack: biggest table first onto the lightest shard
    (deterministic: ties break to the lowest shard id).  Returns the
    shard id per table."""
    order = np.argsort(-rows, kind="stable")
    load = np.zeros(n_shards, np.int64)
    shard_of_table = np.empty(len(rows), np.int32)
    for t in order.tolist():
        s = int(np.argmin(load))  # argmin ties -> lowest index
        shard_of_table[t] = s
        load[s] += rows[t]
    return shard_of_table


def _split_budget(capacity: int, weights: np.ndarray,
                  shard_rows: np.ndarray) -> np.ndarray:
    """Proportional fast-tier split with a one-row floor, clamped to the
    rows a shard actually holds, excess clawed back largest-first (the
    same deterministic scheme as the multi-table facade)."""
    w = np.maximum(np.asarray(weights, np.float64), 1e-12)
    caps = np.maximum(1, np.floor(capacity * w / w.sum())).astype(np.int64)
    caps = np.minimum(caps, shard_rows)
    excess = int(caps.sum() - capacity)
    while excess > 0:
        i = int(np.argmax(caps))
        take = min(excess, int(caps[i]) - 1)
        if take <= 0:
            break
        caps[i] -= take
        excess -= take
    # Leftover budget (rounding) tops up the largest-weight shards.
    short = int(capacity - caps.sum())
    order = np.argsort(-w, kind="stable")
    while short > 0:
        gave = 0
        for i in order.tolist():
            if short == 0:
                break
            if caps[i] < shard_rows[i]:
                caps[i] += 1
                short -= 1
                gave += 1
        if gave == 0:
            break  # every shard is at its row count: budget > n_vectors
    return caps


def _assign_freq(freq: np.ndarray, caps: np.ndarray,
                 n_shards: int) -> np.ndarray:
    """RecShard-style frequency-aware assignment.

    Hot set = the ``sum(caps)`` most-accessed rows (ties -> lower global
    id).  Hot rows are dealt by weighted round-robin proportional to each
    shard's fast-tier budget — shard ``s`` receives exactly ``caps[s]``
    hot rows, interleaved by rank so expected hot *traffic* is spread in
    the same proportion (a fast-tier-rich shard gets both more and hotter
    rows, never only the tail).  Cold rows fill per-shard quotas chosen
    to equalize total row counts.
    """
    n_vectors = len(freq)
    # Stable hotness order: frequency descending, global id ascending.
    order = np.lexsort((np.arange(n_vectors), -freq))
    n_hot = int(caps.sum())
    hot, cold = order[:n_hot], order[n_hot:]

    shard_of = np.empty(n_vectors, np.int32)
    # Weighted round-robin: shard s occupies virtual positions (k+1)/caps[s]
    # — sorting them interleaves shards proportionally to budget (ties ->
    # lower shard id via the secondary key).
    seq = np.repeat(np.arange(n_shards), caps)
    pos = np.concatenate([(np.arange(c) + 1) / max(c, 1) for c in caps])
    shard_of[hot] = seq[np.lexsort((seq, pos))].astype(np.int32)

    if cold.size:
        # Equalize totals: shard quota = balanced total minus hot count.
        target = np.full(n_shards, n_vectors // n_shards, np.int64)
        target[: n_vectors % n_shards] += 1
        quota = np.maximum(target - caps, 0)
        short = int(cold.size - quota.sum())
        # Rounding/clamping remainder goes to the least-loaded shards.
        order_q = np.argsort(caps + quota, kind="stable")
        i = 0
        while short > 0:
            quota[order_q[i % n_shards]] += 1
            short -= 1
            i += 1
        while short < 0:
            s = int(order_q[(i - 1) % n_shards])
            if quota[s] > 0:
                quota[s] -= 1
                short += 1
            i -= 1
        # Deal cold rows coldest-last in contiguous per-shard blocks
        # (cold rows rarely drive load; determinism matters more).
        shard_of[cold] = np.repeat(np.arange(n_shards), quota)
    return shard_of
