"""Sharded, atomic, resumable checkpoints (no orbax in this container).

Layout: <dir>/step_<N>/
  manifest.json        — pytree structure, shapes, dtypes, step, metadata
  shard_<host>.npz     — this host's param/opt leaves (addressable shards)

Fault-tolerance properties:
  * atomic publish: written to step_<N>.tmp then os.replace'd — a crash
    mid-write never corrupts the latest checkpoint;
  * async: ``save_async`` snapshots to host RAM synchronously (cheap) and
    writes in a background thread so the train loop keeps stepping;
  * elastic restore: leaves are stored unsharded per-host here (single-host
    container); ``restore`` re-device_puts onto whatever sharding the new
    mesh prescribes, so restarts on a different topology work.
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, meta: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Synchronous atomic save.  Returns the final directory path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        import shutil

        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    host = jax.process_index()
    arrs = {}
    for i, leaf in enumerate(leaves):
        arrs[f"leaf_{i}"] = np.asarray(leaf)
    np.savez(tmp / f"shard_{host}.npz", **arrs)
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "shapes": [list(np.shape(l)) for l in leaves],
        "dtypes": [str(np.asarray(l).dtype) for l in leaves],
        "meta": meta or {},
        "time": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():  # re-save of the same step: replace atomically-enough
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)

    # Retention.
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for old in steps[:-keep]:
        import shutil

        shutil.rmtree(old, ignore_errors=True)
    return str(final)


_PENDING: Dict[str, threading.Thread] = {}


def save_async(ckpt_dir: str, step: int, tree: Any,
               meta: Optional[Dict] = None, keep: int = 3) -> threading.Thread:
    """Snapshot to host RAM now, write in the background."""
    snapshot = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, snapshot, meta, keep), daemon=True
    )
    t.start()
    _PENDING[ckpt_dir] = t
    return t


def wait_pending(ckpt_dir: str):
    t = _PENDING.pop(ckpt_dir, None)
    if t is not None:
        t.join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = Path(ckpt_dir)
    if not p.exists():
        return None
    steps = sorted(
        int(d.name.split("_")[1]) for d in p.glob("step_*")
        if d.is_dir() and not d.name.endswith(".tmp")
    )
    return steps[-1] if steps else None


def restore(ckpt_dir: str, like: Any, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of ``like``; re-shards onto ``shardings``
    (pytree of NamedSharding) if given — this is the elastic-restart path."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    data = np.load(d / f"shard_{jax.process_index()}.npz")
    leaves, treedef = _flatten(like)
    n = json.loads((d / "manifest.json").read_text())["n_leaves"]
    assert n == len(leaves), f"checkpoint has {n} leaves, model has {len(leaves)}"
    new_leaves = [data[f"leaf_{i}"] for i in range(n)]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    return tree, step
