"""dlrm-recmg — the paper's own architecture (RecMG's DLRM).

Sized after the paper's evaluation platform: 856 sparse features (we shard
the 62M unique vectors evenly across tables), emb dim 128, bottom/top MLPs
per the open-source DLRM reference [arXiv:1906.00091].  EMBs are row-sharded
across the whole mesh (the "tiered memory" device buffer is the serving-side
feature; at dry-run scale the tables live sharded in HBM).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dlrm-recmg",
    family="dlrm",
    n_tables=856,
    rows_per_table=72704,  # ~62M unique vectors / 856 tables (512-divisible)
    emb_dim=128,
    multi_hot=20,
    dense_features=13,
    bottom_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    source="[arXiv:1906.00091 + paper §VII; calibrated]",
)
