"""falcon-mamba-7b — mamba-1, attention-free [arXiv:2410.05355; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    d_ff=0,  # attention-free, MLP-free mamba blocks
    vocab=65024,
    ssm_state=16,
    d_inner=8192,
    source="[arXiv:2410.05355; unverified]",
)
