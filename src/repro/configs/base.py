"""Config system for the repro framework.

ModelConfig describes an architecture (all families in the assigned pool:
dense GQA transformers, MoE, SSM (mamba-1), hybrid attn+SSM, encoder-decoder
(whisper), VLM backbone with a stub vision frontend, and the paper's DLRM).

ShapeConfig describes one input-shape cell (train / prefill / decode /
long-decode).  RunConfig carries runtime knobs (microbatching, remat policy,
dtypes, sharding variant) that the perf loop iterates on.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Tuple

# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | dlrm

    # Transformer backbone.
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    d_ff: int = 0
    vocab: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5

    # Attention variant.
    attn_type: str = "full"  # full | sliding
    window: int = 4096  # sliding-window size when attn_type == "sliding"

    # MoE.
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert FFN width (0 -> use d_ff)
    capacity_factor: float = 1.25

    # SSM (mamba-1).
    ssm_state: int = 0
    d_inner: int = 0  # 0 -> 2 * d_model
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    conv_width: int = 4
    ssm_chunk: int = 256  # chunked selective-scan block length

    # Encoder-decoder (whisper-style).
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_len: int = 1500  # stub frame count fed to the encoder

    # Modality frontend stub: number of precomputed patch/frame embeddings
    # spliced into the decoder input sequence ("vlm") or fed to the encoder
    # ("audio").  0 -> no frontend.
    frontend: str = ""  # "" | vision | audio
    n_frontend_tokens: int = 0

    # DLRM (paper's own architecture).
    n_tables: int = 0
    rows_per_table: int = 0
    emb_dim: int = 0
    multi_hot: int = 0  # pooling factor per table
    dense_features: int = 0
    bottom_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()

    # Dtypes / runtime defaults (overridable via RunConfig).
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    source: str = ""  # provenance note: [source; verified-tier]

    # ---------------- derived helpers ----------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtrank(self) -> int:
        return self.dt_rank or max(1, (self.d_model + 15) // 16)

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with a bounded-size per-step state at 500k?"""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2) or 0,
            d_model=min(self.d_model, 64) if self.d_model else 0,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab=min(self.vocab, 512) if self.vocab else 0,
            param_dtype="float32",
            compute_dtype="float32",
        )
        if self.n_heads:
            # Preserve GQA structure with small heads.
            kw["n_heads"] = 4
            kw["n_kv_heads"] = 2 if self.kv_heads < self.n_heads else 4
            kw["head_dim"] = 16
        if self.n_experts:
            kw["n_experts"] = 4
            kw["top_k"] = min(self.top_k, 2)
            kw["moe_d_ff"] = 64
            kw["capacity_factor"] = 8.0  # droppless at smoke scale
        if self.ssm_state:
            kw["ssm_state"] = 8
            kw["d_inner"] = 128
            kw["dt_rank"] = 4
            kw["ssm_chunk"] = 16
        if self.enc_dec:
            kw["n_enc_layers"] = 2
            kw["enc_len"] = 16
        if self.frontend:
            kw["n_frontend_tokens"] = 8
        if self.family == "dlrm":
            kw.update(
                n_tables=8,
                rows_per_table=256,
                emb_dim=16,
                multi_hot=4,
                dense_features=8,
                bottom_mlp=(32, 16),
                top_mlp=(32, 16, 1),
            )
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# The paper's DLRM has its own serving-style shapes (Table I batch sizes).
DLRM_SHAPES = {
    "infer_6k": ShapeConfig("infer_6k", "prefill", 0, 6144),
    "infer_18k": ShapeConfig("infer_18k", "prefill", 0, 18432),
    "train_6k": ShapeConfig("train_6k", "train", 0, 6144),
}


def shapes_for(cfg: ModelConfig):
    if cfg.family == "dlrm":
        return dict(DLRM_SHAPES)
    return dict(LM_SHAPES)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs, and why not if it doesn't."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 500k-token decode requires "
            "sub-quadratic attention (skip per assignment; see DESIGN.md)"
        )
    return True, ""


# ---------------------------------------------------------------------------
# Runtime knobs (the perf loop iterates these)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    microbatches: int = 0  # 0 -> auto-size to fit HBM
    remat: str = "full"  # full | dots | none
    # Sharding variant: "fsdp_tp" (default), "tp" (no FSDP), "dp" (pure
    # data), "fsdp" (params over every axis, no TP), "fsdp_seq" (fsdp +
    # sequence dim on the model axis — small-batch prefill).
    sharding: str = "fsdp_tp"
    # §Perf knobs (see EXPERIMENTS.md):
    constrain_grads: bool = False  # pin grad accumulator to FSDP shards
    emb_rows: str = "all"  # DLRM EMB row sharding: "all" | "model"
    dlrm_sharded_lookup: bool = False  # pool-before-reduce shard_map lookup
    moe_local_dispatch: bool = False  # data-local MoE capacity buffers
    #   (halves the dispatch all-reduce but multiplies FSDP weight gathers;
    #   net-negative with FSDP'd experts — kept for EP-style setups. §Perf)
    # Shard decode KV-cache sequence dim on `model` (else KV heads if divisible).
    shard_kv_seq: bool = True
    opt_dtype: str = "float32"  # adam moment dtype
    grad_compression: str = ""  # "" | int8_ef
    logits_chunk: int = 0  # 0 -> whole-seq logits; else chunked loss
    attn_block_q: int = 512
    attn_block_kv: int = 512
    use_pallas: bool = False  # TPU-only fast path; CPU tests use XLA path


def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, n_data_shards: int,
                      tokens_budget: int = 4096) -> int:
    """Pick a microbatch count so per-device microbatch tokens <= budget."""
    if shape.kind != "train" or cfg.family == "dlrm":
        return 1
    per_dev_seqs = max(1, shape.global_batch // max(n_data_shards, 1))
    per_dev_tokens = per_dev_seqs * shape.seq_len
    mb = 1
    while per_dev_tokens // mb > tokens_budget and mb < per_dev_seqs:
        mb *= 2
    return mb
