"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    DLRM_SHAPES,
    LM_SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    auto_microbatches,
    shape_applicable,
    shapes_for,
)

# arch-id -> module name (one module per assigned architecture + paper's own)
_ARCH_MODULES = {
    "internvl2-26b": "internvl2_26b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen3-14b": "qwen3_14b",
    "smollm-360m": "smollm_360m",
    "smollm-135m": "smollm_135m",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "grok-1-314b": "grok1_314b",
    "whisper-large-v3": "whisper_large_v3",
    "hymba-1.5b": "hymba_1_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "dlrm-recmg": "dlrm_recmg",
}

ASSIGNED_ARCHS = [a for a in _ARCH_MODULES if a != "dlrm-recmg"]
ALL_ARCHS = list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs():
    return list(_ARCH_MODULES)
