"""internvl2-26b — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

Assigned as [vlm]: the transformer BACKBONE only; the vision frontend is a
STUB — ``input_specs()`` provides precomputed patch embeddings that are
spliced over the first ``n_frontend_tokens`` positions of the sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92553,
    frontend="vision",
    n_frontend_tokens=256,
    source="[arXiv:2404.16821; hf]",
)
