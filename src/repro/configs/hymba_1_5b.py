"""hymba-1.5b — parallel attn+mamba heads [arXiv:2411.13676; hf].

Hybrid block: attention (sliding-window, GQA) and a mamba-1 SSM head run in
parallel on the same input and their outputs are mean-combined, per the
paper's parallel-heads design.  Sliding-window attention keeps the decode
state bounded, which is what qualifies hymba for the long_500k cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    attn_type="sliding",
    window=1024,
    source="[arXiv:2411.13676; hf]",
)
