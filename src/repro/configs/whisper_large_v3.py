"""whisper-large-v3 — enc-dec, conv frontend (stub) [arXiv:2212.04356;
unverified].

[audio]: the transformer backbone only; the conv/mel frontend is a STUB —
``input_specs()`` provides precomputed frame embeddings (enc_len x d_model)
that feed the encoder directly.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    enc_dec=True,
    enc_len=1500,
    frontend="audio",
    n_frontend_tokens=1500,
    rope_theta=10000.0,
    source="[arXiv:2212.04356; unverified]",
)
