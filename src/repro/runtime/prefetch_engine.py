"""Background prefetch engine: bounded work queue + worker that turns
prediction streams into batched ``populate`` traffic on a tiered store.

Producers (the RecMG models via :class:`~repro.core.recmg.RecMGOutputs`,
or any rule-based :class:`~repro.core.prefetchers.Prefetcher` through
:func:`heuristic_prediction_stream`) submit work items — ``(trunk, bits,
prefetch_ids)`` triples in the store's public id space.  The engine

* **deduplicates in-flight keys**: a prefetch id already queued but not
  yet issued is dropped (the first issue will make it resident, the store
  would filter the duplicate anyway);
* **cancels before issue**: ids that became resident between submission
  and issue (demand-fetched first) are cancelled, and priority rankings
  for ids evicted before issue are dropped by the store's resident
  filter — both are counted in telemetry;
* **coalesces** consecutive prefetch-only items into one batched
  ``apply_model_outputs`` populate call (one fused admit + scatter
  instead of many small ones);
* models **timeliness** on a single background fetch channel: each issue
  costs ``fetch_us_fixed + fetch_us_per_row * rows`` of modeled time, and
  a later demand access is classified timely (completed before the
  demand) or late.

Two schedulers share the same apply path:

* ``"inline"`` — the caller *is* the worker: queued items are applied at
  explicit :meth:`drain` points (the serving pipeline drains before every
  lookup).  Fully deterministic; this is the mode the equivalence tests
  replay byte-for-byte against the synchronous path.
* ``"thread"`` — a daemon worker pulls from a bounded ``queue.Queue`` and
  applies under the shared store lock, overlapping wall-clock time with
  the caller.  Store state stays consistent (the lock), but apply timing
  relative to lookups is scheduler-dependent, so counters may differ
  from the synchronous replay.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.obs.tracing import get_tracer
from repro.runtime.clock import Clock, VirtualClock
from repro.runtime.telemetry import RuntimeTelemetry

_STOP = object()
_EMPTY = np.empty(0, np.int64)


@dataclass
class WorkItem:
    """One staged model-output application."""

    trunk: np.ndarray          # ids to (re-)rank with caching bits
    bits: np.ndarray           # keep/evict bits for ``trunk``
    prefetch: np.ndarray       # ids to populate into the fast tier
    submit_us: float = 0.0     # modeled submission time

    @property
    def prefetch_only(self) -> bool:
        return self.trunk.size == 0 and self.prefetch.size > 0


class PrefetchEngine:
    """Consume prediction streams, issue batched populates on ``store``.

    ``store`` is any object with the tiered-store co-management surface:
    ``apply_model_outputs(trunk, bits, prefetch_ids)`` and
    ``resident_mask(ids)`` (both :class:`TieredEmbeddingStore` and
    :class:`MultiTableTieredStore`).
    """

    def __init__(self, store, telemetry: Optional[RuntimeTelemetry] = None,
                 clock: Optional[Clock] = None, scheduler: str = "inline",
                 max_queue: int = 64, coalesce_rows: int = 4096,
                 fetch_us_per_row: float = 10.0, fetch_us_fixed: float = 30.0,
                 lock: Optional[threading.Lock] = None,
                 trace_track: str = "pf"):
        if scheduler not in ("inline", "thread"):
            raise ValueError(f"unknown scheduler {scheduler!r}")
        self.store = store
        # Each engine models its own fetch channel, so each needs its own
        # trace track — two engines sharing one track would interleave
        # non-monotone span ends.
        self.trace_track = trace_track
        self.telemetry = telemetry if telemetry is not None \
            else RuntimeTelemetry()
        self.clock = clock or VirtualClock()
        self.scheduler = scheduler
        self.coalesce_rows = int(coalesce_rows)
        self.fetch_us_per_row = float(fetch_us_per_row)
        self.fetch_us_fixed = float(fetch_us_fixed)
        self.lock = lock or threading.Lock()
        self._inflight: set = set()
        self._pf_eta: Dict[int, float] = {}   # key -> modeled completion us
        self._channel_free_us = 0.0           # background fetch channel
        self._backpressure = False            # admission-control signal
        self._down = False                    # target shard dead (failover)
        self._closed = False
        self._worker_exc = None               # thread-mode failure, if any
        if scheduler == "thread":
            self._q: "queue.Queue" = queue.Queue(maxsize=max_queue)
            self._worker = threading.Thread(
                target=self._worker_loop, name="prefetch-engine", daemon=True)
            self._worker.start()
        else:
            self._q = None
            self._pending: List[WorkItem] = []
            self._max_pending = int(max_queue)

    # ---------------- producer side ----------------

    def submit(self, trunk, bits, prefetch_ids, now_us: Optional[float] = None):
        """Stage one model-output application (Algorithm 1 triple).

        Prefetch ids are deduplicated against the in-flight set and
        scheduled on the modeled background channel immediately — the
        worker would start fetching as soon as the prediction lands.
        """
        if self._closed:
            raise RuntimeError("engine is closed")
        now = self.clock.now() if now_us is None else float(now_us)
        trunk = np.asarray(trunk, np.int64).ravel()
        bits = np.asarray(bits).ravel()
        pf = np.asarray(prefetch_ids, np.int64).ravel()
        tel = self.telemetry
        tel.pf_submitted += int(pf.size)
        if self._down:
            # Target shard is dead (failover): nothing can be populated or
            # ranked there.  The whole item is cancelled with its own fate
            # (``pf.shard_down`` extends the submitted identity) rather
            # than raising into the serving path or vanishing uncounted.
            tel.pf_shard_down += int(pf.size)
            return
        if self._backpressure and pf.size:
            # Admission-control pressure: the serving queue is backed up,
            # so background prefetch traffic would only steal slow-tier
            # bandwidth from demand fetches.  Drop the prefetch ids (the
            # ranking trunk still applies — it is bookkeeping, not
            # traffic) and account them so the fate identity closes:
            # submitted == suppressed + deduped + cancelled + issued +
            # queued.
            tel.pf_suppressed += int(pf.size)
            pf = _EMPTY
        if pf.size:
            # In-flight dedup (first occurrence wins, within and across
            # queued items): the store would filter the duplicate against
            # residency at apply time anyway, so dropping it here is
            # behavior-preserving and saves queue/channel traffic.
            # Within-chunk duplicates collapse vectorially first, so the
            # locked set probe (coherent with the worker's _retire in
            # thread mode) only walks the unique ids.
            u, first = np.unique(pf, return_index=True)
            cand = pf[np.sort(first)] if u.size < pf.size else pf
            seen = self._inflight
            with self.lock:
                fresh = np.fromiter((k not in seen for k in cand.tolist()),
                                    bool, cand.size)
                keep = cand[fresh]
                seen.update(keep.tolist())
            tel.pf_deduped += int(pf.size) - int(keep.size)
            pf = keep
            self._schedule_channel(pf, now)
        item = WorkItem(trunk, bits, pf, submit_us=now)
        if self._q is not None:
            self._q.put(item)  # bounded: blocks when the worker lags
        else:
            self._pending.append(item)
            if len(self._pending) > self._max_pending:
                self.drain()  # inline backpressure: caller absorbs the work

    def _schedule_channel(self, pf: np.ndarray, now: float):
        """Model the background fetch: ids already resident at submission
        are cancelled (no traffic); the rest occupy the single channel."""
        if not pf.size:
            return
        with self.lock:  # the thread worker mutates residency under it
            fresh = pf[~self.store.resident_mask(pf)]
        if not fresh.size:
            return
        cost = self.fetch_us_fixed + self.fetch_us_per_row * fresh.size
        start = max(self._channel_free_us, now)
        self._channel_free_us = start + cost
        tel = self.telemetry
        tel.pf_fetch_ms += cost * 1e-3
        tel.pf_channel_scheduled += int(fresh.size)
        done = self._channel_free_us
        tr = get_tracer()
        if tr.enabled:
            # Modeled background-channel occupancy [start, start+cost).
            tr.add_span("pf", "channel", start, cost,
                        track=self.trace_track,
                        args={"rows": int(fresh.size)})
        eta = self._pf_eta
        for k in fresh.tolist():
            # Overwrite: a key can only be rescheduled after its previous
            # issue retired (in-flight dedup), i.e. this is a genuinely
            # new fetch — keeping the old ETA would fake timeliness.  The
            # lost ETA is counted so the timeliness identity still closes
            # (channel_scheduled == timely+late+unused+overwritten+pending).
            if k in eta:
                tel.pf_eta_overwritten += 1
            eta[k] = done

    # ---------------- worker side ----------------

    def drain(self):
        """Apply everything queued.  Inline: synchronously, in submission
        order (the deterministic drain point).  Thread: block until the
        worker has emptied the queue (flush barrier)."""
        if self._q is not None:
            self._q.join()
            if self._worker_exc is not None:
                exc, self._worker_exc = self._worker_exc, None
                raise RuntimeError("prefetch worker failed") from exc
            return
        items, self._pending = self._pending, []
        if items:
            with self.lock:
                self._apply(items)

    def _worker_loop(self):
        while True:
            item = self._q.get()
            stop = item is _STOP
            batch = [] if stop else [item]
            # Opportunistically pull whatever else is queued so adjacent
            # prefetch items coalesce into one populate call.
            while not stop:
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _STOP:
                    stop = True
                else:
                    batch.append(nxt)
            try:
                if batch and self._worker_exc is None:
                    with self.lock:
                        self._apply(batch)
            except BaseException as exc:  # surfaced at the next barrier
                self._worker_exc = exc
            finally:
                # task_done() for every get() — even on error or shutdown —
                # so drain()/close() barriers on q.join() never hang.
                for _ in range(len(batch) + stop):
                    self._q.task_done()
            if stop:
                return

    def _apply(self, items: List[WorkItem]):
        """Apply work items in order, coalescing consecutive
        prefetch-only items into one batched populate call."""
        tel = self.telemetry
        i = 0
        while i < len(items):
            it = items[i]
            if it.prefetch_only:
                pf = [it.prefetch]
                rows = it.prefetch.size
                j = i + 1
                while (j < len(items) and items[j].prefetch_only
                       and rows + items[j].prefetch.size
                       <= self.coalesce_rows):
                    pf.append(items[j].prefetch)
                    rows += items[j].prefetch.size
                    j += 1
                self._issue(np.concatenate(pf), coalesced=j - i)
                i = j
            else:
                if it.trunk.size:
                    # The store drops rankings for ids evicted before
                    # issue; count them so Fig. 14 attribution can see
                    # how stale the pipelined stream ran.
                    n_evicted = int(np.count_nonzero(
                        ~self.store.resident_mask(it.trunk)))
                    tel.rank_cancelled_evicted += n_evicted
                if it.prefetch.size:  # mixed rank+prefetch item
                    fresh = int(np.count_nonzero(
                        ~self.store.resident_mask(it.prefetch)))
                    tel.pf_cancelled_resident += it.prefetch.size - fresh
                    tel.pf_issued += fresh
                    tel.pf_populate_calls += bool(fresh)
                self.store.apply_model_outputs(it.trunk, it.bits, it.prefetch)
                self._retire(it.prefetch)
                i += 1

    def _issue(self, pf: np.ndarray, coalesced: int):
        """One batched populate: cancel ids that became resident before
        issue, then hand the rest to the store in one call."""
        tel = self.telemetry
        resident = self.store.resident_mask(pf)
        tel.pf_cancelled_resident += int(np.count_nonzero(resident))
        fresh = pf[~resident]
        if fresh.size:
            self.store.apply_model_outputs(_EMPTY, _EMPTY, fresh)
            tel.pf_issued += int(fresh.size)
            tel.pf_populate_calls += 1
        self._retire(pf)

    def _retire(self, pf: np.ndarray):
        # Callers hold self.lock (worker loop / inline drain), pairing
        # with the locked dedup in submit().
        self._inflight.difference_update(np.asarray(pf).tolist())

    # ---------------- demand-side hooks ----------------

    def set_down(self, down: bool):
        """Shard health signal from the failover layer.

        Going down cancels every in-flight work item for the dead shard —
        queued prefetch rows take the distinct ``pf.shard_down`` fate
        (extending the submitted identity) and undemanded channel ETAs
        fold into ``pf.unused`` — so a drain-after-kill is a safe no-op
        instead of a populate call on a dead store.  While down, newly
        submitted items are cancelled the same way at submit time.  Going
        back up re-opens submission; recovery repopulation then arrives
        as ordinary submit traffic.
        """
        down = bool(down)
        if down and not self._down:
            self._cancel_inflight()
        self._down = down

    def _cancel_inflight(self):
        tel = self.telemetry
        if self._q is not None:
            self.drain()  # thread mode: barrier — applied work stands
        else:
            items, self._pending = self._pending, []
            for it in items:
                tel.pf_shard_down += int(it.prefetch.size)
        with self.lock:
            self._inflight.clear()
        tel.pf_unused += len(self._pf_eta)
        self._pf_eta.clear()

    def set_backpressure(self, on: bool):
        """Admission-control signal: while on, newly submitted prefetch
        ids are suppressed (counted in ``pf_suppressed``) instead of
        scheduled — graceful degradation keeps the modeled slow-tier
        channel free for demand traffic under overload."""
        self._backpressure = bool(on)

    def observe_demand(self, uniq_ids: np.ndarray, now_us: float):
        """Classify prefetch timeliness for a demand batch starting at
        ``now_us``: a previously prefetched id whose modeled fetch
        completed by now was timely; one still in flight was late."""
        if not self._pf_eta:
            return
        tel = self.telemetry
        n_timely = n_late = 0
        for k in np.asarray(uniq_ids).ravel().tolist():
            eta = self._pf_eta.pop(k, None)
            if eta is None:
                continue
            if eta <= now_us:
                tel.pf_timely += 1
                n_timely += 1
            else:
                tel.pf_late += 1
                n_late += 1
                tel.pf_late_ms += (eta - now_us) * 1e-3
        if n_timely or n_late:
            tr = get_tracer()
            if tr.enabled:
                tr.add_instant("pf", "demand", ts=now_us,
                               track=self.trace_track,
                               args={"timely": n_timely, "late": n_late})
    def publish(self, reg, prefix: str = "rt"):
        """Publish the engine's telemetry plus its live-state gauges into a
        :class:`repro.obs.MetricsRegistry`.  The gauges close the fate
        identities mid-run: ``pf.queued`` (submitted rows still staged,
        zero after a drain) and ``pf.eta_pending`` (channel fetches not
        yet demanded — becomes ``pf.unused`` at close)."""
        self.telemetry.publish(reg, prefix)
        if self._q is None:
            queued = sum(int(it.prefetch.size) for it in self._pending)
        else:  # thread mode: publish after a drain/close barrier
            queued = 0
        reg.gauge(f"{prefix}.pf.queued").set(queued)
        reg.gauge(f"{prefix}.pf.eta_pending").set(len(self._pf_eta))
        return reg

    # ---------------- lifecycle ----------------

    def close(self):
        """Flush and stop the worker; count never-demanded prefetches."""
        if self._closed:
            return
        try:
            self.drain()
        finally:
            if self._q is not None:
                self._q.put(_STOP)
                self._worker.join(timeout=5.0)
            self.telemetry.pf_unused += len(self._pf_eta)
            self._pf_eta.clear()
            self._closed = True

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def heuristic_prediction_stream(keys: np.ndarray, prefetcher, chunk: int = 15,
                                max_per_chunk: int = 5,
                                sim_capacity: int = 4096):
    """Run a rule-based :class:`~repro.core.prefetchers.Prefetcher` over a
    trace and package its issues as a :class:`~repro.core.recmg.RecMGOutputs`
    stream (chunk boundaries every ``chunk`` accesses, like the models) so
    the engine can serve heuristic predictions with no training step.

    A small LRU shadow cache (``sim_capacity`` rows, prefetch-inserted)
    supplies the ``hit`` feedback signal — adaptive prefetchers like the
    MAB coordinator need a real reward, not a constant.
    """
    from repro.core.cache_sim import FALRU
    from repro.core.recmg import RecMGOutputs

    keys = np.asarray(keys, np.int64).ravel()
    n = int(keys.max()) + 1 if keys.size else 0
    shadow = FALRU(sim_capacity)
    starts = np.arange(chunk, len(keys), chunk, dtype=np.int64)
    pf = np.empty(len(starts), object)  # ragged: one id array per chunk
    lo = 0
    for ci, s in enumerate(starts.tolist()):
        issued: List[int] = []
        for k in keys[lo:s].tolist():
            preds = prefetcher.on_access(k, shadow.access(k))
            for p in preds:
                if 0 <= p < n:  # clip out-of-table offsets
                    issued.append(p)
                    if not shadow.contains(p):
                        shadow.insert_prefetch(p)
        lo = s
        pf[ci] = np.asarray(issued[-max_per_chunk:], np.int64)
    return RecMGOutputs(starts, None, pf)
