"""Injectable clocks for the serving runtime.

The pipelined runtime keeps two notions of time:

* **wall time** — what actually elapsed on this machine (benchmarks);
* **modeled time** — a deterministic microsecond timeline built from the
  slow-tier cost model (``fetch_us_fixed + fetch_us_per_row * rows``) and
  per-batch compute, so pipelining results are reproducible byte-for-byte
  on any host and transfer to the real two-tier hardware this container
  lacks.

Every runtime component takes a :class:`Clock`; tests inject a
:class:`VirtualClock` and the whole run replays identically.
"""
from __future__ import annotations

import time


class Clock:
    """Monotonic microsecond clock interface."""

    def now(self) -> float:
        raise NotImplementedError


class VirtualClock(Clock):
    """Deterministic clock: advances only when the runtime says so."""

    def __init__(self, start_us: float = 0.0):
        self._now = float(start_us)

    def now(self) -> float:
        return self._now

    def advance(self, dt_us: float) -> float:
        if dt_us < 0:
            raise ValueError("clock cannot run backwards")
        self._now += dt_us
        return self._now

    def advance_to(self, t_us: float) -> float:
        """Monotone jump: no-op if ``t_us`` is in the past."""
        self._now = max(self._now, float(t_us))
        return self._now


class WallClock(Clock):
    """Real time in microseconds (thread-scheduler benchmarks)."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6
