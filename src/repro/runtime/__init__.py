"""Asynchronous pipelined serving runtime (background prefetch engine,
micro-batching request pipeline, SLO-aware admission control, telemetry).
See docs/architecture.md ("Serving runtime" and "Admission control &
overload behavior") for the determinism contract."""
from repro.runtime.admission import (PRIORITY_CLASSES, AdmissionConfig,
                                     AdmissionQueue, AdmissionStats)
from repro.runtime.clock import Clock, VirtualClock, WallClock
from repro.runtime.drift import (AdaptiveController, DriftConfig,
                                 DriftDetector)
from repro.runtime.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  FtStats, TransientFetchError)
from repro.runtime.pipeline import (MicroBatcher, PipelinedRuntime, Request,
                                    RuntimeConfig)
from repro.runtime.prefetch_engine import (PrefetchEngine,
                                           heuristic_prediction_stream)
from repro.runtime.telemetry import RuntimeTelemetry, latency_percentiles

__all__ = [
    "PRIORITY_CLASSES", "AdmissionConfig", "AdmissionQueue",
    "AdmissionStats",
    "Clock", "VirtualClock", "WallClock",
    "AdaptiveController", "DriftConfig", "DriftDetector",
    "FaultEvent", "FaultInjector", "FaultPlan", "FtStats",
    "TransientFetchError",
    "MicroBatcher", "PipelinedRuntime", "Request", "RuntimeConfig",
    "PrefetchEngine", "heuristic_prediction_stream",
    "RuntimeTelemetry", "latency_percentiles",
]
