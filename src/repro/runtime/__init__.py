"""Asynchronous pipelined serving runtime (background prefetch engine,
micro-batching request pipeline, telemetry).  See docs/architecture.md
("Serving runtime") for the determinism contract."""
from repro.runtime.clock import Clock, VirtualClock, WallClock
from repro.runtime.drift import (AdaptiveController, DriftConfig,
                                 DriftDetector)
from repro.runtime.pipeline import (MicroBatcher, PipelinedRuntime, Request,
                                    RuntimeConfig)
from repro.runtime.prefetch_engine import (PrefetchEngine,
                                           heuristic_prediction_stream)
from repro.runtime.telemetry import RuntimeTelemetry, latency_percentiles

__all__ = [
    "Clock", "VirtualClock", "WallClock",
    "AdaptiveController", "DriftConfig", "DriftDetector",
    "MicroBatcher", "PipelinedRuntime", "Request", "RuntimeConfig",
    "PrefetchEngine", "heuristic_prediction_stream",
    "RuntimeTelemetry", "latency_percentiles",
]
