"""Deterministic fault injection for the sharded serving path.

Production DLRM serving spreads terabyte-scale embedding tables over many
workers; at that scale shard loss, slow hosts and fetch-channel hiccups
are routine events, not exceptions.  This module turns them into *seeded,
schedulable* events on the serving run's deterministic timeline so every
chaos experiment is byte-reproducible and golden-pinnable:

* :class:`FaultPlan` — a parsed schedule of fault events (shard kills,
  recoveries, slow-shard latency windows, transient fetch-failure
  windows) plus the retry-policy knobs, built from a compact CLI string
  (``serve --fault-plan "kill:1@mid,recover:1@75%"``);
* :class:`FaultInjector` — executes the plan against a run: per-shard
  health/slow/flaky state machines stepped at batch boundaries, a seeded
  RNG for transient-failure draws, and exact per-shard down-time
  accounting on the virtual clock;
* :class:`FtStats` — the exactly-reconciled ``ft.*`` counter namespace
  (``served == primary + failover_replica + failover_degraded``,
  ``retries == retry_succeeded + retry_exhausted``; checked by
  :func:`repro.obs.reconcile.check_ft`).

Fault model taxonomy (what each event means for the simulated worker):

* ``kill``    — the shard process dies.  Its fast tier survives only as a
  read-only stale snapshot (the facade's last-known-good standby view):
  requests for the dead shard's rows are answered from hot-row replicas
  when the plan replicated them, else through the degraded
  ``lookup_resident`` contract (stale-but-resident row or zero default —
  never a wrong vector, never a hang).
* ``recover`` — a replacement worker comes up *empty*; the rows that were
  resident at kill time stream back in bounded background chunks through
  the shard's prefetch channel as int8 row transfers
  (:mod:`repro.distributed.compression`) while serving continues.
* ``slow``    — the shard's modeled slow-tier fetch time is multiplied by
  ``factor`` inside the window (a congested / thermally-throttled host).
* ``flaky``   — each of the shard's slow-tier fetch attempts fails with
  probability ``factor`` inside the window (seeded draws); failures go
  through the clock-driven retry/backoff wrapper (rebuilt from
  :func:`repro.distributed.fault_tolerance.retry_step`) with a hard
  deadline so admission deadlines still hold — exhausted retries take
  the degraded path.

Event times are **batch indices** by default (exactly reproducible no
matter what the cost model charges); ``mid`` / ``N%`` tokens resolve
against the run's batch horizon, and an absolute virtual-time trigger is
available as ``Nus``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("kill", "recover", "slow", "flaky")


class TransientFetchError(RuntimeError):
    """A retryable slow-tier fetch failure (injected or real)."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault transition.

    ``at`` / ``until`` are batch indices once resolved; before resolution
    they may be fractions of the horizon (``frac=True``) or absolute
    virtual microseconds (``unit="us"``).
    """

    kind: str
    shard: int
    at: float
    until: Optional[float] = None     # window end (slow / flaky)
    factor: float = 1.0               # slow multiplier / failure probability
    frac: bool = False                # at/until are horizon fractions
    unit: str = "batch"               # "batch" | "us"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.shard < 0:
            raise ValueError("shard must be >= 0")
        if self.kind == "slow" and self.factor < 1.0:
            raise ValueError("slow factor must be >= 1")
        if self.kind == "flaky" and not (0.0 <= self.factor <= 1.0):
            raise ValueError("flaky probability must be in [0, 1]")


# ``kind[:shard[xfactor]]@start[..end]`` — e.g. ``kill:1@mid``,
# ``slow:0x4@25%..75%``, ``flaky:2x0.3@10..40``, ``recover:1@80%``.
_EVENT_RE = re.compile(
    r"^(?P<kind>[a-z]+)"
    r"(?::(?P<shard>\d+)(?:x(?P<factor>[0-9.]+))?)?"
    r"@(?P<at>[a-z0-9.%]+?)"
    r"(?:\.\.(?P<until>[a-z0-9.%]+))?$")


def _parse_time(tok: str) -> Tuple[float, bool, str]:
    """Time token -> (value, is_fraction, unit)."""
    if tok == "mid":
        return 0.5, True, "batch"
    if tok == "start":
        return 0.0, True, "batch"
    if tok == "end":
        return 1.0, True, "batch"
    if tok.endswith("%"):
        return float(tok[:-1]) / 100.0, True, "batch"
    if tok.endswith("us"):
        return float(tok[:-2]), False, "us"
    return float(tok), False, "batch"


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, parseable schedule of fault events + retry policy.

    The plan is pure data — byte-reproducible, hashable into goldens.
    ``seed`` drives the injector's transient-failure draws; the retry
    knobs configure the clock-driven wrapper around flaky fetches.
    """

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    # Retry policy for transient (flaky) fetch failures: each failed
    # attempt costs ``retry_timeout_us`` of modeled time, retries back
    # off exponentially from ``retry_backoff_us``, and the whole episode
    # is bounded by ``retry_deadline_us`` so a batch can never hang past
    # an admission deadline.
    max_retries: int = 3
    retry_timeout_us: float = 120.0
    retry_backoff_us: float = 60.0
    retry_deadline_us: float = 4000.0
    # Recovery streaming: rows restored per background chunk (one chunk
    # per serving batch — bounded background work, serving never halts).
    recovery_chunk: int = 256

    @classmethod
    def parse(cls, text: str, seed: int = 0, **kw) -> "FaultPlan":
        """Parse a comma-separated event list, e.g.
        ``"kill:1@mid,recover:1@75%"`` or ``"slow:0x4@25%..75%"``.
        Shard defaults to 0; ``kill@mid`` is the CI chaos smoke."""
        events: List[FaultEvent] = []
        for item in filter(None, (s.strip() for s in text.split(","))):
            m = _EVENT_RE.match(item)
            if not m:
                raise ValueError(f"cannot parse fault event {item!r} "
                                 "(grammar: kind[:shard[xfactor]]"
                                 "@start[..end])")
            at, at_frac, unit = _parse_time(m.group("at"))
            until = until_frac = None
            if m.group("until") is not None:
                until, until_frac, u_unit = _parse_time(m.group("until"))
                if u_unit != unit or until_frac != at_frac:
                    raise ValueError(f"mixed time units in {item!r}")
            events.append(FaultEvent(
                kind=m.group("kind"),
                shard=int(m.group("shard") or 0),
                factor=float(m.group("factor") or
                             (1.0 if m.group("kind") != "flaky" else 0.5)),
                at=at, until=until, frac=at_frac, unit=unit))
        return cls(events=tuple(events), seed=seed, **kw)

    @property
    def needs_horizon(self) -> bool:
        return any(e.frac for e in self.events)

    def describe(self) -> str:
        """Canonical plan string; ``parse(describe())`` gives back the
        same events (the chaos harness pins this field in results)."""
        def fmt(t: float, e: FaultEvent) -> str:
            if e.frac:
                return f"{t * 100:g}%"
            return f"{t:g}{'us' if e.unit == 'us' else ''}"

        parts = []
        for e in self.events:
            head = f"{e.kind}:{e.shard}"
            if e.kind in ("slow", "flaky"):
                head += f"x{e.factor:g}"
            t = fmt(e.at, e)
            if e.until is not None:
                t += f"..{fmt(e.until, e)}"
            parts.append(f"{head}@{t}")
        return ",".join(parts)


@dataclass
class FtStats:
    """The exactly-reconciled ``ft.*`` namespace.

    Identities (:func:`repro.obs.reconcile.check_ft`):

    * ``served == primary + failover_replica + failover_degraded`` —
      every row routed while the fault layer is armed has exactly one
      answer source;
    * ``retries == retry_succeeded + retry_exhausted`` — every retry
      episode ends exactly one way.
    """

    n_shards: int = 1
    served: int = 0                 # rows routed while faults armed
    primary: int = 0                # answered by the row's healthy shard
    failover_replica: int = 0       # dead shard, answered from a replica
    failover_degraded: int = 0      # dead shard / exhausted retries:
    #                                 stale-resident or zero-default row
    degraded_default: int = 0       # the zero-default subset of the above
    retries: int = 0                # retry episodes (>=1 failed attempt)
    retry_succeeded: int = 0        # episode ended in a successful fetch
    retry_exhausted: int = 0        # episode hit max retries / deadline
    retry_overhead_ms: float = 0.0  # modeled timeout+backoff time charged
    kills: int = 0
    recoveries: int = 0
    recovery_rows: int = 0          # rows streamed back post-recovery
    recovery_chunks: int = 0        # bounded background chunks used
    recovery_bytes: int = 0         # int8 payload bytes on the wire
    recovery_bytes_raw: int = 0     # fp32-equivalent bytes (the savings)
    slow_ms: float = 0.0            # extra critical-path ms from slow shards
    staged_dropped: int = 0         # staged model-output rows for a dead shard
    down_us: np.ndarray = field(default=None)  # per-shard down time

    def __post_init__(self):
        if self.down_us is None:
            self.down_us = np.zeros(self.n_shards, np.float64)

    def check(self):
        assert self.served == (self.primary + self.failover_replica
                               + self.failover_degraded), \
            (f"ft: served({self.served}) != primary({self.primary}) + "
             f"replica({self.failover_replica}) + "
             f"degraded({self.failover_degraded})")
        assert self.retries == self.retry_succeeded + self.retry_exhausted
        assert self.degraded_default <= self.failover_degraded

    def as_dict(self) -> dict:
        return {
            "served": self.served, "primary": self.primary,
            "failover_replica": self.failover_replica,
            "failover_degraded": self.failover_degraded,
            "degraded_default": self.degraded_default,
            "retries": self.retries,
            "retry_succeeded": self.retry_succeeded,
            "retry_exhausted": self.retry_exhausted,
            "retry_overhead_ms": round(self.retry_overhead_ms, 3),
            "kills": self.kills, "recoveries": self.recoveries,
            "recovery_rows": self.recovery_rows,
            "recovery_chunks": self.recovery_chunks,
            "recovery_bytes": self.recovery_bytes,
            "recovery_bytes_raw": self.recovery_bytes_raw,
            "slow_ms": round(self.slow_ms, 3),
            "staged_dropped": self.staged_dropped,
            "down_ms": [round(u * 1e-3, 3) for u in self.down_us.tolist()],
        }

    def publish(self, reg, prefix: str = "ft"):
        """Publish into a :class:`repro.obs.MetricsRegistry`; the layout
        :func:`repro.obs.reconcile.check_ft` reconciles."""
        for key, val in (
            ("served", self.served), ("primary", self.primary),
            ("failover_replica", self.failover_replica),
            ("failover_degraded", self.failover_degraded),
            ("degraded_default", self.degraded_default),
            ("retries", self.retries),
            ("retry_succeeded", self.retry_succeeded),
            ("retry_exhausted", self.retry_exhausted),
            ("retry_overhead_ms", self.retry_overhead_ms),
            ("kills", self.kills), ("recoveries", self.recoveries),
            ("recovery_rows", self.recovery_rows),
            ("recovery_chunks", self.recovery_chunks),
            ("recovery_bytes", self.recovery_bytes),
            ("recovery_bytes_raw", self.recovery_bytes_raw),
            ("slow_ms", self.slow_ms),
            ("staged_dropped", self.staged_dropped),
        ):
            reg.counter(f"{prefix}.{key}").inc(val)
        for s in range(self.n_shards):
            reg.gauge(f"{prefix}.shard.{s}.down_ms").set(
                float(self.down_us[s]) * 1e-3)
        return reg


class FaultInjector:
    """Execute a :class:`FaultPlan` against a serving run.

    The owning store polls :meth:`poll` once per batch (before routing);
    due events fire in schedule order and the injector returns them so
    the store can act (kill/recover side effects) and emit span events.
    Per-shard state between polls: ``up`` (health), ``slow`` (latency
    multiplier), ``flaky`` (fetch-failure probability).  All transient
    draws come from one seeded generator in a fixed order, so two runs of
    the same plan over the same trace are byte-identical.
    """

    def __init__(self, plan: FaultPlan, n_shards: int,
                 horizon_batches: Optional[int] = None):
        self.plan = plan
        self.n_shards = int(n_shards)
        if plan.needs_horizon and not horizon_batches:
            raise ValueError("fault plan uses fractional times "
                             "(mid / N%); pass horizon_batches")
        self.horizon = int(horizon_batches or 0)
        # Expand windows into transitions: (batch, seq, event, clear).
        self._timeline: List[Tuple[float, int, FaultEvent, bool]] = []
        seq = 0
        for e in self.events_resolved():
            self._timeline.append((e.at, seq, e, False))
            seq += 1
            if e.until is not None:
                self._timeline.append((e.until, seq, e, True))
                seq += 1
        self._timeline.sort(key=lambda t: (t[0], t[1]))
        self._next = 0
        self.up = np.ones(self.n_shards, bool)
        self.slow = np.ones(self.n_shards, np.float64)
        self.flaky = np.zeros(self.n_shards, np.float64)
        self.down_since_us = np.full(self.n_shards, np.nan)
        self._rng = np.random.default_rng(plan.seed)

    def events_resolved(self) -> List[FaultEvent]:
        """The plan's events with fractional times resolved to batches."""
        out = []
        for e in self.plan.events:
            if e.shard >= self.n_shards:
                raise ValueError(f"fault event targets shard {e.shard}, "
                                 f"store has {self.n_shards}")
            if e.frac:
                at = float(int(e.at * self.horizon))
                until = (float(int(e.until * self.horizon))
                         if e.until is not None else None)
                e = FaultEvent(e.kind, e.shard, at, until, e.factor,
                               frac=False, unit=e.unit)
            out.append(e)
        return out

    @property
    def any_down(self) -> bool:
        return not bool(self.up.all())

    @property
    def armed(self) -> bool:
        """Any fault behavior still pending or active?"""
        return (self._next < len(self._timeline) or self.any_down
                or bool((self.slow != 1.0).any())
                or bool((self.flaky > 0.0).any()))

    def poll(self, batch: int, now_us: float) -> List[Tuple[FaultEvent, bool]]:
        """Fire every transition due at ``batch`` (or by ``now_us`` for
        absolute-virtual-time events); returns ``(event, is_clear)``
        pairs in firing order.  State mutates here; kill/recover side
        effects on the store are the caller's job."""
        fired: List[Tuple[FaultEvent, bool]] = []
        while self._next < len(self._timeline):
            at, _, e, clear = self._timeline[self._next]
            due = (now_us >= at) if e.unit == "us" else (batch >= at)
            if not due:
                break
            self._next += 1
            s = e.shard
            if e.kind == "kill" and not clear:
                if self.up[s]:
                    self.up[s] = False
                    self.down_since_us[s] = now_us
                    fired.append((e, False))
            elif e.kind == "recover" and not clear:
                if not self.up[s]:
                    self.up[s] = True
                    fired.append((e, False))
            elif e.kind == "slow":
                self.slow[s] = 1.0 if clear else e.factor
                fired.append((e, clear))
            elif e.kind == "flaky":
                self.flaky[s] = 0.0 if clear else e.factor
                fired.append((e, clear))
        return fired

    def draw_failure(self, shard: int) -> bool:
        """One seeded transient-failure draw for a fetch attempt."""
        p = self.flaky[shard]
        return bool(p > 0.0 and self._rng.random() < p)

    def down_time_us(self, shard: int, now_us: float) -> float:
        """Open downtime window through ``now`` (0 if never killed, or if
        the window was already closed via :meth:`close_downtime`)."""
        if np.isnan(self.down_since_us[shard]):
            return 0.0
        return float(now_us - self.down_since_us[shard])

    def close_downtime(self, shard: int, now_us: float) -> float:
        """On recovery: return and clear the closed downtime window."""
        dt = self.down_time_us(shard, now_us)
        self.down_since_us[shard] = np.nan
        return dt
