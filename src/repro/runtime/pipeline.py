"""Micro-batching request pipeline with a double-buffered fetch/compute
timeline.

Requests (one query's embedding ids each) enter an **admission queue**;
a size/deadline **micro-batcher** closes a batch when ``max_batch``
requests are waiting or the oldest has waited ``deadline_us``.  Batches
then flow through a two-stage pipeline modeled on the paper's deployment
(Fig. 6): the *host* stage drains staged model outputs, runs the tiered
lookup and pays the slow-tier on-demand fetch; the *device* stage runs the
dense forward.  With ``pipeline_depth >= 2`` the host may run ahead of the
device, so batch *k*'s slow-tier fetch overlaps batch *k-1*'s dense
forward — the fetch only **stalls** the device for the part that outlasts
the overlap window:

    host_start[k]   = max(host_free, close[k], compute_done[k - depth])
    fetch_done[k]   = host_start[k] + fetch_us[k]
    compute_start[k]= max(fetch_done[k], compute_done[k-1])
    stall[k]        = max(0, fetch_done[k] - max(compute_done[k-1],
                                                 host_start[k]))

``pipeline_depth=1`` degenerates to the synchronous runtime
(``stall == demand fetch``, exactly the store's ``modeled_fetch_s``).

Determinism contract: with the inline scheduler and a
:class:`~repro.runtime.clock.VirtualClock`, the store sees *exactly* the
same sequence of drains, lookups and model-output applications as the
synchronous serving loop — hit/miss/eviction counters reproduce
byte-for-byte — while the timeline above moves fetch time off the modeled
critical path.  Only the accounting changes, never the residency math.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Tuple

import numpy as np

from repro.obs.tracing import get_tracer
from repro.runtime.clock import Clock, VirtualClock
from repro.runtime.prefetch_engine import PrefetchEngine
from repro.runtime.telemetry import RuntimeTelemetry


@dataclass
class Request:
    """One inference query's embedding-id vector."""

    rid: int
    ids: np.ndarray
    arrival_us: float = 0.0


@dataclass
class RuntimeConfig:
    max_batch: int = 32              # micro-batcher size trigger (queries)
    deadline_us: float = float("inf")  # micro-batcher age trigger
    pipeline_depth: int = 2          # host may run this many batches ahead
    interarrival_us: float = 0.0     # >0: open-loop arrivals at this rate
    fetch_us_per_row: float = 10.0   # slow-tier cost model (matches store)
    fetch_us_fixed: float = 30.0
    compute_us: Optional[float] = None  # None: use measured compute
    scheduler: str = "inline"        # "inline" (deterministic) | "thread"
    max_queue: int = 64              # prefetch work-queue bound
    coalesce_rows: int = 4096        # populate coalescing cap

    def __post_init__(self):
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")


class MicroBatcher:
    """Size/deadline micro-batcher over an admission queue."""

    def __init__(self, max_batch: int, deadline_us: float = float("inf")):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.deadline_us = float(deadline_us)
        self._queue: List[Request] = []

    def __len__(self):
        return len(self._queue)

    @property
    def oldest_arrival_us(self) -> float:
        return self._queue[0].arrival_us if self._queue else float("inf")

    def push(self, req: Request):
        self._queue.append(req)

    def ready(self, now_us: float) -> bool:
        """A batch should close: full, or the oldest request timed out."""
        if len(self._queue) >= self.max_batch:
            return True
        return bool(self._queue) and \
            now_us - self.oldest_arrival_us >= self.deadline_us

    def pop(self) -> Tuple[List[Request], float]:
        """Close one batch; returns (requests, close time).  A full batch
        closes when its last member arrived; a deadline batch when the
        oldest request's patience ran out."""
        take, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        if len(take) == self.max_batch:
            close = max(r.arrival_us for r in take)
        else:
            close = take[0].arrival_us + self.deadline_us
        return take, close

    def flush(self) -> Tuple[List[Request], float]:
        """End-of-stream: close whatever is waiting at its last arrival."""
        take, self._queue = self._queue[: self.max_batch], \
            self._queue[self.max_batch:]
        return take, max(r.arrival_us for r in take)


class PipelinedRuntime:
    """Asynchronous pipelined serving runtime over a tiered store.

    Drives ``store.lookup`` through the micro-batcher and the modeled
    double-buffered timeline, with the :class:`PrefetchEngine` applying
    staged model outputs at deterministic drain points (inline scheduler)
    or on the background worker (thread scheduler).
    """

    def __init__(self, store, cfg: Optional[RuntimeConfig] = None,
                 clock: Optional[Clock] = None, batch_hook=None):
        """``batch_hook(ids, hits, batch_index) -> [(trunk, bits,
        prefetch_ids), ...]`` is called once per processed batch with the
        batch's ids and its fast-tier hit count; returned items are
        submitted through the prefetch engine like staged model outputs.
        The drift-adaptive serving path passes
        :meth:`~repro.runtime.drift.AdaptiveController.on_batch` here."""
        self.store = store
        self.cfg = cfg or RuntimeConfig()
        self.clock = clock or VirtualClock()
        self._batch_hook = batch_hook
        self.telemetry = RuntimeTelemetry()
        self.engine = PrefetchEngine(
            store, telemetry=self.telemetry, clock=self.clock,
            scheduler=self.cfg.scheduler, max_queue=self.cfg.max_queue,
            coalesce_rows=self.cfg.coalesce_rows,
            fetch_us_per_row=self.cfg.fetch_us_per_row,
            fetch_us_fixed=self.cfg.fetch_us_fixed)
        self.batcher = MicroBatcher(self.cfg.max_batch, self.cfg.deadline_us)
        # ---- modeled timeline state ----
        self._host_free_us = 0.0
        self._compute_done_us: List[float] = []   # per finished batch
        self._batch_index = 0
        self._next_rid = 0
        self.wall_batch_s: List[float] = []       # measured, per batch

    # ---------------- request admission ----------------

    def _arrival(self) -> float:
        if self.cfg.interarrival_us > 0:
            return self._next_rid * self.cfg.interarrival_us
        return 0.0  # closed loop: latency measured from admission

    def submit(self, ids: np.ndarray) -> Request:
        req = Request(self._next_rid, np.asarray(ids, np.int64).ravel(),
                      self._arrival())
        self._next_rid += 1
        self.batcher.push(req)
        return req

    # ---------------- pipeline core ----------------

    def run(self, id_stream: Iterable[np.ndarray],
            step_fn: Callable[[int, object], Tuple[float, List[tuple]]]):
        """Serve a stream of per-query id vectors end to end.

        ``step_fn(batch_index, embeddings) -> (compute_seconds, staged)``
        runs the dense forward for one closed batch and returns its
        measured compute time plus the list of ``(trunk, bits,
        prefetch_ids)`` model outputs to stage for later batches.
        """
        for ids in id_stream:
            arrival = self._arrival()
            # A waiting partial batch whose deadline expires before this
            # request arrives must close without it.
            while self.batcher.ready(arrival):
                reqs, close = self.batcher.pop()
                self._process(reqs, close, step_fn)
            self.submit(ids)
            while len(self.batcher) >= self.batcher.max_batch:
                reqs, close = self.batcher.pop()
                self._process(reqs, close, step_fn)
        while len(self.batcher):
            reqs, close = self.batcher.flush()
            self._process(reqs, close, step_fn)
        self.engine.close()
        return self.telemetry

    def _process(self, reqs: List[Request], close_us: float, step_fn):
        cfg, tel = self.cfg, self.telemetry
        b = self._batch_index
        tr = get_tracer()
        if tr.enabled:
            tr.set_batch(b)  # correlates store/pf/rt events for this batch
        done = self._compute_done_us
        prev_done = done[-1] if done else 0.0
        # Back-pressure: at depth d the host may only run while batch
        # b-d's output buffer has been consumed (double buffering at d=2).
        gate = done[b - cfg.pipeline_depth] if b >= cfg.pipeline_depth \
            else 0.0
        host_start = max(self._host_free_us, close_us, gate)

        ids = np.concatenate([r.ids for r in reqs])
        self.engine.observe_demand(np.unique(ids), host_start)
        if cfg.scheduler == "inline":
            self.engine.drain()  # the deterministic pre-lookup drain point
        pre_fetch_s = self.store.stats.modeled_fetch_s
        pre_hits = self.store.stats.hits
        # Wall timing covers lookup + the reported forward time only, so
        # the measured window matches the synchronous loop, which stages,
        # packages and flushes model outputs outside its timed window.
        t_wall = time.perf_counter()
        with self.engine.lock:
            emb = self.store.lookup(ids)
        lookup_wall_s = time.perf_counter() - t_wall
        fetch_us = (self.store.stats.modeled_fetch_s - pre_fetch_s) * 1e6

        fetch_done = host_start + fetch_us
        stall = max(0.0, fetch_done - max(prev_done, host_start))
        compute_start = max(fetch_done, prev_done)

        compute_s, staged = step_fn(b, emb)
        compute_us = cfg.compute_us if cfg.compute_us is not None \
            else compute_s * 1e6
        compute_done = compute_start + compute_us
        self.wall_batch_s.append(lookup_wall_s + compute_s)
        if tr.enabled:
            # Modeled-timeline lanes, fully explicit timestamps: the host
            # lane carries the on-demand fetch window, the device lane the
            # stall (the part of the fetch the overlap could not hide)
            # followed by the dense forward.
            rid0 = reqs[0].rid
            tr.add_span("rt", "fetch", host_start, fetch_us, track="host",
                        args={"rid0": rid0, "n_req": len(reqs)})
            if stall > 0.0:
                tr.add_span("rt", "stall", max(prev_done, host_start),
                            stall, track="device", args={"rid0": rid0})
            tr.add_span("rt", "compute", compute_start, compute_us,
                        track="device",
                        args={"rid0": rid0, "n_req": len(reqs)})

        # ---- bookkeeping ----
        tel.batches += 1
        tel.requests += len(reqs)
        tel.demand_fetch_ms += fetch_us * 1e-3
        tel.stall_ms += stall * 1e-3
        tel.compute_ms += compute_us * 1e-3
        for r in reqs:
            arrive = r.arrival_us if cfg.interarrival_us > 0 else host_start
            tel.latencies_us.append(compute_done - arrive)
        self._host_free_us = fetch_done
        done.append(compute_done)
        self._batch_index = b + 1
        if hasattr(self.clock, "advance_to"):
            self.clock.advance_to(compute_done)
        # Stage the model outputs this batch produced (the CPU models run
        # pipelined during the batch; their outputs land afterwards).
        for trunk, bits, pf in staged:
            self.engine.submit(trunk, bits, pf, now_us=compute_done)
        # Drift-adaptation hook: refresh items land after the model's, so
        # fresh re-ranks override stale ones at the next drain.
        if self._batch_hook is not None:
            hits = self.store.stats.hits - pre_hits
            for trunk, bits, pf in self._batch_hook(ids, hits, b) or ():
                self.engine.submit(trunk, bits, pf, now_us=compute_done)

    # ---------------- results ----------------

    def results(self) -> dict:
        return self.telemetry.as_dict()

    def publish(self, reg, prefix: str = "rt"):
        """Publish runtime telemetry + engine live-state gauges into a
        :class:`repro.obs.MetricsRegistry` (the engine shares this
        runtime's telemetry object, so one call covers both)."""
        return self.engine.publish(reg, prefix)
