"""Micro-batching request pipeline with a double-buffered fetch/compute
timeline.

Requests (one query's embedding ids each) enter an **admission queue**;
a size/deadline **micro-batcher** closes a batch when ``max_batch``
requests are waiting or the oldest has waited ``deadline_us``.  Batches
then flow through a two-stage pipeline modeled on the paper's deployment
(Fig. 6): the *host* stage drains staged model outputs, runs the tiered
lookup and pays the slow-tier on-demand fetch; the *device* stage runs the
dense forward.  With ``pipeline_depth >= 2`` the host may run ahead of the
device, so batch *k*'s slow-tier fetch overlaps batch *k-1*'s dense
forward — the fetch only **stalls** the device for the part that outlasts
the overlap window:

    host_start[k]   = max(host_free, close[k], compute_done[k - depth])
    fetch_done[k]   = host_start[k] + fetch_us[k]
    compute_start[k]= max(fetch_done[k], compute_done[k-1])
    stall[k]        = max(0, fetch_done[k] - max(compute_done[k-1],
                                                 host_start[k]))

``pipeline_depth=1`` degenerates to the synchronous runtime
(``stall == demand fetch``, exactly the store's ``modeled_fetch_s``).

Determinism contract: with the inline scheduler and a
:class:`~repro.runtime.clock.VirtualClock`, the store sees *exactly* the
same sequence of drains, lookups and model-output applications as the
synchronous serving loop — hit/miss/eviction counters reproduce
byte-for-byte — while the timeline above moves fetch time off the modeled
critical path.  Only the accounting changes, never the residency math.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass
from typing import Callable, Iterable, List, Optional, Tuple

import numpy as np

from repro.obs.tracing import get_tracer
from repro.runtime.admission import (AdmissionConfig, AdmissionQueue,
                                     AdmissionStats)
from repro.runtime.clock import Clock, VirtualClock
from repro.runtime.prefetch_engine import PrefetchEngine
from repro.runtime.telemetry import RuntimeTelemetry


@dataclass
class Request:
    """One inference query's embedding-id vector.

    ``priority`` / ``deadline_us`` only matter on the admission-control
    path (``RuntimeConfig.admission``): class index 0 is the most
    important, and the deadline is *absolute* modeled time (arrival plus
    the class latency budget).  The defaults keep the plain micro-batched
    path byte-identical to before."""

    rid: int
    ids: np.ndarray
    arrival_us: float = 0.0
    priority: int = 0
    deadline_us: float = float("inf")


@dataclass
class RuntimeConfig:
    max_batch: int = 32              # micro-batcher size trigger (queries)
    deadline_us: float = float("inf")  # micro-batcher age trigger
    pipeline_depth: int = 2          # host may run this many batches ahead
    interarrival_us: float = 0.0     # >0: open-loop arrivals at this rate
    fetch_us_per_row: float = 10.0   # slow-tier cost model (matches store)
    fetch_us_fixed: float = 30.0
    compute_us: Optional[float] = None  # None: use measured compute
    scheduler: str = "inline"        # "inline" (deterministic) | "thread"
    max_queue: int = 64              # prefetch work-queue bound
    coalesce_rows: int = 4096        # populate coalescing cap
    admission: Optional[AdmissionConfig] = None  # overload-control path

    def __post_init__(self):
        if self.pipeline_depth < 1:
            raise ValueError("pipeline_depth must be >= 1")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        # NaN comparisons are all False, so a NaN deadline would make
        # MicroBatcher.ready() silently never fire — reject it here with
        # the other nonsensical timing values.  inf deadline (size-only
        # batching) stays legal.
        if math.isnan(self.deadline_us) or self.deadline_us < 0:
            raise ValueError(
                f"deadline_us must be >= 0 (inf ok), got {self.deadline_us}")
        if math.isnan(self.interarrival_us) or self.interarrival_us < 0 \
                or math.isinf(self.interarrival_us):
            raise ValueError("interarrival_us must be finite and >= 0, "
                             f"got {self.interarrival_us}")


class MicroBatcher:
    """Size/deadline micro-batcher over an admission queue."""

    def __init__(self, max_batch: int, deadline_us: float = float("inf")):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        deadline_us = float(deadline_us)
        if math.isnan(deadline_us) or deadline_us < 0:
            raise ValueError(
                f"deadline_us must be >= 0 (inf ok), got {deadline_us}")
        self.max_batch = int(max_batch)
        self.deadline_us = deadline_us
        self._queue: List[Request] = []

    def __len__(self):
        return len(self._queue)

    @property
    def oldest_arrival_us(self) -> float:
        return self._queue[0].arrival_us if self._queue else float("inf")

    def push(self, req: Request):
        self._queue.append(req)

    def ready(self, now_us: float) -> bool:
        """A batch should close: full, or the oldest request timed out."""
        if len(self._queue) >= self.max_batch:
            return True
        return bool(self._queue) and \
            now_us - self.oldest_arrival_us >= self.deadline_us

    def pop(self) -> Tuple[List[Request], float]:
        """Close one batch; returns (requests, close time).  A full batch
        closes when its last member arrived; a deadline batch when the
        oldest request's patience ran out.  With ``deadline_us=inf`` a
        partial batch can only be popped by an explicit caller decision,
        so its close time clamps to the last arrival — an infinite close
        time would poison every latency percentile downstream."""
        take, self._queue = (self._queue[: self.max_batch],
                             self._queue[self.max_batch:])
        if not take:
            raise ValueError("pop on empty micro-batcher queue")
        last_arrival = max(r.arrival_us for r in take)
        if len(take) == self.max_batch:
            close = last_arrival
        else:
            close = take[0].arrival_us + self.deadline_us
            if not math.isfinite(close):
                close = last_arrival
        return take, close

    def flush(self, now_us: float = 0.0) -> Tuple[List[Request], float]:
        """End-of-stream: close whatever is waiting at its last arrival.
        An empty queue flushes to ``([], now_us)`` instead of raising —
        overload runs legitimately drain to empty before end-of-stream."""
        take, self._queue = self._queue[: self.max_batch], \
            self._queue[self.max_batch:]
        if not take:
            return [], float(now_us)
        return take, max(r.arrival_us for r in take)


class PipelinedRuntime:
    """Asynchronous pipelined serving runtime over a tiered store.

    Drives ``store.lookup`` through the micro-batcher and the modeled
    double-buffered timeline, with the :class:`PrefetchEngine` applying
    staged model outputs at deterministic drain points (inline scheduler)
    or on the background worker (thread scheduler).
    """

    def __init__(self, store, cfg: Optional[RuntimeConfig] = None,
                 clock: Optional[Clock] = None, batch_hook=None):
        """``batch_hook(ids, hits, batch_index) -> [(trunk, bits,
        prefetch_ids), ...]`` is called once per processed batch with the
        batch's ids and its fast-tier hit count; returned items are
        submitted through the prefetch engine like staged model outputs.
        The drift-adaptive serving path passes
        :meth:`~repro.runtime.drift.AdaptiveController.on_batch` here."""
        self.store = store
        self.cfg = cfg or RuntimeConfig()
        self.clock = clock or VirtualClock()
        self._batch_hook = batch_hook
        self.telemetry = RuntimeTelemetry()
        self.engine = PrefetchEngine(
            store, telemetry=self.telemetry, clock=self.clock,
            scheduler=self.cfg.scheduler, max_queue=self.cfg.max_queue,
            coalesce_rows=self.cfg.coalesce_rows,
            fetch_us_per_row=self.cfg.fetch_us_per_row,
            fetch_us_fixed=self.cfg.fetch_us_fixed)
        self.batcher = MicroBatcher(self.cfg.max_batch, self.cfg.deadline_us)
        # ---- admission-control state (None on the plain path) ----
        self.admission_stats: Optional[AdmissionStats] = None
        self._adm_queue: Optional[AdmissionQueue] = None
        self._bp_on = False
        if self.cfg.admission is not None:
            self.admission_stats = AdmissionStats(
                n_classes=self.cfg.admission.n_classes)
            self._adm_queue = AdmissionQueue(self.cfg.admission,
                                             self.admission_stats)
        # ---- modeled timeline state ----
        self._host_free_us = 0.0
        self._compute_done_us: List[float] = []   # per finished batch
        self._batch_index = 0
        self._next_rid = 0
        self.wall_batch_s: List[float] = []       # measured, per batch

    # ---------------- request admission ----------------

    def _arrival(self) -> float:
        if self.cfg.interarrival_us > 0:
            return self._next_rid * self.cfg.interarrival_us
        return 0.0  # closed loop: latency measured from admission

    def _make_request(self, ids: np.ndarray, priority: int = 0) -> Request:
        arrival = self._arrival()
        deadline = float("inf")
        if self.cfg.admission is not None:
            deadline = self.cfg.admission.deadline_for(priority, arrival)
        req = Request(self._next_rid, np.asarray(ids, np.int64).ravel(),
                      arrival, priority=priority, deadline_us=deadline)
        self._next_rid += 1
        return req

    def submit(self, ids: np.ndarray, priority: int = 0) -> Request:
        req = self._make_request(ids, priority)
        self.batcher.push(req)
        return req

    # ---------------- pipeline core ----------------

    def run(self, id_stream: Iterable[np.ndarray],
            step_fn: Callable[[int, object], Tuple[float, List[tuple]]]):
        """Serve a stream of per-query id vectors end to end.

        ``step_fn(batch_index, embeddings) -> (compute_seconds, staged)``
        runs the dense forward for one closed batch and returns its
        measured compute time plus the list of ``(trunk, bits,
        prefetch_ids)`` model outputs to stage for later batches.

        With ``cfg.admission`` set, stream items may also be
        ``(ids, priority)`` pairs and dispatch goes through the bounded
        EDF admission queue (:meth:`_run_admission`) instead of the
        FIFO micro-batcher; the plain path below is byte-identical to
        the pre-admission runtime.
        """
        if self.cfg.admission is not None:
            return self._run_admission(id_stream, step_fn)
        for ids in id_stream:
            arrival = self._arrival()
            # A waiting partial batch whose deadline expires before this
            # request arrives must close without it.
            while self.batcher.ready(arrival):
                reqs, close = self.batcher.pop()
                self._process(reqs, close, step_fn)
            self.submit(ids)
            while len(self.batcher) >= self.batcher.max_batch:
                reqs, close = self.batcher.pop()
                self._process(reqs, close, step_fn)
        while len(self.batcher):
            reqs, close = self.batcher.flush()
            self._process(reqs, close, step_fn)
        self.engine.close()
        return self.telemetry

    # ---------------- admission-control dispatch ----------------

    def _server_free_us(self) -> float:
        """Earliest modeled time the host can start the next batch (the
        same lower bound :meth:`_process` computes as ``max(host_free,
        gate)`` — close time is then the dispatch decision on top)."""
        b, done = self._batch_index, self._compute_done_us
        gate = done[b - self.cfg.pipeline_depth] \
            if b >= self.cfg.pipeline_depth else 0.0
        return max(self._host_free_us, gate)

    def _update_backpressure(self):
        """Queue-occupancy hysteresis driving the prefetch engine's
        issue-suppression signal (on above hi, off below lo — no
        flapping when occupancy hovers at one threshold)."""
        adm = self.cfg.admission
        occ = self._adm_queue.occupancy
        if not self._bp_on and occ >= adm.backpressure_hi:
            self._bp_on = True
            self.engine.set_backpressure(True)
        elif self._bp_on and occ <= adm.backpressure_lo:
            self._bp_on = False
            self.engine.set_backpressure(False)

    def _run_admission(self, id_stream, step_fn):
        """Overload-aware dispatch: arrivals flow through the bounded
        :class:`AdmissionQueue`; whenever the modeled server is free and
        work is queued, a batch closes in EDF order.  The server is
        work-conserving — under light load batches run partial, under
        overload the queue saturates, excess is shed lowest-priority-
        first, and over-deadline requests take the degraded path inside
        :meth:`_process`.  Fully deterministic on the VirtualClock."""
        aq, cfg = self._adm_queue, self.cfg
        pending = deque()
        for item in id_stream:
            if isinstance(item, tuple):
                ids, pri = item
            else:
                ids, pri = item, 0
            pending.append(self._make_request(ids, int(pri)))
        while pending or len(aq):
            t_free = self._server_free_us()
            # Admit everything that arrived while the server was busy, in
            # arrival order — shedding decisions happen at arrival time.
            while pending and pending[0].arrival_us <= t_free:
                aq.offer(pending.popleft())
                self._update_backpressure()
            if not len(aq):
                # Idle server: wait for (and admit) the next arrival.
                aq.offer(pending.popleft())
                self._update_backpressure()
                continue
            reqs = aq.pop(cfg.max_batch)
            self._update_backpressure()
            close = max(t_free, max(r.arrival_us for r in reqs))
            self._process(reqs, close, step_fn)
        self.engine.close()
        self.admission_stats.check()
        return self.telemetry

    def _split_degraded(self, reqs: List[Request], host_start: float):
        """Partition a closing batch into live requests (full-quality
        lookup) and over-deadline requests (degraded answer)."""
        adm = self.cfg.admission
        if adm is None or not adm.degrade:
            return reqs, []
        live = [r for r in reqs if r.deadline_us >= host_start]
        deg = [r for r in reqs if r.deadline_us < host_start]
        return live, deg

    def _assemble_degraded(self, reqs, live, degraded, emb_live):
        """Reassemble a batch's embedding matrix in request order when
        some requests took the degraded path: live rows come from the
        full lookup, degraded rows from residency-only reads (stale rows
        for what happens to be in the fast tier, a zero default row per
        slow-tier miss) — the answer always has the full batch shape."""
        deg_ids = np.concatenate([r.ids for r in degraded])
        deg_rows, n_default = self.store.lookup_resident(deg_ids)
        st = self.admission_stats
        st.degraded_rows_default += n_default
        st.degraded_rows_stale += int(deg_ids.size) - n_default
        live_rows = np.asarray(emb_live) if live else None
        deg_set = {r.rid for r in degraded}
        parts, li, di = [], 0, 0
        for r in reqs:
            n = int(r.ids.size)
            if r.rid in deg_set:
                parts.append(deg_rows[di: di + n])
                di += n
            else:
                parts.append(live_rows[li: li + n])
                li += n
        return np.concatenate(parts) if parts else deg_rows

    def _process(self, reqs: List[Request], close_us: float, step_fn):
        cfg, tel = self.cfg, self.telemetry
        b = self._batch_index
        tr = get_tracer()
        if tr.enabled:
            tr.set_batch(b)  # correlates store/pf/rt events for this batch
        done = self._compute_done_us
        prev_done = done[-1] if done else 0.0
        # Back-pressure: at depth d the host may only run while batch
        # b-d's output buffer has been consumed (double buffering at d=2).
        gate = done[b - cfg.pipeline_depth] if b >= cfg.pipeline_depth \
            else 0.0
        host_start = max(self._host_free_us, close_us, gate)

        ids = np.concatenate([r.ids for r in reqs])
        live, degraded = self._split_degraded(reqs, host_start)
        live_ids = np.concatenate([r.ids for r in live]) if live \
            else np.empty(0, np.int64)
        if live:
            self.engine.observe_demand(np.unique(live_ids), host_start)
        if cfg.scheduler == "inline":
            self.engine.drain()  # the deterministic pre-lookup drain point
        pre_fetch_s = self.store.stats.modeled_fetch_s
        pre_hits = self.store.stats.hits
        # Wall timing covers lookup + the reported forward time only, so
        # the measured window matches the synchronous loop, which stages,
        # packages and flushes model outputs outside its timed window.
        lookup_wall_s = 0.0
        emb = None
        if live:
            t_wall = time.perf_counter()
            with self.engine.lock:
                emb = self.store.lookup(live_ids)
            lookup_wall_s = time.perf_counter() - t_wall
        fetch_us = (self.store.stats.modeled_fetch_s - pre_fetch_s) * 1e6
        if degraded:
            emb = self._assemble_degraded(reqs, live, degraded, emb)

        fetch_done = host_start + fetch_us
        stall = max(0.0, fetch_done - max(prev_done, host_start))
        compute_start = max(fetch_done, prev_done)

        compute_s, staged = step_fn(b, emb)
        compute_us = cfg.compute_us if cfg.compute_us is not None \
            else compute_s * 1e6
        compute_done = compute_start + compute_us
        self.wall_batch_s.append(lookup_wall_s + compute_s)
        if tr.enabled:
            # Modeled-timeline lanes, fully explicit timestamps: the host
            # lane carries the on-demand fetch window, the device lane the
            # stall (the part of the fetch the overlap could not hide)
            # followed by the dense forward.
            rid0 = reqs[0].rid
            tr.add_span("rt", "fetch", host_start, fetch_us, track="host",
                        args={"rid0": rid0, "n_req": len(reqs)})
            if stall > 0.0:
                tr.add_span("rt", "stall", max(prev_done, host_start),
                            stall, track="device", args={"rid0": rid0})
            tr.add_span("rt", "compute", compute_start, compute_us,
                        track="device",
                        args={"rid0": rid0, "n_req": len(reqs)})

        # ---- bookkeeping ----
        if self.admission_stats is not None:
            st = self.admission_stats
            for r in live:
                st.served[r.priority] += 1
            for r in degraded:
                st.degraded[r.priority] += 1
        tel.batches += 1
        tel.requests += len(reqs)
        tel.demand_fetch_ms += fetch_us * 1e-3
        tel.stall_ms += stall * 1e-3
        tel.compute_ms += compute_us * 1e-3
        for r in reqs:
            arrive = r.arrival_us if cfg.interarrival_us > 0 else host_start
            tel.latencies_us.append(compute_done - arrive)
        self._host_free_us = fetch_done
        done.append(compute_done)
        self._batch_index = b + 1
        if hasattr(self.clock, "advance_to"):
            self.clock.advance_to(compute_done)
        # Stage the model outputs this batch produced (the CPU models run
        # pipelined during the batch; their outputs land afterwards).
        for trunk, bits, pf in staged:
            self.engine.submit(trunk, bits, pf, now_us=compute_done)
        # Drift-adaptation hook: refresh items land after the model's, so
        # fresh re-ranks override stale ones at the next drain.
        if self._batch_hook is not None:
            hits = self.store.stats.hits - pre_hits
            for trunk, bits, pf in self._batch_hook(ids, hits, b) or ():
                self.engine.submit(trunk, bits, pf, now_us=compute_done)

    # ---------------- results ----------------

    def results(self) -> dict:
        d = self.telemetry.as_dict()
        if self.admission_stats is not None:
            d["admission"] = self.admission_stats.as_dict(self.cfg.admission)
        return d

    def publish(self, reg, prefix: str = "rt"):
        """Publish runtime telemetry + engine live-state gauges into a
        :class:`repro.obs.MetricsRegistry` (the engine shares this
        runtime's telemetry object, so one call covers both).  With
        admission control active the ``adm.*`` namespace rides along."""
        self.engine.publish(reg, prefix)
        if self.admission_stats is not None:
            self.admission_stats.publish(reg, prefix="adm",
                                         cfg=self.cfg.admission)
        return reg
