"""Serving-runtime telemetry: prefetch timeliness/accuracy/coverage and
on-demand fetch-stall accounting.

Extends the store-level Fig. 14 attribution (``_pf_flag`` first-touch
prefetch hits) with the *runtime*-side counters the paper's deployment
story needs: was a prefetch issued early enough to beat the demand access
(**timeliness**), how much slow-tier traffic stayed on the inference
critical path (**stall**), and how much the pipeline hid (**hidden**).

All times are modeled microseconds from the runtime's deterministic
timeline (see :mod:`repro.runtime.clock`), reported in ms.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

import numpy as np

from repro.obs.metrics import Reservoir

# Per-request latency samples are kept in a bounded deterministic
# reservoir (exact below the cap, Algorithm-R subsample past it) instead
# of an unbounded list — a long-running service must not grow memory with
# request count.  ``len()`` still reports the total observed count and
# iteration yields the retained samples, so existing consumers are
# unchanged; percentiles stay exact for runs under the cap.
LATENCY_RESERVOIR_CAP = 16384


def latency_percentiles(samples_ms, prefix: str = "") -> Dict[str, float]:
    """p50/p95/p99 of a latency sample list, in ms (NaN-safe on empty)."""
    if len(samples_ms) == 0:
        return {f"{prefix}p50_ms": 0.0, f"{prefix}p95_ms": 0.0,
                f"{prefix}p99_ms": 0.0}
    s = np.asarray(samples_ms, np.float64)
    return {
        f"{prefix}p50_ms": float(np.percentile(s, 50)),
        f"{prefix}p95_ms": float(np.percentile(s, 95)),
        f"{prefix}p99_ms": float(np.percentile(s, 99)),
    }


@dataclass
class RuntimeTelemetry:
    """Counters for one pipelined serving run (additive via ``merge``)."""

    batches: int = 0
    requests: int = 0
    # ---- prefetch engine ----
    pf_submitted: int = 0          # rows handed to the engine
    pf_suppressed: int = 0         # dropped at submit: backpressure on
    pf_deduped: int = 0            # dropped: already queued in-flight
    pf_cancelled_resident: int = 0  # dropped at issue: became resident
    pf_shard_down: int = 0         # cancelled: target shard died (failover)
    pf_issued: int = 0             # rows actually populated
    pf_populate_calls: int = 0     # coalesced batched populate calls
    pf_timely: int = 0             # modeled completion <= demand time
    pf_late: int = 0               # demanded while still in flight
    pf_late_ms: float = 0.0        # total modeled lateness
    pf_unused: int = 0             # never demanded before run end
    pf_fetch_ms: float = 0.0       # background-channel traffic (modeled)
    pf_channel_scheduled: int = 0  # rows put on the modeled fetch channel
    pf_eta_overwritten: int = 0    # rescheduled rows whose old ETA was lost
    rank_cancelled_evicted: int = 0  # rankings dropped: evicted pre-issue
    # ---- critical path ----
    demand_fetch_ms: float = 0.0   # total on-demand slow-tier cost
    stall_ms: float = 0.0          # part of it the pipeline could NOT hide
    compute_ms: float = 0.0        # modeled device compute
    # ---- per-request latency (modeled us; bounded reservoir) ----
    latencies_us: Reservoir = field(
        default_factory=lambda: Reservoir(cap=LATENCY_RESERVOIR_CAP))

    def __post_init__(self):
        # Accept a plain list at construction (test/legacy convenience).
        if not isinstance(self.latencies_us, Reservoir):
            self.latencies_us = Reservoir(cap=LATENCY_RESERVOIR_CAP,
                                          items=self.latencies_us)

    # ------------------------------------------------------------------
    @property
    def hidden_ms(self) -> float:
        """On-demand fetch time overlapped with compute (the pipeline win)."""
        return self.demand_fetch_ms - self.stall_ms

    @property
    def stall_reduction(self) -> float:
        """1 - stall/total: fraction of on-demand fetch taken off the
        critical path (the sync runtime is 0 by construction)."""
        return self.hidden_ms / max(self.demand_fetch_ms, 1e-12)

    @property
    def pf_timeliness(self) -> float:
        return self.pf_timely / max(self.pf_timely + self.pf_late, 1)

    def request_percentiles(self) -> Dict[str, float]:
        return latency_percentiles(
            [u * 1e-3 for u in self.latencies_us], prefix="req_")

    def as_dict(self) -> Dict:
        d = {
            "batches": self.batches, "requests": self.requests,
            "pf_submitted": self.pf_submitted,
            "pf_suppressed": self.pf_suppressed,
            "pf_deduped": self.pf_deduped,
            "pf_cancelled_resident": self.pf_cancelled_resident,
            "pf_shard_down": self.pf_shard_down,
            "pf_issued": self.pf_issued,
            "pf_populate_calls": self.pf_populate_calls,
            "pf_timely": self.pf_timely, "pf_late": self.pf_late,
            "pf_timeliness": round(self.pf_timeliness, 4),
            "pf_late_ms": round(self.pf_late_ms, 3),
            "pf_unused": self.pf_unused,
            "pf_fetch_ms": round(self.pf_fetch_ms, 3),
            "pf_channel_scheduled": self.pf_channel_scheduled,
            "pf_eta_overwritten": self.pf_eta_overwritten,
            "rank_cancelled_evicted": self.rank_cancelled_evicted,
            "demand_fetch_ms": round(self.demand_fetch_ms, 3),
            "stall_ms": round(self.stall_ms, 3),
            "hidden_ms": round(self.hidden_ms, 3),
            "stall_reduction": round(self.stall_reduction, 4),
            "compute_ms": round(self.compute_ms, 3),
        }
        d.update({k: round(v, 3)
                  for k, v in self.request_percentiles().items()})
        return d

    def merge(self, other: "RuntimeTelemetry") -> "RuntimeTelemetry":
        for f in ("batches", "requests", "pf_submitted", "pf_suppressed",
                  "pf_deduped",
                  "pf_cancelled_resident", "pf_shard_down",
                  "pf_issued", "pf_populate_calls",
                  "pf_timely", "pf_late", "pf_unused",
                  "pf_channel_scheduled", "pf_eta_overwritten",
                  "rank_cancelled_evicted"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        for f in ("pf_late_ms", "pf_fetch_ms", "demand_fetch_ms",
                  "stall_ms", "compute_ms"):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        if isinstance(other.latencies_us, Reservoir):
            self.latencies_us.merge(other.latencies_us)
        else:
            self.latencies_us.extend(other.latencies_us)
        return self

    def publish(self, reg, prefix: str = "rt"):
        """Publish into a :class:`repro.obs.MetricsRegistry` under the
        ``rt.*`` namespace (see docs/architecture.md)."""
        for key, val in (
            ("batches", self.batches), ("requests", self.requests),
            ("pf.submitted", self.pf_submitted),
            ("pf.suppressed", self.pf_suppressed),
            ("pf.deduped", self.pf_deduped),
            ("pf.cancelled_resident", self.pf_cancelled_resident),
            ("pf.shard_down", self.pf_shard_down),
            ("pf.issued", self.pf_issued),
            ("pf.populate_calls", self.pf_populate_calls),
            ("pf.timely", self.pf_timely), ("pf.late", self.pf_late),
            ("pf.late_ms", self.pf_late_ms),
            ("pf.unused", self.pf_unused),
            ("pf.fetch_ms", self.pf_fetch_ms),
            ("pf.channel_scheduled", self.pf_channel_scheduled),
            ("pf.eta_overwritten", self.pf_eta_overwritten),
            ("rank_cancelled_evicted", self.rank_cancelled_evicted),
            ("demand_fetch_ms", self.demand_fetch_ms),
            ("stall_ms", self.stall_ms),
            ("compute_ms", self.compute_ms),
        ):
            reg.counter(f"{prefix}.{key}").inc(val)
        reg.gauge(f"{prefix}.hidden_ms").set(self.hidden_ms)
        reg.gauge(f"{prefix}.stall_reduction").set(self.stall_reduction)
        reg.gauge(f"{prefix}.pf.timeliness").set(self.pf_timeliness)
        reg.histogram(f"{prefix}.req_latency_us",
                      cap=self.latencies_us.cap).merge(self.latencies_us)
        return reg
