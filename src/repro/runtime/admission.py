"""SLO-aware admission control for the pipelined serving runtime.

Production DLRM serving (SDM, PAPERS.md) is governed by tail-latency
SLOs, and the defining regime of a millions-of-users service is offered
load **exceeding** capacity.  This module adds the overload vocabulary
the `MicroBatcher` lacks:

* **Priority classes** (:data:`PRIORITY_CLASSES`): every request carries
  a class index — 0 is the most important — and a per-class latency
  budget that turns its arrival time into an absolute deadline.
* **EDF batch scheduling**: :meth:`AdmissionQueue.pop` closes batches in
  earliest-deadline-first order (ties broken by arrival, then request
  id) instead of the batcher's FIFO order, so urgent work jumps the
  queue deterministically.
* **Bounded queue with exact shed accounting**: when the queue is at
  ``queue_bound``, :meth:`AdmissionQueue.offer` sheds **lowest-priority-
  first** — an important arrival displaces the least important queued
  request; an unimportant arrival is turned away at the door.  Every
  shed is counted per class.
* **Graceful degradation**: requests already past their deadline when a
  batch starts service are answered from fast-tier residency only
  (:meth:`~repro.core.tiered.TieredEmbeddingStore.lookup_resident` —
  stale-but-resident rows plus a zero default row, never a wrong-shape
  answer) and counted as *degraded*, keeping the slow tier off their
  critical path.  Queue pressure also raises a **backpressure** signal
  that makes the :class:`~repro.runtime.prefetch_engine.PrefetchEngine`
  skip prefetch issue until the queue drains (hysteresis, so the signal
  does not flap batch to batch).

Everything runs on the deterministic VirtualClock timeline, so every
overload scenario replays byte-for-byte, and the accounting closes
exactly::

    admitted == served + shed + degraded          (per class and total)

— reconciled by :func:`repro.obs.reconcile.check_admission` under the
``adm.*`` metrics namespace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# Class index 0 is the most important.  The names are labels for metrics
# and CLI mixes; the scheduler only ever sees the index.
PRIORITY_CLASSES: Tuple[str, ...] = ("gold", "silver", "bronze")

# Default per-class latency budgets (modeled us): interactive gold
# traffic, near-line silver, batch-ish bronze.
DEFAULT_CLASS_DEADLINE_US: Tuple[float, ...] = (50_000.0, 200_000.0,
                                                1_000_000.0)


def _finite_nonneg(name: str, v: float, allow_inf: bool = False) -> float:
    v = float(v)
    if math.isnan(v) or v < 0 or (not allow_inf and math.isinf(v)):
        raise ValueError(f"{name} must be a finite non-negative number, "
                         f"got {v!r}")
    return v


@dataclass(frozen=True)
class AdmissionConfig:
    """Overload-behavior knobs for :class:`PipelinedRuntime`."""

    queue_bound: int = 256            # max queued requests before shedding
    class_deadline_us: Tuple[float, ...] = DEFAULT_CLASS_DEADLINE_US
    degrade: bool = True              # serve stale/default past deadline
    # Backpressure hysteresis, as fractions of queue_bound: the prefetch
    # engine stops issuing above ``hi`` occupancy and resumes below ``lo``.
    backpressure_hi: float = 0.75
    backpressure_lo: float = 0.50

    def __post_init__(self):
        if int(self.queue_bound) < 1:
            raise ValueError("queue_bound must be >= 1")
        object.__setattr__(self, "queue_bound", int(self.queue_bound))
        dl = tuple(_finite_nonneg("class_deadline_us", d, allow_inf=True)
                   for d in self.class_deadline_us)
        if not dl:
            raise ValueError("class_deadline_us must name >= 1 class")
        object.__setattr__(self, "class_deadline_us", dl)
        hi = _finite_nonneg("backpressure_hi", self.backpressure_hi)
        lo = _finite_nonneg("backpressure_lo", self.backpressure_lo)
        if not (0.0 <= lo <= hi <= 1.0):
            raise ValueError("need 0 <= backpressure_lo <= backpressure_hi"
                             f" <= 1, got lo={lo} hi={hi}")

    @property
    def n_classes(self) -> int:
        return len(self.class_deadline_us)

    def class_name(self, pri: int) -> str:
        if pri < len(PRIORITY_CLASSES):
            return PRIORITY_CLASSES[pri]
        return f"class{pri}"

    def deadline_for(self, pri: int, arrival_us: float) -> float:
        """Absolute deadline for a class-``pri`` request arriving now."""
        if not 0 <= pri < self.n_classes:
            raise ValueError(f"priority {pri} out of range "
                             f"[0, {self.n_classes})")
        return arrival_us + self.class_deadline_us[pri]


@dataclass
class AdmissionStats:
    """Per-class request-fate counters.  Every offered request lands in
    exactly one of served / shed / degraded, so the identity
    ``admitted == served + shed + degraded`` holds at all times (and per
    class), which :func:`repro.obs.reconcile.check_admission` asserts."""

    n_classes: int = len(PRIORITY_CLASSES)
    admitted: List[int] = field(default_factory=list)   # offered to queue
    served: List[int] = field(default_factory=list)     # full-quality answer
    shed: List[int] = field(default_factory=list)       # turned away
    degraded: List[int] = field(default_factory=list)   # stale/default answer
    degraded_rows_stale: int = 0    # resident rows served without recency
    degraded_rows_default: int = 0  # zero-vector default rows served

    def __post_init__(self):
        for f in ("admitted", "served", "shed", "degraded"):
            if not getattr(self, f):
                setattr(self, f, [0] * self.n_classes)

    # ---- totals ----
    @property
    def total_admitted(self) -> int:
        return sum(self.admitted)

    @property
    def total_served(self) -> int:
        return sum(self.served)

    @property
    def total_shed(self) -> int:
        return sum(self.shed)

    @property
    def total_degraded(self) -> int:
        return sum(self.degraded)

    def check(self):
        """Raise if the fate identity is violated (cheap, exact)."""
        for c in range(self.n_classes):
            got = self.served[c] + self.shed[c] + self.degraded[c]
            if got != self.admitted[c]:
                raise AssertionError(
                    f"class {c}: admitted {self.admitted[c]} != "
                    f"served+shed+degraded {got}")

    def as_dict(self, cfg: Optional[AdmissionConfig] = None) -> Dict:
        name = (cfg.class_name if cfg is not None
                else lambda c: PRIORITY_CLASSES[c]
                if c < len(PRIORITY_CLASSES) else f"class{c}")
        d = {
            "admitted": self.total_admitted,
            "served": self.total_served,
            "shed": self.total_shed,
            "degraded": self.total_degraded,
            "degraded_rows_stale": self.degraded_rows_stale,
            "degraded_rows_default": self.degraded_rows_default,
        }
        for c in range(self.n_classes):
            d[f"{name(c)}_admitted"] = self.admitted[c]
            d[f"{name(c)}_served"] = self.served[c]
            d[f"{name(c)}_shed"] = self.shed[c]
            d[f"{name(c)}_degraded"] = self.degraded[c]
        return d

    def merge(self, other: "AdmissionStats") -> "AdmissionStats":
        if other.n_classes != self.n_classes:
            raise ValueError("class-count mismatch in merge")
        for f in ("admitted", "served", "shed", "degraded"):
            mine, theirs = getattr(self, f), getattr(other, f)
            for c in range(self.n_classes):
                mine[c] += theirs[c]
        self.degraded_rows_stale += other.degraded_rows_stale
        self.degraded_rows_default += other.degraded_rows_default
        return self

    def publish(self, reg, prefix: str = "adm",
                cfg: Optional[AdmissionConfig] = None):
        """Publish into a :class:`repro.obs.MetricsRegistry` under the
        ``adm.*`` namespace: totals plus one ``adm.class.<name>.*``
        sub-namespace per priority class (reconciled by
        :func:`repro.obs.reconcile.check_admission`)."""
        name = (cfg.class_name if cfg is not None
                else lambda c: PRIORITY_CLASSES[c]
                if c < len(PRIORITY_CLASSES) else f"class{c}")
        reg.counter(f"{prefix}.admitted").inc(self.total_admitted)
        reg.counter(f"{prefix}.served").inc(self.total_served)
        reg.counter(f"{prefix}.shed").inc(self.total_shed)
        reg.counter(f"{prefix}.degraded").inc(self.total_degraded)
        reg.counter(f"{prefix}.degraded_rows_stale").inc(
            self.degraded_rows_stale)
        reg.counter(f"{prefix}.degraded_rows_default").inc(
            self.degraded_rows_default)
        for c in range(self.n_classes):
            ns = f"{prefix}.class.{name(c)}"
            reg.counter(f"{ns}.admitted").inc(self.admitted[c])
            reg.counter(f"{ns}.served").inc(self.served[c])
            reg.counter(f"{ns}.shed").inc(self.shed[c])
            reg.counter(f"{ns}.degraded").inc(self.degraded[c])
        return reg


class AdmissionQueue:
    """Bounded admission queue with EDF pop order and lowest-priority-
    first shedding.  Deterministic: every tie is broken by (priority,
    deadline, arrival, rid), so two runs over the same arrival sequence
    shed and schedule identically."""

    def __init__(self, cfg: AdmissionConfig,
                 stats: Optional[AdmissionStats] = None):
        self.cfg = cfg
        self.stats = stats if stats is not None \
            else AdmissionStats(n_classes=cfg.n_classes)
        self._q: List = []   # unordered; pop() sorts by EDF key

    def __len__(self):
        return len(self._q)

    @property
    def occupancy(self) -> float:
        return len(self._q) / self.cfg.queue_bound

    @staticmethod
    def _edf_key(req) -> tuple:
        return (req.deadline_us, req.arrival_us, req.rid)

    @staticmethod
    def _shed_key(req) -> tuple:
        """Largest key = first to shed: least important class, then the
        least urgent (latest deadline), then the youngest arrival."""
        return (req.priority, req.deadline_us, req.arrival_us, req.rid)

    def offer(self, req) -> bool:
        """Admit ``req``; returns False when it (not necessarily another
        request) was shed.  At the bound the *least important* request —
        queued or incoming — is shed, so a gold arrival always finds
        room while bronze is waiting."""
        st = self.stats
        st.admitted[req.priority] += 1
        if len(self._q) < self.cfg.queue_bound:
            self._q.append(req)
            return True
        victim_i = max(range(len(self._q)),
                       key=lambda i: self._shed_key(self._q[i]))
        victim = self._q[victim_i]
        if self._shed_key(victim) > self._shed_key(req):
            self._q[victim_i] = req
            st.shed[victim.priority] += 1
            return True
        st.shed[req.priority] += 1
        return False

    def pop(self, max_batch: int) -> List:
        """Close one batch: up to ``max_batch`` requests in EDF order."""
        if not self._q:
            raise ValueError("pop on empty admission queue")
        self._q.sort(key=self._edf_key)
        take, self._q = self._q[:max_batch], self._q[max_batch:]
        return take

    def drain(self) -> List:
        """Take everything queued (end-of-stream), in EDF order."""
        self._q.sort(key=self._edf_key)
        take, self._q = self._q, []
        return take
