"""Drift detection + online adaptation for tiered serving.

The RecMG models are trained offline and frozen; when the access
distribution moves (diurnal hot-set rotation, a flash crowd), their
outputs keep protecting and prefetching *stale* rows and the policy
decays toward (or below) LRU.  This module closes the loop:

* :class:`DriftDetector` — windowed telemetry over the live access
  stream: per-window hit rate against an EWMA baseline, and the Jaccard
  overlap between consecutive windows' hot sets.  Either signal crossing
  its threshold flags drift (hot-set Jaccard catches the *cause*, the
  hit-rate drop catches the *symptom* — a switch inside the buffer's
  capacity can move Jaccard without hurting hit rate yet, and vice
  versa).
* :class:`AdaptiveController` — owns a detector plus a ring of the most
  recent accesses; on a drift trigger it rebuilds the model-output
  *features* from that live window (the incremental refresh: the hot-id
  candidate pool and keep-priorities are re-derived online, exactly the
  inputs the offline models were approximating) and emits Algorithm-1
  items ``(trunk, bits, prefetch_ids)``: protect the currently-hot
  resident rows, prefetch the currently-hot non-resident ones.  Staging
  those through the normal model-output path re-ranks the buffer without
  touching residency invariants.

Wiring: the synchronous ``serve_trace`` loop calls
``controller.on_batch(ids, hits, b)`` after each batch and stages the
returned items; :class:`~repro.runtime.pipeline.PipelinedRuntime` accepts
the same callable as its ``batch_hook`` and submits the items through the
prefetch engine — one controller, both serving paths.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.obs.tracing import get_tracer

_EMPTY = np.empty(0, np.int64)


@dataclass(frozen=True)
class DriftConfig:
    window: int = 4096        # accesses per telemetry window
    hot_k: int = 256          # hot-set size for the Jaccard signal
    jaccard_min: float = 0.35  # drift when overlap falls below this
    hitrate_drop: float = 0.12  # drift when window hit rate falls this far
    #                             below the EWMA baseline (absolute)
    ewma: float = 0.3         # baseline smoothing factor
    warmup_windows: int = 2   # closed windows before triggers may fire
    cooldown_windows: int = 1  # post-trigger windows with triggers held
    refresh_pf: int = 512     # max prefetch rows per adaptation refresh


class DriftDetector:
    """Windowed hit-rate + hot-set-Jaccard drift telemetry.

    Feed every served batch through :meth:`observe`; it returns ``True``
    exactly when an access window closes *and* flags drift.  All state is
    derived from the fed stream, so the detector is deterministic for a
    deterministic serving loop (golden-testable).
    """

    def __init__(self, cfg: Optional[DriftConfig] = None):
        self.cfg = cfg or DriftConfig()
        self._ids: List[np.ndarray] = []
        self._n = 0
        self._hits = 0
        self._prev_hot: Optional[np.ndarray] = None
        self._baseline: Optional[float] = None
        self._cooldown = 0
        # ---- telemetry counters ----
        self.accesses = 0
        self.windows = 0
        self.triggers = 0
        self.jaccard_triggers = 0
        self.hitrate_triggers = 0
        self.last_jaccard = 1.0
        self.min_jaccard = 1.0
        self.last_window_hit_rate = 0.0

    def observe(self, ids: np.ndarray, hits: int) -> bool:
        """Add one served batch (``ids`` accessed, ``hits`` of them served
        from the fast tier); returns True when a window closes with
        drift."""
        ids = np.asarray(ids, np.int64).ravel()
        self._ids.append(ids)
        self._n += ids.size
        self._hits += int(hits)
        self.accesses += ids.size
        if self._n < self.cfg.window:
            return False
        return self._close_window()

    def _hot_set(self, ids: np.ndarray) -> np.ndarray:
        from repro.core.cache_sim import top_ids_by_count

        return np.sort(top_ids_by_count(ids, self.cfg.hot_k))

    def _close_window(self) -> bool:
        cfg = self.cfg
        ids = np.concatenate(self._ids)
        win_hr = self._hits / max(self._n, 1)
        hot = self._hot_set(ids)
        jac = 1.0
        if self._prev_hot is not None:
            inter = np.intersect1d(hot, self._prev_hot,
                                   assume_unique=True).size
            union = hot.size + self._prev_hot.size - inter
            jac = inter / max(union, 1)
        self.windows += 1
        self.last_jaccard = jac
        self.min_jaccard = min(self.min_jaccard, jac)
        self.last_window_hit_rate = win_hr

        fired = False
        armed = (self.windows > cfg.warmup_windows and self._cooldown == 0)
        if armed and self._prev_hot is not None and jac < cfg.jaccard_min:
            self.jaccard_triggers += 1
            fired = True
        if (armed and self._baseline is not None
                and win_hr < self._baseline - cfg.hitrate_drop):
            self.hitrate_triggers += 1
            fired = True
        if fired:
            self.triggers += 1
            tr = get_tracer()
            if tr.enabled:
                tr.add_instant("drift", "trigger", track="drift", args={
                    "window": self.windows, "jaccard": round(jac, 4),
                    "hit_rate": round(win_hr, 4)})
            self._cooldown = cfg.cooldown_windows
            # Adopt the post-drift regime as the new normal so a single
            # switch does not re-trigger every following window.
            self._baseline = win_hr
        else:
            if self._cooldown:
                self._cooldown -= 1
            self._baseline = (win_hr if self._baseline is None else
                              (1 - cfg.ewma) * self._baseline
                              + cfg.ewma * win_hr)
        self._prev_hot = hot
        self._ids, self._n, self._hits = [], 0, 0
        return fired

    def publish(self, reg, prefix: str = "drift"):
        """Publish into a :class:`repro.obs.MetricsRegistry` under the
        ``drift.*`` namespace."""
        for key, val in (("accesses", self.accesses),
                         ("windows", self.windows),
                         ("triggers", self.triggers),
                         ("jaccard_triggers", self.jaccard_triggers),
                         ("hitrate_triggers", self.hitrate_triggers)):
            reg.counter(f"{prefix}.{key}").inc(val)
        reg.gauge(f"{prefix}.last_jaccard").set(self.last_jaccard)
        reg.gauge(f"{prefix}.min_jaccard").set(self.min_jaccard)
        reg.gauge(f"{prefix}.last_window_hit_rate").set(
            self.last_window_hit_rate)
        return reg

    def as_dict(self) -> dict:
        return {
            "accesses": self.accesses,
            "windows": self.windows,
            "triggers": self.triggers,
            "jaccard_triggers": self.jaccard_triggers,
            "hitrate_triggers": self.hitrate_triggers,
            "last_jaccard": round(self.last_jaccard, 4),
            "min_jaccard": round(self.min_jaccard, 4),
            "last_window_hit_rate": round(self.last_window_hit_rate, 4),
            "baseline_hit_rate": (None if self._baseline is None
                                  else round(self._baseline, 4)),
        }


class AdaptiveController:
    """Drift detector + live-window feature refresh for one store.

    ``on_batch(ids, hits, batch_index)`` is the single hook both serving
    paths call per batch; it returns ``(trunk, bits, prefetch_ids)``
    items to stage.  Until drift fires the list is empty — the offline
    model runs untouched.  The first trigger switches the controller into
    **online mode**, where the model's *features* are continuously
    refreshed from the live stream:

    * the hot-id pool (the feature the frozen model derived from its
      training window) is rebuilt from the last ``window`` accesses at
      the trigger and again at every later window close — incremental,
      one ``unique`` per window;
    * every batch, the just-accessed chunk is re-ranked against the live
      pool (keep-bit = pool membership).  Staged *after* the frozen
      model's items, these fresh ranks win, so stale demotions of
      newly-hot rows stop immediately;
    * hot non-resident rows are prefetched at each pool rebuild (bounded
      by ``refresh_pf``) over the background channel.

    A one-shot refresh is not enough: the frozen model keeps demoting the
    new regime's rows on every subsequent chunk, and would undo it within
    a window.
    """

    def __init__(self, store, capacity: int,
                 cfg: Optional[DriftConfig] = None):
        self.cfg = cfg or DriftConfig()
        self.detector = DriftDetector(self.cfg)
        self.store = store
        self.capacity = int(capacity)
        self._recent: List[np.ndarray] = []
        self._recent_n = 0
        self._pool: Optional[np.ndarray] = None  # sorted live hot ids
        self.refreshes = 0
        self.refresh_pf_rows = 0
        self.rerank_rows = 0

    def on_batch(self, ids: np.ndarray, hits: int,
                 batch_index: int = 0) -> List[Tuple]:
        ids = np.asarray(ids, np.int64).ravel()
        self._recent.append(ids)
        self._recent_n += ids.size
        while (len(self._recent) > 1
               and self._recent_n - self._recent[0].size >= self.cfg.window):
            self._recent_n -= self._recent[0].size
            self._recent.pop(0)
        windows_before = self.detector.windows
        fired = self.detector.observe(ids, hits)
        items: List[Tuple] = []
        if fired or (self._pool is not None
                     and self.detector.windows > windows_before):
            items.extend(self._refresh_pool())
        if self._pool is not None:
            items.append(self._rerank_chunk(ids))
        return items

    def recent_ids(self) -> np.ndarray:
        """The controller's live access window (most recent ~``window``
        accesses, oldest first) — the data an online fine-tune trains on
        (:class:`~repro.core.model_runtime.LearnedController`)."""
        if not self._recent:
            return _EMPTY
        return np.concatenate(self._recent)

    def _refresh_pool(self) -> List[Tuple]:
        from repro.core.cache_sim import top_ids_by_count

        tr = get_tracer()
        if tr.enabled:
            t0 = tr.clock.now()
        hot = top_ids_by_count(np.concatenate(self._recent), self.capacity)
        self._pool = np.sort(hot)
        # Truncate the bounded prefetch budget in HEAT order (``hot`` is
        # hottest-first) — spending it on the lowest ids instead would
        # leave the genuinely hottest rows on the on-demand path.
        pf = hot[~self.store.resident_mask(hot)][: self.cfg.refresh_pf]
        self.refreshes += 1
        self.refresh_pf_rows += int(pf.size)
        if tr.enabled:
            tr.add_span("drift", "refresh", t0, tr.clock.now() - t0,
                        track="drift",
                        args={"pool": int(hot.size), "pf_rows": int(pf.size)})
        return [(_EMPTY, _EMPTY, pf)] if pf.size else []

    def _rerank_chunk(self, ids: np.ndarray) -> Tuple:
        """Fresh keep-bits for the just-accessed chunk: membership of the
        live hot pool (the online stand-in for the caching model's
        inference on refreshed features)."""
        from repro.core.cache_sim import isin_sorted

        uniq = np.unique(ids)
        bits = isin_sorted(self._pool, uniq).astype(np.int64)
        self.rerank_rows += uniq.size
        return (uniq, bits, _EMPTY)

    def as_dict(self) -> dict:
        d = self.detector.as_dict()
        d.update(refreshes=self.refreshes,
                 refresh_pf_rows=self.refresh_pf_rows,
                 rerank_rows=self.rerank_rows)
        return d

    def publish(self, reg, prefix: str = "drift"):
        """Detector counters plus the controller's refresh counters."""
        self.detector.publish(reg, prefix)
        for key, val in (("refreshes", self.refreshes),
                         ("refresh_pf_rows", self.refresh_pf_rows),
                         ("rerank_rows", self.rerank_rows)):
            reg.counter(f"{prefix}.{key}").inc(val)
        return reg


# The hook signature both serving paths use.
BatchHook = Callable[[np.ndarray, int, int], List[Tuple]]
