"""Fused embedding gather + sum-pool Pallas TPU kernel.

This is the paper's hot spot: multi-hot lookups into large embedding tables
(TorchRec's fused kernels on GPU).  TPU-native formulation: the multi-hot
index matrix is *scalar-prefetched* so it can drive ``BlockSpec.index_map``
— each grid step DMAs exactly one needed table row HBM->VMEM (no
gather-scatter in registers, rows stream through the MXU-aligned 128-lane
layout) and accumulates the pool sum in the revisited output block.

Grid: (batch, pooling) with the pooling axis innermost — the output block
(1, D) stays resident in VMEM across the whole pooling loop and is written
back once (TPU grids are sequential, revisited blocks are kept live).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_pool_kernel(idx_ref, table_ref, out_ref):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


def _gather_rows_kernel(idx_ref, table_ref, out_ref):
    out_ref[...] = table_ref[...]


def gather_rows(table: jax.Array, idx: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """table: (N, D); idx: (M,) -> (M, D) = table[idx], no pooling.

    The un-pooled gather the tiered serving buffer uses: the flat slot-index
    vector is scalar-prefetched so ``BlockSpec.index_map`` DMAs exactly the
    needed buffer row HBM->VMEM per grid step (same streaming layout as
    ``gather_pool``, minus the accumulation).  D should be a multiple of 128
    (lane width) for the non-interpret path.
    """
    (M,) = idx.shape
    N, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, D), lambda m, idx_ref: (idx_ref[m], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda m, idx_ref: (m, 0)),
    )
    return pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def gather_pool(table: jax.Array, idx: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """table: (N, D); idx: (B, P) int32 -> pooled (B, D) = sum_p table[idx].

    D should be a multiple of 128 (lane width) for the non-interpret path.
    """
    B, P = idx.shape
    N, D = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, idx_ref: (idx_ref[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, p, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        _gather_pool_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)
