"""Fused embedding gather + sum-pool Pallas TPU kernels, fp32 and quantized.

This is the paper's hot spot: multi-hot lookups into large embedding tables
(TorchRec's fused kernels on GPU).  TPU-native formulation: the multi-hot
index matrix is *scalar-prefetched* so it can drive ``BlockSpec.index_map``
— each grid step DMAs exactly one needed table row HBM->VMEM (no
gather-scatter in registers, rows stream through the MXU-aligned 128-lane
layout) and accumulates the pool sum in the revisited output block.

Grid: (batch, pooling) with the pooling axis innermost — the output block
(1, D) stays resident in VMEM across the whole pooling loop and is written
back once (TPU grids are sequential, revisited blocks are kept live).

The ``*_dequant`` variants serve the quantized fast tier (SDM's
capacity/precision trade): the table holds int8 or fp8 rows with one fp32
scale per row, and dequantization happens *in kernel* — each grid step DMAs
the 1-byte-per-element row plus its (1, 1) scale and multiplies in VMEM, so
the HBM traffic per gathered row is ``D + 4`` bytes instead of ``4 * D``.
``quantize_rows`` is the matching populate-side kernel: per-row absmax ->
scale -> round/clip device-side, so admits never round-trip through host
NumPy.  Row formats (``ROW_FORMATS``): ``int8`` (symmetric, +-127) and
``fp8`` (``float8_e4m3fn``, +-448).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# row format -> (storage dtype, largest representable magnitude the scale
# normalizes to).  Shared by the kernels, the jnp reference, and the store.
ROW_FORMATS = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}


def _check_lane_width(d: int, interpret: bool, fn: str):
    """The compiled TPU path streams rows through the 128-lane VREG
    layout; a ragged last lane-group silently corrupts the DMA tiling, so
    fail loudly instead (the interpret path has no such constraint)."""
    if not interpret and d % 128:
        raise ValueError(
            f"{fn}: embedding dim D={d} must be a multiple of 128 (TPU "
            "lane width) on the compiled path — pad the table to a "
            "multiple of 128 or pass interpret=True")


def _gather_pool_kernel(idx_ref, table_ref, out_ref):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(out_ref.dtype)


def _gather_rows_kernel(idx_ref, table_ref, out_ref):
    out_ref[...] = table_ref[...]


def gather_rows(table: jax.Array, idx: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """table: (N, D); idx: (M,) -> (M, D) = table[idx], no pooling.

    The un-pooled gather the tiered serving buffer uses: the flat slot-index
    vector is scalar-prefetched so ``BlockSpec.index_map`` DMAs exactly the
    needed buffer row HBM->VMEM per grid step (same streaming layout as
    ``gather_pool``, minus the accumulation).  D must be a multiple of 128
    (lane width) for the non-interpret path (checked).
    """
    (M,) = idx.shape
    N, D = table.shape
    _check_lane_width(D, interpret, "gather_rows")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, D), lambda m, idx_ref: (idx_ref[m], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda m, idx_ref: (m, 0)),
    )
    return pl.pallas_call(
        _gather_rows_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), table.dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


def gather_pool(table: jax.Array, idx: jax.Array, *,
                interpret: bool = False) -> jax.Array:
    """table: (N, D); idx: (B, P) int32 -> pooled (B, D) = sum_p table[idx].

    D must be a multiple of 128 (lane width) for the non-interpret path
    (checked).
    """
    B, P = idx.shape
    N, D = table.shape
    _check_lane_width(D, interpret, "gather_pool")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, idx_ref: (idx_ref[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, p, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        _gather_pool_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), table)


# ---------------------------------------------------------------------------
# Quantized fast tier: fused dequantizing gathers + device-side quantizer.
# ---------------------------------------------------------------------------


def _gather_rows_dequant_kernel(idx_ref, table_ref, scale_ref, out_ref):
    out_ref[...] = table_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def gather_rows_dequant(table: jax.Array, scales: jax.Array, idx: jax.Array,
                        *, interpret: bool = False) -> jax.Array:
    """table: (N, D) int8/fp8; scales: (N,) fp32; idx: (M,) ->
    (M, D) fp32 = table[idx] * scales[idx, None], dequantized in-kernel.

    Same streaming layout as :func:`gather_rows`: the scalar-prefetched
    index vector drives both block index maps, so each grid step DMAs one
    quantized row (D bytes) plus its (1, 1) scale and dequantizes in VMEM
    — the fp32 row never exists in HBM.  D must be a multiple of 128 on
    the non-interpret path (checked).
    """
    (M,) = idx.shape
    N, D = table.shape
    _check_lane_width(D, interpret, "gather_rows_dequant")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(M,),
        in_specs=[
            pl.BlockSpec((1, D), lambda m, idx_ref: (idx_ref[m], 0)),
            pl.BlockSpec((1, 1), lambda m, idx_ref: (idx_ref[m], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda m, idx_ref: (m, 0)),
    )
    return pl.pallas_call(
        _gather_rows_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, D), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), table, scales.reshape(-1, 1))


def _gather_pool_dequant_kernel(idx_ref, table_ref, scale_ref, out_ref):
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += table_ref[...].astype(jnp.float32) * scale_ref[0, 0]


def gather_pool_dequant(table: jax.Array, scales: jax.Array, idx: jax.Array,
                        *, interpret: bool = False) -> jax.Array:
    """table: (N, D) int8/fp8; scales: (N,); idx: (B, P) ->
    (B, D) fp32 = sum_p table[idx] * scales[idx], dequantized in-kernel.

    The pooled variant accumulates *dequantized* rows in the revisited
    VMEM output block, so pooling never materialises per-hot fp32 rows.
    D must be a multiple of 128 on the non-interpret path (checked).
    """
    B, P = idx.shape
    N, D = table.shape
    _check_lane_width(D, interpret, "gather_pool_dequant")
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, p, idx_ref: (idx_ref[b, p], 0)),
            pl.BlockSpec((1, 1), lambda b, p, idx_ref: (idx_ref[b, p], 0)),
        ],
        out_specs=pl.BlockSpec((1, D), lambda b, p, idx_ref: (b, 0)),
    )
    return pl.pallas_call(
        _gather_pool_dequant_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, D), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), table, scales.reshape(-1, 1))


def _quantize_rows_kernel(rows_ref, q_ref, scale_ref, *, row_format):
    qdtype, qmax = ROW_FORMATS[row_format]
    row = rows_ref[...].astype(jnp.float32)
    scale = jnp.max(jnp.abs(row)) / qmax + 1e-12
    y = row / scale
    if row_format == "int8":
        # jnp.round is round-half-even, bit-identical to np.round — the
        # fidelity suite pins host/device quantizer parity on that.
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    q_ref[...] = y.astype(qdtype)
    scale_ref[0, 0] = scale


def quantize_rows(rows: jax.Array, *, row_format: str = "int8",
                  interpret: bool = False):
    """rows: (M, D) float -> ((M, D) quantized, (M,) fp32 per-row scales).

    The populate-side kernel: one grid step per admitted row computes the
    per-row absmax, derives ``scale = absmax / qmax + 1e-12`` and
    round/clips (int8) or narrows (fp8) in VMEM — the device-side twin of
    the host NumPy quantizer the store used to run per admit.  D must be
    a multiple of 128 on the non-interpret path (checked).
    """
    if row_format not in ROW_FORMATS:
        raise ValueError(f"unknown row_format {row_format!r} "
                         f"(expected one of {sorted(ROW_FORMATS)})")
    M, D = rows.shape
    _check_lane_width(D, interpret, "quantize_rows")
    qdtype, _ = ROW_FORMATS[row_format]
    q, scales = pl.pallas_call(
        functools.partial(_quantize_rows_kernel, row_format=row_format),
        grid=(M,),
        in_specs=[pl.BlockSpec((1, D), lambda m: (m, 0))],
        out_specs=[pl.BlockSpec((1, D), lambda m: (m, 0)),
                   pl.BlockSpec((1, 1), lambda m: (m, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, D), qdtype),
                   jax.ShapeDtypeStruct((M, 1), jnp.float32)],
        interpret=interpret,
    )(rows.astype(jnp.float32))
    return q, scales.reshape(-1)


def quantize_rows_ref(rows: jax.Array, row_format: str = "int8"):
    """jnp reference for :func:`quantize_rows` (also the store's default
    device-side quantizer off the kernel path) — same scale derivation,
    same round-half-even, so host NumPy / jnp / Pallas agree bit-for-bit
    on fp32 inputs."""
    if row_format not in ROW_FORMATS:
        raise ValueError(f"unknown row_format {row_format!r} "
                         f"(expected one of {sorted(ROW_FORMATS)})")
    qdtype, qmax = ROW_FORMATS[row_format]
    rows = rows.astype(jnp.float32)
    scales = jnp.max(jnp.abs(rows), axis=1) / qmax + 1e-12
    y = rows / scales[:, None]
    if row_format == "int8":
        y = jnp.clip(jnp.round(y), -qmax, qmax)
    return y.astype(qdtype), scales


def dequantize_rows_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    """Dequantization oracle: (M, D) quantized + (M,) scales -> (M, D) fp32."""
    return q.astype(jnp.float32) * scales[:, None]
