"""Jitted public wrappers around the Pallas kernels.

On TPU the real kernels run; everywhere else (this CPU container, unit
tests) the wrappers fall back to the jnp reference implementation, and the
kernels themselves are validated in ``interpret=True`` mode (Python
execution of the kernel body) against the same references.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import ref
from repro.kernels.chamfer_kernel import chamfer as _chamfer_pallas
from repro.kernels.embedding_gather import gather_pool as _gather_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.lstm_cell import lstm_cell as _lstm_pallas


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("use_pallas",))
def gather_pool(table, idx, use_pallas: bool = False):
    if use_pallas and on_tpu():
        return _gather_pallas(table, idx)
    return ref.gather_pool_ref(table, idx)


@partial(jax.jit, static_argnames=("alpha", "use_pallas"))
def chamfer(po, w, alpha: float = 0.7, use_pallas: bool = False):
    if use_pallas and on_tpu():
        return _chamfer_pallas(po, w, alpha)
    return ref.chamfer_ref(po, w, alpha)


@partial(jax.jit, static_argnames=("use_pallas",))
def flash_attention(q, k, v, use_pallas: bool = False):
    if use_pallas and on_tpu():
        return _flash_pallas(q, k, v)
    return ref.flash_attention_ref(q, k, v)


@partial(jax.jit, static_argnames=("use_pallas",))
def lstm_cell(x, h, c, w, b, use_pallas: bool = False):
    if use_pallas and on_tpu():
        return _lstm_pallas(x, h, c, w, b)
    return ref.lstm_cell_ref(x, h, c, w, b)
