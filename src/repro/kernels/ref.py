"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gather_pool_ref(table, idx):
    """table: (N, D); idx: (B, P) -> (B, D) sum-pool."""
    return table[idx].astype(jnp.float32).sum(axis=1)


def chamfer_ref(po, w, alpha: float = 0.7):
    """po: (B, P, F); w: (B, W, F) -> (B,)."""
    po = po.astype(jnp.float32)
    w = w.astype(jnp.float32)
    d = po[:, :, None, :] - w[:, None, :, :]
    d2 = (d * d).sum(-1)
    fwd = d2.min(axis=2).mean(axis=1)
    bwd = d2.min(axis=1).mean(axis=1)
    return alpha * fwd + (1 - alpha) * bwd


def flash_attention_ref(q, k, v):
    """Causal attention oracle.  q/k/v: (BH, S, hd)."""
    S, hd = q.shape[1], q.shape[2]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum(
        "bqh,bkh->bqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def lstm_cell_ref(x, h, c, w, b):
    """Batched LSTM cell oracle (matches core/lstm.lstm_step math)."""
    z = jnp.concatenate([x, h], axis=1).astype(jnp.float32) @ w.astype(
        jnp.float32) + b
    H = h.shape[1]
    i, f, g, o = (z[:, :H], z[:, H:2*H], z[:, 2*H:3*H], z[:, 3*H:])
    c2 = jax.nn.sigmoid(f) * c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h2 = jax.nn.sigmoid(o) * jnp.tanh(c2)
    return h2.astype(h.dtype), c2
