"""Bidirectional Chamfer distance Pallas TPU kernel (the paper's Eq. 5).

Training the prefetch model evaluates millions of tiny (|PO| x |W|) pairwise
min-reductions per epoch; this kernel tiles the batch into VMEM blocks and
fuses distance + both min-reductions + the alpha blend in one pass, so the
(B, P, W, F) broadcast difference tensor never round-trips through HBM.

Block shapes: (bb, P, F) and (bb, W, F) resident in VMEM; P, W, F are tiny
(5/15/~26) so bb can be large (512) while staying well under VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chamfer_kernel(po_ref, w_ref, out_ref, *, alpha: float):
    po = po_ref[...].astype(jnp.float32)  # (bb, P, F)
    w = w_ref[...].astype(jnp.float32)  # (bb, W, F)
    d = po[:, :, None, :] - w[:, None, :, :]
    d2 = (d * d).sum(axis=-1)  # (bb, P, W)
    fwd = d2.min(axis=2).mean(axis=1)
    bwd = d2.min(axis=1).mean(axis=1)
    out_ref[...] = alpha * fwd + (1.0 - alpha) * bwd


def chamfer(po: jax.Array, w: jax.Array, alpha: float = 0.7, *,
            block: int = 512, interpret: bool = False) -> jax.Array:
    """po: (B, P, F); w: (B, W, F) -> (B,) bidirectional Chamfer."""
    B, P, F = po.shape
    W = w.shape[1]
    bb = min(block, B)
    pad = (-B) % bb
    if pad:
        po = jnp.pad(po, ((0, pad), (0, 0), (0, 0)))
        w = jnp.pad(w, ((0, pad), (0, 0), (0, 0)), constant_values=1e9)
        # NOTE: padded rows produce garbage losses; sliced off below.
    Bp = po.shape[0]
    out = pl.pallas_call(
        functools.partial(_chamfer_kernel, alpha=alpha),
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, P, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, W, F), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((Bp,), jnp.float32),
        interpret=interpret,
    )(po, w)
    return out[:B]
