"""Fused LSTM-cell Pallas TPU kernel.

The RecMG models run millions of LSTM steps per retraining epoch; the naive
form materializes the (B, 4H) gate tensor in HBM between the matmul and the
pointwise gates.  This kernel fuses concat([x,h]) @ W + b with the
sigmoid/tanh gate math in VMEM — one HBM round-trip per step instead of
three.

Blocks: batch is tiled (bb rows); the weight (in+H, 4H) stays resident in
VMEM across the whole grid (RecMG weights are ~40KB).  Production note: H
should be padded to the 128-lane width on real TPUs; interpret-mode
validation is exact at any H.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lstm_cell_kernel(x_ref, h_ref, c_ref, w_ref, b_ref, h_out, c_out, *,
                      hidden: int):
    xh = jnp.concatenate([x_ref[...], h_ref[...]], axis=1)
    z = (
        jax.lax.dot_general(
            xh, w_ref[...], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b_ref[...]
    )
    i = jax.nn.sigmoid(z[:, :hidden])
    f = jax.nn.sigmoid(z[:, hidden : 2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden : 3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden :])
    c = f * c_ref[...].astype(jnp.float32) + i * g
    h_out[...] = (o * jnp.tanh(c)).astype(h_out.dtype)
    c_out[...] = c.astype(c_out.dtype)


def lstm_cell(x: jax.Array, h: jax.Array, c: jax.Array, w: jax.Array,
              b: jax.Array, *, block: int = 256,
              interpret: bool = False):
    """x: (B, in); h/c: (B, H); w: (in+H, 4H); b: (4H,) -> (h', c')."""
    B, H = h.shape
    bb = min(block, B)
    pad = (-B) % bb
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        h = jnp.pad(h, ((0, pad), (0, 0)))
        c = jnp.pad(c, ((0, pad), (0, 0)))
    Bp = h.shape[0]
    h2, c2 = pl.pallas_call(
        functools.partial(_lstm_cell_kernel, hidden=H),
        grid=(Bp // bb,),
        in_specs=[
            pl.BlockSpec((bb, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0)),  # weights resident
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
            pl.BlockSpec((bb, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, H), h.dtype),
            jax.ShapeDtypeStruct((Bp, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, h, c, w, b)
    return h2[:B], c2[:B]
