"""Causal flash-attention Pallas TPU kernel.

The LM serving/training cells of the assigned pool are attention-dominant at
32k sequence length; this kernel is the TPU-native fused form: online
softmax with VMEM-resident (bq, hd) accumulators, KV streamed block-by-block
as the innermost (sequential) grid axis, output written once on the last KV
step.  The XLA fallback (models/layers.blocked_causal_attention) implements
the same algorithm; this kernel removes the per-block HBM round-trips.

Layout: heads are folded into the leading grid axis — q (BH, S, hd); GQA is
handled by the ops.py wrapper (repeats KV heads before the call).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -jnp.inf)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)  # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = q_pos >= k_pos
    s = jnp.where(mask, s, -jnp.inf)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(jnp.where(m_prev == -jnp.inf, 0.0, m_prev - m_new))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0] = (
            acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)[:, None]
        ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    bq: int = 256, bk: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Causal attention.  q/k/v: (BH, S, hd) same head count -> (BH, S, hd).

    Blocks past the causal frontier are masked (a production splash kernel
    would skip them with a sparse grid map — see DESIGN.md perf notes).
    """
    BH, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_kv = S // bk
    grid = (BH, S // bq, n_kv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
