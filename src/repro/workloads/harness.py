"""Model-free scenario serving harness: replay a workload spec through a
tiered store (single-worker or sharded) exactly like ``serve_trace`` does
— same batched lookups, same one-prefetch-set-per-batch Algorithm-1
staging, same optional drift adaptation — but without the DLRM dense
forward or any model training.  That keeps a full scenario matrix cell to
tens of milliseconds, so the regression tests can afford
``regime x policy x shard-count`` and the bench can afford per-scenario
rows.

The recmg arm's outputs come from the ``model`` switch: ``"frequency"``
(the deterministic frequency-heuristic stand-in, the default),
``"learned"`` (the trained dual models —
:class:`repro.core.model_runtime.LearnedRecMGModel` trained on the trace
prefix, jitted bucketed inference, and with ``adapt=True`` the online
fine-tune loop), or ``"voyager"`` (the ML-prefetcher baseline: LRU store
+ Voyager prefetch stream).  ``profile_frac < 1`` freezes the
profile/training on a trace prefix — the frozen-model decay arm of the
drift experiments.

Counters returned here are exactly the store's ``TierStats`` (plus drift
telemetry when ``adapt=True``), so golden files pin the same quantities
as the full ``serve_trace`` goldens.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import numpy as np

from repro.core.recmg import frequency_outputs
from repro.core.tiered import TieredEmbeddingStore
from repro.obs import MetricsRegistry
from repro.obs.tracing import get_tracer
from repro.runtime.drift import AdaptiveController, DriftConfig
from repro.workloads.spec import WorkloadSpec, iter_batches, make_trace

# Deterministic serve metrics a golden file may pin (no wall-clock).
GOLDEN_KEYS = ("regime", "policy", "batches", "lookups", "hits", "hit_rate",
               "prefetch_hits", "on_demand_rows", "evictions",
               "modeled_fetch_ms_per_batch")


def build_store(host: np.ndarray, rows_per_table: np.ndarray, capacity: int,
                policy: str, shards: int = 0, placement: str = "table",
                fetch_us_per_row: float = 10.0,
                quantize: bool = False, row_format: Optional[str] = None,
                warmup_batch: Optional[int] = None):
    """The same store-selection switch ``serve_trace`` uses (shards=0 ->
    single worker)."""
    if shards:
        from repro.core.sharded_serving import ShardedTieredStore

        return ShardedTieredStore.build(
            host, rows_per_table, shards, placement, capacity=capacity,
            policy=policy, quantize=quantize, row_format=row_format,
            fetch_us_per_row=fetch_us_per_row, warmup_batch=warmup_batch)
    return TieredEmbeddingStore(
        host, capacity, policy=policy, quantize=quantize,
        row_format=row_format, fetch_us_per_row=fetch_us_per_row,
        warmup_batch=warmup_batch)


def replay_scenario(spec: WorkloadSpec, policy: str = "lru",
                    capacity_frac: float = 0.12, batch: int = 256,
                    shards: int = 0, placement: str = "table",
                    adapt: bool = False,
                    adapt_cfg: Optional[DriftConfig] = None,
                    profile_frac: float = 1.0, emb_dim: int = 8,
                    capacity: Optional[int] = None,
                    byte_budget: Optional[int] = None,
                    quantize: bool = False,
                    row_format: Optional[str] = None,
                    in_len: int = 15, out_len: int = 5,
                    model: str = "frequency", model_cfg=None) -> Dict:
    """Serve one scenario end to end; returns the metrics dict.

    ``policy`` is ``"lru"`` or ``"recmg"``; ``model`` selects where the
    recmg outputs come from (``"frequency"`` heuristic, ``"learned"``
    trained dual models, or ``"voyager"`` — the prefetch-only baseline,
    served on an LRU store) and ``model_cfg`` optionally overrides the
    :class:`~repro.core.model_runtime.LearnedModelConfig`.  The profile /
    training data is the first ``profile_frac`` of the trace.
    ``adapt=True`` attaches an :class:`AdaptiveController` whose refresh
    items are staged through the same model-output path; with
    ``model="learned"`` the controller additionally fine-tunes the model
    online on every drift refresh
    (:class:`~repro.core.model_runtime.LearnedController`).

    ``byte_budget`` sizes the fast tier in bytes instead of rows
    (mutually exclusive with ``capacity``), converted with the
    quantization-aware per-row footprint — the fixed-byte-budget cells
    (``quantize=True`` holds more rows in the same bytes) compare arms
    through this knob.
    """
    if model not in ("frequency", "learned", "voyager"):
        raise ValueError(f"unknown model {model!r} "
                         "(frequency | learned | voyager)")
    if capacity is not None and byte_budget is not None:
        raise ValueError("pass at most one of capacity / byte_budget")
    trace = make_trace(spec)
    if byte_budget is not None:
        from repro.core.tiered import fast_row_bytes

        cap = max(1, int(byte_budget) // fast_row_bytes(
            emb_dim, np.float32, quantize, row_format or "int8"))
    else:
        cap = int(capacity) if capacity else max(
            4, int(capacity_frac * trace.unique_count()))
    host = np.random.default_rng(0).normal(
        size=(trace.n_vectors, emb_dim)).astype(np.float32)
    store = build_store(host, trace.rows_per_table, cap, policy,
                        shards=shards, placement=placement,
                        quantize=quantize, row_format=row_format,
                        warmup_batch=batch)
    upto = int(profile_frac * len(trace)) if profile_frac < 1.0 else None
    outputs = None
    learned = None
    if model == "voyager":
        from repro.core.model_runtime import voyager_outputs

        outputs = voyager_outputs(trace, cap, in_len=in_len,
                                  out_len=out_len, profile_upto=upto)
    elif policy == "recmg":
        if model == "learned":
            from repro.core.model_runtime import LearnedRecMGModel

            learned = LearnedRecMGModel.train_from_trace(
                trace, cap, model_cfg, profile_upto=upto)
            outputs = learned.outputs_for(trace)
        else:
            outputs = frequency_outputs(trace, cap, in_len=in_len,
                                        out_len=out_len, profile_upto=upto)
    from repro.core.model_runtime import OutputsRef

    oref = OutputsRef(outputs)

    controller = None
    if adapt:
        if adapt_cfg is None:
            adapt_cfg = DriftConfig(window=max(512, 4 * batch),
                                    hot_k=min(cap, 256))
        if learned is not None:
            from repro.core.model_runtime import LearnedController

            controller = LearnedController(store, cap, learned, oref,
                                           trace, adapt_cfg)
        else:
            controller = AdaptiveController(store, cap, adapt_cfg)

    gid = trace.global_id
    chunk_ptr = 0
    lat, batch_hit_rates = [], []
    empty = np.empty(0, np.int64)
    tr = get_tracer()
    for b, ids in enumerate(iter_batches(spec, batch, trace=trace)):
        if tr.enabled:
            tr.set_batch(b)
        pre_hits = store.stats.hits
        t0 = time.perf_counter()
        store.lookup(ids)
        lat.append(time.perf_counter() - t0)
        hits = store.stats.hits - pre_hits
        batch_hit_rates.append(hits / max(ids.size, 1))
        # Stage the chunks this batch covered — caching ranks for every
        # chunk, prefetches only from the most recent one (serve_trace's
        # one-prefetch-set-per-batch rule, paper Fig. 6).  Outputs are
        # read through ``oref`` so an online refresh (LearnedController)
        # swaps them mid-run; the chunk grid is identical, so the chunk
        # pointer stays valid.
        if oref.outputs is not None:
            out = oref.outputs
            hi = (b + 1) * batch
            last_pf = None
            while (chunk_ptr < len(out.chunk_starts)
                   and out.chunk_starts[chunk_ptr] < hi):
                s = int(out.chunk_starts[chunk_ptr])
                trunk = gid[max(0, s - in_len): s]
                bits = (out.caching_bits[chunk_ptr]
                        if out.caching_bits is not None
                        else np.zeros(len(trunk)))
                store.stage_model_outputs(trunk, bits, empty)
                if out.prefetch_ids is not None:
                    last_pf = out.prefetch_ids[chunk_ptr]
                chunk_ptr += 1
            if last_pf is not None:
                store.stage_model_outputs(empty, empty,
                                          np.asarray(last_pf, np.int64))
        if controller is not None:
            for item in controller.on_batch(ids, hits, b):
                store.stage_model_outputs(*item)
        store.flush_staged()

    res = store.stats.as_dict()
    res.update(
        regime=spec.regime, policy=policy, model=model, capacity=cap,
        n_accesses=len(trace), shards=shards,
        p50_batch_ms=float(np.percentile(lat, 50) * 1e3) if lat else 0.0,
        p95_batch_ms=float(np.percentile(lat, 95) * 1e3) if lat else 0.0,
        modeled_fetch_ms_per_batch=store.modeled_batch_ms(),
        batch_hit_rates=batch_hit_rates,
    )
    if shards:
        res["shard"] = store.shard_telemetry()
    if learned is not None:
        res["learned"] = learned.telemetry()
    if controller is not None:
        res["drift"] = controller.as_dict()

    # Same unified registry surface as ``serve_trace``: one namespace the
    # reconciliation checker (and the scenario bench artifact) can read.
    reg = MetricsRegistry()
    store.publish_metrics(reg)
    if controller is not None and hasattr(controller, "publish"):
        controller.publish(reg)
    res["metrics"] = reg.snapshot()
    return res


def golden_metrics(res: Dict) -> Dict:
    """The deterministic subset of a :func:`replay_scenario` result that a
    golden file pins (counters + cost model; no wall-clock, no series)."""
    return {k: res[k] for k in GOLDEN_KEYS}


def phase_steady_hit_rates(res: Dict, n_phases: int) -> np.ndarray:
    """Mean hit rate over the steady (second) half of each of ``n_phases``
    equal phases of a :func:`replay_scenario` result — the pre/post-switch
    comparison the drift tests, the adaptation example and the
    ``adapt_recovery`` bench row all share (one definition, so the
    acceptance bar and the gate measure the same thing)."""
    hr = np.asarray(res["batch_hit_rates"])
    hr = hr[: len(hr) - len(hr) % n_phases].reshape(n_phases, -1)
    return hr[:, hr.shape[1] // 2:].mean(axis=1)
