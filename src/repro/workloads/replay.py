"""The ``replay`` workload regime: serve an external trace file through
the same ``WorkloadSpec -> trace/batches`` API as the synthetic regimes.

    spec = make_spec("replay", path="runs/prod_trace.npz")
    for ids in iter_batches(spec, 256): ...

Files are ``.npz`` or ``.csv`` in the layout of
:func:`repro.core.trace.save_trace`; a trace written by ``save_trace``
round-trips byte-identically (property-tested).  The file's geometry is
authoritative: its table count and per-table row counts replace the
spec's uniform scale fields.  ``n_accesses=0`` means "whole file";
a positive value truncates to that prefix.
"""
from __future__ import annotations

from repro.core.trace import Trace, load_trace
from repro.workloads.spec import WorkloadSpec, register


@register("replay", params=("path",))
def replay(spec: WorkloadSpec, rng) -> tuple:  # pragma: no cover
    # Never called: make_trace dispatches "replay" to make_replay_trace
    # before reaching the generic generator path (a generator can only
    # emit ids into the spec's uniform geometry; the file carries its
    # own).  Registered so the regime shows up in listings/parse errors.
    raise RuntimeError("replay traces load through make_trace")


def make_replay_trace(spec: WorkloadSpec) -> Trace:
    path = spec.param("path")
    if not path:
        raise ValueError("replay spec needs a path param "
                         "(make_spec('replay', path='trace.npz'))")
    tr = load_trace(path)
    n = int(spec.n_accesses or 0)
    if n and n < len(tr):
        tr = tr.slice(0, n)
    return tr
