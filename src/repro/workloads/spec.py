"""Workload scenario specs: named, seeded, composable access-pattern regimes.

The paper's headline numbers come from *diverse, shifting* production
traffic (RecShard shows per-table access CDFs differ wildly and drift over
time; SDM evaluates against production traffic mixes).  This module is the
single entry point every serving/bench/test path uses to get such traffic:

    spec  = scenario("diurnal", n_accesses=50_000, seed=3)
    trace = make_trace(spec)                  # a repro.core.trace.Trace
    for ids in iter_batches(spec, 256):       # flat global-id batches
        store.lookup(ids)

A :class:`WorkloadSpec` is a frozen, hashable value: ``(regime, scale,
seed, regime params)``.  Two equal specs always produce byte-identical
traces (asserted in ``tests/test_workloads.py``), which is what lets the
scenario regression matrix pin golden metrics per scenario.

Regime generators live in :mod:`repro.workloads.regimes` and register
themselves into :data:`REGIMES`; the ``replay`` adapter
(:mod:`repro.workloads.replay`) serves external ``.npz``/``.csv`` traces
through the same API.  :data:`SCENARIOS` is the named catalog (one entry
per taxonomy row in docs/architecture.md) consumed by the test matrix,
``bench_e2e`` and ``launch/serve.py --workload``.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.trace import Trace

# regime name -> generator(spec, rng) -> (table_id int32, row_id int64)
REGIMES: Dict[str, Callable] = {}
# regime name -> the param keys its generator reads (typo guard).
REGIME_PARAMS: Dict[str, frozenset] = {}


def register(name: str, params: Tuple[str, ...] = ()):
    """Decorator: register a regime generator under ``name``.  ``params``
    declares the regime knobs it reads; ``make_trace`` rejects specs
    carrying any other key, so a typo'd CLI knob fails loudly instead of
    silently serving the default."""
    def deco(fn):
        REGIMES[name] = fn
        REGIME_PARAMS[name] = frozenset(params) | {"table_zipf_a"}
        return fn
    return deco


@dataclass(frozen=True)
class WorkloadSpec:
    """One named, seeded access-pattern regime at a given scale.

    ``params`` holds the regime-specific knobs as a sorted tuple of
    ``(key, value)`` pairs so the spec stays hashable; use :meth:`param`
    to read them and :func:`make_spec` / :meth:`with_` to build them from
    keyword arguments.
    """

    regime: str
    n_tables: int = 8
    rows_per_table: int = 2048
    n_accesses: int = 60_000
    seed: int = 0
    params: Tuple[Tuple[str, object], ...] = field(default_factory=tuple)

    def param(self, key: str, default=None):
        for k, v in self.params:
            if k == key:
                return v
        return default

    def with_(self, **kw) -> "WorkloadSpec":
        """Copy with scale fields and/or regime params overridden."""
        fields = {k: kw.pop(k) for k in
                  ("regime", "n_tables", "rows_per_table", "n_accesses",
                   "seed") if k in kw}
        if kw:
            merged = dict(self.params)
            merged.update(kw)
            fields["params"] = tuple(sorted(merged.items()))
        return replace(self, **fields)

    @property
    def n_vectors(self) -> int:
        return self.n_tables * self.rows_per_table


def make_spec(regime: str, *, n_tables: int = 8, rows_per_table: int = 2048,
              n_accesses: int = 60_000, seed: int = 0,
              **params) -> WorkloadSpec:
    """Build a spec; unknown keywords become regime params."""
    return WorkloadSpec(regime, n_tables, rows_per_table, n_accesses, seed,
                        tuple(sorted(params.items())))


def make_trace(spec: WorkloadSpec) -> Trace:
    """Generate the full trace for a spec (seeded, deterministic).

    The ``replay`` regime loads its file instead of generating (the
    file's table geometry is authoritative — see
    :mod:`repro.workloads.replay`)."""
    if spec.regime not in REGIMES:
        raise KeyError(f"unknown workload regime {spec.regime!r} "
                       f"(known: {sorted(REGIMES)})")
    allowed = REGIME_PARAMS[spec.regime]
    unknown = sorted(k for k, _ in spec.params if k not in allowed)
    if unknown:
        raise KeyError(f"regime {spec.regime!r} does not read params "
                       f"{unknown} (it reads: {sorted(allowed)})")
    if spec.regime == "replay":
        from repro.workloads.replay import make_replay_trace

        return make_replay_trace(spec)
    rng = np.random.default_rng(spec.seed)
    table_id, row_id = REGIMES[spec.regime](spec, rng)
    table_id = np.asarray(table_id, np.int32).ravel()[: spec.n_accesses]
    row_id = np.asarray(row_id, np.int64).ravel()[: spec.n_accesses]
    if len(table_id) != spec.n_accesses or len(row_id) != spec.n_accesses:
        raise ValueError(
            f"regime {spec.regime!r} produced {len(row_id)} accesses, "
            f"spec asked for {spec.n_accesses}")
    rows_per_table = np.full(spec.n_tables, spec.rows_per_table, np.int64)
    return Trace(table_id, row_id, rows_per_table)


def iter_batches(spec: WorkloadSpec, batch: int,
                 trace: Optional[Trace] = None) -> Iterator[np.ndarray]:
    """Yield the spec's access stream as flat global-id batches of exactly
    ``batch`` ids each (``n_accesses // batch`` batches; the remainder is
    dropped, mirroring the serving loops).  Pass ``trace`` to reuse an
    already-generated trace."""
    if batch < 1:
        raise ValueError("batch must be >= 1")
    if trace is None:
        trace = make_trace(spec)
    gid = trace.global_id
    for b in range(len(gid) // batch):
        yield gid[b * batch: (b + 1) * batch]


# ---------------------------------------------------------------------------
# Named scenario catalog (the taxonomy table in docs/architecture.md)
# ---------------------------------------------------------------------------

# name -> (regime, default params).  Scale fields (n_tables/rows/accesses/
# seed) are supplied by the caller via scenario(**overrides).
SCENARIOS: Dict[str, Tuple[str, Dict[str, object]]] = {
    # Stationary zipf family at three skews: the paper's steady-state
    # power-law regime (~20% of vectors take ~80% of accesses at the
    # mid/high skews).
    "zipf_low": ("stationary", {"zipf_a": 0.8}),
    "zipf_mid": ("stationary", {"zipf_a": 1.05}),
    "zipf_hot": ("stationary", {"zipf_a": 1.4}),
    # Diurnal hot-set rotation: the working set moves wholesale every
    # period (day/night traffic mix shifting which users are active).
    "diurnal": ("diurnal", {"n_phases": 4, "hot_frac": 0.05,
                            "p_hot": 0.9}),
    # Flash crowd: a burst of traffic lands on previously-cold rows
    # (viral item) and then subsides.
    "flash_crowd": ("flash_crowd", {"onset": 0.5, "duration": 0.3,
                                    "p_burst": 0.85, "burst_frac": 0.03}),
    # Multi-tenant interleave: several per-tenant zipfs over disjoint hot
    # sets, scheduled in coarse blocks (one model server, many traffic
    # sources).
    "multi_tenant": ("multi_tenant", {"n_tenants": 4, "block": 512,
                                      "zipf_a": 1.2}),
    # Popularity-decay churn: the hot set drifts continuously instead of
    # switching (items go stale, new items warm up).
    "churn": ("churn", {"zipf_a": 1.1, "churn_per_k": 24.0}),
}

# The regimes whose steady distribution the paper's skew claims target —
# the scenario matrix asserts recmg's on-demand fetches <= LRU's here.
PAPER_TARGET_SCENARIOS = ("zipf_low", "zipf_mid", "zipf_hot", "churn")
# Regimes with a distribution switch mid-trace — the drift-adaptation
# acceptance criterion applies to these.
DRIFT_SCENARIOS = ("diurnal", "flash_crowd")


def scenario(name: str, **overrides) -> WorkloadSpec:
    """Instantiate a named catalog scenario; ``overrides`` may set scale
    fields (``n_tables``...) and/or regime params."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r} "
                       f"(known: {sorted(SCENARIOS)})")
    regime, params = SCENARIOS[name]
    spec = make_spec(regime, **params)
    return spec.with_(**overrides) if overrides else spec


def parse_workload(text: str) -> WorkloadSpec:
    """Parse a CLI workload argument: ``name`` or ``name:key=val,...``.

    ``name`` is a catalog scenario or a bare regime name; values parse as
    int, then float, then string (``replay:path=trace.npz``).  A replay
    workload defaults to the *whole file* (``n_accesses=0``) rather than
    the spec default — pass ``replay:path=...,n_accesses=N`` to truncate
    to a prefix."""
    name, _, rest = text.partition(":")
    kw: Dict[str, object] = {}
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        for cast in (int, float):
            try:
                kw[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            kw[k] = v
    if name == "replay":
        kw.setdefault("n_accesses", 0)
    if name in SCENARIOS:
        return scenario(name, **kw)
    if name in REGIMES:
        return make_spec(name, **kw)
    raise KeyError(f"unknown workload {text!r} (scenarios: "
                   f"{sorted(SCENARIOS)}; regimes: {sorted(REGIMES)})")
