"""Sustained-overload serving harness: offered load as a multiple of
modeled compute capacity, replayed through the admission-controlled
:class:`~repro.runtime.pipeline.PipelinedRuntime` on the deterministic
VirtualClock.

Capacity is the modeled dense-forward rate: one batch of ``batch``
queries per ``compute_us``, so offered load ``load_x`` maps to an
open-loop arrival process with::

    interarrival_us = compute_us / (batch * load_x)

At ``load_x < 1`` the admission queue stays shallow and everything is
served; past 1x the queue saturates at its bound, the excess is shed
lowest-priority-first, over-deadline stragglers take the degraded
(stale/default-row) path, and prefetch issue is suppressed under
backpressure.  **Goodput** counts full-quality served requests per
modeled second — the smooth-degradation gate in
``scripts/check_bench_regression.py`` asserts goodput at 4x offered load
stays within 0.7x of goodput at 1x (no congestion collapse).

Everything here is deterministic: equal specs + equal knobs give
byte-identical shed/degrade/served counts (asserted in
``tests/test_admission.py``), and the ``adm.*`` /  ``rt.*`` / ``store.*``
namespaces reconcile exactly (``scripts/check_accounting.py``).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.obs import MetricsRegistry
from repro.obs.reconcile import reconcile
from repro.runtime.admission import AdmissionConfig
from repro.runtime.pipeline import PipelinedRuntime, RuntimeConfig
from repro.workloads.harness import build_store
from repro.workloads.spec import WorkloadSpec, make_spec, make_trace

_EMPTY = np.empty(0, np.int64)

# Fate / goodput keys a regression test may pin (all deterministic).
OVERLOAD_KEYS = ("load_x", "offered_rps", "goodput_rps", "admitted",
                 "served", "shed", "degraded")


def default_priority_mix(n_classes: int = 3) -> Tuple[float, ...]:
    """Traffic mix over priority classes, most-important first: a small
    gold slice, a moderate silver slice, the bronze bulk."""
    if n_classes == 1:
        return (1.0,)
    if n_classes == 2:
        return (0.3, 0.7)
    rest = (1.0 - 0.5) / max(n_classes - 2, 1)
    return (0.2, 0.3) + (rest,) * (n_classes - 2)


def replay_overload(spec: Optional[WorkloadSpec] = None, *,
                    load_x: float = 1.0, policy: str = "lru",
                    batch: int = 64, per_query: int = 8,
                    compute_us: float = 500.0,
                    queue_bound: Optional[int] = None,
                    class_deadline_us: Optional[Sequence[float]] = None,
                    priority_mix: Optional[Sequence[float]] = None,
                    capacity_frac: float = 0.12,
                    capacity: Optional[int] = None,
                    shards: int = 0, placement: str = "table",
                    pipeline_depth: int = 2, emb_dim: int = 8,
                    degrade: bool = True, prefetch: bool = True,
                    check: bool = True) -> Dict:
    """Serve one overload scenario end to end; returns the fate counters,
    goodput, tail latency and the full metrics snapshot.

    ``spec`` defaults to the ``sustained_overload`` regime; a ``load_x``
    param riding on the spec (``parse_workload("sustained_overload:
    load_x=4")``) overrides the keyword.  ``queue_bound`` defaults to 4
    batches of headroom; ``class_deadline_us`` defaults to (4, 16, 64)
    batch times — tight enough that EDF and the degraded path both
    matter at saturation.  ``prefetch=True`` stages each batch's unique
    ids as a prefetch set, so backpressure suppression has traffic to
    act on.
    """
    if spec is None:
        spec = make_spec("sustained_overload", n_accesses=48_000)
    load_x = float(spec.param("load_x", load_x))
    if not load_x > 0:
        raise ValueError(f"load_x must be > 0, got {load_x}")
    trace = make_trace(spec)
    cap = int(capacity) if capacity else max(
        4, int(capacity_frac * trace.unique_count()))
    host = np.random.default_rng(0).normal(
        size=(trace.n_vectors, emb_dim)).astype(np.float32)
    store = build_store(host, trace.rows_per_table, cap, policy,
                        shards=shards, placement=placement,
                        warmup_batch=batch * per_query)

    if class_deadline_us is None:
        class_deadline_us = (4 * compute_us, 16 * compute_us,
                             64 * compute_us)
    adm = AdmissionConfig(
        queue_bound=int(queue_bound) if queue_bound else 4 * batch,
        class_deadline_us=tuple(float(d) for d in class_deadline_us),
        degrade=degrade)
    if priority_mix is None:
        priority_mix = default_priority_mix(adm.n_classes)
    mix = np.asarray(priority_mix, np.float64)
    if mix.size != adm.n_classes or mix.min() < 0 or mix.sum() <= 0:
        raise ValueError(f"priority_mix needs {adm.n_classes} non-negative "
                         f"weights, got {priority_mix!r}")
    mix = mix / mix.sum()

    interarrival_us = compute_us / (batch * load_x)
    rt = PipelinedRuntime(store, RuntimeConfig(
        max_batch=batch, pipeline_depth=pipeline_depth,
        interarrival_us=interarrival_us, compute_us=compute_us,
        admission=adm))

    # Queries: consecutive ``per_query``-id slices of the trace, each
    # tagged with a deterministically drawn priority class.
    gid = trace.global_id
    n_q = len(gid) // per_query
    pri = np.random.default_rng(spec.seed + 1).choice(
        adm.n_classes, size=n_q, p=mix)
    stream = ((gid[q * per_query: (q + 1) * per_query], int(pri[q]))
              for q in range(n_q))

    if prefetch:
        # Model-free prefetch stream: each batch's unique ids go back in
        # as a prefetch set (hot rows recur, and under backpressure this
        # is exactly the traffic that gets suppressed).  The batch hook
        # receives the batch ids the step function never sees.
        def batch_hook(ids, hits, b):
            return [(_EMPTY, _EMPTY, np.unique(ids))]
        rt._batch_hook = batch_hook

    # The dense forward is the configured modeled constant; the step
    # function itself does no work and stages nothing.
    rt.run(stream, lambda b, emb: (0.0, []))

    st = rt.admission_stats
    tel = rt.telemetry
    modeled_s = max(rt.clock.now() * 1e-6, 1e-12)
    lat_ms = np.asarray([u * 1e-3 for u in tel.latencies_us], np.float64)
    res = {
        "regime": spec.regime, "policy": policy, "shards": shards,
        "load_x": load_x,
        "offered_rps": round(1e6 / interarrival_us, 3),
        "goodput_rps": round(st.total_served / modeled_s, 3),
        "served_rps": round((st.total_served + st.total_degraded)
                            / modeled_s, 3),
        "modeled_s": round(modeled_s, 6),
        "batches": tel.batches,
        "queue_bound": adm.queue_bound,
        "pf_suppressed": tel.pf_suppressed,
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 3)
        if lat_ms.size else 0.0,
        "p999_ms": round(float(np.percentile(lat_ms, 99.9)), 3)
        if lat_ms.size else 0.0,
    }
    res.update(st.as_dict(adm))
    st.check()

    reg = MetricsRegistry()
    rt.publish(reg)
    store.publish_metrics(reg)
    if check:
        reconcile(metrics=reg.as_dict(), strict=True)
    res["metrics"] = reg.snapshot()
    return res


def overload_sweep(loads: Sequence[float] = (0.5, 1.0, 2.0, 4.0),
                   **kw) -> Dict[float, Dict]:
    """Replay the same scenario at each offered load (fresh store and
    runtime per point — the sweep compares steady states, not history).
    Returns ``{load_x: replay_overload result}``."""
    return {float(x): replay_overload(load_x=float(x), **kw)
            for x in loads}


def degradation_ratio(sweep: Dict[float, Dict], hi: float = 4.0,
                      lo: float = 1.0) -> float:
    """The smooth-degradation figure of merit: goodput at ``hi``x offered
    load over goodput at ``lo``x (1.0 == perfectly flat; collapse pulls
    it toward 0)."""
    return (sweep[hi]["goodput_rps"]
            / max(sweep[lo]["goodput_rps"], 1e-12))
