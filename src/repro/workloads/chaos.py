"""Chaos serving harness: deterministic shard-fault replay with a
lockstep zero-wrong-answers audit.

``replay_chaos`` serves a workload through the sharded store with a
:class:`~repro.runtime.faults.FaultPlan` armed, and — the part a counter
can't prove — runs a **clean shadow store** (same plan, same trace, no
faults) in lockstep, byte-comparing every output row:

* a row is **exact** if it equals the no-fault run's row bit-for-bit
  (healthy shards, hot-row replicas, stale-but-resident degraded rows —
  embedding values never change in this system, so stale == exact);
* a row is a **zero default** if it is all-zero (the degraded contract's
  only other allowed answer);
* anything else is a **wrong answer**, and the failover contract says
  there are exactly zero of them.

Everything is deterministic on the virtual clock: equal specs + plans
give byte-identical outputs, fates and ``ft.*`` counters (asserted in
``tests/test_faults.py``), and the full metrics snapshot reconciles
(``scripts/check_accounting.py``).

``failover_goodput`` is the gated figure of merit: full-quality rows per
modeled second under a mid-run kill, over the same workload with no
faults — the ``failover_goodput_kill_vs_clean`` floor in
``scripts/check_bench_regression.py``.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.sharded_serving import ShardedTieredStore
from repro.obs import MetricsRegistry
from repro.obs.reconcile import reconcile
from repro.workloads.spec import WorkloadSpec, make_spec, make_trace

_EMPTY = np.empty(0, np.int64)

# Deterministic chaos metrics a regression test may pin.
CHAOS_KEYS = ("regime", "fault_plan", "batches", "served", "primary",
              "failover_replica", "failover_degraded", "wrong_rows",
              "goodput_rps")

DEFAULT_FAULT_PLAN = "kill:1@mid,recover:1@75%"


def replay_chaos(spec: Optional[WorkloadSpec] = None, *,
                 fault_plan: Optional[str] = DEFAULT_FAULT_PLAN,
                 seed: int = 0, replicate_hot_frac: float = 0.05,
                 policy: str = "lru", batch: int = 256, shards: int = 4,
                 placement: str = "row", capacity_frac: float = 0.12,
                 capacity: Optional[int] = None, emb_dim: int = 8,
                 profile_frac: float = 0.25, audit: bool = True,
                 check: bool = True) -> Dict:
    """Serve one chaos scenario end to end; returns fates, the audit
    verdict, goodput and the full metrics snapshot.

    ``fault_plan`` is the CLI-grammar schedule (``None`` or ``""`` runs
    the clean arm — the goodput denominator).  ``replicate_hot_frac``
    sizes the hot-row replica set as a fraction of total vectors, from
    frequencies profiled on the first ``profile_frac`` of the trace.
    ``audit`` runs the lockstep no-fault shadow and byte-compares every
    row (skipped automatically on the clean arm).
    """
    if spec is None:
        spec = make_spec("shard_failure", n_accesses=48_000)
    trace = make_trace(spec)
    gid = trace.global_id
    batch = int(batch)
    n_batches = len(gid) // batch
    if n_batches < 4:
        raise ValueError(f"trace of {len(gid)} ids gives only {n_batches} "
                         f"batches of {batch}; chaos needs >= 4")
    cap = int(capacity) if capacity else max(
        shards, int(capacity_frac * trace.unique_count()))
    host = np.random.default_rng(0).normal(
        size=(trace.n_vectors, emb_dim)).astype(np.float32)
    n_prof = max(1, int(len(gid) * profile_frac))
    rep = (max(1, int(replicate_hot_frac * trace.n_vectors))
           if replicate_hot_frac > 0 else 0)

    def build() -> ShardedTieredStore:
        return ShardedTieredStore.build(
            host, trace.rows_per_table, shards, placement, capacity=cap,
            policy=policy, profile_ids=gid[:n_prof], replicate_hot=rep,
            warmup_batch=batch)

    store = build()
    faulty = bool(fault_plan)
    if faulty:
        store.arm_faults(fault_plan, horizon_batches=n_batches, seed=seed)
    shadow = build() if (audit and faulty) else None

    wrong = zero_default = exact = 0
    for b in range(n_batches):
        ids = gid[b * batch: (b + 1) * batch]
        out = np.asarray(store.lookup(ids))
        # Same one-prefetch-set-per-batch Algorithm-1 staging as the
        # scenario harness — the traffic pf.shard_down acts on.
        store.apply_model_outputs(_EMPTY, _EMPTY, np.unique(ids))
        if shadow is not None:
            ref = np.asarray(shadow.lookup(ids))
            shadow.apply_model_outputs(_EMPTY, _EMPTY, np.unique(ids))
            eq = np.all(out == ref, axis=-1)
            z = np.all(out == 0.0, axis=-1)
            wrong += int(np.count_nonzero(~(eq | z)))
            zero_default += int(np.count_nonzero(z & ~eq))
            exact += int(np.count_nonzero(eq))

    total_rows = n_batches * batch
    modeled_s = max(store.clock.now() * 1e-6, 1e-12)
    if shadow is not None:
        quality_rows = exact
    elif faulty:
        quality_rows = total_rows - store.ft_stats.degraded_default
    else:
        quality_rows = total_rows
    res = {
        "regime": spec.regime, "policy": policy, "shards": shards,
        "placement": placement,
        "fault_plan": (store._injector.plan.describe() if faulty else ""),
        "replicated_rows": rep,
        "batches": n_batches,
        "rows": total_rows,
        "modeled_s": round(modeled_s, 6),
        "goodput_rps": round(quality_rows / modeled_s, 3),
        "wrong_rows": wrong,
        "zero_default_rows": zero_default,
        "exact_rows": exact if shadow is not None else total_rows,
        "recovery_pending": sum(len(c) for c in store._recovery.values()),
    }
    if faulty:
        ft = store.ft_stats
        ft.check()
        res.update({k: ft.as_dict()[k]
                    for k in ("served", "primary", "failover_replica",
                              "failover_degraded", "degraded_default",
                              "kills", "recoveries", "recovery_rows",
                              "recovery_chunks", "recovery_bytes",
                              "recovery_bytes_raw", "retries")})
    else:
        res.update({"served": total_rows, "primary": total_rows,
                    "failover_replica": 0, "failover_degraded": 0})

    reg = MetricsRegistry()
    store.publish_metrics(reg)
    if check:
        reconcile(metrics=reg.as_dict(), strict=True)
    res["metrics"] = reg.snapshot()
    return res


def chaos_sweep(plans: Sequence[Optional[str]] = (
        None, DEFAULT_FAULT_PLAN, "kill:1@mid",
        "flaky:2x0.4@25%..75%", "slow:0x4@25%..75%"),
        **kw) -> Dict[str, Dict]:
    """Replay the same scenario under each fault plan (fresh stores per
    point; ``None`` is the clean arm).  Returns ``{plan: result}`` keyed
    by the plan string (``""`` for clean)."""
    return {(p or ""): replay_chaos(fault_plan=p, **kw) for p in plans}


def failover_goodput(sweep: Dict[str, Dict],
                     plan: str = DEFAULT_FAULT_PLAN) -> float:
    """Goodput under the kill plan over clean goodput (1.0 == the kill
    cost nothing; the bench gate floors this ratio)."""
    return (sweep[plan]["goodput_rps"]
            / max(sweep[""]["goodput_rps"], 1e-12))
