"""Workload scenario subsystem: named, seeded, composable access-pattern
regimes behind one ``WorkloadSpec -> trace / iterator-of-batches`` API.

See :mod:`repro.workloads.spec` for the API, :mod:`repro.workloads.regimes`
for the generator taxonomy, :mod:`repro.workloads.replay` for the external
trace adapter and :mod:`repro.workloads.harness` for the model-free serving
replay used by the scenario regression matrix and the benchmarks.
"""
from repro.workloads import regimes as _regimes  # noqa: F401  (registers)
from repro.workloads import replay as _replay  # noqa: F401  (registers)
from repro.workloads.chaos import (CHAOS_KEYS, DEFAULT_FAULT_PLAN,
                                   chaos_sweep, failover_goodput,
                                   replay_chaos)
from repro.workloads.harness import (GOLDEN_KEYS, build_store,
                                     golden_metrics, phase_steady_hit_rates,
                                     replay_scenario)
from repro.workloads.overload import (OVERLOAD_KEYS, degradation_ratio,
                                      overload_sweep, replay_overload)
from repro.workloads.spec import (DRIFT_SCENARIOS, PAPER_TARGET_SCENARIOS,
                                  REGIMES, SCENARIOS, WorkloadSpec,
                                  iter_batches, make_spec, make_trace,
                                  parse_workload, scenario)

__all__ = [
    "CHAOS_KEYS", "DEFAULT_FAULT_PLAN", "DRIFT_SCENARIOS", "GOLDEN_KEYS",
    "OVERLOAD_KEYS",
    "PAPER_TARGET_SCENARIOS", "REGIMES", "SCENARIOS", "WorkloadSpec",
    "build_store", "chaos_sweep", "degradation_ratio", "failover_goodput",
    "golden_metrics", "iter_batches",
    "make_spec", "make_trace", "overload_sweep", "parse_workload",
    "phase_steady_hit_rates", "replay_chaos", "replay_overload",
    "replay_scenario", "scenario",
]
