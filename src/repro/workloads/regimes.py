"""Workload regime generators — one seeded function per access-pattern
family (see the taxonomy table in docs/architecture.md).

Every generator takes ``(spec, rng)`` and returns ``(table_id, row_id)``
arrays of exactly ``spec.n_accesses`` entries with ids inside the spec's
table bounds (fuzzed in ``tests/test_workloads.py``).  All draws come
from the single passed generator in a fixed order, so a spec is a pure
function of its fields — equal specs give byte-identical traces.

Shared conventions:

* **Table choice** is a zipf over tables (hot tables exist in production;
  RecShard's motivating observation), keyed by ``table_zipf_a``.
* **Hot rows are scattered**, not contiguous: zipf *ranks* map to rows
  through a keyed multiplicative permutation (same trick as the
  calibrated generator in :mod:`repro.core.trace`), so no spatial
  prefetcher can exploit adjacency the real workload doesn't have.
"""
from __future__ import annotations

import numpy as np

from repro.core.trace import _zipf_ranks
from repro.workloads.spec import WorkloadSpec, register

_MULT = 2654435761  # Knuth multiplicative-hash constant (odd -> bijective)


def _tables(spec: WorkloadSpec, rng, n: int) -> np.ndarray:
    a = float(spec.param("table_zipf_a", 1.1))
    return (_zipf_ranks(rng, a, spec.n_tables, n)
            % spec.n_tables).astype(np.int32)


def _permute(ranks: np.ndarray, salt, n_rows: int) -> np.ndarray:
    """Keyed permutation rank -> row (vectorized, salt may be per-access)."""
    return (ranks * _MULT + salt) % n_rows


@register("stationary", params=("zipf_a",))
def stationary(spec: WorkloadSpec, rng) -> tuple:
    """Stationary per-table zipf at skew ``zipf_a`` — the steady-state
    power-law regime (no drift; the control arm of the drift tests)."""
    n, R = spec.n_accesses, spec.rows_per_table
    table_id = _tables(spec, rng, n)
    ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.05)), R, n)
    salt = rng.integers(0, 2**31, size=spec.n_tables)
    row_id = _permute(ranks, salt[table_id], R)
    return table_id, row_id


@register("diurnal", params=("n_phases", "hot_frac", "p_hot"))
def diurnal(spec: WorkloadSpec, rng) -> tuple:
    """Diurnal hot-set rotation: time splits into ``n_phases`` equal
    phases; each phase has its own hot set of ``hot_frac * rows`` rows per
    table, hit with probability ``p_hot`` (zipf-shaped inside the hot
    set), else a uniform cold draw.  Consecutive phases share no hot rows
    by construction — the wholesale working-set switch the drift detector
    must catch."""
    n, R, T = spec.n_accesses, spec.rows_per_table, spec.n_tables
    n_phases = int(spec.param("n_phases", 4))
    hot = max(1, int(float(spec.param("hot_frac", 0.05)) * R))
    p_hot = float(spec.param("p_hot", 0.9))
    table_id = _tables(spec, rng, n)
    phase = np.minimum(np.arange(n) * n_phases // max(n, 1),
                       n_phases - 1)
    # Phase p's hot rows per table: a disjoint slice of a fixed keyed
    # permutation (disjoint while n_phases * hot <= R).
    salt = rng.integers(0, 2**31, size=T)
    ranks = _zipf_ranks(rng, 1.1, hot, n)
    hot_rows = _permute(phase * hot + ranks, salt[table_id], R)
    cold_rows = rng.integers(0, R, size=n)
    is_hot = rng.random(n) < p_hot
    return table_id, np.where(is_hot, hot_rows, cold_rows)


@register("flash_crowd", params=("zipf_a", "onset", "duration", "p_burst",
                                 "burst_frac"))
def flash_crowd(spec: WorkloadSpec, rng) -> tuple:
    """Flash crowd: a stationary zipf baseline, then at ``onset`` (fraction
    of the trace) a burst window of ``duration`` where ``p_burst`` of
    accesses slam a tiny set of previously-cold rows (``burst_frac`` of
    each table) — the viral-item spike.  After the window the baseline
    resumes (the crowd disperses)."""
    n, R = spec.n_accesses, spec.rows_per_table
    table_id = _tables(spec, rng, n)
    base_ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.05)), R, n)
    salt = rng.integers(0, 2**31, size=spec.n_tables)
    base_rows = _permute(base_ranks, salt[table_id], R)
    onset = int(float(spec.param("onset", 0.5)) * n)
    end = min(n, onset + int(float(spec.param("duration", 0.3)) * n))
    burst = max(1, int(float(spec.param("burst_frac", 0.03)) * R))
    # Burst rows come from the *far end* of a second permutation: cold
    # under the baseline zipf (which concentrates on low ranks).
    b_ranks = _zipf_ranks(rng, 1.2, burst, n)
    burst_rows = _permute(R - 1 - b_ranks, salt[table_id] ^ 0x5BF03635, R)
    in_window = (np.arange(n) >= onset) & (np.arange(n) < end)
    hit_burst = in_window & (rng.random(n) <
                             float(spec.param("p_burst", 0.85)))
    return table_id, np.where(hit_burst, burst_rows, base_rows)


@register("multi_tenant", params=("n_tenants", "block", "zipf_a"))
def multi_tenant(spec: WorkloadSpec, rng) -> tuple:
    """Multi-tenant interleave: ``n_tenants`` independent zipfs over
    disjoint per-tenant row permutations, scheduled in coarse blocks of
    ``block`` consecutive accesses (a tenant's requests arrive bursty, not
    access-interleaved).  The aggregate distribution is stationary but the
    *short-window* hot set swings tenant to tenant."""
    n, R = spec.n_accesses, spec.rows_per_table
    n_ten = int(spec.param("n_tenants", 4))
    block = max(1, int(spec.param("block", 512)))
    table_id = _tables(spec, rng, n)
    n_blocks = n // block + 2
    tenant_of_block = rng.integers(0, n_ten, size=n_blocks)
    tenant = tenant_of_block[np.arange(n) // block]
    ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.2)), R, n)
    salt = rng.integers(0, 2**31, size=(n_ten, spec.n_tables))
    row_id = _permute(ranks, salt[tenant, table_id], R)
    return table_id, row_id


@register("sustained_overload", params=("zipf_a", "load_x", "hot_frac",
                                        "p_hot_end"))
def sustained_overload(spec: WorkloadSpec, rng) -> tuple:
    """Sustained overload traffic: a stationary zipf baseline whose hot
    set *concentrates* as the surge persists — the fraction of accesses
    slamming a tiny hot set (``hot_frac`` of each table) ramps linearly
    from 0 to ``p_hot_end`` over the trace, modeling the skew
    concentration RecShard observes when traffic spikes.  The ``load_x``
    param is not read here: it rides on the spec for the serving harness
    (:mod:`repro.workloads.overload`), which turns it into an offered
    load of ``load_x`` times modeled compute capacity."""
    n, R = spec.n_accesses, spec.rows_per_table
    table_id = _tables(spec, rng, n)
    ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.2)), R, n)
    salt = rng.integers(0, 2**31, size=spec.n_tables)
    base_rows = _permute(ranks, salt[table_id], R)
    hot = max(1, int(float(spec.param("hot_frac", 0.02)) * R))
    h_ranks = _zipf_ranks(rng, 1.3, hot, n)
    hot_rows = _permute(h_ranks, salt[table_id] ^ 0x9E3779B9, R)
    p_hot = np.linspace(0.0, float(spec.param("p_hot_end", 0.5)), n)
    is_hot = rng.random(n) < p_hot
    return table_id, np.where(is_hot, hot_rows, base_rows)


@register("shard_failure", params=("zipf_a", "hot_frac", "p_hot"))
def shard_failure(spec: WorkloadSpec, rng) -> tuple:
    """Traffic for the shard-failover chaos runs: a stationary zipf
    baseline with a *steady* concentrated hot set (``hot_frac`` of each
    table, hit with probability ``p_hot``) — the RecShard-CDF shape that
    makes hot-row replication the failover lever: when a shard dies
    mid-run, the replicated top-k keeps most of this traffic exactly
    answerable from survivors.  The fault timeline itself is not in the
    trace; it rides on the serving harness (:mod:`repro.workloads.chaos`)
    as a :class:`~repro.runtime.faults.FaultPlan`."""
    n, R = spec.n_accesses, spec.rows_per_table
    table_id = _tables(spec, rng, n)
    ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.1)), R, n)
    salt = rng.integers(0, 2**31, size=spec.n_tables)
    base_rows = _permute(ranks, salt[table_id], R)
    hot = max(1, int(float(spec.param("hot_frac", 0.03)) * R))
    h_ranks = _zipf_ranks(rng, 1.3, hot, n)
    hot_rows = _permute(h_ranks, salt[table_id] ^ 0x7F4A7C15, R)
    is_hot = rng.random(n) < float(spec.param("p_hot", 0.6))
    return table_id, np.where(is_hot, hot_rows, base_rows)


@register("churn", params=("zipf_a", "churn_per_k"))
def churn(spec: WorkloadSpec, rng) -> tuple:
    """Popularity-decay churn: zipf over a *sliding* rank window — the
    rank->row mapping advances by ``churn_per_k`` rows every 1000
    accesses, so items continuously go stale while fresh ones warm up
    (RecShard's observed slow CDF drift, as opposed to the diurnal
    regime's hard switch)."""
    n, R = spec.n_accesses, spec.rows_per_table
    table_id = _tables(spec, rng, n)
    ranks = _zipf_ranks(rng, float(spec.param("zipf_a", 1.1)), R, n)
    front = (np.arange(n, dtype=np.int64)
             * float(spec.param("churn_per_k", 24.0)) / 1000.0)
    salt = rng.integers(0, 2**31, size=spec.n_tables)
    row_id = _permute(ranks + front.astype(np.int64), salt[table_id], R)
    return table_id, row_id
