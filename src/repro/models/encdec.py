"""Whisper-style encoder-decoder backbone.

The conv/mel audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, enc_len, d_model) that feed the
encoder directly.  Decoder layers: causal self-attention (+cache), cross
attention over the encoder output (cross K/V cached for decode), GELU MLP.
Rotary positions replace whisper's learned/sinusoidal embeddings (documented
TPU-era adaptation in DESIGN.md §7).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.sharding.partition import constrain_batch
from repro.models.transformer import _ce, _logits, _remat


def init_enc_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attn(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": L.init_mlp(ks[1], cfg, gated=False),
    }


def init_dec_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": jnp.ones((cfg.d_model,), dt),
        "attn": L.init_attn(ks[0], cfg),
        "lnx": jnp.ones((cfg.d_model,), dt),
        "xattn": L.init_attn(ks[1], cfg, cross=True),
        "ln2": jnp.ones((cfg.d_model,), dt),
        "mlp": L.init_mlp(ks[2], cfg, gated=False),
    }


def init_encdec(key, cfg: ModelConfig):
    ke, kd, kemb, kh = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    enc_keys = jax.random.split(ke, cfg.n_enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": (jax.random.normal(kemb, (cfg.vocab, cfg.d_model)) * 0.02).astype(dt),
        "enc_blocks": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": jnp.ones((cfg.d_model,), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "lm_head": (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab)) / math.sqrt(cfg.d_model)
        ).astype(dt),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params, cfg: ModelConfig, run: RunConfig, frames):
    """frames: (B, Se, D) precomputed stub embeddings -> (B, Se, D)."""
    Se = frames.shape[1]
    positions = jnp.arange(Se)[None, :]
    x = frames.astype(jnp.dtype(cfg.compute_dtype))

    def body(x, lp):
        def fn(lp_, x_):
            x_ = constrain_batch(x_)
            h = L.rms_norm(x_, lp_["ln1"], cfg.norm_eps)
            q, k, v = L._qkv(lp_["attn"], cfg, h, positions)
            o = L.plain_attention(q, k, v, causal=False)
            o = jnp.einsum(
                "bsh,hd->bsd", o.reshape(*o.shape[:2], -1), lp_["attn"]["wo"]
            )
            x_ = x_ + o
            h2 = L.rms_norm(x_, lp_["ln2"], cfg.norm_eps)
            return x_ + L.mlp_block(lp_["mlp"], h2)

        return _remat(fn, run)(lp, x), None

    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------


def _dec_layer(lp, cfg, run, x, positions, enc_out):
    x = constrain_batch(x)
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, (k, v) = L.attn_block(lp["attn"], cfg, run, h, positions)
    x = x + attn_out
    hx = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
    xk, xv = L.cross_kv(lp["xattn"], cfg, enc_out)
    x = x + L.cross_attn_block(lp["xattn"], cfg, hx, xk, xv)
    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + L.mlp_block(lp["mlp"], h2)
    return x, {"k": k, "v": v, "xk": xk, "xv": xv}


def decode_forward(params, cfg: ModelConfig, run: RunConfig, tokens, enc_out,
                   want_cache: bool = False):
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = params["embed"].astype(jnp.dtype(cfg.compute_dtype))[tokens]

    def body(x, lp):
        fn = _remat(
            lambda lp_, x_: _dec_layer(lp_, cfg, run, x_, positions, enc_out),
            run,
        )
        x, cache = fn(lp, x)
        return x, (cache if want_cache else 0)

    x, caches = lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, (caches if want_cache else None)


def encdec_loss(params, cfg: ModelConfig, run: RunConfig, tokens, labels,
                frames):
    enc_out = encode(params, cfg, run, frames)
    x, _ = decode_forward(params, cfg, run, tokens, enc_out)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)
    num, den = _ce(_logits(params, cfg, x), labels_c, mask)
    return num / jnp.maximum(den, 1.0)


def encdec_prefill(params, cfg: ModelConfig, run: RunConfig, tokens, frames,
                   cache_len=None):
    enc_out = encode(params, cfg, run, frames)
    x, caches = decode_forward(params, cfg, run, tokens, enc_out,
                               want_cache=True)
    logits = _logits(params, cfg, x[:, -1:, :])[:, 0]
    cache = dict(caches)
    S = tokens.shape[1]
    cap = cache_len or S
    if cap > S:
        pad = [(0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0)]
        cache["k"] = jnp.pad(cache["k"], pad)
        cache["v"] = jnp.pad(cache["v"], pad)
    cache["pos"] = jnp.full((), S, jnp.int32)
    return logits, cache


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int):
    dt = jnp.dtype(cfg.compute_dtype)
    Lr, K, hd = cfg.n_layers, cfg.kv_heads, cfg.hd
    return {
        "pos": jnp.zeros((), jnp.int32),
        "k": jnp.zeros((Lr, batch, cache_len, K, hd), dt),
        "v": jnp.zeros((Lr, batch, cache_len, K, hd), dt),
        "xk": jnp.zeros((Lr, batch, cfg.enc_len, K, hd), dt),
        "xv": jnp.zeros((Lr, batch, cfg.enc_len, K, hd), dt),
    }


def encdec_decode_step(params, cfg: ModelConfig, run: RunConfig, token, cache):
    pos = cache["pos"]
    x = params["embed"].astype(jnp.dtype(cfg.compute_dtype))[token]

    def body(x, inp):
        lp, lc = inp
        x = constrain_batch(x)
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn_out, k_c, v_c = L.attn_decode_block(
            lp["attn"], cfg, h, lc["k"], lc["v"], pos
        )
        x = x + attn_out
        hx = L.rms_norm(x, lp["lnx"], cfg.norm_eps)
        x = x + L.cross_attn_block(lp["xattn"], cfg, hx, lc["xk"], lc["xv"])
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_block(lp["mlp"], h2)
        return x, {"k": k_c, "v": v_c, "xk": lc["xk"], "xv": lc["xv"]}

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_caches = lax.scan(body, x, (params["dec_blocks"], layer_caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, 0]
    new_cache = dict(new_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
