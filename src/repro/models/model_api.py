"""Facade tying configs -> model functions -> input/cache specs.

Everything the launcher, dry-run, tests and benchmarks need goes through
``build(cfg, run)``; no caller touches family-specific modules directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import dlrm as D
from repro.models import encdec as ED
from repro.models import transformer as T


@dataclass
class ModelBundle:
    cfg: ModelConfig
    run: RunConfig
    init: Callable[[Any], Any]
    loss: Callable[..., Any]  # loss(params, batch) -> scalar
    prefill: Optional[Callable[..., Any]]  # prefill(params, batch) -> out
    decode: Optional[Callable[..., Any]]  # decode(params, token, cache)

    # ---------------- structure helpers ----------------
    def param_struct(self):
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    def n_params(self) -> int:
        import math

        return sum(
            math.prod(l.shape) if l.shape else 1
            for l in jax.tree_util.tree_leaves(self.param_struct())
        )

    def n_active_params(self) -> int:
        """MoE: experts count at top_k/E; everything else fully."""
        cfg = self.cfg
        if not cfg.n_experts:
            return self.n_params()
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            self.param_struct()
        )[0]:
            names = [str(getattr(k, "key", "")) for k in path]
            n = 1
            for s in leaf.shape:
                n *= s
            if "moe" in names and names[-1] != "router":
                n = int(n * cfg.top_k / cfg.n_experts)
            total += n
        return total

    def batch_struct(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        B = shape.global_batch
        if cfg.family == "dlrm":
            d = {
                "dense": jax.ShapeDtypeStruct((B, cfg.dense_features), jnp.float32),
                "sparse": jax.ShapeDtypeStruct(
                    (B, cfg.n_tables, cfg.multi_hot), jnp.int32
                ),
            }
            if shape.kind == "train":
                d["label"] = jax.ShapeDtypeStruct((B,), jnp.float32)
            return d
        S = shape.seq_len
        ct = jnp.dtype(cfg.compute_dtype)
        if shape.kind == "decode":
            return {"token": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        d = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        if shape.kind == "train":
            d["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.frontend == "vision":
            d["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), ct
            )
        elif cfg.frontend == "audio":
            d["frontend"] = jax.ShapeDtypeStruct((B, cfg.enc_len, cfg.d_model), ct)
        return d

    def cache_struct(self, shape: ShapeConfig):
        cfg = self.cfg
        if cfg.family == "dlrm":
            return None
        fn = (
            partial(ED.init_encdec_cache, cfg)
            if cfg.enc_dec
            else partial(T.init_cache, cfg)
        )
        return jax.eval_shape(
            lambda: fn(shape.global_batch, shape.seq_len)
        )


def build(cfg: ModelConfig, run: Optional[RunConfig] = None) -> ModelBundle:
    run = run or RunConfig()

    if cfg.family == "dlrm":
        def loss(params, batch):
            return D.dlrm_loss(params, cfg, batch["dense"], batch["sparse"],
                               batch["label"], run.dlrm_sharded_lookup)

        def serve(params, batch):
            return D.dlrm_forward(params, cfg, batch["dense"],
                                  batch["sparse"], run.dlrm_sharded_lookup)

        return ModelBundle(
            cfg=cfg, run=run,
            init=partial(D.init_dlrm, cfg=cfg),
            loss=loss, prefill=serve, decode=None,
        )

    if cfg.enc_dec:
        def loss(params, batch):
            return ED.encdec_loss(params, cfg, run, batch["tokens"],
                                  batch["labels"], batch["frontend"])

        def prefill_fn(params, batch, cache_len=None):
            return ED.encdec_prefill(params, cfg, run, batch["tokens"],
                                     batch["frontend"], cache_len)

        def decode_fn(params, token, cache):
            return ED.encdec_decode_step(params, cfg, run, token, cache)

        return ModelBundle(
            cfg=cfg, run=run,
            init=partial(ED.init_encdec, cfg=cfg),
            loss=loss, prefill=prefill_fn, decode=decode_fn,
        )

    def loss(params, batch):
        return T.lm_loss(params, cfg, run, batch["tokens"], batch["labels"],
                         batch.get("frontend"))

    def prefill_fn(params, batch, cache_len=None):
        return T.prefill(params, cfg, run, batch["tokens"],
                         batch.get("frontend"), cache_len)

    def decode_fn(params, token, cache):
        return T.decode_step(params, cfg, run, token, cache)

    return ModelBundle(
        cfg=cfg, run=run,
        init=partial(T.init_lm, cfg=cfg),
        loss=loss, prefill=prefill_fn, decode=decode_fn,
    )
