"""The paper's own architecture: DLRM (embeddings + interaction + MLPs).

Matches the open-source DLRM reference [arXiv:1906.00091] that the paper's
evaluation uses: a bottom MLP projects dense features to emb_dim, sparse
categorical features gather+sum-pool multi-hot rows from per-table EMBs,
pairwise dot-product interaction feeds the top MLP, sigmoid CTR output.

At dry-run scale the stacked EMB tensor (856 x 72704 x 128) is row-sharded
across the whole mesh; at serving time on real tiered memory the EMBs live on
the host tier behind the RecMG-managed device buffer (src/repro/core) — that
path is exercised by the examples and benchmarks, not by the dry-run.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.partition import constrain_batch


def _init_mlp(key, dims, dt):
    ks = jax.random.split(key, len(dims) - 1)
    ws, bs = [], []
    for i, k in enumerate(ks):
        fan_in = dims[i]
        ws.append(
            (jax.random.normal(k, (dims[i], dims[i + 1])) / math.sqrt(fan_in)).astype(dt)
        )
        bs.append(jnp.zeros((dims[i + 1],), dt))
    return {"w": ws, "b": bs}


def _mlp(p, x, final_act=None):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i].astype(x.dtype) + p["b"][i].astype(x.dtype)
        if i < n - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act else x


def num_interactions(cfg: ModelConfig) -> int:
    f = cfg.n_tables + 1
    return f * (f - 1) // 2


def init_dlrm(key, cfg: ModelConfig):
    kt, kb, ktop = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    emb = (
        jax.random.normal(kt, (cfg.n_tables, cfg.rows_per_table, cfg.emb_dim))
        * (1.0 / math.sqrt(cfg.emb_dim))
    ).astype(dt)
    bot_dims = (cfg.dense_features,) + tuple(cfg.bottom_mlp)
    top_in = cfg.emb_dim + num_interactions(cfg)
    top_dims = (top_in,) + tuple(cfg.top_mlp)
    return {
        "emb": emb,
        "bottom": _init_mlp(kb, bot_dims, dt),
        "top": _init_mlp(ktop, top_dims, dt),
    }


def embedding_lookup_rowsharded(emb, sparse_idx, mesh):
    """Pool-before-reduce lookup for EMB rows sharded on the *model* axis.

    GSPMD resolves the naive gather from row-sharded tables by exchanging
    the UNPOOLED (B, T, P, D) partials — 20x (the pooling factor) more
    collective traffic than necessary.  This shard_map version pools each
    device's owned rows locally and psums only the (B_local, T, D) result
    — the TorchRec row-wise-sharding communication pattern.  §Perf.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.partition import data_axes

    T, R, D = emb.shape
    n_m = mesh.shape["model"]
    shard_rows = R // n_m
    dp = data_axes(mesh)

    def local(emb_l, idx_l):
        m = jax.lax.axis_index("model")
        rel = idx_l - m * shard_rows
        ok = (rel >= 0) & (rel < shard_rows)
        relc = jnp.clip(rel, 0, shard_rows - 1)

        def per_table(tab, ix, okx):  # tab (Rs, D); ix/okx (B, P)
            rows = tab[ix]  # (B, P, D)
            return jnp.where(okx[..., None], rows, 0).sum(axis=1)

        pooled = jax.vmap(per_table, in_axes=(0, 1, 1), out_axes=1)(
            emb_l, relc, ok
        )  # (B_local, T, D)
        return jax.lax.psum(pooled, "model")

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(None, "model", None), P(dp, None, None)),
        out_specs=P(dp, None, None),
        check_rep=False,
    )
    return fn(emb, sparse_idx)


def embedding_lookup(emb, sparse_idx):
    """emb: (T, R, D); sparse_idx: (B, T, P) -> pooled (B, T, D).

    Per-table multi-hot gather + sum pooling — the operation the paper's
    entire memory system optimizes.  The Pallas fused version lives in
    repro/kernels/embedding_gather.py; this is the XLA path.
    """
    # (B, T, P, D): gather rows per table via take_along_axis on a vmap.
    def per_table(table, idx):  # table (R, D), idx (B, P)
        return table[idx].sum(axis=1)  # (B, D)

    pooled = jax.vmap(per_table, in_axes=(0, 1), out_axes=1)(
        emb, sparse_idx
    )  # (B, T, D)
    return pooled


def dlrm_forward(params, cfg: ModelConfig, dense, sparse_idx,
                 sharded_lookup: bool = False):
    """dense: (B, F_dense) f32; sparse_idx: (B, T, P) int32 -> logits (B,)."""
    ct = jnp.dtype(cfg.compute_dtype)
    bot = _mlp(params["bottom"], dense.astype(ct))  # (B, emb_dim)
    if sharded_lookup:
        from repro.sharding import partition as _p

        assert _p._ACT_MESH is not None, "sharded lookup needs a mesh scope"
        pooled = embedding_lookup_rowsharded(
            params["emb"].astype(ct), sparse_idx, _p._ACT_MESH
        )
    else:
        pooled = constrain_batch(
            embedding_lookup(params["emb"].astype(ct), sparse_idx)
        )  # (B,T,D)
    z = jnp.concatenate([bot[:, None, :], pooled], axis=1)  # (B, F, D)
    zz = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = zz[:, iu, ju]  # (B, F*(F-1)/2)
    top_in = jnp.concatenate([bot.astype(jnp.float32), inter], axis=1)
    logit = _mlp(params["top"], top_in.astype(ct))[:, 0]
    return logit.astype(jnp.float32)


def dlrm_loss(params, cfg: ModelConfig, dense, sparse_idx, labels,
              sharded_lookup: bool = False):
    logit = dlrm_forward(params, cfg, dense, sparse_idx, sharded_lookup)
    # Numerically-stable BCE with logits.
    loss = jnp.maximum(logit, 0.0) - logit * labels + jnp.log1p(
        jnp.exp(-jnp.abs(logit))
    )
    return loss.mean()
