"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are stacked on a leading L dim and iterated with ``lax.scan`` so the
HLO (and compile time) is O(1) in depth; remat policy wraps the per-layer
body.  Three entry points:

  * ``lm_loss``     — training forward (causal CE), microbatch-friendly;
  * ``prefill``     — full-sequence forward returning last-token logits + cache;
  * ``decode_step`` — one token in, one token of logits out, cache updated.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.sharding.partition import constrain_batch


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    p = {"ln1": jnp.ones((cfg.d_model,), dt)}
    fam = cfg.family
    if fam == "ssm":
        p["ssm"] = L.init_mamba(ks[0], cfg)
        return p
    p["ln2"] = jnp.ones((cfg.d_model,), dt)
    p["attn"] = L.init_attn(ks[0], cfg)
    if fam == "hybrid":
        p["ssm"] = L.init_mamba(ks[1], cfg)
    if cfg.n_experts:
        p["moe"] = L.init_moe(ks[2], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg, gated=True)
    return p


def init_lm(key, cfg: ModelConfig):
    kx, ke, kh = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(kx, cfg.n_layers)
    blocks = jax.vmap(lambda k: init_layer(k, cfg))(keys)
    params = {
        "embed": (jax.random.normal(ke, (cfg.vocab, cfg.d_model)) * 0.02).astype(dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(kh, (cfg.d_model, cfg.vocab))
            / math.sqrt(cfg.d_model)
        ).astype(dt)
    return params


# ---------------------------------------------------------------------------
# Per-layer bodies
# ---------------------------------------------------------------------------


def _layer_forward(lp, cfg: ModelConfig, run: RunConfig, x, positions):
    """Full-sequence layer.  Returns (x, aux_loss, kv_for_cache_or_None)."""
    x = constrain_batch(x)
    fam = cfg.family
    aux = jnp.zeros((), jnp.float32)
    if fam == "ssm":
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, (conv_tail, h_last) = L.mamba_block(lp["ssm"], cfg, h)
        return x + out, aux, {"conv": conv_tail, "h": h_last}

    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, (k, v) = L.attn_block(lp["attn"], cfg, run, h, positions)
    cache = {"k": k, "v": v}
    if fam == "hybrid":
        ssm_out, (conv_tail, h_last) = L.mamba_block(lp["ssm"], cfg, h)
        attn_out = (attn_out + ssm_out) * 0.5
        cache.update(conv=conv_tail, h=h_last)
    x = x + attn_out

    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, aux = L.moe_block(lp["moe"], cfg, h2,
                              local_dispatch=run.moe_local_dispatch)
    else:
        ff = L.mlp_block(lp["mlp"], h2)
    return x + ff, aux, cache


def _layer_decode(lp, cfg: ModelConfig, x, cache, pos):
    """One-token layer.  cache: dict of this layer's state arrays."""
    x = constrain_batch(x)
    fam = cfg.family
    new_cache = {}
    if fam == "ssm":
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, conv_state, hs = L.mamba_decode_block(
            lp["ssm"], cfg, h, cache["conv"], cache["h"]
        )
        return x + out, {"conv": conv_state, "h": hs}

    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    attn_out, k_c, v_c = L.attn_decode_block(
        lp["attn"], cfg, h, cache["k"], cache["v"], pos
    )
    new_cache.update(k=k_c, v=v_c)
    if fam == "hybrid":
        ssm_out, conv_state, hs = L.mamba_decode_block(
            lp["ssm"], cfg, h, cache["conv"], cache["h"]
        )
        attn_out = (attn_out + ssm_out) * 0.5
        new_cache.update(conv=conv_state, h=hs)
    x = x + attn_out

    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        ff, _ = L.moe_block(lp["moe"], cfg, h2, dense_route=True)
    else:
        ff = L.mlp_block(lp["mlp"], h2)
    return x + ff, new_cache


def _remat(fn, run: RunConfig):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, tokens, frontend_embeds=None):
    x = params["embed"].astype(jnp.dtype(cfg.compute_dtype))[tokens]
    x = constrain_batch(x)
    if frontend_embeds is not None and cfg.n_frontend_tokens:
        fe = frontend_embeds.astype(x.dtype)
        x = lax.dynamic_update_slice(x, fe, (0, 0, 0))
    return x


def backbone(params, cfg: ModelConfig, run: RunConfig, x, positions,
             want_cache: bool = False):
    """Scan over layers.  Returns (x_final_normed, aux_loss, cache|None)."""

    def body(carry, lp):
        x, aux = carry
        fn = _remat(
            lambda lp_, x_: _layer_forward(lp_, cfg, run, x_, positions), run
        )
        x, a, cache = fn(lp, x)
        return (x, aux + a), (cache if want_cache else 0)

    (x, aux), caches = lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux / max(cfg.n_layers, 1), (caches if want_cache else None)


def _logits(params, cfg: ModelConfig, x):
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    return jnp.einsum(
        "bsd,dv->bsv", x, head.astype(x.dtype),
        preferred_element_type=jnp.float32,
    )


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def _ce(logits, labels, mask):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum(), mask.sum()


def lm_loss(params, cfg: ModelConfig, run: RunConfig, tokens, labels,
            frontend_embeds=None):
    """Causal LM loss.  tokens/labels: (B, S) int32; labels < 0 masked."""
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = _embed(params, cfg, tokens, frontend_embeds)
    x, aux, _ = backbone(params, cfg, run, x, positions)
    mask = (labels >= 0).astype(jnp.float32)
    labels_c = jnp.maximum(labels, 0)

    if run.logits_chunk and S > run.logits_chunk and S % run.logits_chunk == 0:
        nch = S // run.logits_chunk
        xs = x.reshape(B, nch, run.logits_chunk, -1).transpose(1, 0, 2, 3)
        ls = labels_c.reshape(B, nch, -1).transpose(1, 0, 2)
        ms = mask.reshape(B, nch, -1).transpose(1, 0, 2)

        def chunk(carry, inp):
            xs_, ls_, ms_ = inp
            n, d = _ce(_logits(params, cfg, xs_), ls_, ms_)
            return (carry[0] + n, carry[1] + d), None

        (num, den), _ = lax.scan(chunk, (jnp.zeros(()), jnp.zeros(())),
                                 (xs, ls, ms))
    else:
        num, den = _ce(_logits(params, cfg, x), labels_c, mask)

    loss = num / jnp.maximum(den, 1.0)
    if cfg.n_experts:
        loss = loss + 0.01 * aux
    return loss


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=None):
    """Zeroed decode cache sized for ``cache_len`` context."""
    dt = jnp.dtype(dtype or cfg.compute_dtype)
    Lr, K, hd = cfg.n_layers, cfg.kv_heads, cfg.hd
    cache = {"pos": jnp.zeros((), jnp.int32)}
    fam = cfg.family
    if fam != "ssm":
        eff = min(cache_len, cfg.window) if cfg.attn_type == "sliding" else cache_len
        cache["k"] = jnp.zeros((Lr, batch, eff, K, hd), dt)
        cache["v"] = jnp.zeros((Lr, batch, eff, K, hd), dt)
    if fam in ("ssm", "hybrid"):
        Di = cfg.inner
        cache["conv"] = jnp.zeros((Lr, batch, cfg.conv_width - 1, Di), dt)
        cache["h"] = jnp.zeros((Lr, batch, Di, cfg.ssm_state), jnp.float32)
    return cache


def prefill(params, cfg: ModelConfig, run: RunConfig, tokens,
            frontend_embeds=None, cache_len: Optional[int] = None):
    """Full forward; returns (last-token logits (B, V), cache at pos=S).

    ``cache_len`` sets KV-cache *capacity* (>= S) so subsequent decode steps
    have room; the cache is a ring buffer (slot = pos % capacity), so a full
    cache degrades to a sliding window rather than corrupting slot 0.
    """
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    x = _embed(params, cfg, tokens, frontend_embeds)
    x, _, caches = backbone(params, cfg, run, x, positions, want_cache=True)
    logits = _logits(params, cfg, x[:, -1:, :])[:, 0]

    cache = {"pos": jnp.full((), S, jnp.int32)}
    if "k" in caches:
        k, v = caches["k"], caches["v"]  # (L, B, S, K, hd)
        cap = cache_len or S
        if cfg.attn_type == "sliding":
            cap = min(cap, cfg.window)
        if S > cap:
            # Keep the last `cap` keys, rotated so slot = pos % cap.
            k = jnp.roll(k[:, :, S - cap:], S % cap, axis=2)
            v = jnp.roll(v[:, :, S - cap:], S % cap, axis=2)
        elif cap > S:
            pad = [(0, 0), (0, 0), (0, cap - S), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache["k"], cache["v"] = k, v
    if "conv" in caches:
        cache["conv"], cache["h"] = caches["conv"], caches["h"]
    return logits, cache


def decode_step_embeds(params, cfg: ModelConfig, run: RunConfig, x, cache):
    """Decode from precomputed token embeddings x: (B, 1, D).

    This is the tiered-vocab serving entry point: the embedding row comes
    from the RecMG-managed fast-tier buffer (repro/core/tiered.py) instead
    of the resident table — the paper's technique applied to an LM's vocab
    embedding (DESIGN.md §4)."""
    return _decode_from(params, cfg, run, constrain_batch(x), cache)


def decode_step(params, cfg: ModelConfig, run: RunConfig, token, cache):
    """token: (B, 1) int32.  Returns (logits (B, V), new cache)."""
    return _decode_from(params, cfg, run, _embed(params, cfg, token), cache)


def _decode_from(params, cfg: ModelConfig, run: RunConfig, x, cache):
    pos = cache["pos"]

    def body(x, inp):
        lp, lc = inp
        x, new_c = _layer_decode(lp, cfg, x, lc, pos)
        return x, new_c

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}
    x, new_caches = lax.scan(body, x, (params["blocks"], layer_caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)[:, 0]
    new_cache = dict(new_caches)
    new_cache["pos"] = pos + 1
    return logits, new_cache
