"""Pure-JAX building blocks shared by every architecture in the pool.

Everything here is a plain function over pytrees of arrays — no framework.
Design constraints (these matter at dry-run scale: 512 devices, 32k-500k
sequences, 314B params):

* attention never materializes an (S, S) score matrix for long sequences —
  ``blocked_causal_attention`` is an online-softmax flash-style formulation
  with a *static* python loop over query blocks (so causal blocks are simply
  never computed: no masked-FLOP waste in ``cost_analysis``) and a
  ``lax.scan`` over key/value blocks (O(bq*bk) live memory);
* MoE dispatch is scatter/gather with a capacity buffer — never a dense
  (tokens, experts, capacity) one-hot einsum;
* the mamba-1 selective scan is chunked: sequential ``lax.scan`` over chunks,
  ``associative_scan`` within a chunk, so the (S, d_inner, d_state) state
  tensor is never materialized.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.sharding.partition import constrain_batch

# ---------------------------------------------------------------------------
# Norms / rotary embeddings
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _gqa_scores(q, k, scale):
    """q: (B, bq, K, G, hd), k: (B, bk, K, hd) -> (B, K, G, bq, bk) fp32."""
    return jnp.einsum(
        "bqkgh,bskh->bkgqs", q, k, preferred_element_type=jnp.float32
    ) * scale


def _gqa_out(p, v):
    """p: (B, K, G, bq, bk) fp32, v: (B, bk, K, hd) -> (B, bq, K, G, hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v)


def plain_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset: int = 0):
    """Reference attention for short sequences.  Shapes:
    q (B, Sq, H, hd), k/v (B, Sk, K, hd).  Materializes (Sq, Sk) scores."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, K, G, hd)
    s = _gqa_scores(qg, k, scale)  # (B, K, G, Sq, Sk)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), dtype=bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_out(p, v)
    return o.reshape(B, Sq, H, hd)


def _attend_block(q_blk, kv_blocks, first_kpos, q_pos, window, scale):
    """Online-softmax over a stack of KV blocks for one query block.

    q_blk: (B, bq, K, G, hd); kv_blocks: (nb, B, bk, K, hd) x2 stacked pytree;
    q_pos: (bq,) absolute query positions; first_kpos: absolute position of
    the first key in kv_blocks[0].
    """
    ks, vs = kv_blocks
    nb, B, bk, K, hd = ks.shape
    G = q_blk.shape[3]
    bq = q_blk.shape[1]

    m0 = jnp.full((B, K, G, bq), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((B, K, G, bq), dtype=jnp.float32)
    a0 = jnp.zeros((B, bq, K, G, hd), dtype=jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, blk_idx = inp
        kpos = first_kpos + blk_idx * bk + jnp.arange(bk)
        s = _gqa_scores(q_blk, k_blk, scale)  # (B,K,G,bq,bk)
        mask = q_pos[:, None] >= kpos[None, :]
        if window:
            mask &= q_pos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # exp(-inf - -inf) guard: rows that have seen nothing stay zero.
        corr = jnp.exp(jnp.where(m == -jnp.inf, 0.0, m - m_new))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * corr + p.sum(axis=-1)
        pv = _gqa_out(p, v_blk).astype(jnp.float32)
        corr_o = jnp.moveaxis(corr, -1, 1)[..., None]  # (B,bq,K,G,1)
        acc_new = acc * corr_o + pv
        return (m_new, l_new, acc_new), None

    idx = jnp.arange(nb)
    (m, l, acc), _ = lax.scan(step, (m0, l0, a0), (ks, vs, idx))
    l_o = jnp.moveaxis(l, -1, 1)[..., None]
    return acc / jnp.maximum(l_o, 1e-30)


def blocked_causal_attention(q, k, v, *, window: int = 0, bq: int = 512,
                             bk: int = 512):
    """Flash-style causal attention.  q (B,S,H,hd), k/v (B,S,K,hd).

    Static python loop over query blocks -> strictly-upper blocks are never
    lowered (no wasted FLOPs); ``lax.scan`` over KV blocks inside keeps live
    memory at O(bq*bk).  Each query block is wrapped in ``jax.checkpoint`` so
    the backward pass recomputes instead of saving per-step residuals.
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    if S <= max(2048, bq):
        return plain_attention(q, k, v, causal=True, window=window)

    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = q.shape[1]
    assert Sp % bq == 0 and Sp % bk == 0
    nq = Sp // bq

    qg = q.reshape(B, nq, bq, K, G, hd)

    @partial(jax.checkpoint, prevent_cse=False)
    def one_q_block(q_blk, ks, vs, i, lo):
        nb = ks.shape[1] // bk
        kv = (
            ks.reshape(B, nb, bk, K, hd).transpose(1, 0, 2, 3, 4),
            vs.reshape(B, nb, bk, K, hd).transpose(1, 0, 2, 3, 4),
        )
        q_pos = i * bq + jnp.arange(bq)
        return _attend_block(q_blk, kv, lo * bk, q_pos, window, scale)

    outs = []
    for i in range(nq):
        hi = ((i + 1) * bq) // bk  # exclusive kv block bound (causal)
        lo = 0
        if window:
            lo = max(0, (i * bq - window + 1) // bk)
        ks = lax.slice_in_dim(k, lo * bk, hi * bk, axis=1)
        vs = lax.slice_in_dim(v, lo * bk, hi * bk, axis=1)
        outs.append(one_q_block(qg[:, i], ks, vs, i, lo))
    out = jnp.stack(outs, axis=1).reshape(B, Sp, K, G, hd)
    out = out.reshape(B, Sp, H, hd)[:, :S]
    return out.astype(q.dtype)


def kv_stream_attention(q, k, v, *, window: int = 0, bk: int = 512):
    """Q-stationary causal attention for sequence-parallel prefill.

    Q keeps its (sharded) full sequence dim so GSPMD partitions every einsum
    along it; K/V stream block-by-block through a ``lax.scan`` (replicated
    across the seq shards by ``constrain_kv_gather``).  The masked upper
    triangle costs ~2x the causal FLOPs, but the sequence axis parallelizes
    over the otherwise-idle model axis — a large net win for small-batch
    prefill (§Perf iteration A3).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    pad = (-S) % bk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // bk
    qg = q.reshape(B, S, K, G, hd)
    q_pos = jnp.arange(S)

    ks = k.reshape(B, nb, bk, K, hd).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nb, bk, K, hd).transpose(1, 0, 2, 3, 4)

    m0 = jnp.full((B, K, G, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)

    def step(carry, inp):
        m, l, acc = carry
        k_blk, v_blk, j = inp
        kpos = j * bk + jnp.arange(bk)
        s = _gqa_scores(qg, k_blk, scale)  # (B,K,G,S,bk)
        mask = q_pos[:, None] >= kpos[None, :]
        if window:
            mask &= q_pos[:, None] - kpos[None, :] < window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(jnp.where(m == -jnp.inf, 0.0, m - m_new))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l = l * corr + p.sum(axis=-1)
        acc = acc * jnp.moveaxis(corr, -1, 1)[..., None] + _gqa_out(
            p, v_blk).astype(jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = lax.scan(step, (m0, l0, a0),
                              (ks, vs, jnp.arange(nb)))
    out = acc / jnp.maximum(jnp.moveaxis(l, -1, 1)[..., None], 1e-30)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0):
    """Single-token attention over a cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, S, K, hd); pos: scalar int32 —
    number of valid entries (for a ring buffer, min(pos, S) are valid).
    """
    B, _, H, hd = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, 1, K, G, hd)
    s = _gqa_scores(qg, k_cache, scale)[..., 0, :]  # (B, K, G, S)
    valid = jnp.arange(S) < jnp.minimum(pos, S)
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# Attention block (projections + norms + rope)
# ---------------------------------------------------------------------------


def init_attn(key, cfg: ModelConfig, cross: bool = False):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    sc = 1.0 / math.sqrt(D)
    p = {
        "wq": (jax.random.normal(ks[0], (D, H * hd)) * sc).astype(dt),
        "wk": (jax.random.normal(ks[1], (D, K * hd)) * sc).astype(dt),
        "wv": (jax.random.normal(ks[2], (D, K * hd)) * sc).astype(dt),
        "wo": (jax.random.normal(ks[3], (H * hd, D)) * (1.0 / math.sqrt(H * hd))).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _qkv(p, cfg: ModelConfig, x, positions, apply_rope: bool = True):
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if apply_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(p, cfg: ModelConfig, run: RunConfig, x, positions):
    """Full-sequence (train/prefill) self-attention.  Returns (out, (k, v))."""
    from repro.sharding.partition import constrain_kv_gather

    from repro.sharding import partition as _p

    q, k, v = _qkv(p, cfg, x, positions)
    window = cfg.window if cfg.attn_type == "sliding" else 0
    if _p._ACT_MESH is not None and _p.seq_entry(_p._ACT_MESH):
        # Sequence-parallel prefill ('fsdp_seq'): Q keeps its seq shards,
        # K/V replicate across them once per layer (cheap under GQA), and
        # the q-stationary kernel partitions along Q's sequence.
        k = constrain_kv_gather(k)
        v = constrain_kv_gather(v)
        o = jax.checkpoint(
            lambda q_, k_, v_: kv_stream_attention(
                q_, k_, v_, window=window, bk=run.attn_block_kv),
            prevent_cse=False,
        )(q, k, v)
    else:
        o = blocked_causal_attention(
            q, k, v, window=window, bq=run.attn_block_q, bk=run.attn_block_kv
        )
    out = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    return out, (k, v)


def attn_decode_block(p, cfg: ModelConfig, x, k_cache, v_cache, pos):
    """One-token self-attention with cache update.  x: (B, 1, D)."""
    S = k_cache.shape[1]
    q, k, v = _qkv(p, cfg, x, pos[None] if pos.ndim == 0 else pos)
    slot = jnp.where(jnp.asarray(S) > 0, pos % S, 0)
    k_cache = lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    window = cfg.window if cfg.attn_type == "sliding" else 0
    o = decode_attention(q, k_cache, v_cache, pos + 1, window=window)
    out = jnp.einsum("bsh,hd->bsd", o.reshape(*o.shape[:2], -1), p["wo"])
    return out, k_cache, v_cache


# ---------------------------------------------------------------------------
# Cross attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_attn_block(p, cfg: ModelConfig, x, k_enc, v_enc):
    """x: (B, S, D); k_enc/v_enc: (B, Se, K, hd) precomputed from encoder."""
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    o = plain_attention(q, k_enc, v_enc, causal=False)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])


def cross_kv(p, cfg: ModelConfig, enc_out):
    B, Se, D = enc_out.shape
    K, hd = cfg.kv_heads, cfg.hd
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(B, Se, K, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(B, Se, K, hd)
    return k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU for LM archs, GELU for whisper)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, gated: bool = True):
    D, F = cfg.d_model, cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    p = {
        "w1": (jax.random.normal(ks[0], (D, F)) * sc_in).astype(dt),
        "w2": (jax.random.normal(ks[1], (F, D)) * sc_out).astype(dt),
    }
    if gated:
        p["w3"] = (jax.random.normal(ks[2], (D, F)) * sc_in).astype(dt)
    return p


def mlp_block(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w1"])
    if "w3" in p:
        h = jax.nn.silu(h) * jnp.einsum("bsd,df->bsf", x, p["w3"])
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


# ---------------------------------------------------------------------------
# MoE (capacity-based scatter dispatch; experts TP on Fe, dispatch local)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    D, E, Fe = cfg.d_model, cfg.n_experts, cfg.expert_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    sc_in, sc_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(Fe)
    return {
        "router": (jax.random.normal(ks[0], (D, E)) * sc_in).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (E, D, Fe)) * sc_in).astype(dt),
        "w3": (jax.random.normal(ks[2], (E, D, Fe)) * sc_in).astype(dt),
        "w2": (jax.random.normal(ks[3], (E, Fe, D)) * sc_out).astype(dt),
    }


def _moe_dispatch_ffn(p, cfg: ModelConfig, xf):
    """Capacity dispatch + expert FFN for one token shard.  xf: (T, D)."""
    T, D = xf.shape
    E, K = cfg.n_experts, cfg.top_k

    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)  # (T, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # Load-balance auxiliary loss (Switch-style).
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (T * K)
    aux = E * jnp.sum(me * ce)

    C = max(1, int(math.ceil(cfg.capacity_factor * T * K / E)))
    flat_e = top_e.reshape(-1)  # (T*K,)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - 1  # rank within expert
    pos = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, flat_e * C + pos, E * C)  # E*C = drop slot

    x_rep = jnp.repeat(xf, K, axis=0)  # (T*K, D)
    buf = jnp.zeros((E * C + 1, D), xf.dtype).at[slot].set(x_rep)
    h = buf[: E * C].reshape(E, C, D)

    g = jnp.einsum("ecd,edf->ecf", h, p["w1"])
    u = jnp.einsum("ecd,edf->ecf", h, p["w3"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w2"])

    y_flat = jnp.concatenate([y.reshape(E * C, D), jnp.zeros((1, D), y.dtype)])
    gathered = y_flat[slot] * (top_p.reshape(-1)[:, None]).astype(y.dtype)
    out = gathered.reshape(T, K, D).sum(axis=1)
    return out, aux


def moe_block(p, cfg: ModelConfig, x, dense_route: bool = False,
              local_dispatch: bool = False):
    """Top-k capacity-dispatched MoE.  x: (B, S, D) -> (out, aux_loss).

    Dispatch is a scatter into an (E*C, D) buffer (capacity C), expert FFNs
    run as a batched einsum over E with Fe TP-sharded; no (T, E, C) one-hot
    tensor is ever built, so this is memory-safe at millions of tokens.

    Under a mesh scope the dispatch is DATA-LOCAL: tokens reshape to an
    explicit (data_shards, T_local, D) layout and the capacity buffer gets a
    sharded leading dim, so the scatter/gather never crosses data shards —
    without this, GSPMD all-reduces the global (E, C, D) buffer every layer
    (18+ TB/device/step on grok-1 train_4k; §Perf iteration B4).

    ``dense_route=True`` (decode path, few tokens): evaluate every expert and
    combine with routing weights — droppless/exact, trivially cheap at
    decode token counts.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xf = constrain_batch(x.reshape(T, D))

    if dense_route:
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = lax.top_k(probs, K)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        g = jnp.einsum("td,edf->tef", xf, p["w1"])
        u = jnp.einsum("td,edf->tef", xf, p["w3"])
        y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w2"])
        w = jnp.zeros((T, E), top_p.dtype)
        w = w.at[jnp.arange(T)[:, None], top_e].set(top_p)
        out = jnp.einsum("ted,te->td", y, w.astype(y.dtype))
        return out.reshape(B, S, D), jnp.zeros((), jnp.float32)

    from repro.sharding import partition as _p

    mesh = _p._ACT_MESH
    n_shards = 1
    if local_dispatch and mesh is not None:
        for a in _p.batch_entry(mesh):
            n_shards *= mesh.shape[a]
    if n_shards > 1 and T % n_shards == 0:
        out, aux = _moe_dispatch_ffn_sharded(p, cfg, xf, n_shards)
        return out.reshape(B, S, D), aux

    out, aux = _moe_dispatch_ffn(p, cfg, xf)
    return out.reshape(B, S, D), aux


def _moe_dispatch_ffn_sharded(p, cfg: ModelConfig, xf, n_shards: int):
    """Data-local dispatch: explicit (shards, T_local) layout with a sharding
    constraint on every materialized intermediate, so the capacity buffer,
    scatter and gather never leave their data shard (§Perf iteration B5)."""
    T, D = xf.shape
    E, K = cfg.n_experts, cfg.top_k
    S_, Tl = n_shards, T // n_shards
    cb = constrain_batch

    xs = cb(xf.reshape(S_, Tl, D))
    logits = cb(jnp.einsum("std,de->ste", xs.astype(jnp.float32), p["router"]))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)  # (S, Tl, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=1)  # (S, E)
    ce = jnp.zeros((S_, E), jnp.float32)
    sidx = jnp.arange(S_)[:, None]
    ce = ce.at[sidx, top_e.reshape(S_, -1)].add(1.0) / (Tl * K)
    aux = (E * (me * ce).sum(-1)).mean()

    C = max(1, int(math.ceil(cfg.capacity_factor * Tl * K / E)))
    flat_e = cb(top_e.reshape(S_, Tl * K))
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=1) - 1  # per-shard expert rank
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C
    slot = cb(jnp.where(keep, flat_e * C + pos, E * C))  # (S, Tl*K)

    x_rep = cb(jnp.repeat(xs, K, axis=1))  # (S, Tl*K, D)
    buf = jnp.zeros((S_, E * C + 1, D), xf.dtype)
    buf = cb(buf.at[sidx, slot].set(x_rep))
    h = cb(buf[:, : E * C].reshape(S_, E, C, D))

    g = cb(jnp.einsum("secd,edf->secf", h, p["w1"]))
    u = cb(jnp.einsum("secd,edf->secf", h, p["w3"]))
    y = cb(jnp.einsum("secf,efd->secd", jax.nn.silu(g) * u, p["w2"]))

    y_flat = jnp.concatenate(
        [y.reshape(S_, E * C, D), jnp.zeros((S_, 1, D), y.dtype)], axis=1
    )
    gathered = cb(y_flat[sidx, slot])  # (S, Tl*K, D)
    gathered = gathered * top_p.reshape(S_, Tl * K)[..., None].astype(y.dtype)
    out = cb(gathered.reshape(S_, Tl, K, D).sum(axis=2))
    return out.reshape(T, D), aux


# ---------------------------------------------------------------------------
# Mamba-1 (chunked selective scan)
# ---------------------------------------------------------------------------


def init_mamba(key, cfg: ModelConfig):
    D, Di, N, R, W = cfg.d_model, cfg.inner, cfg.ssm_state, cfg.dtrank, cfg.conv_width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(D)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (Di, 1))
    return {
        "in_proj": (jax.random.normal(ks[0], (D, 2 * Di)) * sc).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (W, Di)) * 0.5).astype(dt),
        "conv_b": jnp.zeros((Di,), dt),
        "x_proj": (jax.random.normal(ks[2], (Di, R + 2 * N)) / math.sqrt(Di)).astype(dt),
        "dt_proj": (jax.random.normal(ks[3], (R, Di)) / math.sqrt(R)).astype(dt),
        "dt_bias": jnp.full((Di,), -2.0, jnp.float32),
        "A_log": jnp.log(A),  # fp32, (Di, N)
        "D_skip": jnp.ones((Di,), jnp.float32),
        "out_proj": (jax.random.normal(ks[5], (Di, D)) / math.sqrt(Di)).astype(dt),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv.  x: (B, S, Di); w: (W, Di)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    return out + b


def _ssm_params(p, cfg: ModelConfig, xc):
    """xc: (B, S, Di) post-conv.  Returns dt (B,S,Di), Bm/Cm (B,S,N) fp32."""
    N, R = cfg.ssm_state, cfg.dtrank
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"]).astype(jnp.float32)
    dtr, Bm, Cm = proj[..., :R], proj[..., R : R + N], proj[..., R + N :]
    dt = jax.nn.softplus(
        jnp.einsum("bsr,rd->bsd", dtr, p["dt_proj"].astype(jnp.float32))
        + p["dt_bias"]
    )
    return dt, Bm, Cm


def _chunk_scan(dA, dBx, h0):
    """Associative scan of h_t = dA_t * h_{t-1} + dBx_t within a chunk.

    dA, dBx: (B, c, Di, N) fp32; h0: (B, Di, N).  Returns (h_all, h_last).
    """
    def combine(a, b):
        (a1, b1), (a2, b2) = a, b
        return a1 * a2, a2 * b1 + b2

    cumA, cumB = lax.associative_scan(combine, (dA, dBx), axis=1)
    h_all = cumA * h0[:, None] + cumB
    return h_all, h_all[:, -1]


def selective_scan(p, cfg: ModelConfig, xc, z, h0=None):
    """Chunked mamba-1 scan.  xc/z: (B, S, Di) (post-conv / gate).

    Returns (y (B, S, Di), h_last (B, Di, N)) — never materializes the full
    (S, Di, N) state tensor (only (chunk, Di, N) per scan step).
    """
    B, S, Di = xc.shape
    N = cfg.ssm_state
    c = min(cfg.ssm_chunk, S)
    pad = (-S) % c
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        z = jnp.pad(z, ((0, 0), (0, pad), (0, 0)))
    Sp = xc.shape[1]
    nch = Sp // c

    dt, Bm, Cm = _ssm_params(p, cfg, xc)
    if pad:
        # Padded steps must be identity updates (dt=0 -> dA=1, dBx=0) so the
        # carried state h_last equals the state at the true final position.
        valid = (jnp.arange(Sp) < S)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(p["A_log"])  # (Di, N)
    xf = xc.astype(jnp.float32)

    dA = jnp.exp(dt[..., None] * A)  # (B, Sp, Di, N)
    dBx = (dt * xf)[..., None] * Bm[..., None, :]  # (B, Sp, Di, N)

    dA_c = dA.reshape(B, nch, c, Di, N).transpose(1, 0, 2, 3, 4)
    dBx_c = dBx.reshape(B, nch, c, Di, N).transpose(1, 0, 2, 3, 4)
    Cm_c = Cm.reshape(B, nch, c, N).transpose(1, 0, 2, 3)

    if h0 is None:
        h0 = jnp.zeros((B, Di, N), jnp.float32)

    def chunk_step(h, inp):
        dA_i, dBx_i, C_i = inp
        h_all, h_last = _chunk_scan(dA_i, dBx_i, h)
        y = jnp.einsum("bcdn,bcn->bcd", h_all, C_i)
        return h_last, y

    h_last, ys = lax.scan(chunk_step, h0, (dA_c, dBx_c, Cm_c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, Sp, Di)[:, :S]
    y = y + p["D_skip"] * xf[:, :S]
    y = y * jax.nn.silu(z[:, :S].astype(jnp.float32))
    return y.astype(xc.dtype), h_last


def mamba_block(p, cfg: ModelConfig, x, state=None):
    """Full-sequence mamba-1 block.  x: (B, S, D) -> (out, (conv_tail, h))."""
    B, S, D = x.shape
    Di, W = cfg.inner, cfg.conv_width
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :Di], xz[..., Di:]
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    y, h_last = selective_scan(p, cfg, xc, z)
    out = jnp.einsum("bsd,de->bse", y, p["out_proj"])
    conv_tail = xi[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        xi, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    return out, (conv_tail, h_last)


def mamba_decode_block(p, cfg: ModelConfig, x, conv_state, h):
    """One-token mamba step.  x: (B, 1, D); conv_state: (B, W-1, Di);
    h: (B, Di, N).  Returns (out, conv_state, h)."""
    B = x.shape[0]
    Di, N, W = cfg.inner, cfg.ssm_state, cfg.conv_width
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xi, z = xz[..., :Di], xz[..., Di:]  # (B, 1, Di)
    window = jnp.concatenate([conv_state, xi], axis=1)  # (B, W, Di)
    xc = jax.nn.silu(
        jnp.einsum("bwd,wd->bd", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    dt, Bm, Cm = _ssm_params(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)  # (B, Di, N)
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * Bm[:, 0, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["D_skip"] * xc[:, 0].astype(jnp.float32)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32))
    out = jnp.einsum("bd,de->be", y.astype(x.dtype), p["out_proj"])[:, None, :]
    return out, window[:, 1:], h
