"""Fault-tolerance and straggler-mitigation utilities for the train loop.

Designed for 1000+-node operation; everything here is host-side control
logic (no accelerator state), so it composes with any jitted step:

  * ``retry_step`` — transient-failure retry with exponential backoff
    (XLA RESOURCE_EXHAUSTED / network blips);
  * ``StragglerMonitor`` — EWMA step-time tracker; flags hosts whose step
    times exceed k·sigma so the controller can re-shard around them
    (in single-controller JAX the action is: checkpoint + elastic restart
    without the slow host);
  * ``ElasticMesh`` — re-factor the mesh to the currently-live device count
    (restore path re-device_puts checkpointed leaves onto the new mesh);
  * ``Heartbeat`` — periodic liveness file for external supervisors
    (k8s/slurm) to detect hangs and restart the job.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional, Tuple, Type, Union

import jax


class RetryDeadlineExceeded(TimeoutError):
    """The retry episode's wall/virtual-time deadline passed before a
    successful attempt; carries the last underlying error as cause."""


def retry_step(fn: Callable, *args, retries: int = 3, backoff_s: float = 0.5,
               on_retry: Optional[Callable] = None,
               retryable: Union[Type[BaseException],
                                Tuple[Type[BaseException], ...]] = Exception,
               sleep: Optional[Callable[[float], None]] = None,
               now: Optional[Callable[[], float]] = None,
               deadline_s: Optional[float] = None):
    """Run fn(*args); retry *retryable* failures with exponential backoff.

    Serving-path requirements (vs the original train-loop helper):

    * ``retryable`` — only the named exception classes are retried;
      anything else (a logic bug, a KeyboardInterrupt) propagates on the
      first raise instead of being swallowed by a catch-all.  The default
      ``Exception`` keeps the legacy train-loop behavior.
    * ``sleep`` / ``now`` — injectable clock.  On the serving path these
      charge modeled microseconds to the deterministic virtual timeline
      (no bare ``time.sleep`` blocking a request); defaults keep
      wall-clock semantics for the train loop.
    * ``deadline_s`` — a hard bound on the whole episode measured via
      ``now()``: if the next backoff would land past the deadline, raise
      :class:`RetryDeadlineExceeded` immediately so admission deadlines
      still hold (a retry loop must never outlast the request).
    """
    _sleep = sleep if sleep is not None else time.sleep
    _now = now if now is not None else time.monotonic
    start = _now() if deadline_s is not None else 0.0
    attempt = 0
    while True:
        try:
            return fn(*args)
        except retryable as e:
            attempt += 1
            if attempt > retries:
                raise
            pause = backoff_s * (2 ** (attempt - 1))
            if deadline_s is not None and (_now() - start) + pause > deadline_s:
                raise RetryDeadlineExceeded(
                    f"retry deadline {deadline_s}s exceeded after "
                    f"{attempt} attempt(s)") from e
            if on_retry:
                on_retry(attempt, e)
            _sleep(pause)


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker with outlier detection.

    ``clock`` is optional and only used by :meth:`record_since` for
    callers that want the monitor to own timing; ``record`` takes an
    explicit duration and needs no clock at all.
    """

    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 10
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    slow_steps: List[int] = field(default_factory=list)
    clock: Optional[Callable[[], float]] = None
    _last_t: Optional[float] = None

    def record_since(self, step: int) -> bool:
        """Record the interval since the previous call using the injected
        clock (defaults to ``time.monotonic``). First call only arms."""
        now = (self.clock or time.monotonic)()
        prev, self._last_t = self._last_t, now
        if prev is None:
            return False
        return self.record(step, now - prev)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        slow = False
        if self.n > self.warmup:
            sd = math.sqrt(max(self.var, 1e-12))
            if dt > self.mean + self.k_sigma * sd and dt > 1.2 * self.mean:
                slow = True
                self.slow_steps.append(step)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return slow

    def summary(self):
        return {"mean_s": round(self.mean, 4),
                "std_s": round(math.sqrt(max(self.var, 0.0)), 4),
                "stragglers": len(self.slow_steps)}


class ElasticMesh:
    """Re-factor (data, model) to the live device count on restart.

    model_parallel is treated as an upper bound: if devices were lost and
    the count no longer factors, model parallelism shrinks to the largest
    divisor — training resumes at reduced TP rather than not at all."""

    def __init__(self, model_parallel: int = 1):
        self.model_parallel = model_parallel

    def make(self):
        n = len(jax.devices())
        mp = self.model_parallel
        while n % mp:
            mp -= 1
        return jax.make_mesh((n // mp, mp), ("data", "model"))


class Heartbeat:
    """Periodic liveness file; ``clock`` is injectable so the cadence can
    run on a virtual timeline in tests (first beat always writes)."""

    def __init__(self, path: str, every_s: float = 30.0,
                 clock: Optional[Callable[[], float]] = None):
        self.path = Path(path)
        self.every_s = every_s
        self.clock = clock or time.time
        self._last: Optional[float] = None

    def beat(self, step: int, **info):
        now = self.clock()
        if self._last is not None and now - self._last < self.every_s:
            return
        self._last = now
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"step": step, "time": now, **info}))
        os.replace(tmp, self.path)
