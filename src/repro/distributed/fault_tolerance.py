"""Fault-tolerance and straggler-mitigation utilities for the train loop.

Designed for 1000+-node operation; everything here is host-side control
logic (no accelerator state), so it composes with any jitted step:

  * ``retry_step`` — transient-failure retry with exponential backoff
    (XLA RESOURCE_EXHAUSTED / network blips);
  * ``StragglerMonitor`` — EWMA step-time tracker; flags hosts whose step
    times exceed k·sigma so the controller can re-shard around them
    (in single-controller JAX the action is: checkpoint + elastic restart
    without the slow host);
  * ``ElasticMesh`` — re-factor the mesh to the currently-live device count
    (restore path re-device_puts checkpointed leaves onto the new mesh);
  * ``Heartbeat`` — periodic liveness file for external supervisors
    (k8s/slurm) to detect hangs and restart the job.
"""
from __future__ import annotations

import json
import math
import os
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional

import jax


def retry_step(fn: Callable, *args, retries: int = 3, backoff_s: float = 0.5,
               on_retry: Optional[Callable] = None):
    """Run fn(*args); retry transient failures with exponential backoff."""
    attempt = 0
    while True:
        try:
            return fn(*args)
        except Exception as e:  # noqa: BLE001 — deliberate catch-all boundary
            attempt += 1
            if attempt > retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(backoff_s * (2 ** (attempt - 1)))


@dataclass
class StragglerMonitor:
    """EWMA step-time tracker with outlier detection."""

    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 10
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    slow_steps: List[int] = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.n == 1:
            self.mean = dt
            return False
        slow = False
        if self.n > self.warmup:
            sd = math.sqrt(max(self.var, 1e-12))
            if dt > self.mean + self.k_sigma * sd and dt > 1.2 * self.mean:
                slow = True
                self.slow_steps.append(step)
        d = dt - self.mean
        self.mean += self.alpha * d
        self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return slow

    def summary(self):
        return {"mean_s": round(self.mean, 4),
                "std_s": round(math.sqrt(max(self.var, 0.0)), 4),
                "stragglers": len(self.slow_steps)}


class ElasticMesh:
    """Re-factor (data, model) to the live device count on restart.

    model_parallel is treated as an upper bound: if devices were lost and
    the count no longer factors, model parallelism shrinks to the largest
    divisor — training resumes at reduced TP rather than not at all."""

    def __init__(self, model_parallel: int = 1):
        self.model_parallel = model_parallel

    def make(self):
        n = len(jax.devices())
        mp = self.model_parallel
        while n % mp:
            mp -= 1
        return jax.make_mesh((n // mp, mp), ("data", "model"))


class Heartbeat:
    def __init__(self, path: str, every_s: float = 30.0):
        self.path = Path(path)
        self.every_s = every_s
        self._last = 0.0

    def beat(self, step: int, **info):
        now = time.time()
        if now - self._last < self.every_s:
            return
        self._last = now
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps({"step": step, "time": now, **info}))
        os.replace(tmp, self.path)
