"""Gradient compression for the data-parallel all-reduce.

int8 quantization with error feedback (EF-SGD style): each step quantizes
(grad + carried error) to int8 with a per-tensor scale, all-reduces the int8
payload (4x less ICI traffic than fp32, 2x less than bf16), dequantizes, and
carries the quantization residual into the next step.  Exposed as a
``shard_map``-based DP train-step wrapper so the collective is explicit and
the HLO shows the reduced payload (the §Perf collective-term knob).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, err: Any):
    """(grads+err) -> (q_tree, scale_tree, new_err_tree)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize_int8(g)
        deq = dequantize_int8(q, s)
        return q, s, g - deq

    flat = jax.tree_util.tree_map(one, grads, err)
    q = jax.tree_util.tree_map(lambda t: t[0], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree_util.tree_map(lambda t: t[1], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
    e = jax.tree_util.tree_map(lambda t: t[2], flat,
                               is_leaf=lambda t: isinstance(t, tuple))
    return q, s, e


def init_error(params: Any):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def psum_int8(q_tree, scale_tree, axis_name: str, n_dev: int):
    """all-reduce int8 payload: int8 sums can overflow int8, so the psum runs
    on int32 views of packed int8 — XLA transfers the int8 operand and
    widens at the reduction; payload on the wire stays 1 byte/elem for the
    gather phase.  Scales are meaned."""
    summed = jax.tree_util.tree_map(
        lambda q: jax.lax.psum(q.astype(jnp.int32), axis_name), q_tree
    )
    scale = jax.tree_util.tree_map(
        lambda s: jax.lax.pmean(s, axis_name), scale_tree
    )
    return jax.tree_util.tree_map(
        lambda si, sc: si.astype(jnp.float32) * sc / 1.0, summed, scale
    )


def make_compressed_dp_grads(loss_fn, mesh, axis: str = "data"):
    """Returns grads_fn(params, err, batch) -> (loss, grads, new_err) where
    the cross-data-shard gradient reduction is int8 + error feedback, run
    under shard_map so the collective payload is explicit in the HLO."""
    from jax.experimental.shard_map import shard_map

    n_dev = mesh.shape[axis]

    def local_step(params, err, batch):
        from repro.sharding.partition import activation_sharding

        # Inside shard_map the mesh axes are manual; per-shard model code
        # must not emit with_sharding_constraint on them.
        with activation_sharding(None):
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
        q, s, new_err = compress_tree(g, err)
        g_sum = psum_int8(q, s, axis, n_dev)
        g_avg = jax.tree_util.tree_map(lambda x: x / n_dev, g_sum)
        return jax.lax.pmean(loss, axis), g_avg, new_err

    pspec = P()  # params replicated across `axis` in the pure-DP wrapper

    def grads_fn(params, err, batch):
        batch_spec = jax.tree_util.tree_map(
            lambda x: P(axis, *([None] * (x.ndim - 1))), batch
        )
        fn = shard_map(
            local_step, mesh=mesh,
            in_specs=(pspec, pspec, batch_spec),
            out_specs=(pspec, pspec, pspec),
            check_rep=False,
        )
        return fn(params, err, batch)

    return grads_fn
