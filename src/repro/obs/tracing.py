"""Structured span tracing on the serving stack's deterministic timeline.

Spans are recorded against whatever clock the run uses — the modeled
``VirtualClock`` under ``--async-prefetch`` (so traces are byte-identical
across runs) or a wall clock otherwise.  The tracer never imports
``repro.runtime`` (that package imports us); it accepts any object with a
``now()`` returning microseconds.

Span taxonomy (category / name — see docs/architecture.md):

* ``store`` — ``lookup`` (whole batch), ``gather``, ``admit`` per batch;
* ``pf`` — ``channel`` (modeled background-channel occupancy per prefetch
  submit), ``populate``; instants ``timely`` / ``late`` / ``unused``;
* ``rt`` — ``fetch`` / ``compute`` / ``stall`` lanes of the pipelined
  modeled timeline;
* ``drift`` — instant ``trigger``, span ``refresh``;
* ``model`` — span ``finetune``, instant ``swap``;
* ``shard`` — per-shard ``lookup`` on ``shard-<i>`` tracks.

Every event carries the current batch id (set once per batch via
:meth:`SpanTracer.set_batch`) in ``args["batch"]`` so cross-layer events
correlate.  Export is Chrome trace-event JSON (Perfetto-loadable):
complete events (``ph: "X"``), instants (``ph: "i"``), plus ``ph: "M"``
metadata naming each track.  A bounded ring buffer keeps the last N
batches as a flight recorder for post-mortem dumps.

Near-zero cost when disabled: the module-level tracer defaults to a
:class:`NullTracer` whose ``enabled`` is ``False``; hot paths guard with
``if tr.enabled:`` so the off cost is one attribute check per *batch*
(never per row).
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Any, Dict, List, Optional


class _WallUs:
    """Minimal wall clock in microseconds (used when no deterministic
    clock is supplied)."""

    def now(self) -> float:
        return time.perf_counter() * 1e6


class NullTracer:
    """Disabled tracer: every record method is a no-op.  ``enabled`` is
    False so instrumented code can skip even argument construction."""

    enabled = False

    def set_batch(self, batch_id: int) -> None:  # pragma: no cover - trivial
        pass

    def add_span(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass

    def add_instant(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass


class SpanTracer:
    """Deterministic span recorder with Chrome-trace export and a
    flight-recorder ring of the last ``ring_batches`` batches.

    Spans are recorded with *explicit* timestamps (callers pass the
    begin timestamp they sampled from the clock, or fully modeled
    ``ts``/``dur`` pairs for virtual-timeline lanes), so recording order
    never perturbs the timeline.
    """

    enabled = True

    def __init__(self, clock: Optional[Any] = None,
                 ring_batches: int = 64) -> None:
        self.clock = clock if clock is not None else _WallUs()
        self.events: List[Dict[str, Any]] = []
        self.ring_batches = max(1, int(ring_batches))
        # The in-progress batch occupies one ring slot, so the deque of
        # *completed* batches keeps one fewer.
        self._ring: deque = deque(maxlen=self.ring_batches - 1)
        self._ring_cur: List[Dict[str, Any]] = []
        self.batch_id: int = -1
        self._tids: Dict[str, int] = {}

    # ---------------- recording ----------------

    def set_batch(self, batch_id: int) -> None:
        """Mark the start of a batch; all subsequent events carry this id
        and the flight-recorder ring rolls to a fresh slot."""
        if self._ring_cur:
            self._ring.append(self._ring_cur)
        self._ring_cur = []
        self.batch_id = int(batch_id)

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = len(self._tids)
            self._tids[track] = tid
        return tid

    def _push(self, ev: Dict[str, Any]) -> None:
        self.events.append(ev)
        self._ring_cur.append(ev)

    def add_span(self, cat: str, name: str, ts: float, dur: float,
                 track: str = "main",
                 args: Optional[Dict[str, Any]] = None) -> None:
        """Record a complete span [ts, ts+dur) in microseconds on
        ``track``.  ``dur`` is clamped non-negative."""
        a = dict(args) if args else {}
        a.setdefault("batch", self.batch_id)
        self._push({
            "ph": "X", "cat": cat, "name": name,
            "ts": float(ts), "dur": max(0.0, float(dur)),
            "pid": 0, "tid": self._tid(track), "args": a,
        })

    def add_instant(self, cat: str, name: str, ts: Optional[float] = None,
                    track: str = "main",
                    args: Optional[Dict[str, Any]] = None) -> None:
        a = dict(args) if args else {}
        a.setdefault("batch", self.batch_id)
        self._push({
            "ph": "i", "cat": cat, "name": name,
            "ts": float(ts if ts is not None else self.clock.now()),
            "s": "t", "pid": 0, "tid": self._tid(track), "args": a,
        })

    # ---------------- export ----------------

    def _metadata(self) -> List[Dict[str, Any]]:
        md: List[Dict[str, Any]] = [{
            "ph": "M", "name": "process_name", "pid": 0, "tid": 0,
            "args": {"name": "repro-serve"},
        }]
        for track, tid in sorted(self._tids.items(), key=lambda kv: kv[1]):
            md.append({"ph": "M", "name": "thread_name", "pid": 0,
                       "tid": tid, "args": {"name": track}})
        return md

    def chrome_trace(self) -> Dict[str, Any]:
        """Full trace as a Chrome trace-event JSON object
        (``{"traceEvents": [...]}`` — load at https://ui.perfetto.dev)."""
        return {"traceEvents": self._metadata() + self.events,
                "displayTimeUnit": "ms"}

    def flight_record(self) -> Dict[str, Any]:
        """Chrome-trace JSON of only the last ``ring_batches`` batches —
        the post-mortem dump on failure."""
        evs: List[Dict[str, Any]] = []
        for batch_evs in self._ring:
            evs.extend(batch_evs)
        evs.extend(self._ring_cur)
        return {"traceEvents": self._metadata() + evs,
                "displayTimeUnit": "ms"}

    def write(self, path, flight_only: bool = False) -> None:
        obj = self.flight_record() if flight_only else self.chrome_trace()
        with open(path, "w") as f:
            json.dump(obj, f)

    # ---------------- queries (reconciliation helpers) ----------------

    def spans(self, cat: Optional[str] = None,
              name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["ph"] == "X"
                and (cat is None or e["cat"] == cat)
                and (name is None or e["name"] == name)]

    def instants(self, cat: Optional[str] = None,
                 name: Optional[str] = None) -> List[Dict[str, Any]]:
        return [e for e in self.events if e["ph"] == "i"
                and (cat is None or e["cat"] == cat)
                and (name is None or e["name"] == name)]

    def sum_arg(self, cat: str, name: str, arg: str) -> float:
        """Sum an args field over matching spans — the bridge between the
        trace and the counter snapshot (e.g. sum of ``hit_ids`` over
        ``store.lookup`` spans must equal ``store.fast.hits``)."""
        return sum(e["args"].get(arg, 0) for e in self.spans(cat, name))


# ---------------- module-level tracer ----------------

_NULL = NullTracer()
_tracer: Any = _NULL


def get_tracer() -> Any:
    """The process-wide tracer; a :class:`NullTracer` unless tracing was
    enabled via :func:`install_tracer`."""
    return _tracer


def install_tracer(tracer: Optional[SpanTracer]) -> Any:
    """Install (or, with ``None``, remove) the process-wide tracer.
    Returns the installed object."""
    global _tracer
    _tracer = tracer if tracer is not None else _NULL
    return _tracer


# ---------------- trace validation (CI smoke) ----------------

def validate_chrome_trace(obj: Dict[str, Any]) -> List[str]:
    """Schema + monotonicity check for an exported trace; returns a list
    of problems (empty == valid).

    * top level must be ``{"traceEvents": [...]}``;
    * every event needs ``ph``/``name``/``pid``/``tid``; complete events
      need numeric ``ts`` >= 0 and ``dur`` >= 0;
    * per track, in append order, span *end* timestamps must be
      non-decreasing — true of a well-nested per-batch timeline on a
      monotone (virtual or wall) clock.
    """
    problems: List[str] = []
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        return ["traceEvents missing or not a list"]
    last_end: Dict[int, float] = {}
    for i, e in enumerate(evs):
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        for k in ("name", "pid", "tid"):
            if k not in e:
                problems.append(f"event {i}: missing {k}")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"event {i}: bad ts {ts!r}")
            continue
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"event {i}: bad dur {dur!r}")
                continue
            tid = e.get("tid", 0)
            end = ts + dur
            if end < last_end.get(tid, 0.0) - 1e-6:
                problems.append(
                    f"event {i}: span end {end} regresses on tid {tid} "
                    f"(prev end {last_end[tid]})")
            last_end[tid] = max(last_end.get(tid, 0.0), end)
    return problems
