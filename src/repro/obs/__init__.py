"""Unified observability: typed metrics registry, deterministic span
tracing, and the counter-reconciliation checker.

Import surface is deliberately dependency-free (numpy + stdlib only) so
every layer of the serving stack can import it without cycles.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Reservoir,
    publish_all,
)
from repro.obs.reconcile import (  # noqa: F401
    check_all,
    check_trace_vs_metrics,
    reconcile,
)
from repro.obs.tracing import (  # noqa: F401
    NullTracer,
    SpanTracer,
    get_tracer,
    install_tracer,
    validate_chrome_trace,
)
