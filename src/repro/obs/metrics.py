"""Typed metrics registry: namespaced Counter / Gauge / Histogram with
lossless snapshot / merge semantics.

Every telemetry producer in the serving stack (``TierStats``,
``RuntimeTelemetry``, the sharded facade, the drift detector, the learned
controller) publishes its counters into one :class:`MetricsRegistry`
under a dotted namespace (``store.fast.hits``, ``rt.pf.issued``,
``shard.0.store.lookups``, ``drift.triggers``, ``model.finetunes``), so a
single snapshot carries the whole run's accounting and the
reconciliation checker (:mod:`repro.obs.reconcile`) can assert the
cross-layer identities in one place.

Design constraints:

* **lossless** — counters are exact ints/floats, never rounded; a
  snapshot round-trips through JSON (:meth:`MetricsRegistry.snapshot` /
  :meth:`MetricsRegistry.from_snapshot`) and two snapshots of split runs
  :meth:`merge` into the whole-run snapshot (counters add, gauges take
  the later value, histograms merge their reservoirs);
* **bounded** — histograms never hold more than ``reservoir`` samples
  (deterministic Algorithm-R subsampling past that), so per-request
  latency series cannot grow with run length;
* **cheap** — publishing happens once per run (or per window), not per
  row; the hot path keeps its plain dataclass counters and hands them
  over in one ``publish`` call.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Optional, Union

import numpy as np

# Dotted lowercase namespace; digit-only segments are allowed for
# per-shard / per-table indices (``shard.0.imbalance``).
_NAME_RE = re.compile(r"^[a-z0-9_]+(\.[a-z0-9_-]+)*$")

Number = Union[int, float]


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name):
        raise ValueError(
            f"bad metric name {name!r}: want dotted lowercase segments "
            "like 'store.fast.hits'")
    return name


class Counter:
    """Monotone additive metric (int or float — time accumulators are
    float counters).  ``inc`` only; use a :class:`Gauge` for values that
    move both ways."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: Number = 0):
        self.name = name
        self.value = value

    def inc(self, n: Number = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (by {n})")
        self.value += n


class Gauge:
    """Last-written value (ratios, imbalance, loss)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: Number = 0.0):
        self.name = name
        self.value = value

    def set(self, v: Number) -> None:
        self.value = v


class Reservoir:
    """Bounded uniform sample of a stream (Algorithm R) with exact
    streaming count / sum / min / max.

    Deterministic: the replacement RNG is seeded at construction, so the
    same insertion stream always yields the same sample (golden-testable).
    Below ``cap`` observations the sample is the exact stream, so small
    runs lose nothing.

    List-compatible surface (``append`` / ``extend`` / ``__iter__`` /
    ``__len__`` / ``==``) so it can replace the unbounded
    ``RuntimeTelemetry.latencies_us`` list in place: ``len`` reports the
    *total observed* count (the old list semantics for bounded streams),
    iteration yields the retained sample.
    """

    __slots__ = ("cap", "count", "total", "mn", "mx", "_samples", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0,
                 items: Optional[Iterable[float]] = None):
        if cap < 1:
            raise ValueError("reservoir cap must be >= 1")
        self.cap = int(cap)
        self.count = 0
        self.total = 0.0
        self.mn = float("inf")
        self.mx = float("-inf")
        self._samples: List[float] = []
        self._rng = np.random.Generator(np.random.PCG64(seed))
        if items is not None:
            self.extend(items)

    # ---------------- stream side ----------------

    def append(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.total += x
        self.mn = min(self.mn, x)
        self.mx = max(self.mx, x)
        if len(self._samples) < self.cap:
            self._samples.append(x)
        else:  # Algorithm R: keep with probability cap/count
            j = int(self._rng.integers(0, self.count))
            if j < self.cap:
                self._samples[j] = x

    def extend(self, xs: Iterable[float]) -> None:
        for x in xs:
            self.append(x)

    def merge(self, other: "Reservoir") -> "Reservoir":
        """Combine two reservoirs; exact while the union fits ``cap``,
        a proportional deterministic subsample past that."""
        mine, theirs = self._samples, list(other.samples())
        if len(mine) + len(theirs) > self.cap:
            total = self.count + other.count
            k_mine = min(len(mine),
                         max(0, round(self.cap * self.count / max(total, 1))))
            k_theirs = self.cap - k_mine
            if k_theirs > len(theirs):  # give the slack back
                k_mine = min(len(mine), self.cap - len(theirs))
                k_theirs = min(len(theirs), self.cap - k_mine)
            mine = list(self._rng.choice(
                mine, size=k_mine, replace=False)) if k_mine < len(mine) \
                else mine
            theirs = list(self._rng.choice(
                theirs, size=k_theirs, replace=False)) \
                if k_theirs < len(theirs) else theirs
        self._samples = [float(x) for x in mine] + [float(x) for x in theirs]
        self.count += other.count
        self.total += other.total
        self.mn = min(self.mn, other.mn)
        self.mx = max(self.mx, other.mx)
        return self

    # ---------------- read side ----------------

    def samples(self) -> List[float]:
        return self._samples

    def percentile(self, q: float) -> float:
        if not self._samples:
            return 0.0
        return float(np.percentile(np.asarray(self._samples, np.float64), q))

    @property
    def mean(self) -> float:
        return self.total / max(self.count, 1)

    # ---------------- list-compat surface ----------------

    def __len__(self) -> int:
        return self.count

    def __iter__(self):
        return iter(self._samples)

    def __eq__(self, other) -> bool:
        if isinstance(other, Reservoir):
            return self._samples == other._samples \
                and self.count == other.count
        if isinstance(other, (list, tuple)):
            return self._samples == list(other)
        return NotImplemented

    def __repr__(self):
        return (f"Reservoir(count={self.count}, kept={len(self._samples)}, "
                f"cap={self.cap})")


class Histogram(Reservoir):
    """A named :class:`Reservoir` registered in a
    :class:`MetricsRegistry` (streaming quantile sketch)."""

    __slots__ = ("name",)

    def __init__(self, name: str, cap: int = 4096, seed: int = 0):
        super().__init__(cap=cap, seed=seed)
        self.name = name

    def as_dict(self, with_samples: bool = True) -> Dict:
        d = {
            "count": self.count,
            "sum": self.total,
            "min": self.mn if self.count else 0.0,
            "max": self.mx if self.count else 0.0,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
            "cap": self.cap,
        }
        if with_samples:
            d["samples"] = list(self._samples)
        return d


class MetricsRegistry:
    """Namespaced typed metrics with lossless snapshot / merge.

    ``counter`` / ``gauge`` / ``histogram`` create-or-fetch by name (the
    type must match on re-fetch — one name, one meaning); producers hold
    the returned object and mutate it directly.
    """

    def __init__(self):
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram]] = {}

    def _get(self, name: str, cls, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = cls(_check_name(name), **kw)
            self._metrics[name] = m
        elif type(m) is not cls:
            raise TypeError(f"metric {name!r} is {type(m).__name__}, "
                            f"not {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        return self._get(name, Histogram, cap=cap)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def value(self, name: str, default=None):
        m = self._metrics.get(name)
        if m is None:
            return default
        return m.value if isinstance(m, (Counter, Gauge)) else m

    # ---------------- snapshot / merge ----------------

    def snapshot(self, with_samples: bool = True) -> Dict:
        """JSON-serializable full state; ``from_snapshot`` round-trips it
        (histograms only up to their retained samples when the stream
        exceeded ``cap``)."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.as_dict(with_samples)
        return out

    @classmethod
    def from_snapshot(cls, snap: Dict) -> "MetricsRegistry":
        reg = cls()
        for name, v in snap.get("counters", {}).items():
            reg.counter(name).inc(v)
        for name, v in snap.get("gauges", {}).items():
            reg.gauge(name).set(v)
        for name, h in snap.get("histograms", {}).items():
            hist = reg.histogram(name, cap=int(h.get("cap", 4096)))
            samples = h.get("samples", [])
            hist.extend(samples)
            # Restore the exact streaming aggregates even when the
            # snapshot only retained a subsample.
            hist.count = int(h["count"])
            hist.total = float(h["sum"])
            if hist.count:
                hist.mn = float(h["min"])
                hist.mx = float(h["max"])
        return reg

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Additive merge: counters add, gauges take ``other``'s value,
        histograms merge reservoirs.  Merging the registries of two run
        halves yields the whole run's registry (exact for counters,
        within reservoir tolerance for quantiles)."""
        for name in other.names():
            m = other._metrics[name]
            if isinstance(m, Counter):
                self.counter(name).inc(m.value)
            elif isinstance(m, Gauge):
                self.gauge(name).set(m.value)
            else:
                self.histogram(name, cap=m.cap).merge(m)
        return self

    def as_dict(self) -> Dict[str, Number]:
        """Flat name -> value view (histograms expand to ``.count`` /
        ``.p50`` / ``.p95`` / ``.p99`` sub-keys) — the human-readable /
        bench-row form."""
        flat: Dict[str, Number] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, (Counter, Gauge)):
                flat[name] = m.value
            else:
                for k, v in m.as_dict(with_samples=False).items():
                    if k != "cap":
                        flat[f"{name}.{k}"] = v
        return flat


def publish_all(reg: MetricsRegistry, *producers) -> MetricsRegistry:
    """Publish every non-None producer (anything with a
    ``publish(registry)`` method) into ``reg``."""
    for p in producers:
        if p is not None:
            p.publish(reg)
    return reg
