"""Counter-reconciliation checker: the serving stack's accounting
identities, asserted explicitly.

The serving stack counts every row at several layers — the store counts
tier hits, the prefetch engine counts the fate of every submitted id,
the pipeline splits on-demand fetch time into hidden vs stalled.  Those
counters must *reconcile*: every submitted prefetch id has exactly one
fate, every request either hit or missed the fast tier, no fetch
millisecond is both hidden and stalled.  This module states those
identities once, over a flat metrics mapping (``MetricsRegistry.as_dict``
or a loaded ``--metrics-out`` snapshot), so they run as a CLI
(``scripts/check_accounting.py``), as a test-lane invariant
(``tests/test_observability.py``), and as a debug assert after any run.

Identities (see docs/architecture.md for the derivations):

* **store**:   ``fast.hits + fast.misses == lookups``  (request level),
  ``fast.prefetch_hits <= fast.hits``;
* **prefetch fate**:  ``pf.submitted == pf.suppressed + pf.deduped
  + pf.cancelled_resident + pf.shard_down + pf.issued + pf.queued``
  (queued == still staged at snapshot time; suppressed == dropped under
  backpressure; shard_down == cancelled because the target shard died);
* **prefetch timeliness**:  ``pf.channel_scheduled == pf.timely + pf.late
  + pf.unused + pf.eta_overwritten + pf.eta_pending``  (every id put on
  the modeled channel is eventually demanded timely/late, never demanded,
  rescheduled, or still awaited);
* **pipeline**:  ``stall_ms + hidden_ms == demand_fetch_ms`` with both
  parts non-negative (hidden is defined as the difference, so the
  substantive check is ``0 <= stall <= demand_fetch``);
* **admission**:  ``adm.admitted == adm.served + adm.shed + adm.degraded``
  (every request has exactly one fate), and each ``adm.class.<name>.*``
  sub-namespace both closes the same identity and sums to the totals;
* **sharded**:  aggregate ``store.*`` == sum over ``shard.<i>.store.*``;
* **fault tolerance**:  ``ft.served == ft.primary + ft.failover_replica
  + ft.failover_degraded`` and ``ft.retries == ft.retry_succeeded +
  ft.retry_exhausted``  (every routed row has one answer source, every
  retry episode ends one way — see :func:`check_ft`).

The trace cross-check (:func:`check_trace_vs_metrics`) closes the loop
between the two observability surfaces: per-batch span args summed over
the trace must equal the counter snapshot exactly.
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Mapping, Optional

_EPS = 1e-6


def _get(flat: Mapping[str, Any], name: str, default: float = 0.0) -> float:
    v = flat.get(name, default)
    return float(v) if v is not None else default


def _has_any(flat: Mapping[str, Any], prefix: str) -> bool:
    return any(k == prefix or k.startswith(prefix + ".") for k in flat)


def check_store(flat: Mapping[str, Any], prefix: str = "store") -> List[str]:
    """Request-level tier accounting for one store namespace."""
    if not _has_any(flat, prefix):
        return []
    p: List[str] = []
    lookups = _get(flat, f"{prefix}.lookups")
    hits = _get(flat, f"{prefix}.fast.hits")
    misses = _get(flat, f"{prefix}.fast.misses")
    pf_hits = _get(flat, f"{prefix}.fast.prefetch_hits")
    if abs(hits + misses - lookups) > _EPS:
        p.append(f"{prefix}: fast.hits({hits:g}) + fast.misses({misses:g}) "
                 f"!= lookups({lookups:g})")
    if pf_hits > hits + _EPS:
        p.append(f"{prefix}: fast.prefetch_hits({pf_hits:g}) > "
                 f"fast.hits({hits:g})")
    for k in ("lookups", "batches", "fast.hits", "fast.misses",
              "fast.prefetch_hits", "fast.on_demand_rows", "fast.evictions"):
        if _get(flat, f"{prefix}.{k}") < -_EPS:
            p.append(f"{prefix}.{k} is negative")
    return p


def check_prefetch(flat: Mapping[str, Any], prefix: str = "rt") -> List[str]:
    """Every submitted prefetch id has exactly one fate; every id put on
    the modeled channel is eventually accounted for."""
    if not _has_any(flat, f"{prefix}.pf"):
        return []
    p: List[str] = []
    sub = _get(flat, f"{prefix}.pf.submitted")
    fate = (_get(flat, f"{prefix}.pf.suppressed")
            + _get(flat, f"{prefix}.pf.deduped")
            + _get(flat, f"{prefix}.pf.cancelled_resident")
            + _get(flat, f"{prefix}.pf.shard_down")
            + _get(flat, f"{prefix}.pf.issued")
            + _get(flat, f"{prefix}.pf.queued"))
    if abs(sub - fate) > _EPS:
        p.append(f"{prefix}: pf.submitted({sub:g}) != suppressed + deduped "
                 f"+ cancelled_resident + shard_down + issued + queued "
                 f"({fate:g})")
    sched = _get(flat, f"{prefix}.pf.channel_scheduled")
    acct = (_get(flat, f"{prefix}.pf.timely")
            + _get(flat, f"{prefix}.pf.late")
            + _get(flat, f"{prefix}.pf.unused")
            + _get(flat, f"{prefix}.pf.eta_overwritten")
            + _get(flat, f"{prefix}.pf.eta_pending"))
    if abs(sched - acct) > _EPS:
        p.append(f"{prefix}: pf.channel_scheduled({sched:g}) != timely + "
                 f"late + unused + eta_overwritten + eta_pending ({acct:g})")
    return p


def check_pipeline(flat: Mapping[str, Any], prefix: str = "rt") -> List[str]:
    """No fetch millisecond is both hidden and stalled."""
    if not _has_any(flat, prefix):
        return []
    p: List[str] = []
    demand = _get(flat, f"{prefix}.demand_fetch_ms")
    stall = _get(flat, f"{prefix}.stall_ms")
    hidden = _get(flat, f"{prefix}.hidden_ms", demand - stall)
    if stall < -_EPS:
        p.append(f"{prefix}: stall_ms({stall:g}) negative")
    if stall > demand + _EPS:
        p.append(f"{prefix}: stall_ms({stall:g}) > "
                 f"demand_fetch_ms({demand:g})")
    if abs(stall + hidden - demand) > max(_EPS, 1e-9 * abs(demand)):
        p.append(f"{prefix}: stall_ms({stall:g}) + hidden_ms({hidden:g}) "
                 f"!= demand_fetch_ms({demand:g})")
    return p


def check_admission(flat: Mapping[str, Any],
                    prefix: str = "adm") -> List[str]:
    """Every admitted request has exactly one fate — served in full,
    shed, or answered degraded — and the per-class sub-namespaces must
    sum to the totals (``adm.class.<name>.* -> adm.*``)."""
    if not _has_any(flat, prefix):
        return []
    p: List[str] = []
    fates = ("admitted", "served", "shed", "degraded")
    adm, srv, shd, deg = (_get(flat, f"{prefix}.{f}") for f in fates)
    if abs(adm - (srv + shd + deg)) > _EPS:
        p.append(f"{prefix}: admitted({adm:g}) != served({srv:g}) + "
                 f"shed({shd:g}) + degraded({deg:g})")
    for f in fates + ("degraded_rows_stale", "degraded_rows_default"):
        if _get(flat, f"{prefix}.{f}") < -_EPS:
            p.append(f"{prefix}.{f} is negative")
    cls_re = re.compile(rf"^{re.escape(prefix)}\.class\.([^.]+)\.")
    classes = sorted({m.group(1) for k in flat if (m := cls_re.match(k))})
    for f in fates:
        total = _get(flat, f"{prefix}.{f}")
        by_class = sum(_get(flat, f"{prefix}.class.{c}.{f}")
                       for c in classes)
        if classes and abs(total - by_class) > _EPS:
            p.append(f"{prefix}: {f}({total:g}) != sum over classes "
                     f"({by_class:g})")
    for c in classes:
        ca = _get(flat, f"{prefix}.class.{c}.admitted")
        cf = sum(_get(flat, f"{prefix}.class.{c}.{f}")
                 for f in ("served", "shed", "degraded"))
        if abs(ca - cf) > _EPS:
            p.append(f"{prefix}.class.{c}: admitted({ca:g}) != "
                     f"served + shed + degraded ({cf:g})")
    return p


def check_ft(flat: Mapping[str, Any], prefix: str = "ft") -> List[str]:
    """Fault-tolerance accounting: every row routed while the fault layer
    is armed has exactly one answer source, and every retry episode ends
    exactly one way.

    * ``ft.served == ft.primary + ft.failover_replica +
      ft.failover_degraded``;
    * ``ft.retries == ft.retry_succeeded + ft.retry_exhausted``;
    * ``ft.degraded_default <= ft.failover_degraded`` (the zero-default
      rows are a subset of the degraded answers);
    * ``ft.recoveries <= ft.kills`` (a shard can only recover after a
      kill) and ``ft.recovery_bytes <= ft.recovery_bytes_raw`` (int8
      transfer never inflates the payload).
    """
    if not _has_any(flat, prefix):
        return []
    p: List[str] = []
    served = _get(flat, f"{prefix}.served")
    src = (_get(flat, f"{prefix}.primary")
           + _get(flat, f"{prefix}.failover_replica")
           + _get(flat, f"{prefix}.failover_degraded"))
    if abs(served - src) > _EPS:
        p.append(f"{prefix}: served({served:g}) != primary + "
                 f"failover_replica + failover_degraded ({src:g})")
    retries = _get(flat, f"{prefix}.retries")
    ended = (_get(flat, f"{prefix}.retry_succeeded")
             + _get(flat, f"{prefix}.retry_exhausted"))
    if abs(retries - ended) > _EPS:
        p.append(f"{prefix}: retries({retries:g}) != retry_succeeded + "
                 f"retry_exhausted ({ended:g})")
    dd = _get(flat, f"{prefix}.degraded_default")
    deg = _get(flat, f"{prefix}.failover_degraded")
    if dd > deg + _EPS:
        p.append(f"{prefix}: degraded_default({dd:g}) > "
                 f"failover_degraded({deg:g})")
    kills = _get(flat, f"{prefix}.kills")
    recov = _get(flat, f"{prefix}.recoveries")
    if recov > kills + _EPS:
        p.append(f"{prefix}: recoveries({recov:g}) > kills({kills:g})")
    rb = _get(flat, f"{prefix}.recovery_bytes")
    rbr = _get(flat, f"{prefix}.recovery_bytes_raw")
    if rb > rbr + _EPS:
        p.append(f"{prefix}: recovery_bytes({rb:g}) > "
                 f"recovery_bytes_raw({rbr:g})")
    for k in ("served", "primary", "failover_replica", "failover_degraded",
              "retries", "retry_succeeded", "retry_exhausted", "kills",
              "recoveries", "recovery_rows", "recovery_chunks",
              "recovery_bytes", "recovery_bytes_raw", "staged_dropped"):
        if _get(flat, f"{prefix}.{k}") < -_EPS:
            p.append(f"{prefix}.{k} is negative")
    return p


_SHARD_RE = re.compile(r"^shard\.(\d+)\.")


def check_sharded(flat: Mapping[str, Any]) -> List[str]:
    """Aggregate counters must equal the sum over per-shard namespaces
    (and each shard namespace must itself reconcile)."""
    shards = sorted({int(m.group(1)) for k in flat
                     if (m := _SHARD_RE.match(k))})
    if not shards:
        return []
    p: List[str] = []
    for c in ("lookups", "fast.hits", "fast.misses", "fast.prefetch_hits",
              "fast.on_demand_rows", "fast.evictions"):
        agg = _get(flat, f"store.{c}")
        total = sum(_get(flat, f"shard.{s}.store.{c}") for s in shards)
        if abs(agg - total) > _EPS:
            p.append(f"sharded: store.{c}({agg:g}) != sum of shards "
                     f"({total:g})")
    for s in shards:
        p += check_store(flat, prefix=f"shard.{s}.store")
        p += check_prefetch(flat, prefix=f"shard.{s}.rt")
    return p


def check_all(flat: Mapping[str, Any]) -> List[str]:
    """All identities over one flat metrics mapping; empty == reconciled."""
    return (check_store(flat) + check_prefetch(flat)
            + check_pipeline(flat) + check_admission(flat)
            + check_sharded(flat) + check_ft(flat))


# ---------------- trace <-> metrics cross-check ----------------

def _span_sums(events, cat: str, name: str, arg: str) -> float:
    return sum(e.get("args", {}).get(arg, 0) for e in events
               if e.get("ph") == "X" and e.get("cat") == cat
               and e.get("name") == name)


def check_trace_vs_metrics(trace: Dict[str, Any],
                           flat: Mapping[str, Any],
                           store_prefix: str = "store") -> List[str]:
    """Spans must reconcile *exactly* with the counter snapshot: per-batch
    ``store.lookup`` span args summed over the trace equal the store
    counters.  ``trace`` is a Chrome trace object (``{"traceEvents":
    [...]}``)."""
    evs = trace.get("traceEvents", [])
    lookup_spans = [e for e in evs if e.get("ph") == "X"
                    and e.get("cat") == "store"
                    and e.get("name") == "lookup"]
    if not lookup_spans or not _has_any(flat, store_prefix):
        return []  # nothing traced on this surface — vacuous
    p: List[str] = []
    pairs = [
        ("ids", f"{store_prefix}.lookups"),
        ("hit_ids", f"{store_prefix}.fast.hits"),
        ("miss_ids", f"{store_prefix}.fast.misses"),
        ("miss_rows", f"{store_prefix}.fast.on_demand_rows"),
    ]
    for arg, metric in pairs:
        got = _span_sums(evs, "store", "lookup", arg)
        want = _get(flat, metric)
        if abs(got - want) > _EPS:
            p.append(f"trace: sum({arg} over store.lookup spans)={got:g} "
                     f"!= {metric}={want:g}")
    # Evictions happen on both the demand path (lookup spans) and the
    # prefetch/populate path (populate spans); together they cover every
    # _evict_slots call.
    got_ev = (_span_sums(evs, "store", "lookup", "evictions")
              + _span_sums(evs, "store", "populate", "evictions"))
    want_ev = _get(flat, f"{store_prefix}.fast.evictions")
    if abs(got_ev - want_ev) > _EPS:
        p.append(f"trace: evictions over lookup+populate spans={got_ev:g} "
                 f"!= {store_prefix}.fast.evictions={want_ev:g}")
    if not _has_any(flat, "shard") and not _has_any(flat, "table"):
        # Sharded / multi-table runs emit one store.lookup span per
        # touched *shard* (resp. *table*) while the facade counts one
        # batch, so the span-count identity only holds for single-store
        # surfaces.
        n = len(lookup_spans)
        batches = _get(flat, f"{store_prefix}.batches")
        if abs(n - batches) > _EPS:
            p.append(f"trace: {n} store.lookup spans != "
                     f"{store_prefix}.batches={batches:g}")
    return p


def reconcile(metrics: Optional[Mapping[str, Any]] = None,
              trace: Optional[Dict[str, Any]] = None,
              strict: bool = True) -> List[str]:
    """Run every applicable identity; with ``strict`` raise
    ``AssertionError`` listing the violations, else return them."""
    problems: List[str] = []
    if metrics is not None:
        flat = dict(metrics)
        if "counters" in flat or "gauges" in flat:  # registry snapshot form
            from repro.obs.metrics import MetricsRegistry
            flat = MetricsRegistry.from_snapshot(metrics).as_dict()
        problems += check_all(flat)
        if trace is not None:
            problems += check_trace_vs_metrics(trace, flat)
    if problems and strict:
        raise AssertionError(
            "accounting identities violated:\n  " + "\n  ".join(problems))
    return problems
