"""AdamW / SGD + global-norm clipping + schedules, in pure JAX.

Moments live in a configurable dtype (fp32 default; bf16 is a memory knob the
perf loop can flip).  The update math runs in fp32 and casts back to the
parameter dtype, so bf16 params train stably without a separate master copy
(documented trade-off; flip ``master_fp32=True`` to keep one).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"
    master_fp32: bool = False


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_opt(cfg: OptConfig, params):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dt), params)
    state = {
        "m": zeros,
        "v": jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, dt), params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def apply_updates(cfg: OptConfig, params, opt_state, grads):
    """One AdamW step.  Returns (params, opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    mdt = jnp.dtype(cfg.moment_dtype)

    bc1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    base = opt_state.get("master", params)

    def upd(p, m, v, g):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        p32 = p.astype(jnp.float32)
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p32
        p_new = p32 - lr * step
        return p_new, m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(base)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_g = treedef.flatten_up_to(grads)
    new = [upd(p, m, v, g) for p, m, v, g in zip(flat_p, flat_m, flat_v, flat_g)]
    new_p32 = treedef.unflatten([t[0] for t in new])
    new_m = treedef.unflatten([t[1] for t in new])
    new_v = treedef.unflatten([t[2] for t in new])

    tgt = jax.tree_util.tree_map(lambda old, n: n.astype(old.dtype), params,
                                 new_p32)
    new_state = {"m": new_m, "v": new_v, "count": count}
    if "master" in opt_state:
        new_state["master"] = new_p32
    return tgt, new_state, {"grad_norm": gnorm, "lr": lr}
