"""Production train launcher: config-driven, fault-tolerant, resumable.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --reduced --seq-len 512 --batch 8 --ckpt runs/quickstart

Single-host CPU runs use the elastic host mesh; on real pods the same code
runs under ``jax.distributed.initialize`` with ``make_production_mesh``.
Features: deterministic resumable data, atomic async checkpoints, retry on
transient step failures, straggler monitoring, optional int8+EF gradient
compression.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax

from repro.checkpoint import checkpoint as ckpt
from repro.configs import RunConfig, get_config
from repro.data.lm_data import LMDataConfig, batch_at
from repro.distributed.fault_tolerance import (ElasticMesh, Heartbeat,
                                               StragglerMonitor, retry_step)
from repro.launch.steps import make_train_step, opt_struct_and_specs
from repro.models.model_api import build
from repro.optim.adamw import OptConfig, init_opt
from repro.sharding.partition import (
    activation_sharding, param_pspecs, to_shardings)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--grad-compression", default="",
                    choices=["", "int8_ef"])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    run = RunConfig(remat=args.remat, microbatches=args.microbatches,
                    grad_compression=args.grad_compression)
    mesh = ElasticMesh(args.model_parallel).make()
    print(f"mesh: {dict(mesh.shape)} devices={mesh.devices.size}")

    bundle = build(cfg, run)
    opt_cfg = OptConfig(lr=args.lr, total_steps=args.steps)
    data_cfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                            global_batch=args.batch)

    pspecs = param_pspecs(bundle.param_struct(), mesh, run.sharding)
    param_sh = to_shardings(pspecs, mesh)
    _, opt_pspecs = opt_struct_and_specs(bundle, pspecs, opt_cfg)
    opt_sh = to_shardings(opt_pspecs, mesh)

    # Init or restore.
    start = 0
    params = jax.jit(bundle.init, out_shardings=param_sh)(
        jax.random.PRNGKey(0)
    )
    opt_state = jax.jit(lambda p: init_opt(opt_cfg, p),
                        out_shardings=opt_sh)(params)
    if args.ckpt and ckpt.latest_step(args.ckpt) is not None:
        (params, opt_state), start = ckpt.restore(
            args.ckpt, (params, opt_state),
            shardings=(param_sh, opt_sh),
        )
        print(f"restored step {start} from {args.ckpt}")

    with mesh, activation_sharding(mesh):
        step_fn = make_train_step(bundle, opt_cfg, args.microbatches, mesh)
        if args.grad_compression == "int8_ef":
            from repro.distributed.compression import (init_error,
                                                       make_compressed_dp_grads)
            from repro.optim.adamw import apply_updates

            grads_fn = make_compressed_dp_grads(bundle.loss, mesh)
            err = init_error(params)

            def step_fn_c(params, opt_state, err, batch):
                loss, grads, err = grads_fn(params, err, batch)
                params, opt_state, m = apply_updates(opt_cfg, params,
                                                     opt_state, grads)
                m["loss"] = loss
                return params, opt_state, err, m

            jstep_c = jax.jit(step_fn_c, donate_argnums=(0, 1, 2))
        else:
            jstep = jax.jit(
                step_fn,
                in_shardings=(param_sh, opt_sh, None),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )

        mon = StragglerMonitor()
        hb = Heartbeat(Path(args.ckpt or "runs") / "heartbeat.json") \
            if args.ckpt else None
        losses = []
        for step in range(start, args.steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in batch_at(data_cfg, step).items()}
            t0 = time.perf_counter()
            if args.grad_compression == "int8_ef":
                params, opt_state, err, m = retry_step(
                    jstep_c, params, opt_state, err, batch
                )
            else:
                params, opt_state, m = retry_step(jstep, params, opt_state,
                                                  batch)
            loss = float(m["loss"])
            dt = time.perf_counter() - t0
            slow = mon.record(step, dt)
            losses.append(loss)
            if hb:
                hb.beat(step, loss=loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms{' STRAGGLER' if slow else ''})",
                      flush=True)
            if args.ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save_async(args.ckpt, step + 1, (params, opt_state))
        if args.ckpt:
            ckpt.wait_pending(args.ckpt)
            ckpt.save(args.ckpt, args.steps, (params, opt_state))
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
              f"steps/s {1.0/max(mon.mean,1e-9):.2f}; {mon.summary()}")
        return losses


if __name__ == "__main__":
    main()
