"""Roofline analysis from dry-run artifacts (§Roofline of the assignment).

Hardware model (TPU v5e per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  All quantities below are PER-DEVICE (the compiled HLO is
the per-device SPMD program), so

    compute term    = HLO_dot_FLOPs_dev / peak_chip
    memory term     = HLO_bytes_dev / hbm_bw
    collective term = collective_bytes_dev / ici_bw

are step-time lower bounds in seconds; the max is the roofline-bound step
time and its argmax is the bottleneck.  HLO FLOPs/bytes are the
trip-count-scaled counters from launch/hlo_analysis (jax's cost_analysis
counts while bodies once — both are recorded; see EXPERIMENTS.md §Dry-run).

MODEL_FLOPS = 6·N·D for training (N = active params for MoE), 2·N·D for
prefill, 2·N·B for decode; useful_ratio = MODEL_FLOPS / (HLO_FLOPs·chips)
exposes remat/replication waste.  roofline_fraction =
(MODEL_FLOPS/(chips·peak)) / max(term) — the score this repo hill-climbs.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s per chip
ICI_BW = 50e9  # B/s per link


_N_ACTIVE_CACHE: Dict[str, int] = {}


def _n_active(arch: str) -> int:
    if arch not in _N_ACTIVE_CACHE:
        from repro.configs import get_config
        from repro.models.model_api import build

        _N_ACTIVE_CACHE[arch] = build(get_config(arch)).n_active_params()
    return _N_ACTIVE_CACHE[arch]


def model_flops(cell: Dict) -> float:
    """Global useful FLOPs per step from the analytic 6ND / 2ND rule."""
    # Always recompute from the config (early sweep artifacts carry an
    # int32-overflowed count for >2B-param archs); cached per arch.
    n_act = _n_active(cell["arch"])
    kind = cell["kind"]
    shape = cell["shape"]
    if cell["arch"] == "dlrm-recmg":
        # Embedding tables are sparsely touched: useful dense compute is the
        # MLPs + pairwise interaction per query, not 2·N_emb.
        from repro.configs import get_config

        cfg = get_config("dlrm-recmg")
        batch = {"infer_6k": 6144, "infer_18k": 18432, "train_6k": 6144}[shape]
        f = cfg.n_tables + 1
        bot = sum(a * b for a, b in zip(
            (cfg.dense_features,) + tuple(cfg.bottom_mlp[:-1]), cfg.bottom_mlp))
        top_in = cfg.emb_dim + f * (f - 1) // 2
        top = sum(a * b for a, b in zip(
            (top_in,) + tuple(cfg.top_mlp[:-1]), cfg.top_mlp))
        inter = f * f * cfg.emb_dim
        pool = cfg.n_tables * cfg.multi_hot * cfg.emb_dim
        per_q = 2 * (bot + top) + 2 * inter + 2 * pool
        mult = 3 if kind == "train" else 1
        return mult * per_q * batch
    seq = {"train_4k": 4096, "prefill_32k": 32768, "decode_32k": 1,
           "long_500k": 1}[shape]
    batch = {"train_4k": 256, "prefill_32k": 32, "decode_32k": 128,
             "long_500k": 1}[shape]
    per = 6 if kind == "train" else 2
    return per * n_act * seq * batch


def model_bytes(cell: Dict) -> float:
    """Minimum global HBM traffic per step — the useful-work yardstick for
    memory-bound (decode) cells: read all live params once + the KV/state
    cache once + write the new cache entries (negligible)."""
    p_bytes = cell.get("param_bytes_per_device", 0) * cell.get("devices", 1)
    shape = cell["shape"]
    if cell["kind"] != "decode":
        return float(p_bytes)
    cache = {"decode_32k": 128 * 32768, "long_500k": 1 * 524288}.get(shape, 0)
    # Cache bytes estimated from the dry-run argument sizes (cache dominates
    # decode arguments): use argument bytes as the live-state proxy.
    ma = cell.get("memory_analysis", {})
    arg_bytes = ma.get("argument_size_in_bytes", 0) * cell.get("devices", 1)
    return float(max(p_bytes, arg_bytes))


def roofline_row(cell: Dict) -> Optional[Dict]:
    if cell.get("status") != "ok":
        return None
    coll = cell.get("collectives", {})
    ca = cell.get("cost_analysis", {})
    flops_dev = coll.get("hlo_dot_flops") or ca.get("flops", 0.0)
    coll_dev = coll.get("collective_bytes", 0.0)
    chips = cell.get("devices", 256)

    # Bytes: jax's cost_analysis counts loop bodies once; our parsed counter
    # trip-scales but uses unfused per-op accounting (upper bound — the CPU
    # backend fuses far less than TPU will).  Scale the XLA figure by the
    # flops trip ratio: same loop structure, fused-op accounting.
    ca_flops = ca.get("flops", 0.0)
    ca_bytes = ca.get("bytes accessed", 0.0)
    trip_ratio = flops_dev / max(ca_flops, 1.0)
    bytes_dev = ca_bytes * max(trip_ratio, 1.0)
    bytes_upper = coll.get("hlo_bytes_accessed", bytes_dev)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cell)
    useful_ratio = mf / max(flops_dev * chips, 1.0)
    bound_t = max(terms.values())
    if cell["kind"] == "decode":
        # Decode is memory-bound by construction: useful work = streaming
        # params+cache once through HBM.
        ideal_t = model_bytes(cell) / (chips * HBM_BW)
    else:
        ideal_t = mf / (chips * PEAK_FLOPS)
    frac = ideal_t / max(bound_t, 1e-30)

    ma = cell.get("memory_analysis", {})
    hbm_gb = (ma.get("argument_size_in_bytes", 0)
              + ma.get("temp_size_in_bytes", 0)
              + ma.get("output_size_in_bytes", 0)
              - ma.get("alias_size_in_bytes", 0)) / 1e9

    return {
        "arch": cell["arch"], "shape": cell["shape"], "mesh": cell["mesh"],
        "kind": cell["kind"],
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dominant, "model_flops": mf,
        "hlo_flops_dev": flops_dev, "useful_ratio": useful_ratio,
        "roofline_fraction": frac, "bound_step_s": bound_t,
        "bytes_dev_upper": bytes_upper,
        "hbm_gb_per_dev": hbm_gb,
        "cost_analysis_flops_dev": ca.get("flops", 0.0),
        "microbatches": cell.get("microbatches"),
    }


def load_rows(tag_dir: Path, mesh: Optional[str] = None) -> List[Dict]:
    rows = []
    for f in sorted(tag_dir.glob("*.json")):
        cell = json.loads(f.read_text())
        if mesh and cell.get("mesh") != mesh:
            continue
        r = roofline_row(cell)
        if r:
            rows.append(r)
    return rows


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def to_markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute | memory | collective | bound | "
           "useful | roofline-frac | HBM/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {r['useful_ratio']*100:.1f}% "
            f"| {r['roofline_fraction']*100:.1f}% "
            f"| {r['hbm_gb_per_dev']:.2f}GB |\n"
        )
    return "".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="runs/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="16x16",
                    help="roofline table is single-pod per assignment")
    ap.add_argument("--json-out", default="")
    args = ap.parse_args(argv)

    rows = load_rows(Path(args.dir) / args.tag, args.mesh or None)
    rows.sort(key=lambda r: r["roofline_fraction"])
    print(to_markdown(rows))
    worst = rows[:3]
    coll_bound = [r for r in rows if r["dominant"] == "collective"]
    print(f"\ncells: {len(rows)}; worst roofline fraction: "
          + ", ".join(f"{r['arch']}/{r['shape']}"
                      f"={r['roofline_fraction']*100:.1f}%" for r in worst))
    if coll_bound:
        print("collective-bound: "
              + ", ".join(f"{r['arch']}/{r['shape']}" for r in coll_bound))
    if args.json_out:
        Path(args.json_out).write_text(json.dumps(rows, indent=2))
    return rows


if __name__ == "__main__":
    main()
