"""Static analysis of compiled HLO text.

``cost_analysis()`` gives FLOPs and bytes; collective traffic is NOT in it,
so we parse the HLO text and sum result bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute.

Collectives inside `while` bodies (lax.scan over layers / microbatches /
KV blocks) execute trip-count times but appear once in the text.  We parse
each while's condition computation (`compare(iv, constant), direction=LT`)
to recover trip counts and scale nested computations by the product of
enclosing trips.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f32": 4, "s32": 4, "u32": 4,
    "f64": 8, "s64": 8, "u64": 8,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-_]+)\s+\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(r"\bwhile\(.*?\),\s*condition=%?([\w.\-_]+),\s*body=%?([\w.\-_]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CONST_RE = re.compile(r"%?([\w.\-_]+)\s*=\s*[su]\d+\[\]\s*constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\(([^)]*)\),?.*direction=(LT|LE|GT|GE|NE)")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = _BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def parse_computations(hlo_text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
            if line.lstrip().startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    comps["__entry__"] = [entry or ""]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = {}
    for ln in cond_lines:
        for name, val in _CONST_RE.findall(ln):
            consts[name] = int(val)
    for ln in cond_lines:
        m = _CMP_RE.search(ln)
        if m:
            args = [a.strip().lstrip("%") for a in m.group(1).split(",")]
            # Strip "name" out of "type[..] %name" operand syntax.
            names = [a.split()[-1].lstrip("%") for a in args]
            for nm in names:
                if nm in consts:
                    return max(1, consts[nm])
    # Unknown trip count: count once (conservative).
    return 1


def computation_multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """mult[c] = how many times computation c executes per program run."""
    entry = comps.get("__entry__", [""])[0]
    # while-call edges: parent -> [(body, trip)]
    edges: Dict[str, List[Tuple[str, int]]] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for ln in lines:
            w = _WHILE_RE.search(ln)
            if w:
                cond, body = w.group(1), w.group(2)
                # XLA annotates known trip counts in backend_config; prefer
                # that, fall back to parsing the condition computation.
                tm = _TRIP_RE.search(ln)
                trip = int(tm.group(1)) if tm else _trip_count(comps.get(cond, []))
                edges.setdefault(name, []).append((body, trip))

    mult: Dict[str, float] = {c: 0.0 for c in comps if c != "__entry__"}
    if entry in mult:
        mult[entry] = 1.0

    # Propagate through the (acyclic) while-nesting DAG.
    changed = True
    iters = 0
    while changed and iters < 64:
        changed = False
        iters += 1
        for parent, kids in edges.items():
            pm = mult.get(parent, 0.0)
            for body, trip in kids:
                new = pm * trip
                if new > mult.get(body, 0.0):
                    mult[body] = new
                    changed = True
    # Computations never reached via while edges (fusions, entry) run once
    # per reference; we only need while bodies scaled, so default to 1.
    for c in mult:
        if mult[c] == 0.0:
            mult[c] = 1.0
    return mult


def _result_bytes(line: str, op: str) -> int:
    eq = line.find("=")
    cut = line.find(op, eq)
    if eq < 0 or cut < 0:
        return 0
    seg = line[eq:cut]
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(seg))


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Trip-scaled collective result bytes per device program.

    all-reduce counts 2x (ring = reduce-scatter + all-gather phases); other
    collectives count their result bytes once.
    """
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)

    stats = {op: 0.0 for op in _COLLECTIVES}
    counts = {op: 0 for op in _COLLECTIVES}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        scale = mult.get(name, 1.0)
        for ln in lines:
            if "=" not in ln:
                continue
            for op in _COLLECTIVES:
                if f" {op}(" in ln or f"{op}-start(" in ln:
                    b = _result_bytes(ln, op)
                    factor = 2.0 if op == "all-reduce" else 1.0
                    stats[op] += b * factor * scale
                    counts[op] += 1
                    break
    out = {f"bytes_{k}": v for k, v in stats.items()}
    out.update({f"count_{k}": float(counts[k]) for k in counts})
    out["collective_bytes"] = sum(stats.values())
    return out


def total_while_flops_scale(hlo_text: str) -> float:
    """Max loop-nesting multiplier — used to sanity-check cost_analysis
    undercounting of while bodies."""
    comps = parse_computations(hlo_text)
    return max(computation_multipliers(comps).values())


# ---------------------------------------------------------------------------
# Trip-scaled FLOP / byte counters.
#
# jax's ``compiled.cost_analysis()`` visits every computation exactly once, so
# anything under a ``lax.scan`` (layers, microbatches, KV blocks) is
# undercounted by its trip count.  We re-derive both quantities from the HLO
# text with the while-nesting multipliers applied.
# ---------------------------------------------------------------------------

_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-_]+)\s*=\s*")
_OP_RE = re.compile(r"=\s*(?:\([^=]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*([a-z][\w\-]*)\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-done",
    "all-gather-done", "all-reduce-done",
}


def _line_shapes(line: str):
    return _SHAPE_RE.findall(line)


def _build_shape_map(lines) -> Dict[str, Tuple[str, Tuple[int, ...]]]:
    """name -> (dtype, dims) from definition lines of one computation."""
    out = {}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        eq = ln.find("=")
        shapes = _SHAPE_RE.findall(ln[eq:])
        if shapes:
            dt, dims = shapes[0]
            out[m.group(1)] = (
                dt, tuple(int(d) for d in dims.split(",") if d)
            )
    return out


def _fusion_called(comps) -> set:
    called = set()
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        for ln in lines:
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-_]+)", ln):
                called.add(m.group(1))
    return called


def hlo_dot_flops(hlo_text: str) -> float:
    """2*M*N*K FLOPs of every dot, scaled by enclosing while trip counts."""
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    total = 0.0
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        scale = mult.get(name, 1.0)
        shape_map = None
        for ln in lines:
            if " dot(" not in ln:
                continue
            eq = ln.find("=")
            cut = ln.find(" dot(", eq)
            if eq < 0 or cut < 0:
                continue
            res = _SHAPE_RE.findall(ln[eq:cut])
            if not res:
                continue
            out_elems = 1
            for d in res[0][1].split(","):
                if d:
                    out_elems *= int(d)
            cm = _CONTRACT_RE.search(ln)
            cdims = [int(x) for x in cm.group(1).split(",") if x] if cm else []
            # Resolve the lhs operand shape.  Operands may be typed
            # ("f32[64,64]{1,0} %name") — the shape's own commas break a
            # naive split, so match shape-then-name and prefer the inline
            # shape over the definition map.
            oper = ln[cut + len(" dot("):]
            m_op = re.match(
                r"\s*(?:([a-z][a-z0-9]*)\[([0-9,]*)\](?:\{[^}]*\})?\s*)?"
                r"%?([\w.\-_]+)", oper)
            dims = None
            if m_op:
                if m_op.group(2) is not None:
                    dims = tuple(int(d) for d in m_op.group(2).split(",")
                                 if d)
                else:
                    if shape_map is None:
                        shape_map = _build_shape_map(lines)
                    if m_op.group(3) in shape_map:
                        dims = shape_map[m_op.group(3)][1]
            k_elems = 1
            if dims:
                for c in cdims:
                    if c < len(dims):
                        k_elems *= dims[c]
            total += 2.0 * out_elems * k_elems * scale
    return total


def hlo_bytes_accessed(hlo_text: str) -> float:
    """Result+operand bytes of every materializing op, trip-scaled.

    Fusion bodies are excluded (their internals never hit HBM); the fusion op
    itself counts its operands and result.  This approximates HBM traffic the
    way XLA's own bytes-accessed metric does, but with loop trip counts.
    """
    comps = parse_computations(hlo_text)
    mult = computation_multipliers(comps)
    fused = _fusion_called(comps)
    total = 0.0
    for name, lines in comps.items():
        if name == "__entry__" or name in fused:
            continue
        scale = mult.get(name, 1.0)
        shape_map = _build_shape_map(lines)
        for ln in lines:
            m = _OP_RE.search(ln)
            if not m:
                continue
            op = m.group(1)
            if op in _NO_TRAFFIC:
                continue
            eq = ln.find("=")
            cut = ln.find(f" {op}(", eq)
            if cut < 0:
                continue
            res_bytes = sum(
                _shape_bytes(d, s) for d, s in _SHAPE_RE.findall(ln[eq:cut])
            )
            # Operand bytes: resolve %names in the operand list.
            oper_seg = ln[cut + len(op) + 2:]
            depth, end = 1, 0
            for i, ch in enumerate(oper_seg):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            opnd_bytes = 0
            for nm in re.findall(r"%([\w.\-_]+)", oper_seg[:end]):
                if nm in shape_map:
                    dt, dims = shape_map[nm]
                    b = _BYTES.get(dt, 0)
                    for d in dims:
                        b *= d
                    opnd_bytes += b
            total += (res_bytes + opnd_bytes) * scale
    return total


def analyze(hlo_text: str) -> Dict[str, float]:
    out = collective_stats(hlo_text)
    out["hlo_dot_flops"] = hlo_dot_flops(hlo_text)
    out["hlo_bytes_accessed"] = hlo_bytes_accessed(hlo_text)
    return out
