"""DLRM serving launcher on tiered memory — the paper's deployment.

    PYTHONPATH=src python -m repro.launch.serve --policy recmg --batches 50

Pipeline per inference batch (paper Fig. 6):
  1. embedding lookups go through the TieredEmbeddingStore (device buffer
     backed by host-tier tables);
  2. the DLRM dense compute runs jitted on the device;
  3. between batches, the CPU-side caching/prefetch model outputs for the
     *previous* chunk are applied (Algorithm 1), pipelined one batch ahead.

Prints the Fig.16-style latency breakdown and hit rates per policy.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.recmg import RecMGOutputs, precompute_outputs
from repro.core.serving import MultiTableTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.core.trace import Trace, TraceGenConfig, generate_trace
from repro.models.dlrm import dlrm_forward, init_dlrm


def serve_trace(cfg, params, trace: Trace, capacity: int, policy: str,
                outputs: Optional[RecMGOutputs], batch_queries: int = 64,
                fetch_us_per_row: float = 10.0, multi_table: bool = False,
                log=None) -> Dict:
    """Replay a trace as DLRM inference batches through the tiered store.

    ``multi_table=True`` serves through the per-table facade (one batched
    store per sparse feature under the shared row budget) instead of one
    monolithic store."""
    T, P = cfg.n_tables, cfg.multi_hot
    per_batch = batch_queries * T * P
    host_rows = int(trace.rows_per_table.sum())
    host = np.random.default_rng(0).normal(
        size=(host_rows, cfg.emb_dim)).astype(np.float32)
    pol = "recmg" if policy == "recmg" else "lru"
    if multi_table:
        store = MultiTableTieredStore.from_global_table(
            host, trace.rows_per_table, capacity=capacity, policy=pol,
            fetch_us_per_row=fetch_us_per_row)
    else:
        store = TieredEmbeddingStore(
            host, capacity, policy=pol, fetch_us_per_row=fetch_us_per_row)
    fwd = jax.jit(lambda pr, d, e: _dense_forward(pr, cfg, d, e))

    gid = trace.global_id
    rng = np.random.default_rng(1)
    n_batches = len(gid) // per_batch
    chunk_ptr = 0
    compute_s = 0.0
    lat = []
    for b in range(n_batches):
        ids = gid[b * per_batch : (b + 1) * per_batch]
        t0 = time.perf_counter()
        emb = store.lookup(ids)  # (per_batch, D)
        emb = emb.reshape(batch_queries, T, P, cfg.emb_dim).sum(axis=2)
        dense = jnp.asarray(
            rng.normal(size=(batch_queries, cfg.dense_features)).astype(np.float32)
        )
        t1 = time.perf_counter()
        out = fwd(params, dense, emb)
        jax.block_until_ready(out)
        t2 = time.perf_counter()
        compute_s += t2 - t1
        lat.append(t2 - t0)

        # Stage pipelined model outputs for the chunks covered by this
        # batch: caching priorities for every covered chunk, but prefetches
        # only from the most recent one — the paper issues ONE prefetch set
        # per inference batch (Fig. 6); flooding every chunk's PO would
        # churn the buffer.  ``stage_model_outputs`` double-buffers: the
        # outputs land at the next batch boundary without blocking lookup.
        if outputs is not None:
            hi = (b + 1) * per_batch
            last_pf = None
            empty = np.empty(0, np.int64)
            while (chunk_ptr < len(outputs.chunk_starts)
                   and outputs.chunk_starts[chunk_ptr] < hi):
                s = int(outputs.chunk_starts[chunk_ptr])
                trunk = gid[max(0, s - 15): s]
                bits = (outputs.caching_bits[chunk_ptr]
                        if outputs.caching_bits is not None
                        else np.zeros(len(trunk)))
                store.stage_model_outputs(trunk, bits, empty)
                if outputs.prefetch_ids is not None:
                    last_pf = outputs.prefetch_ids[chunk_ptr]
                chunk_ptr += 1
            if last_pf is not None:
                store.stage_model_outputs(empty, empty, last_pf)
            # Flush in the inter-batch gap (outside the timed window) so
            # measured batch latency matches the seed's accounting; in a
            # real deployment this overlaps the next batch's host work.
            store.flush_staged()
        if log and b % 10 == 0:
            log(f"batch {b}: {lat[-1]*1e3:.1f} ms hit {store.stats.hit_rate:.3f}")

    st = store.stats.as_dict()
    compute_ms = compute_s / max(n_batches, 1) * 1e3
    st.update(
        policy=policy,
        mean_batch_ms=float(np.mean(lat) * 1e3),
        p99_batch_ms=float(np.percentile(lat, 99) * 1e3),
        compute_ms=compute_ms,
        modeled_fetch_ms_per_batch=store.modeled_batch_ms(),
        # The paper's §VII-F decomposition: device compute (policy-
        # independent) + the slow-tier on-demand model.  Our python slot
        # bookkeeping (TorchRec does it in C++/CUDA, the paper reports a
        # 10x engineering speedup there) is excluded from this figure.
        modeled_e2e_ms=compute_ms + store.modeled_batch_ms(),
    )
    if multi_table:
        st["per_table_hit_rates"] = [
            round(h, 4) for h in store.per_table_hit_rates()]
    return st


def _dense_forward(params, cfg, dense, pooled):
    """DLRM forward given already-pooled embeddings (B, T, D)."""
    from repro.models.dlrm import _mlp

    ct = jnp.dtype(cfg.compute_dtype)
    bot = _mlp(params["bottom"], dense.astype(ct))
    z = jnp.concatenate([bot[:, None, :], pooled.astype(ct)], axis=1)
    zz = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = zz[:, iu, ju]
    top_in = jnp.concatenate([bot.astype(jnp.float32), inter], axis=1)
    return _mlp(params["top"], top_in.astype(ct))[:, 0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="recmg",
                    choices=["lru", "recmg", "recmg-oracle"])
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--batch-queries", type=int, default=32)
    ap.add_argument("--capacity-frac", type=float, default=0.2)
    ap.add_argument("--accesses", type=int, default=200_000)
    ap.add_argument("--train-epochs", type=int, default=3)
    ap.add_argument("--multi-table", action="store_true",
                    help="serve through the per-table facade "
                         "(one batched store per sparse feature)")
    args = ap.parse_args(argv)

    cfg = get_config("dlrm-recmg").reduced()
    params = init_dlrm(jax.random.PRNGKey(0), cfg)

    tr_cfg = TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=args.accesses, drift_every=10**9,
    )
    trace = generate_trace(tr_cfg)
    capacity = int(args.capacity_frac * trace.unique_count())

    outputs = None
    if args.policy.startswith("recmg"):
        from repro.core.belady import belady_labels
        from repro.core.caching_model import (CachingModelConfig,
                                              train_caching_model)
        from repro.core.features import make_windows, split_train_eval
        from repro.core.prefetch_model import (PrefetchModelConfig,
                                               make_prefetch_data,
                                               train_prefetch_model)

        labels, _, _ = belady_labels(trace.global_id, capacity)
        if args.policy == "recmg-oracle":
            outputs = precompute_outputs(trace)
            outputs = RecMGOutputs(outputs.chunk_starts, None, None)
        else:
            mcfg = CachingModelConfig(n_tables=cfg.n_tables)
            data = make_windows(trace, labels=labels)
            cparams, _ = train_caching_model(
                data, mcfg, epochs=args.train_epochs, log=print)
            pcfg = PrefetchModelConfig(n_tables=cfg.n_tables)
            pdata = make_prefetch_data(trace)
            pparams, _ = train_prefetch_model(
                pdata, pcfg, epochs=args.train_epochs, log=print)
            outputs = precompute_outputs(
                trace, caching=(cparams, mcfg), prefetch=(pparams, pcfg))

    res = serve_trace(cfg, params, trace, capacity, args.policy, outputs,
                      batch_queries=args.batch_queries,
                      multi_table=args.multi_table, log=print)
    print({k: v for k, v in res.items()})
    return res


if __name__ == "__main__":
    main()
