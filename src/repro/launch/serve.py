"""DLRM serving launcher on tiered memory — the paper's deployment.

    PYTHONPATH=src python -m repro.launch.serve --policy recmg --batches 50

Pipeline per inference batch (paper Fig. 6):
  1. embedding lookups go through the TieredEmbeddingStore (device buffer
     backed by host-tier tables);
  2. the DLRM dense compute runs jitted on the device;
  3. between batches, the CPU-side caching/prefetch model outputs for the
     *previous* chunk are applied (Algorithm 1), pipelined one batch ahead.

Prints the Fig.16-style latency breakdown and hit rates per policy.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.recmg import RecMGOutputs, precompute_outputs
from repro.core.serving import MultiTableTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.core.trace import Trace, TraceGenConfig, generate_trace
from repro.models.dlrm import init_dlrm
from repro.obs import MetricsRegistry
from repro.obs.tracing import get_tracer


def serve_trace(cfg, params, trace: Trace, capacity: int, policy: str,
                outputs: Optional[RecMGOutputs], batch_queries: int = 64,
                fetch_us_per_row: float = 10.0, multi_table: bool = False,
                shards: int = 0, placement: str = "table",
                async_prefetch: bool = False, pipeline_depth: int = 2,
                scheduler: str = "inline", interarrival_us: float = 0.0,
                compute_us: Optional[float] = None, adapt: bool = False,
                adapt_cfg=None, model=None, overload: float = 0.0,
                priority_mix=None, queue_bound: int = 0,
                fault_plan: str = "", fault_seed: int = 0,
                replicate_hot: int = 0, quantize: bool = False,
                row_format: Optional[str] = None, log=None) -> Dict:
    """Replay a trace as DLRM inference batches through the tiered store.

    ``quantize=True`` stores the fast tier quantized (``row_format``:
    ``"int8"`` default or ``"fp8"``) with per-row fp32 scales — ``D + 4``
    bytes per resident row instead of ``D * 4``, so the same byte budget
    holds more hot rows (``capacity`` here is still in rows; the CLI's
    ``--quantize`` converts the byte budget implied by
    ``--capacity-frac`` into the larger quantized row count).

    ``multi_table=True`` serves through the per-table facade (one batched
    store per sparse feature under the shared row budget) instead of one
    monolithic store.

    ``shards > 0`` serves through the sharded multi-worker store
    (:class:`~repro.core.sharded_serving.ShardedTieredStore`): the tables
    are partitioned across ``shards`` simulated workers under the chosen
    ``placement`` policy (``table`` / ``row`` / ``hash`` / ``freq``; the
    frequency-aware planner profiles the first quarter of the trace) and
    each batch is routed shard-locally and gathered back.  The result
    dict gains a ``"shard"`` key with per-shard load/skew/stall
    telemetry.

    ``async_prefetch=True`` serves through the pipelined runtime
    (:mod:`repro.runtime`): requests go through the admission queue +
    micro-batcher, staged model outputs are applied by the background
    prefetch engine, and batch *k*'s slow-tier fetch overlaps batch
    *k-1*'s dense forward on the modeled timeline.  With the default
    ``"inline"`` scheduler the store sees the exact same operation
    sequence as the synchronous path (identical hit/miss/eviction
    counters); only the on-demand fetch *stall* accounting changes.

    ``adapt=True`` attaches a drift-adaptive controller
    (:class:`~repro.runtime.drift.AdaptiveController`): windowed
    hit-rate + hot-set-Jaccard telemetry over the live stream, and on a
    drift trigger the caching/prefetch model *features* are refreshed
    online (hot-pool rebuild + per-chunk re-rank + prefetch of the
    newly-hot rows), staged through the normal model-output path.  The
    result dict gains a ``"drift"`` telemetry key.

    ``model`` optionally passes the live
    :class:`~repro.core.model_runtime.LearnedRecMGModel` behind
    ``outputs``; with ``adapt=True`` the drift controller then also
    fine-tunes the model online on every refresh and swaps in recomputed
    outputs (:class:`~repro.core.model_runtime.LearnedController`) — on
    both the synchronous and the pipelined (``VirtualClock``) path.

    ``fault_plan`` (requires ``shards``) arms deterministic fault
    injection on the sharded store — the CLI grammar from
    :class:`~repro.runtime.faults.FaultPlan` (``"kill:1@mid,
    recover:1@75%"``; fractional times resolve against the batch count).
    ``replicate_hot`` keeps the top-k profiled rows resident on every
    shard so a dead shard's hot traffic stays exactly answerable.  The
    result gains an ``"ft"`` key and the reconciled ``ft.*`` namespace.

    ``overload > 0`` (requires ``async_prefetch``) serves through the
    SLO-aware admission path (:mod:`repro.runtime.admission`): requests
    arrive open-loop at ``overload`` times the modeled compute capacity
    with priorities drawn from ``priority_mix`` (a weight per class,
    most-important first), the queue is bounded at ``queue_bound``
    (default 4 batches) with lowest-priority-first shedding, EDF batch
    scheduling, deadline-driven degraded answers and prefetch
    backpressure.  The result gains ``admission`` /  ``goodput_rps``
    keys and the ``adm.*`` metrics namespace."""
    T, P = cfg.n_tables, cfg.multi_hot
    per_batch = batch_queries * T * P
    host_rows = int(trace.rows_per_table.sum())
    host = np.random.default_rng(0).normal(
        size=(host_rows, cfg.emb_dim)).astype(np.float32)
    pol = "recmg" if policy == "recmg" else "lru"
    if shards and multi_table:
        raise ValueError("pass at most one of shards / multi_table")
    # Warm the jitted scatter/gather shape buckets at construction (off the
    # measured path): without this, the first batch that hits each
    # power-of-two bucket pays an XLA compile inside the latency window —
    # visible as ~600ms p99 spikes against a ~10ms p50.
    if fault_plan and not shards:
        raise ValueError("--fault-plan requires --shards (the fault layer "
                         "lives in the sharded store)")
    if shards:
        from repro.core.sharded_serving import ShardedTieredStore

        profile = (trace.global_id
                   if placement == "freq" or replicate_hot else None)
        store = ShardedTieredStore.build(
            host, trace.rows_per_table, shards, placement,
            capacity=capacity, policy=pol, profile_ids=profile,
            replicate_hot=int(replicate_hot),
            quantize=quantize, row_format=row_format,
            fetch_us_per_row=fetch_us_per_row, warmup_batch=per_batch)
        if fault_plan:
            store.arm_faults(
                fault_plan, seed=fault_seed,
                horizon_batches=len(trace.global_id) // per_batch)
    elif multi_table:
        store = MultiTableTieredStore.from_global_table(
            host, trace.rows_per_table, capacity=capacity, policy=pol,
            quantize=quantize, row_format=row_format,
            fetch_us_per_row=fetch_us_per_row, warmup_batch=per_batch)
    else:
        store = TieredEmbeddingStore(
            host, capacity, policy=pol, quantize=quantize,
            row_format=row_format, fetch_us_per_row=fetch_us_per_row,
            warmup_batch=per_batch)
    fwd = jax.jit(lambda pr, d, e: _dense_forward(pr, cfg, d, e))

    gid = trace.global_id
    rng = np.random.default_rng(1)
    n_batches = len(gid) // per_batch
    chunk_state = {"ptr": 0}
    compute = {"s": 0.0}

    from repro.core.model_runtime import OutputsRef

    oref = OutputsRef(outputs)

    controller = None
    if adapt:
        from repro.runtime.drift import AdaptiveController, DriftConfig

        if adapt_cfg is None:
            adapt_cfg = DriftConfig(window=max(1024, 4 * per_batch),
                                    hot_k=min(capacity, 256))
        if model is not None:
            from repro.core.model_runtime import LearnedController

            controller = LearnedController(store, capacity, model, oref,
                                           trace, adapt_cfg)
        else:
            controller = AdaptiveController(store, capacity, adapt_cfg)

    def staged_for_batch(b):
        """Model outputs to stage after batch ``b``: caching priorities for
        every chunk the batch covered, but prefetches only from the most
        recent one — the paper issues ONE prefetch set per inference batch
        (Fig. 6); flooding every chunk's PO would churn the buffer.  Reads
        through ``oref`` so an online output refresh (LearnedController)
        takes effect at the next batch; the chunk grid is identical, so
        the chunk pointer stays valid."""
        out = oref.outputs
        if out is None:
            return []
        items, last_pf = [], None
        hi = (b + 1) * per_batch
        empty = np.empty(0, np.int64)
        ptr = chunk_state["ptr"]
        while (ptr < len(out.chunk_starts)
               and out.chunk_starts[ptr] < hi):
            s = int(out.chunk_starts[ptr])
            trunk = gid[max(0, s - 15): s]
            bits = (out.caching_bits[ptr]
                    if out.caching_bits is not None
                    else np.zeros(len(trunk)))
            items.append((trunk, bits, empty))
            if out.prefetch_ids is not None:
                last_pf = out.prefetch_ids[ptr]
            ptr += 1
        chunk_state["ptr"] = ptr
        if last_pf is not None:
            items.append((empty, empty, np.asarray(last_pf, np.int64)))
        return items

    def forward_batch(emb):
        """Pool + dense forward; returns measured compute seconds.
        Partial batches (EDF pops under admission control can close a
        batch below ``max_batch``) are zero-padded to the full shape so
        the jitted forward sees one shape — no per-size XLA recompiles
        on the measured path."""
        rows = batch_queries * T * P
        if emb.shape[0] < rows:
            emb = jnp.concatenate(
                [emb, jnp.zeros((rows - emb.shape[0], emb.shape[1]),
                                emb.dtype)])
        emb = emb.reshape(batch_queries, T, P, cfg.emb_dim).sum(axis=2)
        dense = jnp.asarray(
            rng.normal(size=(batch_queries, cfg.dense_features))
            .astype(np.float32))
        t1 = time.perf_counter()
        out = fwd(params, dense, emb)
        jax.block_until_ready(out)
        c = time.perf_counter() - t1
        compute["s"] += c
        return c

    # Warm the jitted dense forward off the measured path: its first-call
    # XLA compile otherwise lands inside batch 0's latency window and
    # dominates the p99 (~150ms against a ~5ms p50).  Shapes/dtypes match
    # the real batches, so this is a pure compile-cache fill.
    warm_pooled = jnp.zeros((batch_queries, T, cfg.emb_dim), jnp.float32)
    warm_dense = jnp.zeros((batch_queries, cfg.dense_features), jnp.float32)
    jax.block_until_ready(fwd(params, warm_dense, warm_pooled))

    rt = None
    adm_cfg = None
    if overload and not async_prefetch:
        raise ValueError("--overload requires --async-prefetch (the "
                         "admission path lives in the pipelined runtime)")
    if async_prefetch:
        from repro.runtime import (AdmissionConfig, PipelinedRuntime,
                                   RuntimeConfig)

        if overload:
            # Offered load as a multiple of modeled compute capacity:
            # one batch per compute_us -> interarrival pins the rate.
            if compute_us is None:
                compute_us = 500.0
            interarrival_us = compute_us / (batch_queries * float(overload))
            adm_cfg = AdmissionConfig(
                queue_bound=int(queue_bound) if queue_bound
                else 4 * batch_queries,
                class_deadline_us=(4 * compute_us, 16 * compute_us,
                                   64 * compute_us))

        # ``compute_us`` pins the modeled device time per batch (so the
        # overlap window uses one cost model for both fetch and compute);
        # None overlaps against the measured wall-clock forward instead.
        # When a tracer with a virtual clock is installed, the runtime
        # shares it so the trace timeline and the modeled pipeline
        # timeline are one and the same.
        _tr = get_tracer()
        rt_clock = _tr.clock if (_tr.enabled
                                 and hasattr(_tr.clock, "advance_to")) \
            else None
        rt = PipelinedRuntime(store, RuntimeConfig(
            max_batch=batch_queries, pipeline_depth=pipeline_depth,
            interarrival_us=interarrival_us, scheduler=scheduler,
            fetch_us_per_row=fetch_us_per_row, compute_us=compute_us,
            admission=adm_cfg),
            clock=rt_clock,
            batch_hook=controller.on_batch if controller else None)

        def step(b, emb):
            c = forward_batch(emb)
            if log and b % 10 == 0:
                log(f"batch {b}: hit {store.stats.hit_rate:.3f} "
                    f"stall {rt.telemetry.stall_ms:.1f} ms")
            return c, staged_for_batch(b)

        qp = T * P  # ids per query = one request
        n_queries = n_batches * batch_queries
        if adm_cfg is not None:
            mix = np.asarray(priority_mix if priority_mix is not None
                             else (0.2, 0.3, 0.5), np.float64)
            if mix.size != adm_cfg.n_classes or mix.min() < 0 \
                    or mix.sum() <= 0:
                raise ValueError(f"priority_mix needs {adm_cfg.n_classes} "
                                 f"non-negative weights, got "
                                 f"{priority_mix!r}")
            pri = np.random.default_rng(2).choice(
                adm_cfg.n_classes, size=n_queries, p=mix / mix.sum())
            stream = ((gid[i * qp: (i + 1) * qp], int(pri[i]))
                      for i in range(n_queries))
        else:
            stream = (gid[i * qp: (i + 1) * qp]
                      for i in range(n_queries))
        rt.run(stream, step)
        lat = rt.wall_batch_s
    else:
        lat = []
        _tr = get_tracer()
        for b in range(n_batches):
            if _tr.enabled:
                _tr.set_batch(b)
            ids = gid[b * per_batch: (b + 1) * per_batch]
            pre_hits = store.stats.hits
            t0 = time.perf_counter()
            emb = store.lookup(ids)  # (per_batch, D)
            forward_batch(emb)
            lat.append(time.perf_counter() - t0)
            # ``stage_model_outputs`` double-buffers: the outputs land at
            # the next batch boundary without blocking an in-flight
            # lookup; the flush runs in the inter-batch gap (outside the
            # timed window) so measured batch latency matches the seed's
            # accounting.
            for item in staged_for_batch(b):
                store.stage_model_outputs(*item)
            if controller is not None:
                # Adaptation items stage after the model's: the fresh
                # re-ranks must win over stale ones at the next drain.
                for item in controller.on_batch(
                        ids, store.stats.hits - pre_hits, b):
                    store.stage_model_outputs(*item)
            store.flush_staged()
            if log and b % 10 == 0:
                log(f"batch {b}: {lat[-1]*1e3:.1f} ms "
                    f"hit {store.stats.hit_rate:.3f}")

    st = store.stats.as_dict()
    compute_ms = compute["s"] / max(n_batches, 1) * 1e3
    st.update(
        policy=policy,
        mean_batch_ms=float(np.mean(lat) * 1e3),
        p50_batch_ms=float(np.percentile(lat, 50) * 1e3),
        p95_batch_ms=float(np.percentile(lat, 95) * 1e3),
        p99_batch_ms=float(np.percentile(lat, 99) * 1e3),
        compute_ms=compute_ms,
        modeled_fetch_ms_per_batch=store.modeled_batch_ms(),
        # The paper's §VII-F decomposition: device compute (policy-
        # independent) + the slow-tier on-demand model.  Our python slot
        # bookkeeping (TorchRec does it in C++/CUDA, the paper reports a
        # 10x engineering speedup there) is excluded from this figure.
        modeled_e2e_ms=compute_ms + store.modeled_batch_ms(),
    )
    if rt is not None:
        tel = rt.telemetry
        st["on_demand_stall_ms"] = round(tel.stall_ms, 3)
        st["pf_accuracy"] = round(
            store.stats.prefetch_hits / max(tel.pf_issued, 1), 4)
        st["pf_coverage"] = round(
            store.stats.prefetch_hits
            / max(store.stats.prefetch_hits + store.stats.on_demand_rows, 1),
            4)
        st["runtime"] = rt.results()
        if rt.admission_stats is not None:
            adm = rt.admission_stats
            modeled_s = max(rt.clock.now() * 1e-6, 1e-12)
            st["admission"] = adm.as_dict(adm_cfg)
            st["goodput_rps"] = round(adm.total_served / modeled_s, 3)
            st["offered_rps"] = round(1e6 / interarrival_us, 3)
    else:
        # Synchronous serving: every on-demand fetch sits on the critical
        # path, so the stall is the whole modeled slow-tier cost.
        st["on_demand_stall_ms"] = round(store.stats.modeled_fetch_s * 1e3, 3)
    if controller is not None:
        st["drift"] = controller.as_dict()
    if multi_table:
        st["per_table_hit_rates"] = [
            round(h, 4) for h in store.per_table_hit_rates()]
    if shards:
        st["shard"] = store.shard_telemetry()
        st["shard_load_imbalance"] = st["shard"]["load_imbalance"]
        if store.ft_stats is not None:
            store.ft_stats.check()
            st["ft"] = store.ft_stats.as_dict()

    # Unified metrics registry: every telemetry producer of the run
    # publishes into one namespace, so the reconciliation checker (and
    # ``--metrics-out``) sees a single flat counter space.
    reg = MetricsRegistry()
    store.publish_metrics(reg)
    if rt is not None:
        rt.publish(reg)
    if controller is not None and hasattr(controller, "publish"):
        controller.publish(reg)
    st["metrics"] = reg.snapshot()
    return st


def _dense_forward(params, cfg, dense, pooled):
    """DLRM forward given already-pooled embeddings (B, T, D)."""
    from repro.models.dlrm import _mlp

    ct = jnp.dtype(cfg.compute_dtype)
    bot = _mlp(params["bottom"], dense.astype(ct))
    z = jnp.concatenate([bot[:, None, :], pooled.astype(ct)], axis=1)
    zz = jnp.einsum("bfd,bgd->bfg", z, z, preferred_element_type=jnp.float32)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    inter = zz[:, iu, ju]
    top_in = jnp.concatenate([bot.astype(jnp.float32), inter], axis=1)
    return _mlp(params["top"], top_in.astype(ct))[:, 0]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="recmg",
                    choices=["lru", "recmg", "recmg-oracle"])
    ap.add_argument("--model", default="learned",
                    choices=["learned", "frequency", "voyager"],
                    help="where the recmg model outputs come from: the "
                         "trained dual models (learned — jitted bucketed "
                         "inference, online fine-tune under --adapt), the "
                         "deterministic frequency heuristic, or the "
                         "Voyager-class ML prefetcher baseline (prefetch "
                         "stream on an LRU store)")
    ap.add_argument("--batches", type=int, default=40)
    ap.add_argument("--batch-queries", type=int, default=32)
    ap.add_argument("--capacity-frac", type=float, default=0.2)
    ap.add_argument("--accesses", type=int, default=200_000)
    ap.add_argument("--train-epochs", type=int, default=3)
    ap.add_argument("--quantize", action="store_true",
                    help="store the fast tier quantized (per-row scales); "
                         "the byte budget implied by --capacity-frac is "
                         "re-spent as quantized rows, so the buffer holds "
                         "~2-4x the rows at the same bytes")
    ap.add_argument("--row-format", default="int8",
                    choices=("int8", "fp8"),
                    help="quantized row storage format (with --quantize)")
    ap.add_argument("--multi-table", action="store_true",
                    help="serve through the per-table facade "
                         "(one batched store per sparse feature)")
    ap.add_argument("--shards", type=int, default=0,
                    help="partition the tables across this many simulated "
                         "workers (0 = single-worker store)")
    ap.add_argument("--placement", default="table",
                    choices=["table", "row", "hash", "freq"],
                    help="shard placement policy: table-wise bin-pack, "
                         "row-wise round-robin, keyed hash, or the "
                         "frequency-aware (RecShard-style) planner")
    ap.add_argument("--async-prefetch", action="store_true",
                    help="serve through the pipelined runtime: admission "
                         "queue + micro-batcher, background prefetch "
                         "engine, fetch/compute overlap")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="how many batches the host may run ahead of the "
                         "device (2 = double buffering; 1 = synchronous)")
    ap.add_argument("--scheduler", default="inline",
                    choices=["inline", "thread"],
                    help="prefetch-engine scheduler: inline is "
                         "deterministic, thread overlaps wall-clock")
    ap.add_argument("--overload", type=float, default=0.0,
                    help="serve open-loop at this multiple of modeled "
                         "compute capacity through the SLO-aware admission "
                         "path (EDF scheduling, bounded queue with "
                         "lowest-priority-first shedding, degraded answers "
                         "past deadline, prefetch backpressure); implies "
                         "--async-prefetch")
    ap.add_argument("--priority-mix", default="",
                    help="comma-separated traffic weights per priority "
                         "class, most-important first (default 0.2,0.3,0.5 "
                         "over gold,silver,bronze)")
    ap.add_argument("--queue-bound", type=int, default=0,
                    help="admission-queue bound in requests (default: 4 "
                         "batches); the excess is shed "
                         "lowest-priority-first")
    ap.add_argument("--fault-plan", default="",
                    help="deterministic fault schedule for the sharded "
                         "store (requires --shards): comma-separated "
                         "kind[:shard[xfactor]]@start[..end] events with "
                         "kinds kill/recover/slow/flaky and times as batch "
                         "indices, percentages or 'mid' — e.g. "
                         "'kill:1@mid,recover:1@75%' or "
                         "'flaky:2x0.3@25%..75%'")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault plan's transient-failure "
                         "draws (byte-reproducible per seed)")
    ap.add_argument("--replicate-hot", type=int, default=0,
                    help="replicate the top-k profiled hot rows on every "
                         "shard (RecShard-style) so a dead shard's hot "
                         "traffic is answered exactly from survivors")
    ap.add_argument("--workload", default="",
                    help="serve a named workload scenario instead of the "
                         "default calibrated trace: a catalog name "
                         "(zipf_hot, diurnal, flash_crowd, multi_tenant, "
                         "churn, ...) or 'regime:key=val,...' — e.g. "
                         "'diurnal:n_phases=6' or 'replay:path=tr.npz'")
    ap.add_argument("--adapt", action="store_true",
                    help="drift-adaptive serving: windowed hit-rate + "
                         "hot-set-Jaccard drift detector, online refresh "
                         "of the caching/prefetch features on trigger")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace-event JSON of the "
                         "run to this path (enables span tracing; open at "
                         "https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="write the run's metrics-registry snapshot JSON "
                         "to this path (check it with "
                         "scripts/check_accounting.py)")
    ap.add_argument("--flight-recorder", default="",
                    help="also write the flight-recorder ring — spans of "
                         "the last --trace-ring batches — to this path")
    ap.add_argument("--trace-ring", type=int, default=64,
                    help="flight-recorder ring size in batches")
    args = ap.parse_args(argv)
    if args.overload:
        args.async_prefetch = True

    cfg = get_config("dlrm-recmg").reduced()
    params = init_dlrm(jax.random.PRNGKey(0), cfg)

    if args.workload:
        from repro.workloads import make_trace, parse_workload

        spec = parse_workload(args.workload)
        if spec.regime != "replay":  # replay: the file's geometry wins
            spec = spec.with_(n_tables=cfg.n_tables,
                              rows_per_table=cfg.rows_per_table,
                              n_accesses=args.accesses)
        trace = make_trace(spec)
    else:
        tr_cfg = TraceGenConfig(
            n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
            n_accesses=args.accesses, drift_every=10**9,
        )
        trace = generate_trace(tr_cfg)
    capacity = int(args.capacity_frac * trace.unique_count())
    if args.quantize:
        # Hold the byte budget fixed: re-spend the fp32 budget implied by
        # --capacity-frac as quantized rows (D + 4 bytes each).
        from repro.core.tiered import fast_row_bytes

        fp32_bytes = capacity * fast_row_bytes(cfg.emb_dim, np.float32,
                                               False)
        capacity = fp32_bytes // fast_row_bytes(cfg.emb_dim, np.float32,
                                                True, args.row_format)
        print(f"quantize({args.row_format}): {fp32_bytes} fast-tier bytes "
              f"-> {capacity} resident rows")

    outputs = None
    model_rt = None
    pol = args.policy
    if args.policy.startswith("recmg"):
        if args.policy == "recmg-oracle":
            outputs = precompute_outputs(trace)
            outputs = RecMGOutputs(outputs.chunk_starts, None, None)
        elif args.model == "frequency":
            from repro.core.recmg import frequency_outputs

            outputs = frequency_outputs(trace, capacity)
        elif args.model == "voyager":
            from repro.core.model_runtime import voyager_outputs

            # Prefetch-only baseline: LRU residency + Voyager's stream.
            outputs = voyager_outputs(trace, capacity,
                                      epochs=args.train_epochs)
            pol = "lru"
        else:
            from repro.core.model_runtime import (LearnedModelConfig,
                                                  LearnedRecMGModel)

            # CLI-scale knobs (the LearnedModelConfig defaults are tuned
            # for the small scenario-matrix scale): the seed launcher's
            # model size, epochs from --train-epochs, sparser windows and
            # the wide deployment candidate pool.
            lcfg = LearnedModelConfig(
                hidden=40, caching_epochs=args.train_epochs,
                prefetch_epochs=args.train_epochs, batch_size=256,
                lr=3e-3, train_stride=5, n_candidates=5000)
            model_rt = LearnedRecMGModel.train_from_trace(
                trace, capacity, lcfg, log=print)
            outputs = model_rt.outputs_for(trace)

    tracer = None
    if args.trace_out or args.flight_recorder:
        from repro.obs.tracing import SpanTracer, install_tracer
        from repro.runtime.clock import VirtualClock

        # Pipelined serving runs on the modeled (virtual) timeline, so
        # the trace does too; synchronous serving traces wall time.
        clock = VirtualClock() if args.async_prefetch else None
        tracer = SpanTracer(clock=clock, ring_batches=args.trace_ring)
        install_tracer(tracer)

    try:
        res = serve_trace(cfg, params, trace, capacity, pol, outputs,
                          batch_queries=args.batch_queries,
                          multi_table=args.multi_table,
                          shards=args.shards, placement=args.placement,
                          async_prefetch=args.async_prefetch,
                          pipeline_depth=args.pipeline_depth,
                          scheduler=args.scheduler, adapt=args.adapt,
                          model=model_rt, overload=args.overload,
                          priority_mix=tuple(
                              float(w) for w in
                              args.priority_mix.split(","))
                          if args.priority_mix else None,
                          queue_bound=args.queue_bound,
                          fault_plan=args.fault_plan,
                          fault_seed=args.fault_seed,
                          replicate_hot=args.replicate_hot,
                          quantize=args.quantize,
                          row_format=args.row_format if args.quantize
                          else None, log=print)
    finally:
        if tracer is not None:
            install_tracer(None)

    if args.metrics_out:
        import json

        with open(args.metrics_out, "w") as f:
            json.dump(res["metrics"], f, indent=1, sort_keys=True)
        print(f"metrics snapshot -> {args.metrics_out}")
    if tracer is not None:
        from repro.obs import reconcile, validate_chrome_trace

        trace_obj = tracer.chrome_trace()
        if args.trace_out:
            tracer.write(args.trace_out)
            print(f"trace ({len(trace_obj['traceEvents'])} events) -> "
                  f"{args.trace_out}")
        if args.flight_recorder:
            tracer.write(args.flight_recorder, flight_only=True)
            print(f"flight recorder -> {args.flight_recorder}")
        problems = validate_chrome_trace(trace_obj)
        problems += reconcile(metrics=res["metrics"], trace=trace_obj,
                              strict=False)
        if problems:
            print("RECONCILIATION PROBLEMS:")
            for p in problems:
                print(f"  {p}")
            raise SystemExit(1)
        print("trace/metrics reconciliation: OK")
    print({k: v for k, v in res.items() if k != "metrics"})
    return res


if __name__ == "__main__":
    main()
