import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Only the dry-run gets 512 placeholder devices.

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import (  # noqa: E402
    ALL_ARCHS,
    RunConfig,
    auto_microbatches,
    get_config,
    shape_applicable,
    shapes_for,
)
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_train_step, opt_struct_and_specs  # noqa: E402
from repro.models.model_api import build  # noqa: E402
from repro.optim.adamw import OptConfig  # noqa: E402
from repro.sharding.partition import (  # noqa: E402
    activation_sharding,
    batch_pspecs,
    cache_pspecs,
    data_axes,
    param_pspecs,
    to_shardings,
)


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    cfg = get_config(arch)
    shape = shapes_for(cfg)[shape_name]
    return build(cfg).batch_struct(shape)


def _sizeof(struct, pspecs, mesh) -> int:
    """Per-device bytes of a sharded pytree (structural estimate)."""
    import jax.tree_util as jtu

    total = 0
    flat_s = jtu.tree_leaves(struct)
    flat_p = jtu.tree_leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_s, flat_p):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        shards = 1
        for ent in spec:
            if ent is None:
                continue
            for ax in (ent,) if isinstance(ent, str) else ent:
                shards *= mesh.shape[ax]
        total += n * leaf.dtype.itemsize // shards
    return total


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               run: RunConfig, opt_cfg: OptConfig | None = None) -> dict:
    cfg = get_config(arch)
    shape = shapes_for(cfg)[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    meta = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "kind": shape.kind}
    if not ok:
        return {**meta, "status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    bundle = build(cfg, run)
    param_struct = bundle.param_struct()
    pspecs = param_pspecs(param_struct, mesh, run.sharding, run.emb_rows)
    param_sh = to_shardings(pspecs, mesh)
    batch_struct = bundle.batch_struct(shape)
    batch_sh = to_shardings(batch_pspecs(batch_struct, mesh, run.sharding),
                            mesh)

    opt_cfg = opt_cfg or OptConfig(moment_dtype=run.opt_dtype)
    microbatches = run.microbatches or auto_microbatches(cfg, shape, n_data)
    meta["microbatches"] = microbatches

    with mesh, activation_sharding(mesh, run.sharding):
        if shape.kind == "train":
            step = make_train_step(
                bundle, opt_cfg, microbatches, mesh=mesh,
                grad_pspecs=pspecs if run.constrain_grads else None)
            opt_struct, opt_pspecs = opt_struct_and_specs(bundle, pspecs, opt_cfg)
            opt_sh = to_shardings(opt_pspecs, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            ).lower(param_struct, opt_struct, batch_struct)
        elif shape.kind == "prefill":
            lowered = jax.jit(
                bundle.prefill,
                in_shardings=(param_sh, batch_sh),
            ).lower(param_struct, batch_struct)
        else:  # decode
            cache_struct = bundle.cache_struct(shape)
            cache_sh = to_shardings(
                cache_pspecs(cache_struct, mesh, run.shard_kv_seq), mesh
            )
            token_struct = batch_struct["token"]
            token_sh = to_shardings(
                batch_pspecs(token_struct, mesh, run.sharding), mesh)
            lowered = jax.jit(
                bundle.decode,
                in_shardings=(param_sh, token_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            ).lower(param_struct, token_struct, cache_struct)
        t_lower = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    result = {**meta, "status": "ok", "t_lower_s": round(t_lower, 1),
              "t_compile_s": round(t_compile, 1),
              "devices": int(mesh.devices.size)}

    try:
        ma = compiled.memory_analysis()
        result["memory_analysis"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
        print(f"[{arch}/{shape_name}/{mesh_name}] memory_analysis:", ma)
    except Exception as e:  # CPU backend may not implement it
        result["memory_analysis_error"] = str(e)

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        result["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals") or "bytes" in k
            )
        }
        print(f"[{arch}/{shape_name}/{mesh_name}] cost_analysis: "
              f"flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
    except Exception as e:
        result["cost_analysis_error"] = str(e)

    try:
        text = compiled.as_text()
        result["collectives"] = analyze(text)
        result["hlo_chars"] = len(text)
    except Exception as e:
        result["collectives_error"] = str(e)

    # Structural per-device sizes (works regardless of backend support).
    result["param_bytes_per_device"] = _sizeof(param_struct, pspecs, mesh)
    result["n_params"] = bundle.n_params()
    result["n_active_params"] = bundle.n_active_params()
    result["run_config"] = {
        "remat": run.remat, "sharding": run.sharding,
        "microbatches": microbatches, "opt_dtype": run.opt_dtype,
        "logits_chunk": run.logits_chunk, "shard_kv_seq": run.shard_kv_seq,
        "constrain_grads": run.constrain_grads, "emb_rows": run.emb_rows,
        "dlrm_sharded_lookup": run.dlrm_sharded_lookup,
    }
    return result


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--sharding", default="fsdp_tp")
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--logits-chunk", type=int, default=0)
    ap.add_argument("--attn-block-q", type=int, default=512)
    ap.add_argument("--attn-block-kv", type=int, default=512)
    ap.add_argument("--no-shard-kv-seq", action="store_true")
    ap.add_argument("--constrain-grads", action="store_true")
    ap.add_argument("--emb-rows", default="all", choices=["all", "model"])
    ap.add_argument("--dlrm-sharded-lookup", action="store_true")
    ap.add_argument("--moe-local-dispatch", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    cells = []
    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shapes = [args.shape] if args.shape else list(shapes_for(cfg))
        for s in shapes:
            cells.append((arch, s))
    if args.list:
        for c in cells:
            print(*c)
        return

    run = RunConfig(
        microbatches=args.microbatches,
        remat=args.remat,
        sharding=args.sharding,
        opt_dtype=args.opt_dtype,
        logits_chunk=args.logits_chunk,
        attn_block_q=args.attn_block_q,
        attn_block_kv=args.attn_block_kv,
        shard_kv_seq=not args.no_shard_kv_seq,
        constrain_grads=args.constrain_grads,
        emb_rows=args.emb_rows,
        dlrm_sharded_lookup=args.dlrm_sharded_lookup,
        moe_local_dispatch=args.moe_local_dispatch,
    )

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    outdir = Path(args.out) / args.tag
    outdir.mkdir(parents=True, exist_ok=True)

    n_ok = n_fail = 0
    for arch, shape_name in cells:
        for multi in meshes:
            mesh_name = "2x16x16" if multi else "16x16"
            fname = outdir / f"{arch}__{shape_name}__{mesh_name}.json"
            try:
                res = lower_cell(arch, shape_name, multi, run)
                status = res["status"]
            except Exception as e:
                res = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                       "status": "error", "error": str(e),
                       "traceback": traceback.format_exc()}
                status = "error"
            fname.write_text(json.dumps(res, indent=2))
            mark = {"ok": "PASS", "skipped": "SKIP", "error": "FAIL"}[status]
            n_ok += status == "ok"
            n_fail += status == "error"
            print(f"{mark} {arch} {shape_name} {mesh_name} "
                  f"({res.get('t_compile_s', '-')}s compile)", flush=True)
            if status == "error":
                print(res.get("error", ""), flush=True)
    print(f"dry-run: {n_ok} ok, {n_fail} failed -> {outdir}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
