"""Step builders: training step (grad accumulation + AdamW) and serving
steps, shared by the real launcher and the dry-run."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.model_api import ModelBundle
from repro.optim.adamw import OptConfig, apply_updates, init_opt


def make_train_step(bundle: ModelBundle, opt_cfg: OptConfig,
                    microbatches: int = 1, mesh=None,
                    grad_pspecs=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    Gradient accumulation via ``lax.scan`` over microbatches keeps per-step
    live activation memory at 1/mb of the global batch — the knob that fits
    314B-param training cells into 16GB/chip HBM.  The post-reshape sharding
    constraint is load-bearing: without it GSPMD is free to shard the
    *microbatch* factor of the (mb, B/mb, ...) reshape and replicate the
    batch, blowing per-device activation memory up by the data-axis size.

    ``grad_pspecs``: PartitionSpecs (normally the parameter specs) to pin the
    gradient accumulator to.  Without it GSPMD materializes *replicated*
    full gradients every microbatch (an all-reduce of the whole grad pytree
    per µbatch — 18.5 TB/device/step for grok-1): the constraint turns that
    into per-µbatch reduce-scatters onto the FSDP shards.  §Perf iteration.
    """
    loss_fn = bundle.loss

    if mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.sharding.partition import batch_entry, fit_spec

        dp = batch_entry(mesh, bundle.run.sharding)

        def constrain(x):
            spec = fit_spec(x.shape, [None, dp] + [None] * (x.ndim - 2), mesh)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    else:
        def constrain(x):
            return x

    if grad_pspecs is not None and mesh is not None:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def constrain_grads(g):
            return jax.tree_util.tree_map(
                lambda x, s: jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, s)),
                g, grad_pspecs,
                is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
            )
    else:
        def constrain_grads(g):
            return g

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split(x):
                return constrain(
                    x.reshape(microbatches, x.shape[0] // microbatches,
                              *x.shape[1:])
                )

            mb = jax.tree_util.tree_map(split, batch)
            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(carry, mbatch):
                tot, g = carry
                l, gi = jax.value_and_grad(loss_fn)(params, mbatch)
                g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g, gi
                )
                return (tot + l, constrain_grads(g)), None

            (loss, grads), _ = lax.scan(acc, (jnp.zeros(()), g0), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        params, opt_state, m = apply_updates(opt_cfg, params, opt_state, grads)
        m["loss"] = loss
        return params, opt_state, m

    return train_step


def opt_struct_and_specs(bundle: ModelBundle, param_pspecs, opt_cfg: OptConfig):
    """(eval_shape of opt state, matching PartitionSpec pytree)."""
    from jax.sharding import PartitionSpec as P

    param_struct = bundle.param_struct()
    opt_struct = jax.eval_shape(partial(init_opt, opt_cfg), param_struct)
    specs = {"m": param_pspecs, "v": param_pspecs, "count": P()}
    if "master" in opt_struct:
        specs["master"] = param_pspecs
    return opt_struct, specs
