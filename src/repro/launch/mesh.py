"""Production meshes.  Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Best-effort mesh over whatever devices exist (CPU tests, examples,
    elastic restarts after losing hosts: axes re-factored to the live
    device count)."""
    n = len(jax.devices())
    mp = math.gcd(model_parallel, n)
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def describe(mesh) -> str:
    return f"mesh{dict(mesh.shape)} over {mesh.devices.size} devices"
