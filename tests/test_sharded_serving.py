"""Sharded multi-worker store: placement semantics, budget split, load /
skew / critical-path telemetry, engine routing, and the async runtime
riding on top.  (The exhaustive equivalence fuzzing lives in
``tests/test_property_equivalence.py``.)"""
import numpy as np
import pytest

from repro.core.sharded_serving import ShardedTieredStore
from repro.sharding.embedding_shard import (PLACEMENTS, make_plan,
                                            trace_frequencies)

EMPTY = np.empty(0, np.int64)
ROWS = [100, 50, 200, 70]
N_VEC = sum(ROWS)


def _host(n=N_VEC, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _ids(n_acc=2000, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.15, size=n_acc), N_VEC) - 1
    return rng.permutation(N_VEC)[ranks].astype(np.int64)


# ---------------- placement plans ----------------


def test_table_placement_keeps_tables_whole():
    plan = make_plan(ROWS, 2, 64, "table")
    offs = np.concatenate(([0], np.cumsum(ROWS)))
    table_shards = [np.unique(plan.shard_of[offs[t]: offs[t + 1]])
                    for t in range(len(ROWS))]
    assert all(len(s) == 1 for s in table_shards)  # no split tables
    # LPT bin-pack on (200, 100, 70, 50): {200} vs {100, 70, 50}.
    assert plan.shard_rows.tolist() in ([200, 220], [220, 200])


def test_row_placement_is_round_robin():
    plan = make_plan(ROWS, 4, 64, "row")
    gid = np.arange(N_VEC)
    assert np.array_equal(plan.shard_of, (gid % 4).astype(np.int32))
    assert np.array_equal(plan.local_of, gid // 4)


def test_hash_placement_balances_without_striping():
    plan = make_plan(ROWS, 4, 64, "hash")
    rows = plan.shard_rows
    assert rows.max() / rows.mean() < 1.2  # near-balanced
    gid = np.arange(N_VEC)
    assert not np.array_equal(plan.shard_of, (gid % 4).astype(np.int32))


def test_freq_placement_packs_hot_rows_onto_rich_shards():
    rng = np.random.default_rng(1)
    freq = rng.zipf(1.3, size=N_VEC).astype(np.int64)
    plan = make_plan(ROWS, 2, 60, "freq", frequencies=freq,
                     fast_weights=[3.0, 1.0])
    # Fast-tier-rich shard 0 holds 3x the budget...
    assert plan.capacities.tolist() == [45, 15]
    # ...and every hot row (top sum(caps) by frequency) got a shard whose
    # budget can hold it: shard s received exactly caps[s] hot rows.
    hot = np.lexsort((np.arange(N_VEC), -freq))[:60]
    counts = np.bincount(plan.shard_of[hot], minlength=2)
    assert counts.tolist() == [45, 15]
    # The hottest row of all lands on the rich shard (weighted RR order).
    assert plan.shard_of[hot[0]] == 0
    # Cold rows equalize total row counts.
    assert abs(int(plan.shard_rows[0]) - int(plan.shard_rows[1])) <= 1


def test_one_shard_is_identity():
    for placement in PLACEMENTS:
        plan = make_plan(ROWS, 1, 64, placement,
                         frequencies=np.ones(N_VEC))
        assert np.array_equal(plan.local_of, np.arange(N_VEC))
        assert plan.capacities.tolist() == [64]


def test_plan_errors():
    with pytest.raises(ValueError, match="unknown placement"):
        make_plan(ROWS, 2, 64, "zigzag")
    with pytest.raises(ValueError, match="needs per-row frequencies"):
        make_plan(ROWS, 2, 64, "freq")
    with pytest.raises(ValueError, match="more shards"):
        make_plan(ROWS, 8, 64, "table")
    with pytest.raises(ValueError, match="frequencies cover"):
        make_plan(ROWS, 2, 64, "freq", frequencies=np.ones(3))
    with pytest.raises(ValueError, match="cannot span"):
        make_plan([2], 4, 4, "row")


def test_trace_frequencies_profile_prefix():
    ids = np.array([0, 0, 1, 2, 9, 9, 9, 9], np.int64)
    f = trace_frequencies(ids, 10, sample_frac=0.5)
    assert f.tolist() == [2, 1, 1, 0, 0, 0, 0, 0, 0, 0]


# ---------------- sharded store ----------------


def test_build_byte_budget_quantized():
    """``byte_budget`` sizes the sharded fast tier in bytes with the
    quantization-aware row footprint: the quantized build holds >= 2x
    the rows of the fp32 build at the same bytes (d=8: 32 B vs 12 B)."""
    host = _host()
    budget = 60 * 8 * 4
    fp32 = ShardedTieredStore.build(host, ROWS, 2, capacity=None,
                                    byte_budget=budget,
                                    with_engines=False)
    q = ShardedTieredStore.build(host, ROWS, 2, byte_budget=budget,
                                 quantize=True, with_engines=False)
    cap = lambda st: sum(s.capacity for s in st.stores)
    assert cap(fp32) == budget // 32
    assert cap(q) >= 2 * cap(fp32)
    with pytest.raises(ValueError, match="at most one"):
        ShardedTieredStore.build(host, ROWS, 2, capacity=10,
                                 byte_budget=budget)
    out = np.asarray(q.lookup(_ids(64)))
    assert out.shape == (64, 8) and out.dtype == np.float32


def test_store_plan_shape_mismatch_raises():
    plan = make_plan(ROWS, 2, 64, "table")
    with pytest.raises(ValueError, match="plan covers"):
        ShardedTieredStore(_host(N_VEC - 1), plan)
    with pytest.raises(ValueError, match="capacity .* required"):
        ShardedTieredStore.build(_host(), ROWS, 2, "row")


def test_load_and_critical_path_telemetry():
    plan = make_plan(ROWS, 2, 40, "table")
    st = ShardedTieredStore(_host(), plan)
    ids = _ids(600)
    for b in range(6):
        st.lookup(ids[b * 100: (b + 1) * 100])
    tel = st.shard_telemetry()
    assert sum(tel["per_shard_lookups"]) == 600 == st.stats.lookups
    assert tel["load_imbalance"] >= 1.0
    assert tel["max_batch_imbalance"] >= tel["load_imbalance"] - 1e-9
    # Workers fetch in parallel: the critical path can't exceed the sum,
    # and with >1 shard fetching it must be strictly below.
    assert 0 < tel["modeled_fetch_ms_critical"] < tel["modeled_fetch_ms_sum"]
    assert tel["parallel_fetch_speedup"] > 1.0
    assert st.critical_batch_ms() < st.modeled_batch_ms()


def test_fixed_overhead_charged_once_per_batch():
    """Facade accounting mirrors the multi-table facade: sub-stores model
    per-row cost only; the fixed per-batch overhead lands once per facade
    batch with a miss (sum view)."""
    plan = make_plan(ROWS, 4, 40, "row")
    st = ShardedTieredStore(_host(), plan, fetch_us_fixed=30.0,
                            fetch_us_per_row=10.0)
    st.lookup(np.arange(8))  # 8 misses across 4 shards, one batch
    assert st.stats.modeled_fetch_s == pytest.approx((30 + 8 * 10) * 1e-6)
    st.lookup(np.arange(8))  # all hits: no fixed charge
    assert st.stats.modeled_fetch_s == pytest.approx((30 + 8 * 10) * 1e-6)


def test_engine_routing_and_telemetry():
    plan = make_plan(ROWS, 2, 64, "table")
    st = ShardedTieredStore(_host(), plan, policy="recmg")
    # Prefetch ids on both shards; trunk ranks on one.
    st.apply_model_outputs(EMPTY, EMPTY, np.array([5, 6, 250, 251]))
    assert st.resident_mask(np.array([5, 6, 250, 251])).all()
    assert st.stats.prefetch_hits == 0  # not yet demanded
    st.lookup(np.array([5, 250]))
    assert st.stats.prefetch_hits == 2
    tel = st.shard_telemetry()
    assert sum(tel["per_shard_pf_issued"]) == 4
    assert sum(tel["per_shard_pf_timely"] + tel["per_shard_pf_late"]) == 2


def test_engines_off_matches_engines_on():
    ids = _ids(1200, seed=4)
    runs = []
    for with_engines in (True, False):
        plan = make_plan(ROWS, 2, 48, "hash")
        st = ShardedTieredStore(_host(), plan, policy="recmg",
                                with_engines=with_engines)
        for b in range(12):
            st.lookup(ids[b * 100: (b + 1) * 100])
            st.apply_model_outputs(ids[b * 100: b * 100 + 8],
                                   np.ones(8, np.int64),
                                   np.unique(ids[b * 3: b * 3 + 4]))
        runs.append(st.stats.as_dict())
    for wall in ("fetch_s", "gather_s", "model_s"):
        runs[0].pop(wall), runs[1].pop(wall)
    assert runs[0] == runs[1]


def test_staged_outputs_land_at_next_lookup():
    plan = make_plan(ROWS, 2, 64, "table")
    st = ShardedTieredStore(_host(), plan)
    st.stage_model_outputs(EMPTY, EMPTY, np.array([3, 260]))
    assert st.stats.on_demand_rows == 0  # nothing applied yet
    st.lookup(np.array([3, 260]))
    assert st.stats.prefetch_hits == 2
    st.stage_model_outputs(EMPTY, EMPTY, np.array([7]))
    st.flush_staged()
    assert st.resident_mask(np.array([7])).all()


def test_async_runtime_over_sharded_store_matches_sync():
    """PipelinedRuntime(inline) over the sharded store keeps the
    determinism contract: counters equal the synchronous sharded replay,
    and the pipeline hides part of the fetch stall."""
    from repro.runtime import PipelinedRuntime, RuntimeConfig

    ids = _ids(2400, seed=6)
    batch = 48

    def staged(b):
        return [(EMPTY, EMPTY,
                 np.unique(ids[(b + 1) * batch: (b + 1) * batch + 6]))]

    def build():
        return ShardedTieredStore(
            _host(), make_plan(ROWS, 4, 56, "row"), policy="lru")

    sync = build()
    for b in range(len(ids) // batch):
        sync.lookup(ids[b * batch: (b + 1) * batch])
        for item in staged(b):
            sync.stage_model_outputs(*item)
        sync.flush_staged()

    anc = build()
    rt = PipelinedRuntime(anc, RuntimeConfig(
        max_batch=1, pipeline_depth=2, compute_us=500.0))
    rt.run((ids[i * batch: (i + 1) * batch]
            for i in range(len(ids) // batch)),
           lambda b, emb: (0.0, staged(b)))
    for c in ("batches", "lookups", "hits", "prefetch_hits",
              "on_demand_rows", "evictions"):
        assert getattr(anc.stats, c) == getattr(sync.stats, c), c
    assert anc.stats.prefetch_hits > 0
    assert rt.telemetry.stall_ms < rt.telemetry.demand_fetch_ms
