"""Property-based equivalence suite (hypothesis, shim fallback).

Two oracles, fuzzed over random traces / capacities / policies /
priority streams:

1. **Batched engine == seed reference** — ``TieredEmbeddingStore`` must
   reproduce :class:`~repro.core.tiered_reference.ReferenceTieredStore`'s
   hit / miss / on-demand / prefetch / eviction counters after *every*
   batch, and return the exact host rows, for any generated workload.
2. **Sharded == composition of single stores** — for every placement
   policy and shard count, ``ShardedTieredStore`` must equal N
   independent single stores fed the same shard-local sub-batches
   (aggregate *and* per-shard counters), return gathered vectors
   identical to the monolithic store, and with ``n_shards=1`` collapse
   to the monolithic counters byte-for-byte.

The ``*_deep`` variants are the slow CI lane's >=100-generated-case
budget (40 + 40 + 30); the small variants keep a fuzz presence in the
fast PR lane.  With ``hypothesis`` installed the same tests shrink; the
bundled shim replays deterministically.
"""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.buffer_manager import RecMGBuffer, SlowRecMGBuffer
from repro.core.buffer_manager_reference import RecMGBuffer as HeapRecMGBuffer
from repro.core.sharded_serving import ShardedTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.core.tiered_reference import ReferenceTieredStore
from repro.sharding.embedding_shard import PLACEMENTS, make_plan

COUNTERS = ("batches", "lookups", "hits", "prefetch_hits", "on_demand_rows",
            "evictions")
EMPTY = np.empty(0, np.int64)


def _workload(seed, n_rows, n_acc):
    """Zipf-skewed ids + a deterministic model-output schedule."""
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.2, size=n_acc), n_rows) - 1
    ids = rng.permutation(n_rows)[ranks].astype(np.int64)
    return ids, np.random.default_rng(seed + 1)


def _outputs_for(b, rng, chunk, n_rows, bits_every, pf_every):
    """(trunk, bits, prefetch) items to apply after batch ``b``."""
    items = []
    if bits_every and b % bits_every == 0:
        trunk = chunk[:12]
        items.append((trunk, (rng.random(len(trunk)) < 0.5).astype(np.int64),
                      EMPTY))
    if pf_every and b % pf_every == 0:
        items.append((EMPTY, EMPTY,
                      np.unique(rng.integers(0, n_rows, size=6))))
    return items


# ---------------------------------------------------------------------------
# 1) batched engine vs. per-key seed reference
# ---------------------------------------------------------------------------


def _check_batched_vs_reference(seed, n_rows, cap, batch, policy_bit,
                                bits_every, pf_every):
    policy = ("lru", "recmg")[policy_bit]
    n_acc = batch * 8
    ids, _ = _workload(seed, n_rows, n_acc)
    host = np.random.default_rng(seed + 2).normal(
        size=(n_rows, 4)).astype(np.float32)
    new = TieredEmbeddingStore(host, cap, policy=policy)
    ref = ReferenceTieredStore(host, cap, policy=policy)
    rng_new = np.random.default_rng(seed + 3)
    rng_ref = np.random.default_rng(seed + 3)
    for b in range(n_acc // batch):
        chunk = ids[b * batch: (b + 1) * batch]
        o_new = np.asarray(new.lookup(chunk))
        o_ref = np.asarray(ref.lookup(chunk))
        np.testing.assert_array_equal(o_new, host[chunk])
        np.testing.assert_array_equal(o_ref, host[chunk])
        for item in _outputs_for(b, rng_new, chunk, n_rows, bits_every,
                                 pf_every):
            new.apply_model_outputs(*item)
        for item in _outputs_for(b, rng_ref, chunk, n_rows, bits_every,
                                 pf_every):
            ref.apply_model_outputs(*item)
        state = [(c, getattr(new.stats, c), getattr(ref.stats, c))
                 for c in COUNTERS]
        assert all(a == r for _, a, r in state), (policy, cap, b, state)
    new.check_invariants()
    assert set(new.slot_of) == set(ref.slot_of)


_BATCHED_ARGS = (st.integers(0, 2**31 - 1),   # seed
                 st.integers(24, 160),        # n_rows
                 st.integers(1, 48),          # cap (1 included since the
                 #   reference's own-batch prefetch-mark leak was fixed)
                 st.integers(8, 56),          # batch
                 st.integers(0, 1),           # policy bit
                 st.integers(0, 3),           # bits_every (0 = never)
                 st.integers(0, 3))           # pf_every


@settings(max_examples=8, deadline=None)
@given(*_BATCHED_ARGS)
def test_batched_matches_reference(seed, n_rows, cap, batch, policy_bit,
                                   bits_every, pf_every):
    _check_batched_vs_reference(seed, n_rows, cap, batch, policy_bit,
                                bits_every, pf_every)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(*_BATCHED_ARGS)
def test_batched_matches_reference_deep(seed, n_rows, cap, batch,
                                        policy_bit, bits_every, pf_every):
    _check_batched_vs_reference(seed, n_rows, cap, batch, policy_bit,
                                bits_every, pf_every)


# ---------------------------------------------------------------------------
# 2) sharded store vs. composition of single stores (+ monolithic vectors)
# ---------------------------------------------------------------------------


def _check_sharded(seed, n_shards_bit, placement_idx, cap, batch,
                   policy_bit, pf_every):
    n_shards = (1, 2, 4)[n_shards_bit]
    placement = PLACEMENTS[placement_idx]
    policy = ("lru", "recmg")[policy_bit]
    rng = np.random.default_rng(seed)
    rows_per_table = rng.integers(12, 60, size=int(rng.integers(2, 5)))
    if placement == "table":  # whole tables: can't out-shard the tables
        n_shards = min(n_shards, len(rows_per_table))
    n = int(rows_per_table.sum())
    cap = min(max(cap, n_shards), n)
    n_acc = batch * 8
    ids, _ = _workload(seed + 1, n, n_acc)
    host = np.random.default_rng(seed + 2).normal(
        size=(n, 4)).astype(np.float32)
    freq = np.bincount(ids[: n_acc // 2], minlength=n)
    plan = make_plan(rows_per_table, n_shards, cap, placement,
                     frequencies=freq)
    plan.check()

    sharded = ShardedTieredStore(host, plan, policy=policy)
    mono = TieredEmbeddingStore(host, cap, policy=policy)
    oracles = [TieredEmbeddingStore(host[g], int(c), policy=policy,
                                    fetch_us_fixed=0.0)
               for g, c in zip(plan.global_ids, plan.capacities)]

    rng_s = np.random.default_rng(seed + 3)
    rng_o = np.random.default_rng(seed + 3)
    rng_m = np.random.default_rng(seed + 3)
    for b in range(n_acc // batch):
        chunk = ids[b * batch: (b + 1) * batch]
        out = np.asarray(sharded.lookup(chunk))
        np.testing.assert_array_equal(out, host[chunk])
        # Gathered vectors identical to the monolithic store, any N.
        np.testing.assert_array_equal(out, np.asarray(mono.lookup(chunk)))
        gid, shard, local = plan.route(chunk)
        for s in np.unique(shard).tolist():
            oracles[s].lookup(local[shard == s])
        for trunk, bits, pf in _outputs_for(b, rng_s, chunk, n, 2,
                                            pf_every):
            sharded.apply_model_outputs(trunk, bits, pf)
        for trunk, bits, pf in _outputs_for(b, rng_m, chunk, n, 2,
                                            pf_every):
            mono.apply_model_outputs(trunk, bits, pf)
        for trunk, bits, pf in _outputs_for(b, rng_o, chunk, n, 2,
                                            pf_every):
            _, t_sh, t_loc = plan.route(trunk)
            _, p_sh, p_loc = plan.route(pf)
            for s in np.unique(np.concatenate((t_sh, p_sh))).tolist():
                oracles[s].apply_model_outputs(
                    t_loc[t_sh == s], bits[t_sh == s], p_loc[p_sh == s])
    # Aggregate + per-shard counters equal the single-store composition.
    for c in COUNTERS:
        per = [(getattr(st_.stats, c), getattr(o.stats, c))
               for st_, o in zip(sharded.stores, oracles)]
        assert all(a == b_ for a, b_ in per), (placement, n_shards, c, per)
        if c != "batches":  # facade counts one batch per lookup call
            assert getattr(sharded.stats, c) == sum(o for _, o in per)
    for st_, o in zip(sharded.stores, oracles):
        assert st_.slot_of == o.slot_of
        st_.check_invariants()
    if n_shards == 1:
        for c in COUNTERS:
            assert getattr(sharded.stats, c) == getattr(mono.stats, c), c


_SHARDED_ARGS = (st.integers(0, 2**31 - 1),   # seed
                 st.integers(0, 2),           # n_shards in {1,2,4}
                 st.integers(0, len(PLACEMENTS) - 1),
                 st.integers(2, 48),          # cap
                 st.integers(12, 56),         # batch
                 st.integers(0, 1),           # policy bit
                 st.integers(0, 2))           # pf_every


@settings(max_examples=6, deadline=None)
@given(*_SHARDED_ARGS)
def test_sharded_matches_single_stores(seed, n_shards_bit, placement_idx,
                                       cap, batch, policy_bit, pf_every):
    _check_sharded(seed, n_shards_bit, placement_idx, cap, batch,
                   policy_bit, pf_every)


@pytest.mark.slow
@settings(max_examples=40, deadline=None)
@given(*_SHARDED_ARGS)
def test_sharded_matches_single_stores_deep(seed, n_shards_bit,
                                            placement_idx, cap, batch,
                                            policy_bit, pf_every):
    _check_sharded(seed, n_shards_bit, placement_idx, cap, batch,
                   policy_bit, pf_every)


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, len(PLACEMENTS) - 1),
       st.integers(2, 40), st.integers(0, 1))
def test_one_shard_collapses_to_monolithic(seed, placement_idx, cap,
                                           policy_bit):
    """n_shards=1: every placement is the identity mapping, so counters
    reproduce the monolithic single store byte-for-byte."""
    _check_sharded(seed, 0, placement_idx, cap, 32, policy_bit, 2)


# ---------------------------------------------------------------------------
# 3) array-backed priority engine vs heap reference vs literal transcription
# ---------------------------------------------------------------------------


def _check_engine_vs_heap(seed, cap, ev, n_steps):
    """Fuzzed chunk sequences over the full bulk surface: the array engine
    must match the heap reference victim-for-victim (``populate_many``),
    hit-mask-for-hit-mask (``access_chunk``), and state-for-state (the
    ``score`` dict, ``seq``, and ``epoch``) after every operation."""
    rng = np.random.default_rng(seed)
    fast = RecMGBuffer(cap, ev)
    heap = HeapRecMGBuffer(cap, ev)
    for step in range(n_steps):
        op = int(rng.integers(0, 5))
        if op == 0:
            n = int(rng.integers(0, 8))
            trunk = rng.integers(0, 30, n)
            bits = rng.integers(0, 2, n)
            pf = rng.integers(0, 30, rng.integers(0, 4))
            sb = bool(rng.integers(0, 2))
            fast.load_embeddings(trunk, bits, pf, scaled_bits=sb)
            heap.load_embeddings(trunk, bits, pf, scaled_bits=sb)
        elif op == 1:
            keys = rng.integers(0, 30, rng.integers(1, 25))
            pr = int(rng.integers(0, 5))
            assert (fast.access_chunk(keys, pr).tolist()
                    == heap.access_chunk(keys, pr).tolist()), (seed, step)
        elif op == 2:
            n = int(rng.integers(0, 5))
            assert fast.populate_many(n) == heap.populate_many(n), (seed,
                                                                   step)
        elif op == 3:
            keys = rng.integers(0, 30, rng.integers(0, 10))
            pr = int(rng.integers(0, 5))
            on = bool(rng.integers(0, 2))
            fast.set_priorities(keys, pr, only_new=on)
            heap.set_priorities(keys.tolist(), pr, only_new=on)
        else:
            keys = rng.integers(0, 30, rng.integers(0, 10))
            pr = int(rng.integers(0, 5))
            fast.fetch_many(keys, pr)
            heap.fetch_many(keys.tolist(), pr)
        assert fast.score == heap.score, (seed, step)
        assert fast.seq == heap.seq and fast.epoch == heap.epoch, (seed,
                                                                  step)
        assert len(fast) == len(heap), (seed, step)


_ENGINE_ARGS = (st.integers(0, 2**31 - 1),   # seed
                st.integers(1, 9),           # cap
                st.integers(0, 5),           # eviction_speed
                st.integers(3, 30))          # steps


@settings(max_examples=15, deadline=None)
@given(*_ENGINE_ARGS)
def test_priority_engine_matches_heap(seed, cap, ev, n_steps):
    _check_engine_vs_heap(seed, cap, ev, n_steps)


@pytest.mark.slow
@settings(max_examples=120, deadline=None)
@given(*_ENGINE_ARGS)
def test_priority_engine_matches_heap_deep(seed, cap, ev, n_steps):
    _check_engine_vs_heap(seed, cap, ev, n_steps)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 8), st.integers(0, 40))
def test_engine_heap_slow_victim_for_victim(seed, cap, n_steps):
    """Three-way Algorithm 1/2 protocol: array engine, heap reference, and
    the literal O(capacity) transcription must evict the same victim at
    every ``populate`` and agree on membership throughout."""
    rng = np.random.default_rng(seed)
    bufs = (RecMGBuffer(cap, 4), HeapRecMGBuffer(cap, 4),
            SlowRecMGBuffer(cap, 4, clamp=False))
    fast, heap, slow = bufs
    for step in range(n_steps):
        if rng.integers(0, 3) == 0 and len(heap):
            victims = {b.populate() for b in bufs}
            assert len(victims) == 1, (seed, step, victims)
        else:
            key = int(rng.integers(0, 25))
            bit = int(rng.integers(0, 2))
            if rng.integers(0, 2):
                for b in bufs:
                    b.load_embeddings([], [], [key])
            else:
                for b in bufs:
                    b.load_embeddings([key], [bit], [])
        assert set(fast.score) == set(heap.score) == set(slow.priority), \
            (seed, step)


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 2),
       st.integers(0, len(PLACEMENTS) - 1), st.integers(1, 64))
def test_plan_invariants(seed, n_shards_bit, placement_idx, cap):
    """Any plan: maps are exact inverses, budgets within bounds, the full
    budget is allocated whenever it fits."""
    rng = np.random.default_rng(seed)
    rows = rng.integers(8, 50, size=int(rng.integers(2, 6)))
    n_shards = (1, 2, 4)[n_shards_bit]
    if PLACEMENTS[placement_idx] == "table":
        n_shards = min(n_shards, len(rows))
    n = int(rows.sum())
    freq = rng.integers(0, 100, size=n)
    plan = make_plan(rows, n_shards, cap, PLACEMENTS[placement_idx],
                     frequencies=freq)
    plan.check()
    want = max(n_shards, min(cap, n))
    assert int(plan.capacities.sum()) == min(want, n)
