"""Drift detector + drift-adaptive serving tests.

Hand-built access streams pin the detector's trigger/no-trigger behavior
and its telemetry counters (step change fires, slow churn under the
threshold and stationary streams don't), and the acceptance-criterion
test replays the diurnal drift regime: with ``adapt`` on, recmg's
post-switch steady-state hit rate must recover to within 10% of its
pre-switch steady state (the frozen model, by contrast, stays degraded).
"""
import numpy as np
import pytest

from repro.runtime.drift import AdaptiveController, DriftConfig, DriftDetector

CFG = DriftConfig(window=100, hot_k=10, jaccard_min=0.35, hitrate_drop=0.12,
                  warmup_windows=2, cooldown_windows=1)


def _feed(det, ids, hit_rate=0.9, batch=20):
    """Feed a flat id stream as fixed-size batches with a given hit rate;
    returns the windows at which the detector fired."""
    fired = []
    for b in range(len(ids) // batch):
        chunk = ids[b * batch: (b + 1) * batch]
        if det.observe(chunk, int(round(hit_rate * len(chunk)))):
            fired.append(det.windows)
    return fired


def test_no_change_never_triggers():
    det = DriftDetector(CFG)
    ids = np.tile(np.arange(10), 80)  # same 10-key hot set every window
    assert _feed(det, ids) == []
    t = det.as_dict()
    assert t["windows"] == 8 and t["triggers"] == 0
    assert t["last_jaccard"] == 1.0 and t["min_jaccard"] == 1.0
    assert t["accesses"] == 800
    assert t["last_window_hit_rate"] == pytest.approx(0.9)


def test_step_change_triggers_jaccard_once():
    det = DriftDetector(CFG)
    ids = np.concatenate([np.tile(np.arange(10), 40),        # 4 windows old
                          np.tile(np.arange(100, 110), 40)])  # 4 windows new
    fired = _feed(det, ids)
    t = det.as_dict()
    # Exactly one trigger, at the first full post-switch window (window 5),
    # with the hot sets fully disjoint there.
    assert fired == [5]
    assert t["jaccard_triggers"] == 1 and t["triggers"] == 1
    assert t["min_jaccard"] == 0.0
    # After the switch the hot set is stable again: no re-triggering.
    assert t["last_jaccard"] == 1.0


def test_slow_churn_stays_below_threshold():
    """One hot id rotates out per window: Jaccard 9/11 ~ 0.82 >> 0.35."""
    det = DriftDetector(CFG)
    fired = []
    for w in range(8):
        ids = np.tile(np.arange(w, w + 10), 10)
        if det.observe(ids, int(0.9 * len(ids))):
            fired.append(det.windows)
    assert fired == []
    t = det.as_dict()
    assert t["triggers"] == 0
    assert t["last_jaccard"] == pytest.approx(9 / 11, abs=1e-3)


def test_hit_rate_drop_triggers_without_hotset_motion():
    """Same keys, collapsing hit rate (e.g. capacity stolen by a co-tenant):
    the symptom signal fires even though the Jaccard signal is blind."""
    det = DriftDetector(CFG)
    ids = np.arange(100)
    for _ in range(3):
        det.observe(ids, 90)  # baseline windows at 0.9
    fired = det.observe(ids, 40)  # 0.4 << 0.9 - 0.12
    t = det.as_dict()
    assert fired and t["hitrate_triggers"] == 1 and t["jaccard_triggers"] == 0
    assert t["last_window_hit_rate"] == pytest.approx(0.4)
    # The post-drift rate is adopted as the new baseline: holding at 0.4
    # does not re-trigger...
    assert not det.observe(ids, 40)
    assert not det.observe(ids, 40)
    # ...but a second collapse does (cooldown of 1 window has passed).
    assert det.observe(ids, 10)
    assert det.as_dict()["hitrate_triggers"] == 2


def test_warmup_and_cooldown_suppress_triggers():
    det = DriftDetector(CFG)
    # A switch inside the warmup (first two windows) must not fire.
    det.observe(np.tile(np.arange(10), 10), 90)
    fired = det.observe(np.tile(np.arange(50, 60), 10), 90)
    assert not fired and det.as_dict()["triggers"] == 0
    # Post-warmup switch fires; an immediate second switch is inside the
    # cooldown window and is suppressed; the one after fires again.
    det.observe(np.tile(np.arange(50, 60), 10), 90)       # window 3
    assert det.observe(np.tile(np.arange(100, 110), 10), 90)   # fires
    assert not det.observe(np.tile(np.arange(200, 210), 10), 90)  # cooldown
    assert det.observe(np.tile(np.arange(300, 310), 10), 90)   # re-armed
    assert det.as_dict()["triggers"] == 2


class _FakeStore:
    def __init__(self, resident):
        self.resident = set(resident)

    def resident_mask(self, ids):
        return np.asarray([int(i) in self.resident for i in ids])


def test_controller_refresh_items_protect_and_prefetch():
    """On trigger the controller enters online mode: it prefetches the
    hot non-resident rows, and from then on re-ranks every batch's chunk
    against the live pool (hot -> keep-bit 1)."""
    store = _FakeStore(resident=range(100, 105))
    ctl = AdaptiveController(store, capacity=10, cfg=CFG)
    old = np.tile(np.arange(10), 10)
    new = np.tile(np.arange(100, 110), 10)
    for _ in range(3):
        assert ctl.on_batch(old, 90) == []  # pre-drift: model untouched
    items = ctl.on_batch(new, 10)
    assert ctl.detector.triggers == 1 and ctl.refreshes == 1
    # One prefetch item for the non-resident hot rows + one re-rank item.
    (_, _, pf), (trunk, bits, _) = items
    assert set(pf.tolist()) == set(range(105, 110))
    assert np.array_equal(trunk, np.arange(100, 110))
    assert bits.all()  # whole chunk is in the live hot pool
    # Next batch: pool exists -> re-rank continues without a new trigger.
    items = ctl.on_batch(np.tile(np.arange(100, 110), 10), 90)
    assert ctl.detector.triggers == 1
    assert any(t.size for t, _, _ in items)
    d = ctl.as_dict()
    assert d["refreshes"] >= 1 and d["rerank_rows"] >= 20


# ---------------------------------------------------------------------------
# Acceptance criterion: --adapt recovers recmg after a regime switch
# ---------------------------------------------------------------------------


def test_adapt_recovers_hit_rate_after_regime_switch():
    from repro.runtime.drift import DriftConfig as DC
    from repro.workloads import (phase_steady_hit_rates, replay_scenario,
                                 scenario)

    spec = scenario("diurnal", n_tables=4, rows_per_table=512,
                    n_accesses=12288, seed=0, n_phases=2)
    kw = dict(policy="recmg", batch=256, profile_frac=0.5,
              capacity_frac=0.12)
    frozen = replay_scenario(spec, **kw)
    adapt = replay_scenario(spec, adapt=True,
                            adapt_cfg=DC(window=1024, hot_k=128), **kw)
    pre_f, post_f = phase_steady_hit_rates(frozen, 2)
    pre_a, post_a = phase_steady_hit_rates(adapt, 2)
    assert pre_a == pytest.approx(pre_f)  # identical until the switch
    # The frozen model decays materially after the switch...
    assert post_f < pre_f - 0.05
    # ...while adaptation recovers to within 10% of the pre-switch steady
    # state (the ISSUE's acceptance bar) and beats frozen outright.
    assert post_a >= 0.9 * pre_a
    assert post_a > post_f + 0.05
    assert adapt["drift"]["triggers"] >= 1
    assert adapt["drift"]["refreshes"] >= 1


def test_adapt_wired_through_pipelined_runtime():
    """The PipelinedRuntime batch hook must deliver adaptation items
    through the prefetch engine — counters move exactly as if the items
    had been staged synchronously."""
    from repro.core.tiered import TieredEmbeddingStore
    from repro.runtime import PipelinedRuntime, RuntimeConfig

    rng = np.random.default_rng(0)
    host = rng.normal(size=(64, 8)).astype(np.float32)
    store = TieredEmbeddingStore(host, 16, policy="lru")
    calls = []

    def hook(ids, hits, b):
        calls.append((ids.size, hits, b))
        return [(np.empty(0, np.int64), np.empty(0, np.int64),
                 np.asarray([60, 61], np.int64))]

    rt = PipelinedRuntime(store, RuntimeConfig(max_batch=4, compute_us=10.0),
                          batch_hook=hook)
    ids = np.arange(24).reshape(12, 2)  # 12 requests -> 3 batches of 4
    rt.run(iter(ids), lambda b, emb: (0.0, []))
    assert [c[2] for c in calls] == [0, 1, 2]  # one hook call per batch
    assert all(c[0] == 8 for c in calls)
    # The hook's prefetch items landed: 60/61 resident without a demand
    # access, flagged as prefetched.
    assert store.resident_mask(np.asarray([60, 61])).all()
    hits_before = store.stats.prefetch_hits
    store.lookup(np.asarray([60, 61]))
    assert store.stats.prefetch_hits == hits_before + 2
