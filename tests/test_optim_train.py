"""Optimizer + train-step: convergence, clipping, microbatch equivalence,
checkpoint/restart through the real launcher."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.steps import make_train_step
from repro.models.model_api import build
from repro.optim.adamw import OptConfig, apply_updates, init_opt


def test_adamw_converges_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = init_opt(cfg, params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, m = apply_updates(cfg, params, opt, g)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_grad_clipping():
    cfg = OptConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt(cfg, params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, m = apply_updates(cfg, params, opt, g)
    assert float(m["grad_norm"]) == pytest.approx(100.0)


def test_schedule_warmup_and_decay():
    from repro.optim.adamw import schedule

    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.asarray(1))) < 0.2
    assert float(schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_microbatch_equivalence():
    cfg = get_config("smollm-135m").reduced()
    run = RunConfig()
    bundle = build(cfg, run)
    params = bundle.init(jax.random.PRNGKey(0))
    opt_cfg = OptConfig(lr=1e-3)
    opt = init_opt(opt_cfg, params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    s1 = make_train_step(bundle, opt_cfg, 1)
    s4 = make_train_step(bundle, opt_cfg, 4)
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, opt, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p1),
                        jax.tree_util.tree_leaves(p4))
    )
    assert d < 1e-4  # identical up to accumulation-order rounding


def test_train_launcher_and_resume(tmp_path):
    from repro.launch.train import main

    ck = str(tmp_path / "ck")
    losses = main(["--arch", "smollm-135m", "--reduced", "--steps", "6",
                   "--seq-len", "64", "--batch", "2", "--ckpt", ck,
                   "--ckpt-every", "3", "--log-every", "100"])
    assert losses[-1] < losses[0] * 1.2
    # Resume: starts from step 6 checkpoint, runs 2 more.
    more = main(["--arch", "smollm-135m", "--reduced", "--steps", "8",
                 "--seq-len", "64", "--batch", "2", "--ckpt", ck,
                 "--log-every", "100"])
    assert len(more) == 2


def test_train_launcher_grad_compression():
    from repro.launch.train import main

    losses = main(["--arch", "smollm-135m", "--reduced", "--steps", "4",
                   "--seq-len", "32", "--batch", "2",
                   "--grad-compression", "int8_ef", "--log-every", "100"])
    assert np.isfinite(losses).all()
