"""Belady MIN: exact optimality vs brute force (hypothesis property test) and
label semantics."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.belady import belady_labels, belady_sim, next_use_times


def brute_force_opt_hits(keys, capacity):
    """Exhaustive-ish reference: greedy MIN with bypass, O(N*C)."""
    nxt = next_use_times(keys)
    cache = {}
    hits = 0
    for i, k in enumerate(keys):
        k = int(k)
        if cache.get(k) == i:
            hits += 1
            cache[k] = int(nxt[i])
            continue
        if len(cache) >= capacity:
            far_k = max(cache, key=cache.get)
            if cache[far_k] <= nxt[i]:
                continue  # bypass
            del cache[far_k]
        cache[k] = int(nxt[i])
    return hits


@settings(max_examples=60, deadline=None)
@given(
    keys=st.lists(st.integers(0, 12), min_size=5, max_size=120),
    capacity=st.integers(1, 8),
)
def test_belady_matches_bruteforce(keys, capacity):
    keys = np.array(keys)
    hits, _ = belady_sim(keys, capacity)
    assert hits.sum() == brute_force_opt_hits(keys, capacity)


@settings(max_examples=30, deadline=None)
@given(
    keys=st.lists(st.integers(0, 20), min_size=10, max_size=150),
    capacity=st.integers(1, 10),
)
def test_belady_beats_lru(keys, capacity):
    from repro.core.cache_sim import FALRU, simulate

    keys = np.array(keys)
    hits, _ = belady_sim(keys, capacity)
    lru = simulate(keys, FALRU(capacity))
    assert hits.sum() >= lru.hits  # OPT is optimal


def test_label_semantics():
    # a b a b with capacity 1: first a and first b cannot both be kept.
    keys = np.array([1, 2, 1, 2])
    labels, hits, miss = belady_labels(keys, 1)
    assert hits.sum() <= 1
    # capacity 2: both kept, second accesses hit.
    labels, hits, miss = belady_labels(keys, 2)
    assert list(hits) == [False, False, True, True]
    assert list(labels) == [1, 1, 0, 0]
    assert list(miss) == [True, True, False, False]


def test_never_reused_bypassed():
    keys = np.array([1, 2, 3, 4, 1])  # 2,3,4 never reused
    labels, hits, _ = belady_labels(keys, 1)
    assert hits[4]  # OPT keeps 1 (bypass of 2,3,4)
    assert labels[0] == 1
