"""Scenario regression matrix: every workload regime x {lru, recmg} x
shard count N in {1, 2}, served through the model-free scenario harness
(:func:`repro.workloads.replay_scenario` — the exact serving semantics of
``serve_trace`` minus the dense forward).

Pinned invariants:

* **Seeded determinism** — every cell's counters are reproduced exactly
  by the golden files (``tests/golden/scenario_*.json``, refreshed via
  the existing ``--update-golden`` flow), and a direct double-run check
  covers the harness itself.
* **N=1 sharded collapse** — serving through ``ShardedTieredStore`` with
  one shard is counter-identical to the plain store, per scenario.
* **recmg <= LRU on the paper-target regimes** — on the stationary-skew
  and churn scenarios the ML policy's on-demand fetch count must not
  exceed LRU's (the paper's 2.2-2.8x claim direction).
* **learned < frequency heuristic** — every paper-target cell also runs
  ``model="learned"`` (the trained dual models,
  :class:`repro.core.model_runtime.LearnedRecMGModel`); the trained
  models must need strictly fewer on-demand fetches than the frequency
  stand-in, and the learned cells get their own golden files plus a
  training-determinism double run.
* **replay == generated** — the replay adapter serving a saved zipf_mid
  trace produces the zipf_mid cell's metrics exactly.

The fast lane runs one representative scenario per regime family at N=1
plus two N=2 cells; the extra skews and remaining N=2 cells ride the slow
lane (CI's tests-slow job).
"""
import json
from functools import lru_cache

import numpy as np
import pytest

from repro.workloads import (PAPER_TARGET_SCENARIOS, SCENARIOS,
                             golden_metrics, replay_scenario, scenario)
from test_golden_trace import _check_golden

# One scale for the whole matrix: small enough for tens of ms per cell,
# large enough that every regime's structure (phases, burst, tenants)
# shows up in the counters.
SCALE = dict(n_tables=4, rows_per_table=512, n_accesses=8192, seed=0)
BATCH = 256
CAP_FRAC = 0.12

FAST_SCENARIOS = ("zipf_mid", "diurnal", "flash_crowd", "multi_tenant",
                  "churn")
FAST_N2 = ("zipf_mid", "diurnal")
# Learned cells train the dual models (~20-30s each at this scale): two
# representative regimes on the fast lane, the rest on the slow lane.
LEARNED_FAST = ("zipf_mid", "churn")


def _cells():
    for name in sorted(SCENARIOS):
        for policy in ("lru", "recmg"):
            for n in (1, 2):
                slow = (name not in FAST_SCENARIOS
                        or (n == 2 and name not in FAST_N2))
                marks = [pytest.mark.slow] if slow else []
                yield pytest.param(name, policy, n,
                                   id=f"{name}-{policy}-n{n}", marks=marks)


@lru_cache(maxsize=None)
def _run_cell(name: str, policy: str, n: int) -> dict:
    res = replay_scenario(scenario(name, **SCALE), policy=policy,
                          capacity_frac=CAP_FRAC, batch=BATCH,
                          shards=0 if n == 1 else n)
    return res


@lru_cache(maxsize=None)
def _run_learned_cell(name: str) -> dict:
    return replay_scenario(scenario(name, **SCALE), policy="recmg",
                           model="learned", capacity_frac=CAP_FRAC,
                           batch=BATCH)


def _learned_params():
    return [pytest.param(n, marks=[] if n in LEARNED_FAST
                         else [pytest.mark.slow])
            for n in sorted(PAPER_TARGET_SCENARIOS)]


@pytest.mark.parametrize("name,policy,n", list(_cells()))
def test_scenario_golden(name, policy, n, update_golden):
    res = _run_cell(name, policy, n)
    metrics = golden_metrics(res)
    if n > 1:
        sh = res["shard"]
        metrics["shard"] = {k: sh[k] for k in
                            ("n_shards", "per_shard_lookups",
                             "per_shard_hit_rate", "per_shard_evictions")}
        assert sum(sh["per_shard_lookups"]) == metrics["lookups"]
    # Counters must be lossless JSON (cross-run aggregation contract).
    assert json.loads(json.dumps(metrics)) == metrics
    _check_golden(f"scenario_{name}_{policy}_n{n}", metrics, update_golden)


@pytest.mark.parametrize("name", [
    pytest.param(n, marks=[] if n in ("zipf_mid", "diurnal")
                 else [pytest.mark.slow])
    for n in sorted(SCENARIOS)])
@pytest.mark.parametrize("policy", ["lru", "recmg"])
def test_n1_sharded_collapse(name, policy):
    """One shard == no sharding, counter for counter, per scenario
    (fast lane covers two representative regimes; the rest ride the
    slow lane alongside their matrix cells)."""
    plain = golden_metrics(_run_cell(name, policy, 1))
    sharded = replay_scenario(scenario(name, **SCALE), policy=policy,
                              capacity_frac=CAP_FRAC, batch=BATCH, shards=1)
    for k in ("batches", "lookups", "hits", "prefetch_hits",
              "on_demand_rows", "evictions"):
        assert sharded[k] == plain[k], (k, name, policy)


@pytest.mark.parametrize("name", sorted(PAPER_TARGET_SCENARIOS))
def test_recmg_on_demand_not_worse_than_lru(name, update_golden):
    """The paper's claim direction on its target regimes: the ML policy
    fetches no more rows on demand than LRU (it should fetch fewer)."""
    if update_golden:
        pytest.skip("refresh run")
    lru = _run_cell(name, "lru", 1)
    recmg = _run_cell(name, "recmg", 1)
    assert recmg["on_demand_rows"] <= lru["on_demand_rows"], name
    assert recmg["hit_rate"] >= lru["hit_rate"], name


@pytest.mark.parametrize("name", _learned_params())
def test_scenario_learned_golden(name, update_golden):
    """Every paper-target cell served by the *trained* dual models is
    golden-pinned like the heuristic cells — training, bucketed jitted
    inference and serving are all inside the reproduced bytes."""
    res = _run_learned_cell(name)
    metrics = golden_metrics(res)
    metrics["model"] = res["model"]
    assert json.loads(json.dumps(metrics)) == metrics
    _check_golden(f"scenario_{name}_learned_n1", metrics, update_golden)


@pytest.mark.parametrize("name", _learned_params())
def test_learned_beats_frequency_heuristic(name, update_golden):
    """The ISSUE's acceptance bar: on every paper-target cell the trained
    models need strictly fewer on-demand fetches than the frequency
    heuristic (and at most LRU's) — learning must buy something real over
    the deterministic stand-in."""
    if update_golden:
        pytest.skip("refresh run")
    learned = _run_learned_cell(name)
    freq = _run_cell(name, "recmg", 1)
    lru = _run_cell(name, "lru", 1)
    # The bar is the paper's metric — rows fetched on demand from the
    # slow tier (per-lookup hit rate can sit within noise of the
    # heuristic's while the fetch volume is strictly lower).
    assert learned["on_demand_rows"] < freq["on_demand_rows"], name
    assert learned["on_demand_rows"] <= lru["on_demand_rows"], name


@pytest.mark.slow
def test_learned_training_determinism_double_run():
    """Two fresh train+serve runs of a learned cell are byte-identical —
    training (seeded jax init + numpy shuffles), bucketed inference and
    serving all reproduce, so the learned golden files are stable."""
    spec = scenario("zipf_mid", **SCALE)
    kw = dict(policy="recmg", model="learned", capacity_frac=CAP_FRAC,
              batch=BATCH)
    a = replay_scenario(spec, **kw)
    b = replay_scenario(spec, **kw)
    assert golden_metrics(a) == golden_metrics(b)
    assert a["batch_hit_rates"] == b["batch_hit_rates"]
    assert a["learned"] == b["learned"]


def test_seeded_determinism_double_run():
    """Two fresh harness runs of one spec are byte-identical (the golden
    flow assumes it; this pins it without golden indirection)."""
    spec = scenario("multi_tenant", **SCALE)
    a = replay_scenario(spec, policy="recmg", capacity_frac=CAP_FRAC,
                        batch=BATCH)
    b = replay_scenario(spec, policy="recmg", capacity_frac=CAP_FRAC,
                        batch=BATCH)
    assert golden_metrics(a) == golden_metrics(b)
    assert a["batch_hit_rates"] == b["batch_hit_rates"]


def test_replay_cell_matches_generated(tmp_path):
    """The replay adapter serving a saved trace reproduces the generated
    scenario's cell exactly — external traces are first-class."""
    from repro.core.trace import save_trace
    from repro.workloads import make_spec, make_trace

    spec = scenario("zipf_mid", **SCALE)
    path = tmp_path / "zipf_mid.npz"
    save_trace(make_trace(spec), path)
    replayed = replay_scenario(make_spec("replay", path=str(path)),
                               policy="lru", capacity_frac=CAP_FRAC,
                               batch=BATCH)
    want = dict(golden_metrics(_run_cell("zipf_mid", "lru", 1)))
    got = dict(golden_metrics(replayed))
    assert got.pop("regime") == "replay" and want.pop("regime") == "stationary"
    assert got == want


def test_drift_scenario_adapt_recovers_in_matrix():
    """The matrix-level view of the adaptation acceptance bar: on the
    diurnal regime, adaptive recmg ends with a higher aggregate hit rate
    than the frozen model and the drift telemetry shows the trigger."""
    spec = scenario("diurnal", **SCALE)
    kw = dict(policy="recmg", capacity_frac=CAP_FRAC, batch=BATCH,
              profile_frac=0.25)
    frozen = replay_scenario(spec, **kw)
    adapt = replay_scenario(spec, adapt=True, **kw)
    assert adapt["hit_rate"] > frozen["hit_rate"]
    assert adapt["drift"]["triggers"] >= 1
