"""Layer-level numerics: blocked attention == plain attention, GQA, sliding
windows, chunked selective scan == sequential reference, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import layers as L


def _qkv(key, B, S, H, K, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("S,H,K,bq,bk", [
    (256, 4, 2, 64, 64),
    (384, 6, 3, 128, 64),   # ragged block counts
    (512, 5, 5, 128, 128),  # MHA, odd head count
])
def test_blocked_attention_matches_plain(S, H, K, bq, bk):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, S, H, K, 64)
    ref = L.plain_attention(q, k, v, causal=True)
    # Force the blocked path by setting small thresholds.
    out = L.blocked_causal_attention(q, k, v, bq=bq, bk=bk)
    # S <= 2048 short-circuits to plain; call the internals directly instead.
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


def test_blocked_attention_long_path():
    S = 4096  # > 2048 threshold -> actually blocked
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, S, 2, 1, 32)
    ref = L.plain_attention(q, k, v, causal=True)
    out = L.blocked_causal_attention(q, k, v, bq=512, bk=512)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_sliding_window_attention():
    S, W = 4096, 256
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, S, 2, 2, 32)
    ref = L.plain_attention(q, k, v, causal=True, window=W)
    out = L.blocked_causal_attention(q, k, v, window=W, bq=512, bk=512)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)


def test_decode_attention_matches_last_row():
    B, S, H, K, hd = 2, 64, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, H, K, hd)
    full = L.plain_attention(q, k, v, causal=True)
    out = L.decode_attention(q[:, -1:], k, v, jnp.asarray(S))
    np.testing.assert_allclose(out[:, 0], full[:, -1], rtol=1e-5, atol=1e-5)


def _mamba_sequential_ref(p, cfg, xc, z):
    """Literal per-step recurrence (the chunked scan's oracle)."""
    B, S, Di = xc.shape
    dt, Bm, Cm = L._ssm_params(p, cfg, xc)
    A = -jnp.exp(p["A_log"])
    h = jnp.zeros((B, Di, cfg.ssm_state))
    ys = []
    xf = xc.astype(jnp.float32)
    for t in range(S):
        dA = jnp.exp(dt[:, t, :, None] * A)
        dBx = (dt[:, t] * xf[:, t])[..., None] * Bm[:, t, None, :]
        h = dA * h + dBx
        ys.append(jnp.einsum("bdn,bn->bd", h, Cm[:, t]))
    y = jnp.stack(ys, axis=1) + p["D_skip"] * xf
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y, h


def test_chunked_selective_scan_matches_sequential():
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      vocab=64, ssm_state=8, d_inner=64, dt_rank=4,
                      ssm_chunk=16, param_dtype="float32",
                      compute_dtype="float32")
    p = L.init_mamba(jax.random.PRNGKey(0), cfg)
    xc = jax.random.normal(jax.random.PRNGKey(1), (2, 50, 64))  # ragged
    z = jax.random.normal(jax.random.PRNGKey(2), (2, 50, 64))
    y, h = L.selective_scan(p, cfg, xc, z)
    y_ref, h_ref = _mamba_sequential_ref(p, cfg, xc, z)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h, h_ref, rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_full():
    cfg = ModelConfig(name="t", family="ssm", n_layers=1, d_model=32,
                      vocab=64, ssm_state=8, d_inner=64, dt_rank=4,
                      ssm_chunk=8, param_dtype="float32",
                      compute_dtype="float32")
    p = L.init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    full, (conv_tail, h) = L.mamba_block(p, cfg, x)
    # Step through one token at a time.
    conv = jnp.zeros((2, cfg.conv_width - 1, 64))
    hs = jnp.zeros((2, 64, 8))
    outs = []
    for t in range(12):
        o, conv, hs = L.mamba_decode_block(p, cfg, x[:, t:t+1], conv, hs)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hs, h, rtol=3e-4, atol=3e-4)


def test_moe_capacity_vs_dense_when_droppless():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      d_ff=32, vocab=64, n_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=16.0, param_dtype="float32",
                      compute_dtype="float32")
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 16))
    y_cap, aux = L.moe_block(p, cfg, x)
    y_dense, _ = L.moe_block(p, cfg, x, dense_route=True)
    np.testing.assert_allclose(y_cap, y_dense, rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_gracefully():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      d_ff=32, vocab=64, n_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=0.25, param_dtype="float32",
                      compute_dtype="float32")
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    y, _ = L.moe_block(p, cfg, x)
    assert jnp.all(jnp.isfinite(y))


def test_rope_relative_shift_property():
    # <q(p), k(p')> depends only on p - p' for rope'd vectors.
    hd = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = L.rope(q, jnp.array([[pq]]), 10000.0)
        kr = L.rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))
    a = dot_at(5, 3)
    b = dot_at(105, 103)
    assert abs(a - b) < 1e-3
