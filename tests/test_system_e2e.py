"""End-to-end system behaviour: the full RecMG pipeline (trace -> Belady
labels -> train both models -> co-managed buffer) reduces on-demand fetches
vs the production LRU baseline — the paper's headline claim, at test scale."""
import pytest

from repro.core.belady import belady_labels
from repro.core.cache_sim import FALRU, SALRU, simulate
from repro.core.caching_model import CachingModelConfig, train_caching_model
from repro.core.features import make_windows
from repro.core.recmg import precompute_outputs, run_recmg


@pytest.mark.slow
def test_recmg_end_to_end_beats_lru(tiny_trace):
    tr = tiny_trace
    keys = tr.global_id
    cap = int(0.15 * tr.unique_count())

    labels, opt_hits, _ = belady_labels(keys, cap)
    lru = simulate(keys, FALRU(cap))
    lru32 = simulate(keys, SALRU(cap))

    mcfg = CachingModelConfig(n_tables=tr.n_tables)
    data = make_windows(tr, labels=labels)
    cparams, _ = train_caching_model(data, mcfg, epochs=3, batch_size=256)
    outputs = precompute_outputs(tr, caching=(cparams, mcfg))
    recmg = run_recmg(tr, cap, outputs, use_prefetch=False)

    # Sanity ordering: OPT >= RecMG(learned bits); RecMG accounted fully.
    assert recmg.hits <= opt_hits.sum()
    assert recmg.accesses == lru.accesses == len(keys)
    # The learned policy should at least be in LRU's league at test scale
    # (benchmarks/ runs the full-size comparison where it clearly wins).
    assert recmg.hits > 0.8 * lru.hits


def test_oracle_recmg_strictly_beats_lru(tiny_trace):
    tr = tiny_trace
    keys = tr.global_id
    cap = int(0.1 * tr.unique_count())
    labels, _, _ = belady_labels(keys, cap)
    outputs = precompute_outputs(tr)
    recmg = run_recmg(tr, cap, outputs, oracle_bits=labels,
                      use_prefetch=False)
    lru = simulate(keys, FALRU(cap))
    assert recmg.on_demand < lru.on_demand
