"""Voyager-lite and Mockingjay-lite (the paper's remaining baselines)."""
import numpy as np

from repro.core.cache_sim import MockingjayLite, make_cache, simulate
from repro.core.features import make_windows
from repro.core.voyager import (VoyagerConfig, label_memory_bytes,
                                predict_next, train_voyager)


def test_voyager_label_memory_blowup():
    paper = VoyagerConfig(n_vectors=62_000_000)
    bytes_needed = label_memory_bytes(paper, 400_000_000)
    assert bytes_needed > 512e9  # the paper's OOM on 512GB DDR, reproduced


def test_voyager_trains_and_predicts(tiny_trace):
    tr = tiny_trace
    cfg = VoyagerConfig(n_vectors=tr.n_vectors, page_size=64)
    data = make_windows(tr, stride=15)
    n = int(len(data) * 0.8)
    params, losses = train_voyager(data.batch(np.arange(n)), cfg,
                                   tr.n_tables, epochs=1)
    assert losses[-1] < losses[0]
    pred = predict_next(params, cfg, data.batch(np.arange(n, len(data))))
    assert pred.shape == (len(data) - n,)
    assert (pred >= 0).all() and (pred < cfg.n_pages * cfg.page_size).all()


def test_mockingjay_basic():
    c = MockingjayLite(64, ways=8, table_of=lambda k: 0)
    keys = np.array(list(range(32)) * 20)
    res = simulate(keys, c)
    assert res.hit_rate > 0.8  # working set fits: reuse prediction retains


def test_mockingjay_in_registry():
    assert make_cache("mockingjay", 128).name == "mockingjay"
