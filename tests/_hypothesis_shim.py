"""Import gate for ``hypothesis``: real library when installed, otherwise a
tiny deterministic fallback so the tier-1 suite stays green without the
package (it is an optional dev dependency — see requirements-dev.txt).

The fallback implements just the surface these tests use — ``given`` /
``settings`` decorators, ``st.integers`` / ``st.floats`` / ``st.lists`` /
``st.tuples``, and ``hnp.arrays`` — drawing a fixed number of random
examples from a seeded generator.  No shrinking, no edge-case database:
when you want real property testing, ``pip install hypothesis`` and the
same test code picks it up unchanged.
"""
import functools
import inspect
import zlib

import numpy as np

__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    from hypothesis.extra import numpy as hnp

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw  # draw(rng) -> value

    class st:  # noqa: N801 - mimics the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, width=64, **_kw):
            return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(r):
                n = int(r.integers(min_size, max_size + 1))
                return [elements.draw(r) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda r: tuple(e.draw(r) for e in elems))

    class hnp:  # noqa: N801
        @staticmethod
        def arrays(dtype, shape, elements=None):
            shape = (shape,) if isinstance(shape, int) else tuple(shape)

            def draw(r):
                if elements is None:
                    return r.normal(size=shape).astype(dtype)
                flat = [elements.draw(r) for _ in range(int(np.prod(shape)))]
                return np.array(flat, dtype=dtype).reshape(shape)
            return _Strategy(draw)

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                # Deterministic per-test seed; cap examples (the fallback
                # has no shrinker, so failures replay exactly).  The cap
                # is high enough for the slow-lane property suite's
                # >=100-case budget (tests/test_property_equivalence.py).
                n = min(getattr(wrapper, "_max_examples", 20), 200)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    drawn = [s.draw(rng) for s in arg_strategies]
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)
            # Strategy-filled params must not look like pytest fixtures.
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return deco
