"""Deterministic fault injection + shard failover.

Covers the plan grammar, the injector state machine, hot-row replication
planning, the engine's drain-after-kill contract, and — the point of the
layer — the failover contract on every serving surface (sync loop,
pipelined runtime, admission-controlled runtime):

* **zero wrong answers**: every served row is byte-identical to the
  host value for its id, or the all-zero degraded default;
* **exact ``ft.*`` reconciliation** (``served == primary + replica +
  degraded``; ``retries == succeeded + exhausted``);
* **bounded stall**: a dead shard contributes nothing to the critical
  path and retry episodes never outlast their deadline;
* **byte determinism**: the same plan over the same trace twice gives
  identical outputs and counters.
"""
import numpy as np
import pytest

from repro.core.sharded_serving import ShardedTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.obs import MetricsRegistry
from repro.obs.reconcile import check_ft, reconcile
from repro.runtime.admission import AdmissionConfig
from repro.runtime.faults import (FaultEvent, FaultInjector, FaultPlan,
                                  FtStats)
from repro.runtime.pipeline import PipelinedRuntime, RuntimeConfig
from repro.runtime.prefetch_engine import PrefetchEngine
from repro.runtime.telemetry import RuntimeTelemetry
from repro.sharding.embedding_shard import make_plan
from repro.workloads import make_spec, replay_chaos

EMPTY = np.empty(0, np.int64)
ROWS = [96, 64, 96, 64]
N_VEC = sum(ROWS)


def _host(n=N_VEC, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _ids(n_acc=3072, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.15, size=n_acc), N_VEC) - 1
    return rng.permutation(N_VEC)[ranks].astype(np.int64)


# ---------------- plan grammar ----------------


def test_plan_parse_grammar():
    p = FaultPlan.parse("kill:1@mid,recover:1@75%,slow:0x4@25%..75%,"
                        "flaky:2x0.3@10..40,kill@5000us", seed=7)
    kinds = [e.kind for e in p.events]
    assert kinds == ["kill", "recover", "slow", "flaky", "kill"]
    assert p.events[0].frac and p.events[0].at == 0.5
    assert p.events[2].factor == 4.0 and p.events[2].until == 0.75
    assert p.events[3].at == 10 and p.events[3].until == 40
    assert p.events[4].shard == 0 and p.events[4].unit == "us"
    assert p.seed == 7 and p.needs_horizon
    # flaky factor defaults to 0.5, kill/recover to 1.0
    assert FaultPlan.parse("flaky:1@0..9").events[0].factor == 0.5
    assert not FaultPlan.parse("kill:1@3").needs_horizon


@pytest.mark.parametrize("text", [
    "kill:1@mid,recover:1@75%", "slow:0x4@25%..75%",
    "flaky:2x0.4@10..40", "kill@5000us", "recover:3@end",
])
def test_plan_describe_round_trips(text):
    p = FaultPlan.parse(text)
    assert FaultPlan.parse(p.describe()).events == p.events


@pytest.mark.parametrize("bad", [
    "explode:1@5",          # unknown kind
    "slow:0x0.5@1..3",      # slow factor < 1
    "flaky:0x1.5@1..3",     # probability > 1
    "slow:0x2@10..50%",     # mixed time units in one window
    "kill:1",               # no @time
    "kill:1@",              # empty time
])
def test_plan_parse_rejects(bad):
    with pytest.raises(ValueError):
        FaultPlan.parse(bad)


# ---------------- injector state machine ----------------


def test_injector_timeline_transitions():
    plan = FaultPlan.parse("kill:1@2,slow:0x3@3..6,flaky:1x1.0@4..7,"
                           "recover:1@5")
    inj = FaultInjector(plan, n_shards=2)
    assert inj.armed and inj.up.all()
    assert inj.poll(0, 0.0) == [] and inj.poll(1, 0.0) == []
    fired = inj.poll(2, 100.0)
    assert [(e.kind, clear) for e, clear in fired] == [("kill", False)]
    assert not inj.up[1] and inj.slow[0] == 1.0
    inj.poll(3, 200.0)
    assert inj.slow[0] == 3.0
    fired = inj.poll(5, 500.0)   # batch 4 skipped: flaky + recover both due
    kinds = [(e.kind, clear) for e, clear in fired]
    assert ("flaky", False) in kinds and ("recover", False) in kinds
    assert inj.up[1] and inj.flaky[1] == 1.0
    inj.poll(7, 900.0)           # windows clear
    assert inj.slow[0] == 1.0 and inj.flaky[1] == 0.0
    assert not inj.armed
    # Killing an already-dead shard / recovering a live one are no-ops.
    inj2 = FaultInjector(FaultPlan.parse("kill:0@1,kill:0@2,recover:1@3"),
                         n_shards=2)
    assert len(inj2.poll(2, 0.0)) == 1
    assert inj2.poll(3, 0.0) == []


def test_injector_horizon_resolution():
    plan = FaultPlan.parse("kill:1@mid,recover:1@75%")
    with pytest.raises(ValueError, match="horizon"):
        FaultInjector(plan, n_shards=2)
    inj = FaultInjector(plan, n_shards=2, horizon_batches=20)
    assert [e.at for e in inj.events_resolved()] == [10.0, 15.0]
    with pytest.raises(ValueError, match="shard"):
        FaultInjector(FaultPlan.parse("kill:5@1"), n_shards=2)


def test_injector_downtime_accounting():
    inj = FaultInjector(FaultPlan.parse("kill:0@1"), n_shards=1)
    assert inj.down_time_us(0, 999.0) == 0.0   # never killed
    inj.poll(1, 100.0)
    assert inj.down_time_us(0, 400.0) == 300.0
    assert inj.close_downtime(0, 450.0) == 350.0
    assert inj.down_time_us(0, 500.0) == 0.0   # window closed exactly once


def test_injector_draws_are_seeded():
    def draws(seed):
        inj = FaultInjector(FaultPlan.parse("flaky:0x0.5@0..99", seed=seed),
                            n_shards=1)
        inj.poll(0, 0.0)
        return [inj.draw_failure(0) for _ in range(64)]

    assert draws(3) == draws(3)
    assert draws(3) != draws(4)


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent("kill", shard=-1, at=0)
    with pytest.raises(ValueError):
        FaultEvent("slow", shard=0, at=0, factor=0.5)


# ---------------- ft.* namespace ----------------


def test_ft_stats_identities_and_reconcile():
    ft = FtStats(n_shards=2, served=10, primary=6, failover_replica=3,
                 failover_degraded=1, degraded_default=1, retries=2,
                 retry_succeeded=1, retry_exhausted=1, kills=1, recoveries=1,
                 recovery_bytes=10, recovery_bytes_raw=40)
    ft.check()
    reg = MetricsRegistry()
    ft.publish(reg)
    flat = dict(reg.as_dict())
    assert check_ft(flat) == []
    assert reg.snapshot()["gauges"]["ft.shard.0.down_ms"] == 0.0
    # Every identity trips when its counters drift.
    for key, delta in [("ft.served", 1), ("ft.retry_succeeded", 5),
                       ("ft.degraded_default", 9), ("ft.kills", -1),
                       ("ft.recovery_bytes", 100)]:
        broken = dict(flat)
        broken[key] += delta
        assert check_ft(broken), key
    ft.served += 1
    with pytest.raises(AssertionError):
        ft.check()


# ---------------- engine drain-after-kill ----------------


def test_engine_set_down_cancels_inflight_and_drops_new():
    store = TieredEmbeddingStore(_host(), 64)
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel)
    eng.submit(EMPTY, EMPTY, np.array([1, 2, 3]))     # in flight
    eng.set_down(True)                                # kill mid-flight
    assert tel.pf_shard_down == 3 and store.n_resident == 0
    eng.submit(EMPTY, EMPTY, np.array([4, 5]))        # dropped at the door
    assert tel.pf_shard_down == 5 and tel.pf_issued == 0
    eng.drain()                                       # must not crash/refill
    assert store.n_resident == 0
    # All submitted traffic is fate-accounted.
    assert tel.pf_submitted == (tel.pf_suppressed + tel.pf_deduped
                                + tel.pf_cancelled_resident
                                + tel.pf_shard_down + tel.pf_issued)
    eng.set_down(False)                               # recovery
    eng.submit(EMPTY, EMPTY, np.array([6, 7]))
    eng.drain()
    assert store.n_resident == 2 and tel.pf_issued == 2


# ---------------- replication planning ----------------


def test_make_plan_replicates_hottest_rows():
    freq = np.zeros(N_VEC, np.int64)
    hot = np.array([5, 17, 200, 311])
    freq[hot] = [40, 30, 20, 10]
    plan = make_plan(ROWS, 4, 64, "row", frequencies=freq, replicate_hot=4)
    assert np.array_equal(plan.replicated_ids, np.sort(hot))
    plan.check()
    mask = plan.replica_mask()
    assert mask.sum() == 4 and mask[hot].all()
    # Ties broken by id: with uniform frequencies the first k ids win.
    p2 = make_plan(ROWS, 2, 64, "row",
                   frequencies=np.ones(N_VEC, np.int64), replicate_hot=3)
    assert p2.replicated_ids.tolist() == [0, 1, 2]


def test_make_plan_replicate_requires_frequencies():
    with pytest.raises(ValueError, match="frequencies"):
        make_plan(ROWS, 2, 64, "row", replicate_hot=4)


# ---------------- failover contract: sync surface ----------------


def _small_spec():
    return make_spec("shard_failure", n_accesses=10_240, n_tables=4,
                     rows_per_table=256)


def test_chaos_kill_zero_wrong_answers():
    res = replay_chaos(_small_spec(), batch=128, shards=4,
                       fault_plan="kill:1@mid,recover:1@75%")
    assert res["wrong_rows"] == 0
    assert res["kills"] == 1 and res["recoveries"] == 1
    assert res["failover_replica"] > 0          # replication carried load
    assert res["served"] == (res["primary"] + res["failover_replica"]
                             + res["failover_degraded"])
    assert res["recovery_pending"] == 0          # streaming finished
    assert 0 < res["recovery_bytes"] < res["recovery_bytes_raw"]
    assert res["exact_rows"] + res["zero_default_rows"] == res["rows"]


def test_chaos_flaky_and_slow_reconcile():
    res = replay_chaos(_small_spec(), batch=128, shards=4, seed=11,
                       fault_plan="flaky:2x0.6@25%..75%,slow:0x3@25%..75%")
    assert res["wrong_rows"] == 0
    assert res["retries"] > 0
    ft = {k[3:]: v for k, v in res["metrics"]["counters"].items()
          if k.startswith("ft.")}
    assert ft["retries"] == ft["retry_succeeded"] + ft["retry_exhausted"]
    # Bounded stall: no retry episode outlasts its deadline + final
    # timeout, so total overhead is linear in episode count.
    plan = FaultPlan()
    assert ft["retry_overhead_ms"] <= ft["retries"] * 1e-3 * (
        plan.retry_deadline_us + plan.retry_timeout_us)
    assert ft["slow_ms"] > 0


def test_chaos_double_run_byte_determinism():
    kw = dict(batch=128, shards=4, fault_plan="kill:1@mid,recover:1@75%")
    a = replay_chaos(_small_spec(), **kw)
    b = replay_chaos(_small_spec(), **kw)
    for k in set(a) - {"metrics"}:
        assert a[k] == b[k], k
    # Everything but measured wall time (time.*_s) is byte-deterministic.
    ca, cb = a["metrics"]["counters"], b["metrics"]["counters"]
    assert {k: v for k, v in ca.items() if ".time." not in k} \
        == {k: v for k, v in cb.items() if ".time." not in k}


def test_chaos_clean_arm_has_no_ft_traffic():
    res = replay_chaos(_small_spec(), batch=128, shards=4, fault_plan=None)
    assert res["failover_replica"] == 0 and res["wrong_rows"] == 0
    assert not any(k.startswith("ft.") for k in res["metrics"]["counters"])


def test_chaos_kill_without_recovery_keeps_serving():
    # No recovery ever comes: replicas + degraded rows carry the tail of
    # the run, and the dead shard contributes nothing to the critical
    # path (the run can only get *faster*, never hang).
    clean = replay_chaos(_small_spec(), batch=128, shards=4, fault_plan=None)
    res = replay_chaos(_small_spec(), batch=128, shards=4,
                       fault_plan="kill:1@25%")
    assert res["wrong_rows"] == 0 and res["recoveries"] == 0
    assert res["modeled_s"] <= clean["modeled_s"] * 1.01


# ---------------- failover contract: pipelined / admission ----------


def _drive_runtime(fault_plan, admission=None, n_q=96, per_query=8):
    """Drive a sharded faulted store through PipelinedRuntime; returns
    per-batch (ids, emb) captures plus the runtime and store."""
    gid = _ids(n_q * per_query)
    store = ShardedTieredStore.build(
        _host(), ROWS, 4, "row", capacity=64, policy="lru",
        profile_ids=gid[: len(gid) // 4], replicate_hot=32, warmup_batch=32)
    if fault_plan:
        store.arm_faults(fault_plan, horizon_batches=n_q * per_query // 32)
    cfg = RuntimeConfig(max_batch=4, pipeline_depth=2, interarrival_us=30.0,
                        compute_us=200.0, admission=admission)
    rt = PipelinedRuntime(store, cfg)
    embs, idss = {}, {}

    def hook(ids, hits, b):
        idss[b] = np.asarray(ids).copy()
        return [(EMPTY, EMPTY, np.unique(ids))]

    rt._batch_hook = hook

    def step(b, emb):
        embs[b] = np.asarray(emb).copy()
        return (0.0, [])

    if admission is not None:
        pri = np.random.default_rng(1).integers(0, admission.n_classes,
                                                size=n_q)
        stream = ((gid[q * per_query: (q + 1) * per_query], int(pri[q]))
                  for q in range(n_q))
    else:
        stream = (gid[q * per_query: (q + 1) * per_query]
                  for q in range(n_q))
    rt.run(stream, step)
    return store, rt, idss, embs


def _audit_rows(host, idss, embs):
    """Every served row must be the host row bit-for-bit or the all-zero
    degraded default; returns (exact, zero) counts."""
    exact = zero = 0
    for b, emb in embs.items():
        ref = host[idss[b]]
        eq = np.all(emb == ref, axis=-1)
        z = np.all(emb == 0.0, axis=-1)
        assert int(np.count_nonzero(~(eq | z))) == 0, f"wrong rows, batch {b}"
        exact += int(np.count_nonzero(eq))
        zero += int(np.count_nonzero(z & ~eq))
    return exact, zero


@pytest.mark.parametrize("admission", [
    None,
    AdmissionConfig(queue_bound=16, class_deadline_us=(2e3, 8e3, 3.2e4)),
], ids=["pipelined", "admission"])
def test_failover_contract_on_runtime_surface(admission):
    plan = "kill:1@6,recover:1@14"
    store, rt, idss, embs = _drive_runtime(plan, admission=admission)
    exact, zero = _audit_rows(store._host, idss, embs)
    assert exact > 0
    ft = store.ft_stats
    ft.check()
    assert ft.kills == 1 and ft.recoveries == 1
    assert ft.failover_replica > 0
    reg = MetricsRegistry()
    rt.publish(reg)
    store.publish_metrics(reg)
    assert reconcile(metrics=reg.as_dict(), strict=False) == []


def test_runtime_surface_double_run_determinism():
    def run():
        store, rt, idss, embs = _drive_runtime("kill:1@6,recover:1@14")
        blob = np.concatenate([embs[b].ravel() for b in sorted(embs)])
        return blob, store.ft_stats.as_dict(), rt.clock.now()

    a, b = run(), run()
    assert np.array_equal(a[0], b[0])
    assert a[1] == b[1] and a[2] == b[2]


def test_runtime_no_fault_path_is_byte_identical():
    # Arming nothing must not perturb the pre-fault-layer runtime.
    _, rt0, _, embs0 = _drive_runtime(None)
    _, rt1, _, embs1 = _drive_runtime("")
    for b in embs0:
        assert np.array_equal(embs0[b], embs1[b])
    assert rt0.clock.now() == rt1.clock.now()


# ---------------- recovery streaming + staged drops ----------------


def test_recovery_streams_lost_rows_back():
    gid = _ids(2048, seed=2)
    store = ShardedTieredStore.build(_host(), ROWS, 2, "row", capacity=80,
                                     policy="lru", warmup_batch=64)
    store.arm_faults("kill:1@4,recover:1@6")
    for b in range(16):
        store.lookup(gid[b * 128: (b + 1) * 128])
    ft = store.ft_stats
    assert ft.kills == 1 and ft.recoveries == 1
    assert ft.recovery_rows > 0 and ft.recovery_chunks >= 1
    assert ft.recovery_bytes < ft.recovery_bytes_raw
    assert store._recovery == {}                 # stream fully drained
    assert store.stores[1].n_resident > 0        # replacement warmed back up
    assert ft.down_us[1] > 0 and ft.down_us[0] == 0
    ft.check()


def test_kill_drops_staged_outputs_for_dead_shard():
    store = ShardedTieredStore.build(_host(), ROWS, 2, "row", capacity=80,
                                     warmup_batch=64)
    store.arm_faults("kill:1@1")
    store.lookup(_ids(128))     # batch 0: healthy
    store.stores[1].stage_model_outputs(EMPTY, EMPTY,
                                        np.array([0, 1, 2], np.int64))
    store.lookup(_ids(128))     # batch 1: kill fires before the staged
    #                             rows can land — work discarded, counted
    assert store.ft_stats.staged_dropped == 3
    store.ft_stats.check()
