"""Rule-based prefetchers learn the patterns they're designed for — and
fail on the patterns the paper says they fail on."""
import numpy as np
import pytest

from repro.core.cache_sim import FALRU, simulate
from repro.core.prefetchers import (BOP, BertiLite, BingoLite, DominoLite,
                                    MABLite, prediction_metrics)


def test_bop_learns_constant_offset():
    keys = np.arange(0, 8000, 4)  # stride-4 stream
    pf = BOP()
    res = simulate(keys, FALRU(64), pf)
    assert pf.best == 4
    assert res.prefetch_hits > 0.5 * len(keys)


def test_domino_learns_repeated_sequence():
    seq = np.array([3, 17, 5, 99, 42, 7] * 300)
    # Cache smaller than the 6-key working set, degree 1 so prefetches don't
    # evict each other: temporal correlation is the only way to hit.
    pf = DominoLite(degree=1)
    res = simulate(seq, FALRU(4), pf)
    assert res.prefetch_hits > 100


def test_bingo_learns_spatial_footprint():
    # Regions of 64 revisited with the same footprint.
    base = np.arange(0, 50) * 1000
    foot = np.array([0, 3, 9, 20])
    keys = np.concatenate([(b // 64) * 64 + foot for b in base for _ in (0, 1)])
    pf = BingoLite(region=64)
    res = simulate(keys, FALRU(16), pf)
    assert res.prefetch_issued > 0


def test_rule_based_fail_on_large_jumps():
    """The paper's core claim: large correlated jumps defeat spatial/offset
    prefetchers (offsets are bounded, regions are small)."""
    rng = np.random.default_rng(0)
    jump = 3517
    keys = np.cumsum(rng.choice([jump], size=4000)) % 100_000
    for pf in (BOP(), BingoLite()):
        m = prediction_metrics(keys, pf, window=15)
        assert m["coverage"] < 0.05, type(pf).__name__


def test_mab_runs_and_picks_arm():
    rng = np.random.default_rng(0)
    keys = np.arange(0, 20000, 2)
    pf = MABLite()
    res = simulate(keys, FALRU(64), pf)
    assert res.accesses == len(keys)


def test_berti_learns_local_delta():
    keys = np.arange(0, 3000, 3)
    pf = BertiLite(pc_of=lambda k: 0)
    res = simulate(keys, FALRU(32), pf)
    assert res.prefetch_issued > 100


# ---------------- prediction_metrics (Eq. 2 / Figs. 9-10) ----------------


class _PlusOne:
    """Predicts exactly the next key of an ascending stream."""

    def on_access(self, key, hit):
        return [key + 1]


class _HalfWrong:
    """One good guess (key+1) and one always-wrong guess per access."""

    def on_access(self, key, hit):
        return [key + 1, key + 1000]


class _Silent:
    def on_access(self, key, hit):
        return []


def test_prediction_metrics_perfect_hand_computed():
    """On keys 0..11 with window 3, a +1 predictor issues [i+1, i+2, i+3]
    per window — every prediction lands in the next-3 ground truth, and
    every ground-truth key is covered: correctness = coverage = 1."""
    m = prediction_metrics(np.arange(12), _PlusOne(), window=3)
    assert m["issued"] == 9  # 3 windows (i = 0, 3, 6) x 3 predictions
    assert m["correctness"] == pytest.approx(1.0)
    assert m["coverage"] == pytest.approx(1.0)


def test_prediction_metrics_half_wrong_hand_computed():
    """The half-wrong predictor issues [i+1, i+1000, i+2, ...] per window,
    truncated to the window size 3: of those, 2 land in the future set of
    3 -> correctness 2/3; 2 of 3 ground-truth keys covered -> coverage
    2/3."""
    m = prediction_metrics(np.arange(12), _HalfWrong(), window=3)
    assert m["issued"] == 9
    assert m["correctness"] == pytest.approx(2 / 3)
    assert m["coverage"] == pytest.approx(2 / 3)


def test_prediction_metrics_silent_prefetcher():
    m = prediction_metrics(np.arange(30), _Silent(), window=5)
    assert m["issued"] == 0
    assert m["correctness"] == 0.0  # guarded division
    assert m["coverage"] == 0.0
