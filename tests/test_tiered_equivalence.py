"""Batched engine vs. per-key seed reference: identical counters and rows.

The batched ``TieredEmbeddingStore`` must reproduce the seed semantics
exactly — same hit / miss / on-demand / prefetch counters and the same
returned embeddings on a recorded synthetic trace — under both the LRU and
the recmg policy, including eviction pressure and batch overflow.
"""
import numpy as np
import pytest

from repro.core.tiered import TieredEmbeddingStore
from repro.core.tiered_reference import ReferenceTieredStore

COUNTERS = ("batches", "lookups", "hits", "prefetch_hits", "on_demand_rows",
            "evictions")


def _trace(rng, n_rows, n_acc, zipf_a=1.2):
    """Zipf-skewed key stream like the DLRM generator's per-table law."""
    ranks = np.minimum(rng.zipf(zipf_a, size=n_acc), n_rows) - 1
    perm = rng.permutation(n_rows)
    return perm[ranks].astype(np.int64)


def _replay(store, host, ids, batch, rng, prefetch_every=0, bits_every=0):
    """Drive a store through the trace; returns per-batch counter snapshots."""
    snaps = []
    for b in range(len(ids) // batch):
        chunk = ids[b * batch: (b + 1) * batch]
        out = np.asarray(store.lookup(chunk))
        np.testing.assert_allclose(out, host[chunk], rtol=1e-6)
        if bits_every and b % bits_every == 0:
            trunk = chunk[:16]
            bits = (rng.random(len(trunk)) < 0.5).astype(np.int64)
            store.apply_model_outputs(trunk, bits, np.empty(0, np.int64))
        if prefetch_every and b % prefetch_every == 0:
            pf = np.unique(rng.integers(0, host.shape[0], size=8))
            store.apply_model_outputs(
                np.empty(0, np.int64), np.empty(0, np.int64), pf)
        snaps.append(tuple(getattr(store.stats, c) for c in COUNTERS))
    return snaps


@pytest.mark.parametrize("policy,cap", [
    ("lru", 64), ("lru", 17), ("recmg", 64), ("recmg", 23),
])
def test_counters_match_reference(policy, cap):
    rng = np.random.default_rng(0)
    host = rng.normal(size=(500, 8)).astype(np.float32)
    ids = _trace(rng, 500, 6000)
    new = TieredEmbeddingStore(host, cap, policy=policy)
    ref = ReferenceTieredStore(host, cap, policy=policy)
    s_new = _replay(new, host, ids, 48, np.random.default_rng(1),
                    prefetch_every=3, bits_every=2)
    s_ref = _replay(ref, host, ids, 48, np.random.default_rng(1),
                    prefetch_every=3, bits_every=2)
    assert s_new == s_ref
    new.check_invariants()
    assert new.slot_of == ref.slot_of or set(new.slot_of) == set(ref.slot_of)


@pytest.mark.parametrize("policy", ["lru", "recmg"])
def test_batch_overflow_matches_reference(policy):
    """Working set larger than the buffer: overflow rows are served from the
    host tier and the engines agree on every counter."""
    rng = np.random.default_rng(2)
    host = rng.normal(size=(300, 8)).astype(np.float32)
    cap = 16
    new = TieredEmbeddingStore(host, cap, policy=policy)
    ref = ReferenceTieredStore(host, cap, policy=policy)
    for batch in (np.arange(60), np.arange(30, 90), rng.integers(0, 300, 128)):
        o_new = np.asarray(new.lookup(batch))
        o_ref = np.asarray(ref.lookup(batch))
        np.testing.assert_allclose(o_new, host[batch], rtol=1e-6)
        np.testing.assert_allclose(o_ref, host[batch], rtol=1e-6)
    for c in COUNTERS:
        assert getattr(new.stats, c) == getattr(ref.stats, c), c
    assert new.n_resident == len(ref.slot_of) == cap
    new.check_invariants()


@pytest.mark.parametrize("policy,cap", [
    ("lru", 1), ("recmg", 1), ("lru", 2), ("recmg", 2),
])
def test_capacity_one_prefetch_matches_reference(policy, cap):
    """Regression for the PR-4 reference deviation: a multi-key prefetch
    batch at capacity ~1 evicts its own earlier keys mid-admission, and
    the reference used to leave those keys a phantom ``prefetched`` mark
    that inflated ``prefetch_hits`` on their next residency.  With the
    mark scoped to still-resident keys the engines agree at every
    capacity — the property suite's cap range now starts at 1 instead of
    having to avoid it."""
    rng = np.random.default_rng(5)
    host = rng.normal(size=(40, 8)).astype(np.float32)
    ids = _trace(rng, 40, 1200, zipf_a=1.3)
    new = TieredEmbeddingStore(host, cap, policy=policy)
    ref = ReferenceTieredStore(host, cap, policy=policy)
    s_new = _replay(new, host, ids, 8, np.random.default_rng(6),
                    prefetch_every=2, bits_every=3)
    s_ref = _replay(ref, host, ids, 8, np.random.default_rng(6),
                    prefetch_every=2, bits_every=3)
    assert s_new == s_ref
    new.check_invariants()
    assert set(new.slot_of) == set(ref.slot_of)


def test_quantized_counters_match_reference():
    rng = np.random.default_rng(3)
    host = rng.normal(size=(200, 8)).astype(np.float32)
    ids = _trace(rng, 200, 2000)
    new = TieredEmbeddingStore(host, 32, policy="lru", quantize=True)
    ref = ReferenceTieredStore(host, 32, policy="lru", quantize=True)
    for b in range(len(ids) // 64):
        chunk = ids[b * 64: (b + 1) * 64]
        o_new = np.asarray(new.lookup(chunk))
        o_ref = np.asarray(ref.lookup(chunk))
        np.testing.assert_allclose(o_new, o_ref, rtol=1e-6, atol=1e-7)
    for c in COUNTERS:
        assert getattr(new.stats, c) == getattr(ref.stats, c), c


def test_staged_outputs_apply_at_next_boundary():
    """stage_model_outputs must not mutate the store until the next lookup."""
    rng = np.random.default_rng(4)
    host = rng.normal(size=(100, 8)).astype(np.float32)
    st = TieredEmbeddingStore(host, 16, policy="lru")
    st.stage_model_outputs(np.empty(0, np.int64), np.empty(0, np.int64),
                           np.array([5, 6]))
    assert st.n_resident == 0  # nothing applied yet
    st.lookup(np.array([5, 6]))
    assert st.stats.prefetch_hits == 2  # staged prefetch landed first
    assert st.stats.hits == 2
