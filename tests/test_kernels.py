"""Pallas kernels vs pure-jnp oracles, swept over shapes/dtypes in
interpret mode (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.chamfer_kernel import chamfer
from repro.kernels.embedding_gather import (dequantize_rows_ref,
                                            gather_pool,
                                            gather_pool_dequant,
                                            gather_rows,
                                            gather_rows_dequant,
                                            quantize_rows,
                                            quantize_rows_ref)
from repro.kernels.flash_attention import flash_attention


@pytest.mark.parametrize("N,D,B,P", [
    (256, 128, 8, 4),
    (1000, 128, 16, 7),
    (512, 256, 4, 1),
    (64, 128, 32, 20),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_pool(N, D, B, P, dtype):
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (N, D), dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, N)
    out = gather_pool(table, idx, interpret=True)
    want = ref.gather_pool_ref(table, idx)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("N,D,M", [
    (256, 128, 16),
    (1000, 128, 64),
    (64, 256, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_rows(N, D, M, dtype):
    """Un-pooled row gather (the tiered store's device path): exact match
    with table[idx], duplicates included."""
    table = jax.random.normal(jax.random.PRNGKey(0), (N, D), dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (M,), 0, N)
    idx = idx.at[0].set(idx[-1])  # force a duplicate
    out = gather_rows(table, idx, interpret=True)
    assert out.dtype == table.dtype
    np.testing.assert_array_equal(np.asarray(out), np.asarray(table[idx]))


@pytest.mark.parametrize("N,D,M", [
    (256, 128, 16),
    (64, 256, 33),
])
@pytest.mark.parametrize("row_format", ["int8", "fp8"])
def test_gather_rows_dequant(N, D, M, row_format):
    """Fused dequantizing gather == gather-then-dequantize oracle, bit
    for bit (both multiply the same codes by the same fp32 scales)."""
    rows = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    q, s = quantize_rows_ref(rows, row_format)
    idx = jax.random.randint(jax.random.PRNGKey(1), (M,), 0, N)
    idx = idx.at[0].set(idx[-1])  # force a duplicate
    out = gather_rows_dequant(q, s, idx, interpret=True)
    assert out.dtype == jnp.float32
    want = dequantize_rows_ref(q, s)[idx]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("N,D,B,P", [
    (256, 128, 8, 4),
    (100, 128, 16, 7),
])
@pytest.mark.parametrize("row_format", ["int8", "fp8"])
def test_gather_pool_dequant(N, D, B, P, row_format):
    rows = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    q, s = quantize_rows_ref(rows, row_format)
    idx = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, N)
    out = gather_pool_dequant(q, s, idx, interpret=True)
    want = dequantize_rows_ref(q, s)[idx].sum(axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_lane_width_validated_on_compiled_path():
    """D % 128 != 0 must fail loudly on the non-interpret path (the docs
    promised the constraint; now it's checked) and still run under
    interpret mode."""
    table = jnp.zeros((16, 96), jnp.float32)
    idx = jnp.zeros((4,), jnp.int32)
    pooled_idx = jnp.zeros((4, 2), jnp.int32)
    q, s = quantize_rows_ref(table, "int8")
    for call in (lambda: gather_rows(table, idx),
                 lambda: gather_pool(table, pooled_idx),
                 lambda: gather_rows_dequant(q, s, idx),
                 lambda: gather_pool_dequant(q, s, pooled_idx),
                 lambda: quantize_rows(table)):
        with pytest.raises(ValueError, match="multiple of 128"):
            call()
    # interpret mode has no lane constraint
    out = gather_rows(table, idx, interpret=True)
    assert out.shape == (4, 96)


@pytest.mark.parametrize("B,P,W,F,block", [
    (64, 5, 15, 25, 32),
    (100, 5, 15, 25, 64),  # ragged batch vs block
    (16, 3, 9, 8, 16),
    (257, 7, 21, 16, 128),
])
def test_chamfer_kernel(B, P, W, F, block):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    po = jax.random.normal(k1, (B, P, F))
    w = jax.random.normal(k2, (B, W, F))
    out = chamfer(po, w, 0.7, block=block, interpret=True)
    want = ref.chamfer_ref(po, w, 0.7)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("BH,S,hd,bq,bk", [
    (2, 128, 64, 64, 64),
    (4, 256, 64, 64, 128),
    (1, 512, 128, 128, 128),
    (3, 256, 32, 256, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(BH, S, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, S, hd), dtype)
    k = jax.random.normal(ks[1], (BH, S, hd), dtype)
    v = jax.random.normal(ks[2], (BH, S, hd), dtype)
    out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@pytest.mark.parametrize("B,In,H,block", [
    (64, 27, 40, 32),
    (100, 16, 64, 64),   # ragged batch
    (8, 8, 8, 8),
])
def test_lstm_cell_kernel(B, In, H, block):
    from repro.kernels.lstm_cell import lstm_cell

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, In))
    h = jax.random.normal(ks[1], (B, H))
    c = jax.random.normal(ks[2], (B, H))
    w = jax.random.normal(ks[3], (In + H, 4 * H)) * 0.2
    b = jax.random.normal(ks[4], (4 * H,)) * 0.1
    h2, c2 = lstm_cell(x, h, c, w, b, block=block, interpret=True)
    h_ref, c_ref = ref.lstm_cell_ref(x, h, c, w, b)
    np.testing.assert_allclose(h2, h_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(c2, c_ref, rtol=2e-5, atol=2e-5)


def test_lstm_cell_matches_core_lstm_step():
    from repro.core import lstm as LS
    from repro.kernels.lstm_cell import lstm_cell

    p = LS.lstm_init(jax.random.PRNGKey(0), 12, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 12))
    h = jnp.zeros((4, 16))
    c = jnp.zeros((4, 16))
    (h_ref, c_ref), _ = LS.lstm_step(p, (h, c), x)
    h2, c2 = lstm_cell(x, h, c, p["w"], p["b"], block=4, interpret=True)
    np.testing.assert_allclose(h2, h_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(c2, c_ref, rtol=2e-5, atol=2e-5)


def test_ops_wrappers_fall_back_on_cpu():
    from repro.kernels import ops

    table = jnp.ones((16, 128))
    idx = jnp.zeros((2, 3), jnp.int32)
    out = ops.gather_pool(table, idx, use_pallas=True)  # CPU -> jnp ref
    np.testing.assert_allclose(out, 3 * np.ones((2, 128)))
