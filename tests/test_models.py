"""Per-architecture smoke tests (assignment deliverable f): a REDUCED config
of each assigned family runs one forward/train step on CPU with finite
outputs and the right shapes, plus prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import RunConfig, ShapeConfig
from repro.models.model_api import build

RUN = RunConfig(attn_block_q=32, attn_block_kv=32)


def _batch_for(bundle, cfg, shape):
    key = jax.random.PRNGKey(7)
    out = {}
    for name, st in bundle.batch_struct(shape).items():
        if st.dtype == jnp.int32 and name in ("tokens", "labels", "token"):
            out[name] = jax.random.randint(key, st.shape, 0, cfg.vocab)
        elif st.dtype == jnp.int32:
            out[name] = jax.random.randint(key, st.shape, 0,
                                           max(cfg.rows_per_table, 2))
        else:
            out[name] = jax.random.normal(key, st.shape, jnp.float32)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    bundle = build(cfg, RUN)
    params = bundle.init(jax.random.PRNGKey(0))
    if cfg.family == "dlrm":
        shape = ShapeConfig("t", "train", 0, 8)
    else:
        shape = ShapeConfig("t", "train", 48, 2)
    batch = _batch_for(bundle, cfg, shape)
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in
                jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", [a for a in ALL_ARCHS if a != "dlrm-recmg"])
def test_smoke_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    bundle = build(cfg, RUN)
    params = bundle.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    shape = ShapeConfig("t", "prefill", S, B)
    batch = _batch_for(bundle, cfg, shape)
    logits, cache = bundle.prefill(params, batch, cache_len=S + 4)
    assert logits.shape == (B, cfg.vocab)
    assert jnp.all(jnp.isfinite(logits))
    nxt = jax.random.randint(jax.random.PRNGKey(2), (B, 1), 0, cfg.vocab)
    dec_logits, cache2 = bundle.decode(params, nxt, cache)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    ref_logits, _ = bundle.prefill(params, batch2)
    np.testing.assert_allclose(dec_logits, ref_logits, rtol=5e-2, atol=5e-2)
    assert int(cache2["pos"]) == S + 1


def test_dlrm_forward_shapes():
    cfg = get_config("dlrm-recmg").reduced()
    bundle = build(cfg, RUN)
    params = bundle.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("t", "prefill", 0, 8)
    batch = _batch_for(bundle, cfg, shape)
    out = bundle.prefill(params, batch)
    assert out.shape == (8,)
    assert jnp.all(jnp.isfinite(out))


def test_param_counts_are_sane():
    # Full configs should land near their nameplate sizes.
    expected = {
        "smollm-135m": (100e6, 200e6),
        "smollm-360m": (250e6, 500e6),
        "qwen3-14b": (10e9, 18e9),
        "grok-1-314b": (250e9, 400e9),
        "falcon-mamba-7b": (5e9, 10e9),
    }
    for arch, (lo, hi) in expected.items():
        n = build(get_config(arch)).n_params()
        assert lo < n < hi, (arch, n)


def test_moe_active_params_smaller():
    b = build(get_config("grok-1-314b"))
    assert b.n_active_params() < 0.5 * b.n_params()


def test_vlm_frontend_changes_output():
    cfg = get_config("internvl2-26b").reduced()
    bundle = build(cfg, RUN)
    params = bundle.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    toks = jnp.ones((B, S), jnp.int32)
    fe1 = jnp.zeros((B, cfg.n_frontend_tokens, cfg.d_model))
    fe2 = jnp.ones((B, cfg.n_frontend_tokens, cfg.d_model))
    lab = jnp.ones((B, S), jnp.int32)
    l1 = bundle.loss(params, {"tokens": toks, "labels": lab, "frontend": fe1})
    l2 = bundle.loss(params, {"tokens": toks, "labels": lab, "frontend": fe2})
    assert abs(float(l1) - float(l2)) > 1e-6


def test_decode_step_embeds_matches_decode_step():
    """Tiered-vocab serving path: decoding from externally-supplied
    embedding rows must equal the resident-table path."""
    from repro.models.transformer import decode_step_embeds

    cfg = get_config("smollm-135m").reduced()
    bundle = build(cfg, RUN)
    params = bundle.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, cfg.vocab)
    _, cache = bundle.prefill(params, {"tokens": toks}, cache_len=10)
    nxt = jax.random.randint(jax.random.PRNGKey(2), (2, 1), 0, cfg.vocab)
    ref_logits, _ = bundle.decode(params, nxt, cache)
    rows = params["embed"][nxt[:, 0]][:, None, :]
    got_logits, _ = decode_step_embeds(params, cfg, RUN, rows, cache)
    np.testing.assert_allclose(got_logits, ref_logits, rtol=1e-5, atol=1e-5)
