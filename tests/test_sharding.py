"""Partitioning rules: divisibility fallback, FSDP/TP assignment, batch and
cache specs — validated on a small host mesh."""
import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models.model_api import build
from repro.sharding import partition as sp


def _mesh():
    # Single CPU device: axes of size 1 — rules still exercise fully.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_fit_spec_drops_nondivisible():
    mesh = _mesh()
    spec = sp.fit_spec((15, 64), ["model", "data"], mesh)
    assert spec == P("model", "data")  # size-1 axes always divide


def test_fit_spec_progressive_tuple():
    class FakeMesh:
        shape = {"pod": 2, "data": 4, "model": 8}
        axis_names = ("pod", "data", "model")

    spec = sp.fit_spec((8, 100), [("pod", "data"), None], FakeMesh)
    assert spec == P(("pod", "data"))
    spec = sp.fit_spec((6, 100), [("pod", "data"), None], FakeMesh)
    assert spec == P("pod")  # 6 % 8 != 0 -> drop "data", 6 % 2 == 0 -> keep
    spec = sp.fit_spec((5, 100), [("pod", "data"), None], FakeMesh)
    assert spec == P()


def test_param_pspecs_cover_all_leaves():
    for arch in ["qwen2.5-3b", "grok-1-314b", "falcon-mamba-7b",
                 "whisper-large-v3", "dlrm-recmg"]:
        bundle = build(get_config(arch).reduced())
        ps = bundle.param_struct()
        specs = sp.param_pspecs(ps, _mesh())
        n_leaves = len(jax.tree_util.tree_leaves(ps))
        n_specs = len(jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P)))
        assert n_specs == n_leaves, arch


def test_param_pspecs_shard_big_dims():
    class FakeMesh:
        shape = {"data": 4, "model": 8}
        axis_names = ("data", "model")

    bundle = build(get_config("qwen3-14b"))
    specs = sp.param_pspecs(bundle.param_struct(), FakeMesh)
    # embed (V, D): vocab on model, d_model on data.
    assert specs["embed"] == P("model", "data")
    # stacked attn wq (L, D, H*hd): layer dim unsharded.
    assert specs["blocks"]["attn"]["wq"][0] is None
    assert "model" in jax.tree_util.tree_leaves(
        specs["blocks"]["attn"]["wq"], is_leaf=lambda x: True)[0]


def test_batch_and_cache_specs():
    mesh = _mesh()
    bundle = build(get_config("qwen2.5-3b").reduced())
    shape = ShapeConfig("t", "decode", 32, 4)
    bs = bundle.batch_struct(shape)
    specs = sp.batch_pspecs(bs, mesh)
    assert specs["token"][0] == "data"
    cs = bundle.cache_struct(shape)
    cspecs = sp.cache_pspecs(cs, mesh)
    assert cspecs["k"] == P(None, "data", "model")
    assert cspecs["pos"] == P()


def test_constrain_batch_noop_outside_scope():
    x = jax.numpy.ones((4, 8))
    assert sp.constrain_batch(x) is x


def test_constrain_batch_inside_scope():
    mesh = _mesh()
    with sp.activation_sharding(mesh):
        y = jax.jit(lambda x: sp.constrain_batch(x))(jax.numpy.ones((4, 8)))
    np.testing.assert_allclose(y, np.ones((4, 8)))
