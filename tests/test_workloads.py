"""Property tests for the workload scenario subsystem.

Invariants (fuzzed via hypothesis, or the deterministic shim fallback):

* every ``WorkloadSpec`` yields ids inside the spec's table bounds;
* ``iter_batches`` respects batch size and trace length *exactly*
  (``n_accesses // batch`` batches of exactly ``batch`` ids);
* equal specs produce byte-identical traces (seeded determinism);
* the ``replay`` adapter round-trips a trace written by
  ``repro.core.trace.save_trace`` byte-identically, for both the ``.npz``
  and ``.csv`` formats, arrays and dtypes alike.
"""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from repro.core.trace import (TraceGenConfig, generate_trace, load_trace,
                              save_trace)
from repro.workloads import (REGIMES, SCENARIOS, iter_batches, make_spec,
                             make_trace, parse_workload, scenario)

GEN_REGIMES = sorted(set(REGIMES) - {"replay"})


def _spec_from(regime_idx, n_tables, rows, accesses, seed):
    return make_spec(GEN_REGIMES[regime_idx % len(GEN_REGIMES)],
                     n_tables=n_tables, rows_per_table=rows,
                     n_accesses=accesses, seed=seed)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, len(GEN_REGIMES) - 1),  # regime
       st.integers(1, 6),                     # n_tables
       st.integers(16, 600),                  # rows_per_table
       st.integers(50, 4000),                 # n_accesses
       st.integers(0, 2**31 - 1))             # seed
def test_spec_bounds_and_determinism(regime_idx, n_tables, rows, accesses,
                                     seed):
    spec = _spec_from(regime_idx, n_tables, rows, accesses, seed)
    tr = make_trace(spec)
    assert len(tr) == accesses
    assert tr.table_id.dtype == np.int32 and tr.row_id.dtype == np.int64
    assert tr.table_id.min() >= 0 and tr.table_id.max() < n_tables
    assert tr.row_id.min() >= 0 and tr.row_id.max() < rows
    assert tr.global_id.max() < spec.n_vectors
    tr2 = make_trace(spec)
    assert np.array_equal(tr.table_id, tr2.table_id)
    assert np.array_equal(tr.row_id, tr2.row_id)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, len(GEN_REGIMES) - 1),
       st.integers(40, 1500),   # n_accesses
       st.integers(1, 97),      # batch
       st.integers(0, 1000))    # seed
def test_iter_batches_exact(regime_idx, accesses, batch, seed):
    spec = _spec_from(regime_idx, 3, 64, accesses, seed)
    tr = make_trace(spec)
    batches = list(iter_batches(spec, batch))
    assert len(batches) == accesses // batch
    assert all(b.shape == (batch,) for b in batches)
    if batches:
        # The batches are exactly the trace's global-id stream, in order.
        assert np.array_equal(np.concatenate(batches),
                              tr.global_id[: len(batches) * batch])


@pytest.mark.parametrize("fmt", ["npz", "csv"])
def test_replay_roundtrips_generated_trace(tmp_path, fmt):
    """A trace written by generate_trace must replay byte-identically
    through both serialization formats and the workload API."""
    tr = generate_trace(TraceGenConfig(n_tables=3, rows_per_table=50,
                                       n_accesses=700, seed=4))
    path = tmp_path / f"trace.{fmt}"
    save_trace(tr, path)
    back = load_trace(path)
    for field in ("table_id", "row_id", "rows_per_table", "query_id"):
        a, b = getattr(tr, field), getattr(back, field)
        assert a.dtype == b.dtype, field
        assert np.array_equal(a, b), field

    spec = make_spec("replay", path=str(path), n_accesses=0)
    replayed = make_trace(spec)
    assert np.array_equal(replayed.global_id, tr.global_id)
    assert np.array_equal(replayed.rows_per_table, tr.rows_per_table)
    # Batch iteration over the replay == slicing the original stream.
    bs = list(iter_batches(spec, 64, trace=replayed))
    assert np.array_equal(np.concatenate(bs),
                          tr.global_id[: len(bs) * 64])


def test_replay_prefix_truncation(tmp_path):
    tr = generate_trace(TraceGenConfig(n_tables=2, rows_per_table=40,
                                       n_accesses=300, seed=1))
    path = tmp_path / "t.npz"
    save_trace(tr, path)
    spec = make_spec("replay", path=str(path), n_accesses=120)
    assert len(make_trace(spec)) == 120


def test_trace_io_rejects_unknown_format(tmp_path):
    tr = generate_trace(TraceGenConfig(n_tables=2, rows_per_table=16,
                                       n_accesses=50, seed=0))
    with pytest.raises(ValueError):
        save_trace(tr, tmp_path / "t.parquet")
    with pytest.raises(ValueError):
        load_trace(tmp_path / "t.parquet")


def test_scenario_catalog_instantiates():
    for name in SCENARIOS:
        spec = scenario(name, n_tables=2, rows_per_table=32,
                        n_accesses=200, seed=7)
        tr = make_trace(spec)
        assert len(tr) == 200 and tr.n_tables == 2


def test_parse_workload():
    spec = parse_workload("diurnal:n_phases=6,hot_frac=0.1,seed=3")
    assert spec.regime == "diurnal" and spec.seed == 3
    assert spec.param("n_phases") == 6
    assert spec.param("hot_frac") == pytest.approx(0.1)
    assert spec.param("p_hot") == pytest.approx(0.9)  # catalog default kept
    assert parse_workload("stationary:zipf_a=1.3").regime == "stationary"
    assert parse_workload("replay:path=x.npz").param("path") == "x.npz"
    with pytest.raises(KeyError):
        parse_workload("no_such_workload")


def test_unknown_regime_raises():
    with pytest.raises(KeyError):
        make_trace(make_spec("not_a_regime"))


def test_typoed_param_raises():
    """A mistyped regime knob must fail loudly, not silently serve the
    default (``n_phase`` vs ``n_phases``)."""
    with pytest.raises(KeyError, match="n_phase"):
        make_trace(make_spec("diurnal", n_phase=6, n_accesses=100))
    with pytest.raises(KeyError, match="zipf"):
        make_trace(parse_workload("churn:zipfa=1.3"))


def test_parse_workload_replay_defaults_to_whole_file(tmp_path):
    """CLI replay specs default to the whole file, not the spec-default
    access count; an explicit n_accesses still truncates."""
    tr = generate_trace(TraceGenConfig(n_tables=2, rows_per_table=40,
                                       n_accesses=300, seed=2))
    path = tmp_path / "t.npz"
    save_trace(tr, path)
    spec = parse_workload(f"replay:path={path}")
    assert spec.n_accesses == 0
    assert len(make_trace(spec)) == 300
    spec = parse_workload(f"replay:path={path},n_accesses=100")
    assert len(make_trace(spec)) == 100


def test_query_batches_from_workload():
    """DLRM query streams can be derived from any scenario regime."""
    from repro.data.dlrm_data import DLRMDataConfig, query_batches

    cfg = DLRMDataConfig(n_tables=2, rows_per_table=64, multi_hot=2,
                         batch=4, seed=3)
    batches = list(query_batches(cfg, workload=scenario("zipf_hot"),
                                 n_batches=3))
    assert len(batches) == 3
    for b in batches:
        assert b["sparse"].shape == (4, 2, 2)
        assert b["sparse"].min() >= 0 and b["sparse"].max() < 64
    again = list(query_batches(cfg, workload=scenario("zipf_hot"),
                               n_batches=3))
    assert all(np.array_equal(a["sparse"], b["sparse"])
               for a, b in zip(batches, again))


def test_frequency_outputs_edge_cases():
    """The frequency-heuristic model must handle degenerate traces: a
    trace shorter than one chunk window yields zero chunks (no ragged
    broadcast), and ``profile_upto=0`` means an *empty* profile (a model
    that has seen nothing), not the whole trace."""
    from repro.core.recmg import frequency_outputs

    tiny = make_trace(make_spec("stationary", n_tables=2,
                                rows_per_table=16, n_accesses=10))
    out = frequency_outputs(tiny, 4)
    assert len(out.chunk_starts) == 0
    assert out.caching_bits.shape == (0, 15)

    tr = make_trace(make_spec("stationary", n_tables=2, rows_per_table=16,
                              n_accesses=200))
    blind = frequency_outputs(tr, 4, profile_upto=0)
    assert not blind.caching_bits.any()
    assert blind.prefetch_ids.shape[1] == 0
    full = frequency_outputs(tr, 4)
    assert full.caching_bits.any()
    assert (full.prefetch_ids.shape == (len(full.chunk_starts), 5)
            and len(full.chunk_starts) > 0)


def test_frequency_outputs_profile_upto_keyword_only():
    """``profile_upto`` must be impossible to pass positionally: slipped
    one slot past ``out_len`` it would silently profile beyond the
    freeze point (training the "frozen" drift model on post-switch data)
    instead of failing loudly."""
    from repro.core.recmg import frequency_outputs

    tr = make_trace(make_spec("stationary", n_tables=2, rows_per_table=16,
                              n_accesses=200))
    with pytest.raises(TypeError):
        frequency_outputs(tr, 4, 15, 5, 100)
    out = frequency_outputs(tr, 4, 15, 5, profile_upto=100)
    assert len(out.chunk_starts) > 0


def test_spec_with_override_and_hashability():
    spec = scenario("zipf_mid", seed=1)
    other = spec.with_(zipf_a=1.3, n_accesses=100)
    assert other.param("zipf_a") == pytest.approx(1.3)
    assert other.n_accesses == 100 and other.seed == 1
    assert spec.param("zipf_a") == pytest.approx(1.05)  # original untouched
    assert hash(spec) != hash(other)
    assert spec == scenario("zipf_mid", seed=1)
