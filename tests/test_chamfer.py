"""Chamfer measure (Eq. 4/5) properties + kernel-vs-oracle equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_shim import given, hnp, settings, st

from repro.core.chamfer import (chamfer_bidirectional,
                                chamfer_bidirectional_vec, chamfer_forward,
                                l2_truncated, pairwise_abs)


@settings(max_examples=40, deadline=None)
@given(hnp.arrays(np.float32, (3, 5), elements=st.floats(-10, 10, width=32)))
def test_identical_sets_zero(po):
    w = po.copy()
    d = chamfer_bidirectional(jnp.asarray(po), jnp.asarray(w))
    np.testing.assert_allclose(d, 0.0, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    hnp.arrays(np.float32, (2, 4), elements=st.floats(-5, 5, width=32)),
    hnp.arrays(np.float32, (2, 7), elements=st.floats(-5, 5, width=32)),
)
def test_permutation_invariance(po, w):
    d1 = chamfer_bidirectional(jnp.asarray(po), jnp.asarray(w))
    perm = np.random.default_rng(0).permutation(w.shape[1])
    d2 = chamfer_bidirectional(jnp.asarray(po), jnp.asarray(w[:, perm]))
    np.testing.assert_allclose(d1, d2, rtol=1e-5, atol=1e-6)


def test_forward_shortcut_example():
    """The paper's {1,2,3} vs {2,6,7,8} example: one-sided CM is minimized by
    collapsing onto 2; the reverse term penalizes that."""
    po_collapsed = jnp.asarray([[2.0, 2.0, 2.0]])
    po_spread = jnp.asarray([[2.0, 6.0, 7.0]])
    w = jnp.asarray([[2.0, 6.0, 7.0, 8.0]])
    fwd_c = chamfer_forward(po_collapsed, w)[0]
    fwd_s = chamfer_forward(po_spread, w)[0]
    assert float(fwd_c) == 0.0 and float(fwd_s) == 0.0  # fwd can't tell
    bi_c = chamfer_bidirectional(po_collapsed, w)[0]
    bi_s = chamfer_bidirectional(po_spread, w)[0]
    assert float(bi_s) < float(bi_c)  # reverse term prefers coverage


def test_alpha_blend():
    po = jnp.asarray([[0.0, 1.0]])
    w = jnp.asarray([[0.0, 1.0, 5.0]])
    for a in (0.1, 0.5, 0.9):
        d = chamfer_bidirectional(po, w, alpha=a)
        fwd = chamfer_forward(po, w)
        bwd = pairwise_abs(po, w).min(-2).mean(-1)
        np.testing.assert_allclose(d, a * fwd + (1 - a) * bwd, rtol=1e-5,
                                   atol=1e-7)


def test_vec_matches_scalar_when_1d():
    po = np.random.default_rng(0).normal(size=(4, 5)).astype(np.float32)
    w = np.random.default_rng(1).normal(size=(4, 9)).astype(np.float32)
    scalar = chamfer_bidirectional(jnp.asarray(po), jnp.asarray(w))
    # Vector form with F=1 and squared distance: compare via sqrt ordering.
    v = chamfer_bidirectional_vec(jnp.asarray(po)[..., None],
                                  jnp.asarray(w)[..., None])
    assert v.shape == scalar.shape
    # Squared-L2 in 1D == |x-y|^2: min locations agree -> equal for the
    # special case where distances are 0/identical. Just check monotone link:
    assert np.all(np.asarray(v) >= 0)


def test_l2_baseline_uses_prefix():
    po = jnp.asarray([[1.0, 2.0]])
    w = jnp.asarray([[1.0, 2.0, 99.0]])
    np.testing.assert_allclose(l2_truncated(po, w), 0.0, atol=1e-6)


def test_gradients_flow():
    po = jnp.asarray([[0.5, 1.5, 2.5]])
    w = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    g = jax.grad(lambda p: chamfer_bidirectional(p, w).sum())(po)
    assert np.all(np.isfinite(np.asarray(g)))
    assert np.any(np.asarray(g) != 0)
