"""Checkpoint: atomic save/restore, async writer, retention, resume."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


def _tree(x=1.0):
    return {"a": jnp.full((4, 4), x), "b": [jnp.full((2,), 2 * x),
                                            jnp.asarray(3 * x)]}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path)
    ck.save(d, 7, _tree(1.5))
    tree, step = ck.restore(d, _tree(0.0))
    assert step == 7
    np.testing.assert_allclose(tree["a"], 1.5)
    np.testing.assert_allclose(tree["b"][1], 4.5)


def test_latest_and_retention(tmp_path):
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ck.save(d, s, _tree(float(s)), keep=2)
    assert ck.latest_step(d) == 5
    kept = sorted(p.name for p in Path(d).glob("step_*"))
    assert len(kept) == 2


def test_async_save(tmp_path):
    d = str(tmp_path)
    t = ck.save_async(d, 11, _tree(2.0))
    ck.wait_pending(d)
    assert ck.latest_step(d) == 11


def test_atomicity_no_tmp_left(tmp_path):
    d = str(tmp_path)
    ck.save(d, 3, _tree())
    assert not list(Path(d).glob("*.tmp"))
    manifest = json.loads((Path(d) / "step_00000003" / "manifest.json").read_text())
    assert manifest["step"] == 3 and manifest["n_leaves"] == 3


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), _tree())


def test_restore_with_shardings(tmp_path):
    d = str(tmp_path)
    ck.save(d, 1, _tree(4.0))
    mesh = jax.make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree_util.tree_map(lambda _: NamedSharding(mesh, P()), _tree())
    tree, _ = ck.restore(d, _tree(), shardings=sh)
    np.testing.assert_allclose(tree["a"], 4.0)
