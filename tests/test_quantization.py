"""Quantized fast-tier fidelity suite: per-row round-trip error bounds
(int8 + fp8), host-vs-device quantizer parity, ``lookup_resident`` dequant
parity, and kernel-vs-jit gather equivalence under interpret-mode Pallas
(the CPU lane for the fused dequant kernels)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.tiered import TieredEmbeddingStore
from repro.kernels.embedding_gather import (dequantize_rows_ref,
                                            quantize_rows,
                                            quantize_rows_ref)


@pytest.fixture
def host():
    return np.random.default_rng(7).normal(size=(300, 8)).astype(np.float32)


# ---------------- round-trip error bounds ----------------


def test_int8_roundtrip_error_bound_per_row(host):
    """Acceptance bar: max abs dequant error <= max|row|/127 + eps per
    row — and round-half-even actually achieves half that."""
    q, s = quantize_rows_ref(jnp.asarray(host), "int8")
    back = np.asarray(dequantize_rows_ref(q, s))
    err = np.abs(back - host).max(axis=1)
    amax = np.abs(host).max(axis=1)
    assert (err <= amax / 127.0 + 1e-6).all()
    assert (err <= 0.5 * (amax / 127.0 + 1e-12) + 1e-6).all()


def test_fp8_roundtrip_error_bound_per_row(host):
    """fp8 (e4m3, 3 mantissa bits): relative step 2^-3, so round-to-
    nearest keeps the per-element error within amax/16 per row."""
    q, s = quantize_rows_ref(jnp.asarray(host), "fp8")
    back = np.asarray(dequantize_rows_ref(q, s))
    err = np.abs(back - host).max(axis=1)
    amax = np.abs(host).max(axis=1)
    assert (err <= amax / 16.0 + 1e-6).all()


def test_round_half_even_parity():
    """np.round and jnp.round are both round-half-even — the property the
    host/device quantizer bit-parity rests on."""
    grid = np.arange(-8, 8, 0.5, dtype=np.float32)  # every .5 midpoint
    np.testing.assert_array_equal(np.round(grid),
                                  np.asarray(jnp.round(grid)))


# ---------------- host vs device quantizer parity ----------------


def test_device_quantizer_matches_host_reference(host):
    """The store's fused device-side quantize+scatter produces the exact
    int8 codes the old host NumPy quantizer did (scales may differ by one
    float32 ulp: XLA is free to fuse the scale division differently)."""
    st = TieredEmbeddingStore(host, 64, quantize=True)
    ids = np.arange(64)
    st.lookup(ids)
    rows = host[ids]
    scale = np.abs(rows).max(axis=1) / 127.0 + 1e-12
    q = np.clip(np.round(rows / scale[:, None]), -127, 127).astype(np.int8)
    slots = st._slot_map[ids]
    np.testing.assert_array_equal(np.asarray(st.buffer)[slots], q)
    np.testing.assert_allclose(np.asarray(st.scales)[slots], scale,
                               rtol=2e-7)


@pytest.mark.parametrize("row_format", ["int8", "fp8"])
def test_pallas_quantizer_matches_jnp_reference(host, row_format):
    """The populate-side Pallas kernel and the jnp reference agree on the
    stored codes bit-for-bit (interpret mode; scales to one ulp)."""
    rows = jnp.asarray(host[:32])
    qk, sk = quantize_rows(rows, row_format=row_format, interpret=True)
    qr, sr = quantize_rows_ref(rows, row_format)
    np.testing.assert_array_equal(np.asarray(qk).view(np.uint8),
                                  np.asarray(qr).view(np.uint8))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=2e-7)


# ---------------- store-level parity ----------------


@pytest.mark.parametrize("row_format", [None, "fp8"])
def test_lookup_resident_dequant_parity(host, row_format):
    """The degraded read dequantizes host-side; it must return exactly
    what the device gather returns for resident ids."""
    st = TieredEmbeddingStore(host, 32, quantize=True,
                              row_format=row_format)
    ids = np.arange(16)
    out = np.asarray(st.lookup(ids))
    res, n_default = st.lookup_resident(ids)
    assert n_default == 0
    np.testing.assert_array_equal(res, out)


def test_kernel_gather_matches_jit_gather(host):
    """use_kernel=True (interpret) and the default jitted dequant gather
    are bit-identical on the same residency state — the kernel path is a
    drop-in, not an approximation."""
    ids = np.concatenate((np.arange(24), [3, 3, 17]))  # dups + revisit
    st_jit = TieredEmbeddingStore(host, 32, quantize=True)
    st_ker = TieredEmbeddingStore(host, 32, quantize=True,
                                  use_kernel=True, kernel_interpret=True)
    assert st_ker.use_kernel
    out_jit = np.asarray(st_jit.lookup(ids))
    out_ker = np.asarray(st_ker.lookup(ids))
    np.testing.assert_array_equal(out_jit, out_ker)
    for k in ("batches", "lookups", "hits", "misses", "on_demand_rows",
              "evictions"):
        assert st_jit.stats.as_dict()[k] == st_ker.stats.as_dict()[k]
    # Overflow path (working set > capacity): where-select fold included.
    big = np.arange(60)
    np.testing.assert_array_equal(np.asarray(st_jit.lookup(big)),
                                  np.asarray(st_ker.lookup(big)))
    st_ker.check_invariants()


def test_fp8_store_roundtrip(host):
    st = TieredEmbeddingStore(host, 32, quantize=True, row_format="fp8",
                              warmup_batch=32)
    ids = np.array([0, 5, 9, 5])
    out = np.asarray(st.lookup(ids))
    amax = np.abs(host[ids]).max(axis=1)
    assert (np.abs(out - host[ids]).max(axis=1) <= amax / 16.0 + 1e-6).all()


def test_quantized_warmup_preserves_values(host):
    """Warmup re-quantizes slot 0's dequantized row through the fused
    scatter; resident values must survive (requantization maps each code
    back to itself)."""
    st = TieredEmbeddingStore(host, 16, quantize=True)
    ids = np.array([5, 9, 13])
    before = np.asarray(st.lookup(ids))
    st.warmup(64)
    after = np.asarray(st.lookup(ids))
    np.testing.assert_array_equal(before, after)
    assert st.stats.hits == ids.size  # warmup evicted nothing
