"""Golden-trace regression: serve a small fixed trace, compare the
deterministic ``serve_trace`` metrics against checked-in JSON.

The golden files (``tests/golden/*.json``) pin every counter- and
model-derived metric — hit/miss/prefetch/eviction counters, the raw
``hits`` (lossless alongside the rounded ``hit_rate``), the modeled
slow-tier figures, and the sharded run's per-shard load/skew rows.
Wall-clock fields (``*_batch_ms`` percentiles, ``fetch_s``...) are
excluded by construction.

On drift the test fails with a per-key expected-vs-actual diff and dumps
both sides to ``runs/golden_diff/<name>.json`` (uploaded as a CI
artifact).  After an *intentional* semantics change, refresh with:

    PYTHONPATH=src python -m pytest tests/test_golden_trace.py --update-golden
"""
import dataclasses
import json
from functools import lru_cache
from pathlib import Path

import pytest

GOLDEN_DIR = Path(__file__).parent / "golden"
DIFF_DIR = Path(__file__).resolve().parents[1] / "runs" / "golden_diff"

# Deterministic serve_trace outputs: counters + cost-model figures only.
SERVE_KEYS = ("policy", "batches", "lookups", "hits", "hit_rate",
              "prefetch_hits", "on_demand_rows", "evictions",
              "on_demand_stall_ms", "modeled_fetch_ms_per_batch")
SHARD_KEYS = ("n_shards", "placement", "per_shard_rows",
              "per_shard_capacity", "per_shard_lookups",
              "per_shard_hit_rate", "per_shard_evictions",
              "load_imbalance", "max_batch_imbalance",
              "modeled_fetch_ms_sum", "modeled_fetch_ms_critical")


@lru_cache(maxsize=1)
def _fixture():
    import jax

    from repro.configs import get_config
    from repro.core.trace import TraceGenConfig, generate_trace
    from repro.models.dlrm import init_dlrm

    cfg = dataclasses.replace(get_config("dlrm-recmg").reduced(),
                              n_tables=4, rows_per_table=1024, multi_hot=2,
                              emb_dim=16)
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    trace = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=8000, seed=0, drift_every=10**9))
    return cfg, params, trace


def _serve(shards=0, placement="table"):
    from repro.launch.serve import serve_trace

    cfg, params, trace = _fixture()
    cap = int(0.15 * trace.unique_count())
    res = serve_trace(cfg, params, trace, cap, "lru", None, batch_queries=8,
                      shards=shards, placement=placement)
    metrics = {k: res[k] for k in SERVE_KEYS}
    if shards:
        metrics["shard"] = {k: res["shard"][k] for k in SHARD_KEYS}
    return metrics


def _flat(d, prefix=""):
    out = {}
    for k, v in d.items():
        if isinstance(v, dict):
            out.update(_flat(v, f"{prefix}{k}."))
        else:
            out[f"{prefix}{k}"] = v
    return out


def _check_golden(name, metrics, update):
    path = GOLDEN_DIR / f"{name}.json"
    blob = json.dumps(metrics, indent=2, sort_keys=True) + "\n"
    if update:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(blob)
        pytest.skip(f"golden {name} refreshed")
    if not path.exists():
        pytest.fail(f"missing tests/golden/{name}.json — generate it with "
                    "--update-golden and commit it")
    expected = json.loads(path.read_text())
    if expected == metrics:
        return
    exp_f, act_f = _flat(expected), _flat(metrics)
    lines = [f"  {k}: expected {exp_f.get(k, '<missing>')!r}, "
             f"got {act_f.get(k, '<missing>')!r}"
             for k in sorted(set(exp_f) | set(act_f))
             if exp_f.get(k) != act_f.get(k)]
    DIFF_DIR.mkdir(parents=True, exist_ok=True)
    (DIFF_DIR / f"{name}.json").write_text(json.dumps(
        {"expected": expected, "actual": metrics,
         "diff": [ln.strip() for ln in lines]}, indent=2, sort_keys=True))
    pytest.fail(
        f"serve_trace metrics drifted from tests/golden/{name}.json "
        f"({len(lines)} keys; full dump in runs/golden_diff/):\n"
        + "\n".join(lines)
        + "\n  (intentional change? refresh with --update-golden)")


def test_golden_serve_metrics(update_golden):
    metrics = _serve()
    # Satellite regression: the raw ``hits`` counter must be serialized
    # (hit_rate alone is 4-dp-rounded, i.e. lossy for aggregation) and the
    # dict must round-trip through JSON unchanged.
    assert "hits" in metrics and isinstance(metrics["hits"], int)
    assert json.loads(json.dumps(metrics)) == metrics
    assert metrics["hit_rate"] == round(
        metrics["hits"] / metrics["lookups"], 4)
    _check_golden("serve_lru", metrics, update_golden)


def test_golden_sharded_serve_metrics(update_golden):
    metrics = _serve(shards=2, placement="table")
    assert json.loads(json.dumps(metrics)) == metrics
    # The shard aggregate stays lossless too: per-shard ints sum to the
    # facade counters.
    assert sum(metrics["shard"]["per_shard_lookups"]) == metrics["lookups"]
    _check_golden("serve_lru_sharded_table2", metrics, update_golden)


def test_golden_diff_is_readable(tmp_path, monkeypatch, update_golden):
    """A drifted counter must fail with the offending key spelled out and
    leave a machine-readable dump for the CI artifact."""
    if update_golden:
        pytest.skip("refresh run")
    import test_golden_trace as mod

    metrics = json.loads((GOLDEN_DIR / "serve_lru.json").read_text())
    metrics["hits"] += 1
    monkeypatch.setattr(mod, "DIFF_DIR", tmp_path)
    with pytest.raises(pytest.fail.Exception) as ei:
        mod._check_golden("serve_lru", metrics, update=False)
    assert "hits: expected" in str(ei.value)
    dump = json.loads((tmp_path / "serve_lru.json").read_text())
    assert dump["expected"]["hits"] + 1 == dump["actual"]["hits"]
