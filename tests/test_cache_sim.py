"""Cache-policy simulators: behavioral invariants + the Fig.14 attribution
bookkeeping of the unified `simulate` driver."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.belady import belady_sim
from repro.core.cache_sim import FALRU, POLICIES, make_cache, simulate
from repro.core.prefetchers import Prefetcher


def test_lru_basic():
    c = FALRU(2)
    assert not c.access(1)
    assert not c.access(2)
    assert c.access(1)
    assert not c.access(3)  # evicts 2
    assert not c.access(2)
    assert c.access(3)


@settings(max_examples=25, deadline=None)
@given(
    keys=st.lists(st.integers(0, 30), min_size=20, max_size=300),
    cap=st.integers(2, 16),
)
def test_policies_bounded_and_opt_dominates(keys, cap):
    keys = np.array(keys)
    opt_hits, _ = belady_sim(keys, cap)
    for name in POLICIES:
        res = simulate(keys, make_cache(name, cap))
        assert 0 <= res.hits <= len(keys)
        assert res.hits + res.on_demand == len(keys)
        assert res.hits <= opt_hits.sum(), name


def test_repeated_single_key_all_hit():
    keys = np.array([5] * 100)
    for name in POLICIES:
        res = simulate(keys, make_cache(name, 4))
        assert res.hits == 99, name


class _AlwaysNext(Prefetcher):
    """Oracle-ish: prefetches key+1 (matches an ascending stream)."""

    def on_access(self, key, hit):
        return [key + 1]


def test_prefetch_attribution():
    keys = np.arange(100)
    res = simulate(keys, FALRU(10), _AlwaysNext())
    # Every access after the first should be a prefetch hit.
    assert res.prefetch_hits >= 90
    assert res.prefetch_issued >= 90
    assert res.prefetch_accuracy > 0.9
    assert res.hits == res.prefetch_hits + res.cache_hits


def test_belady_cache_replay():
    keys = np.array([1, 2, 1, 3, 1, 2])
    bc = make_cache("belady", 2, keys)
    hits = [bc.access(int(k)) for k in keys]
    ref, _ = belady_sim(keys, 2)
    assert hits == list(ref)
