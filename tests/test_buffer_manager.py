"""RecMG buffer (Algorithms 1 & 2): the array-backed engine implementation
must make the same victim choices as the literal O(capacity) transcription
(and as the heap reference — see tests/test_property_equivalence.py)."""
import numpy as np
from _hypothesis_shim import given, settings, st

from repro.core.buffer_manager import RecMGBuffer, SlowRecMGBuffer
from repro.core.priority_engine import ArrayPriorityEngine


@settings(max_examples=40, deadline=None)
@given(
    ops=st.lists(
        st.tuples(st.integers(0, 25), st.integers(0, 1), st.integers(0, 1)),
        min_size=5, max_size=200,
    ),
    cap=st.integers(2, 8),
)
def test_fast_matches_slow(ops, cap):
    fast = RecMGBuffer(cap, eviction_speed=4)
    slow = SlowRecMGBuffer(cap, eviction_speed=4, clamp=False)
    for key, bit, is_prefetch in ops:
        if is_prefetch:
            fast.load_embeddings([], [], [key])
            slow.load_embeddings([], [], [key])
        else:
            fast.load_embeddings([key], [bit], [])
            slow.load_embeddings([key], [bit], [])
        assert set(fast.score) == set(slow.priority)


def test_algorithm1_priorities():
    buf = RecMGBuffer(10, eviction_speed=4)
    buf.load_embeddings([1, 2], [1, 0], [3])
    # keep -> eviction_speed, evict -> 0 (RRIP class separation); prefetched
    # entries enter at eviction_speed.
    assert buf.score[1] - buf.epoch == 4
    assert buf.score[2] - buf.epoch == 0
    assert buf.score[3] - buf.epoch == 4


def test_paper_literal_priorities():
    buf = RecMGBuffer(10, eviction_speed=4)
    buf.load_embeddings([1, 2], [1, 0], [], scaled_bits=False)
    assert buf.score[1] - buf.epoch == 5
    assert buf.score[2] - buf.epoch == 4


def test_eviction_prefers_low_priority():
    buf = RecMGBuffer(2, eviction_speed=4)
    buf.load_embeddings([1], [1], [])  # priority 5
    buf.load_embeddings([2], [0], [])  # priority 4
    buf.load_embeddings([3], [1], [])  # full -> evict key 2
    assert buf.contains(1) and buf.contains(3) and not buf.contains(2)


def test_age_on_demand_eviction():
    buf = RecMGBuffer(3, eviction_speed=2)
    buf.load_embeddings([1], [1], [])
    assert buf.populate() == 1  # ages until the sole entry reaches 0
    assert len(buf) == 0
    assert buf.populate() is None


def test_engine_array_priorities_align_with_only_new_filter():
    """Regression: per-key priority arrays must follow their keys through
    the only_new filter (a skipped live key must not shift the priorities
    of the surviving ones)."""
    eng = ArrayPriorityEngine()
    eng.set_many(np.array([5]), 0)
    eng.set_many(np.array([5, 6, 6, 7]), np.array([10, 20, 30, 40]),
                 only_new=True)
    assert eng._score[5] == 0      # live: untouched
    assert eng._score[6] == 20     # first occurrence wins, not 10/30
    assert eng._score[7] == 40
    assert eng.count == 3
