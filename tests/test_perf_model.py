"""Linear performance model (paper Fig. 18)."""
import numpy as np
import pytest

from repro.core.perf_model import fit_perf_model


def test_exact_recovery():
    hr = np.linspace(0, 1, 20)
    lat = 100.0 - 60.0 * hr
    m = fit_perf_model(hr, lat)
    assert m.intercept == pytest.approx(100.0, rel=1e-6)
    assert m.slope == pytest.approx(-60.0, rel=1e-6)
    assert m.rmse < 1e-9


def test_noisy_fit_and_rmse():
    rng = np.random.default_rng(0)
    hr = rng.random(200)
    lat = 80.0 - 40.0 * hr + rng.normal(0, 1.0, 200)
    m = fit_perf_model(hr, lat)
    assert m.slope == pytest.approx(-40.0, rel=0.05)
    assert 0.5 < m.rmse < 2.0
    pred = m.predict([0.0, 1.0])
    assert pred[0] > pred[1]
