import sys
from pathlib import Path

# Tests see the real device count (1 CPU device); ONLY the dry-run sets the
# 512-device flag, inside its own process.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current run instead of "
             "comparing against it (tests/test_golden_trace.py)")


@pytest.fixture(scope="session")
def update_golden(request):
    return request.config.getoption("--update-golden")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def tiny_trace():
    from repro.core.trace import TraceGenConfig, generate_trace

    return generate_trace(
        TraceGenConfig(n_tables=8, rows_per_table=2000, n_accesses=30_000,
                       seed=0, drift_every=10**9)
    )
