"""Tiered embedding store: correctness of returned rows, hit accounting,
prefetch insertion, eviction, and the serving path end to end."""
import jax
import numpy as np
import pytest

from repro.core.tiered import TieredEmbeddingStore


@pytest.fixture
def host():
    rng = np.random.default_rng(0)
    return rng.normal(size=(100, 8)).astype(np.float32)


def test_lookup_returns_correct_rows(host):
    store = TieredEmbeddingStore(host, capacity=16, policy="lru")
    ids = np.array([3, 7, 3, 50])
    out = np.asarray(store.lookup(ids))
    np.testing.assert_allclose(out, host[ids], rtol=1e-6)


def test_hit_accounting(host):
    store = TieredEmbeddingStore(host, capacity=16, policy="lru")
    store.lookup(np.array([1, 2, 3]))
    assert store.stats.hits == 0
    store.lookup(np.array([1, 2, 4]))
    assert store.stats.hits == 2
    assert store.stats.on_demand_rows == 4


def test_eviction_under_capacity(host):
    store = TieredEmbeddingStore(host, capacity=4, policy="lru")
    store.lookup(np.arange(8))  # 8 uniques through a 4-slot buffer
    assert len(store.slot_of) == 4
    out = np.asarray(store.lookup(np.array([7])))
    np.testing.assert_allclose(out[0], host[7], rtol=1e-6)


def test_prefetch_insertion_counts_hits(host):
    store = TieredEmbeddingStore(host, capacity=16, policy="recmg")
    store.apply_model_outputs(np.array([]), np.array([]), np.array([5, 6]))
    store.lookup(np.array([5, 6]))
    assert store.stats.prefetch_hits == 2
    assert store.stats.hits == 2


def test_recmg_priorities_protect_kept_rows(host):
    store = TieredEmbeddingStore(host, capacity=3, policy="recmg")
    store.lookup(np.array([1, 2, 3]))
    # Caching model says: keep 1 (bit=1), not 2, 3.
    store.apply_model_outputs(np.array([1, 2, 3]), np.array([1, 0, 0]),
                              np.array([]))
    store.lookup(np.array([9]))  # forces one eviction
    assert 1 in store.slot_of  # the kept row survived


def test_modeled_fetch_accounting(host):
    store = TieredEmbeddingStore(host, capacity=8, policy="lru",
                                 fetch_us_per_row=10, fetch_us_fixed=0)
    store.lookup(np.arange(8))
    assert store.stats.modeled_fetch_s == pytest.approx(80e-6, rel=1e-6)


def test_serve_trace_smoke():
    from repro.configs import get_config
    from repro.core.trace import TraceGenConfig, generate_trace
    from repro.launch.serve import serve_trace
    from repro.models.dlrm import init_dlrm

    cfg = get_config("dlrm-recmg").reduced()
    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    tr = generate_trace(TraceGenConfig(
        n_tables=cfg.n_tables, rows_per_table=cfg.rows_per_table,
        n_accesses=cfg.n_tables * cfg.multi_hot * 8 * 6))
    res = serve_trace(cfg, params, tr, capacity=64, policy="lru",
                      outputs=None, batch_queries=8)
    assert res["batches"] >= 4
    assert 0.0 <= res["hit_rate"] <= 1.0
    assert res["mean_batch_ms"] > 0


def test_recmg_store_survives_eviction_pressure(host):
    """Regression: priority entries for evicted/non-resident keys must not
    desync the slot map (pipelined model outputs reference old vectors)."""
    store = TieredEmbeddingStore(host, capacity=6, policy="recmg")
    rng = np.random.default_rng(0)
    for step in range(30):
        ids = rng.integers(0, 100, size=8)
        store.lookup(ids)
        # Apply outputs referencing BOTH resident and long-gone keys.
        trunk = rng.integers(0, 100, size=5)
        store.apply_model_outputs(trunk, np.ones(5), rng.integers(0, 100, 3))
        assert len(store.slot_of) <= 6
    out = np.asarray(store.lookup(np.array([1, 2])))
    np.testing.assert_allclose(out, host[[1, 2]], rtol=1e-6)


def test_quantized_store_roundtrip(host):
    st = TieredEmbeddingStore(host, capacity=16, policy="lru", quantize=True)
    ids = np.array([0, 5, 9, 5])
    out = np.asarray(st.lookup(ids))
    err = np.abs(out - host[ids]).max() / np.abs(host).max()
    assert err < 0.02
    # eviction + refill path
    st.lookup(np.arange(40))
    out2 = np.asarray(st.lookup(np.array([0])))
    assert np.abs(out2 - host[[0]]).max() / np.abs(host).max() < 0.02


def test_use_kernel_with_quantize_honored(host):
    """Regression: the constructor used to silently drop an explicit
    ``use_kernel=True`` whenever ``quantize=True`` (``bool(use_kernel)
    and not quantize``).  The combination now routes through the fused
    dequantizing kernel path."""
    st = TieredEmbeddingStore(host, capacity=16, quantize=True,
                              use_kernel=True, kernel_interpret=True)
    assert st.use_kernel  # honored, not downgraded
    ids = np.array([0, 5, 9, 5])
    out = np.asarray(st.lookup(ids))
    assert np.abs(out - host[ids]).max() / np.abs(host).max() < 0.02


def test_use_kernel_unsupported_combos_raise(host):
    """An explicit ``use_kernel=True`` is a contract: unsupported setups
    raise instead of silently downgrading (auto mode may still fall
    back)."""
    import jax
    if jax.default_backend() != "tpu":
        # Explicit kernel request off-TPU needs the interpret escape hatch.
        with pytest.raises(ValueError, match="TPU backend"):
            TieredEmbeddingStore(host, capacity=16, use_kernel=True)
        with pytest.raises(ValueError, match="TPU backend"):
            TieredEmbeddingStore(host, capacity=16, quantize=True,
                                 use_kernel=True)
    # row_format is a quantized-tier knob.
    with pytest.raises(ValueError, match="requires quantize=True"):
        TieredEmbeddingStore(host, capacity=16, row_format="fp8")
    with pytest.raises(ValueError, match="unknown row_format"):
        TieredEmbeddingStore(host, capacity=16, quantize=True,
                             row_format="int4")
    # Auto mode still silently picks the portable path.
    st = TieredEmbeddingStore(host, capacity=16, quantize=True)
    assert isinstance(st.use_kernel, bool)


def test_tierstats_merge_additive():
    """TierStats.merge: counter additivity and the merged hit rate."""
    from repro.core.tiered import TierStats

    a = TierStats(batches=2, lookups=10, hits=4, prefetch_hits=1,
                  on_demand_rows=6, evictions=3, fetch_s=0.5, gather_s=0.25,
                  model_s=0.125, modeled_fetch_s=1.0)
    b = TierStats(batches=3, lookups=30, hits=24, prefetch_hits=2,
                  on_demand_rows=6, evictions=5, fetch_s=0.5, gather_s=0.75,
                  model_s=0.375, modeled_fetch_s=0.5)
    out = a.merge(b)
    assert out is a  # merges in place and returns self
    assert (a.batches, a.lookups, a.hits) == (5, 40, 28)
    assert (a.prefetch_hits, a.on_demand_rows, a.evictions) == (3, 12, 8)
    assert a.fetch_s == pytest.approx(1.0)
    assert a.gather_s == pytest.approx(1.0)
    assert a.model_s == pytest.approx(0.5)
    assert a.modeled_fetch_s == pytest.approx(1.5)
    # Merged hit rate is recomputed from merged counters, not averaged:
    # (4 + 24) / (10 + 30), not mean(0.4, 0.8).
    assert a.hit_rate == pytest.approx(28 / 40)
    assert a.as_dict()["evictions"] == 8


def test_tierstats_merge_identity():
    from repro.core.tiered import TierStats

    a = TierStats(batches=1, lookups=5, hits=2)
    a.merge(TierStats())
    assert (a.batches, a.lookups, a.hits) == (1, 5, 2)
    assert TierStats().merge(TierStats()).hit_rate == 0.0


def test_eviction_counter(host):
    store = TieredEmbeddingStore(host, capacity=8, policy="lru")
    store.lookup(np.arange(8))
    assert store.stats.evictions == 0
    store.lookup(np.arange(8, 12))  # 4 admissions force 4 evictions
    assert store.stats.evictions == 4


def test_resident_mask(host):
    store = TieredEmbeddingStore(host, capacity=8, policy="lru")
    store.lookup(np.array([1, 2, 3]))
    mask = store.resident_mask(np.array([1, 2, 3, 4]))
    assert mask.tolist() == [True, True, True, False]


# ---------------- shape-bucket edges ----------------


def test_bucket_exact_powers_of_two():
    from repro.core.tiered import _bucket

    assert _bucket(1) == 16 and _bucket(16) == 16  # floor bucket
    for p in (16, 32, 64, 1024):
        assert _bucket(p) == p           # exact power of two: no padding
        assert _bucket(p + 1) == 2 * p   # one past: next bucket
        assert _bucket(p - 1) == p


@pytest.mark.parametrize("policy", ["lru", "recmg"])
def test_capacity_one_store(host, policy):
    """A single-slot buffer: every distinct id evicts the previous one and
    most of each batch is served from the host overflow path."""
    store = TieredEmbeddingStore(host, capacity=1, policy=policy)
    ids = np.array([3, 7, 3, 50, 7, 3])
    out = np.asarray(store.lookup(ids))
    np.testing.assert_allclose(out, host[ids], rtol=1e-6)
    assert store.n_resident == 1
    store.check_invariants()
    out2 = np.asarray(store.lookup(np.arange(40)))
    np.testing.assert_allclose(out2, host[:40], rtol=1e-6)
    store.check_invariants()


@pytest.mark.parametrize("m", [16, 17, 31, 32, 33])
def test_batch_at_bucket_boundary(host, m):
    """Batches exactly at / one past a power-of-two bucket boundary must
    return correct rows (the padded gather slices back to the true size)."""
    store = TieredEmbeddingStore(host, capacity=64, policy="lru")
    ids = np.arange(m) % host.shape[0]
    out = np.asarray(store.lookup(ids))
    np.testing.assert_allclose(out, host[ids], rtol=1e-6)
    # repeat once resident (pure-hit path) and once more after eviction mix
    out = np.asarray(store.lookup(ids[::-1].copy()))
    np.testing.assert_allclose(out, host[ids[::-1]], rtol=1e-6)


def test_warmup_preserves_buffer_contents(host):
    store = TieredEmbeddingStore(host, capacity=16, policy="lru")
    ids = np.array([5, 9, 13])
    store.lookup(ids)
    store.warmup(64)  # compiles buckets 16..64; must not clobber rows
    out = np.asarray(store.lookup(ids))
    np.testing.assert_allclose(out, host[ids], rtol=1e-6)
    assert store.stats.hits == 3  # still resident: warmup didn't evict


def test_warmup_quantized(host):
    store = TieredEmbeddingStore(host, capacity=16, policy="lru",
                                 quantize=True, warmup_batch=32)
    ids = np.array([0, 5, 9])
    out = np.asarray(store.lookup(ids))
    assert np.abs(out - host[ids]).max() / np.abs(host).max() < 0.02
