"""Model-regression layer for the trained RecMG duo: the losses'
gradients are checked against finite differences in float64, and a tiny
end-to-end training run pins loss descent + bit-exact seeded
reproducibility for both models.

The prefetch loss stop-gradients its target representations (the
anti-collapse detach, §V-B) — so its analytic parameter gradient must
equal the finite difference of a *detached-target* reference loss (the
targets precomputed at the evaluation point and held fixed), not of the
loss itself: FD of the raw loss would differentiate straight through the
target branch the detach is there to cut.  The chamfer / truncated-L2 /
diversity terms are additionally FD-checked directly with respect to the
predicted points, where no detach is involved.
"""
import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64
from jax.flatten_util import ravel_pytree

from repro.core.caching_model import (CachingModelConfig, bce_loss,
                                      init_caching_model,
                                      train_caching_model)
from repro.core.chamfer import chamfer_bidirectional_vec, l2_truncated_vec
from repro.core.features import ROW_BUCKETS, make_windows
from repro.core.prefetch_model import (PrefetchModelConfig, access_reps,
                                       init_prefetch_model,
                                       make_prefetch_data, prefetch_loss,
                                       prefetch_predict_batch,
                                       train_prefetch_model)

# Tiny model dims: the FD check is O(params) per direction and the point
# is gradient *correctness*, not capacity.
N_TABLES, IN_LEN, OUT_LEN, HIDDEN = 3, 6, 3, 8


def _fd_check(loss_fn, params, n_dirs=3, eps=1e-5, tol=1e-6, seed=0):
    """Directional finite differences vs the analytic gradient, in f64.

    Central differences with eps=1e-5 leave ~1e-10 truncation error, so a
    1e-6 relative tolerance only passes when the gradient is genuinely
    right (f32 would drown the comparison in rounding noise).
    """
    flat, unravel = ravel_pytree(params)
    assert flat.dtype == jnp.float64  # params must be built under x64
    g = ravel_pytree(jax.grad(loss_fn)(params))[0]
    assert bool(jnp.all(jnp.isfinite(g)))
    rng = np.random.default_rng(seed)
    for _ in range(n_dirs):
        v = rng.normal(size=flat.shape)
        v = jnp.asarray(v / np.linalg.norm(v))
        lp = float(loss_fn(unravel(flat + eps * v)))
        lm = float(loss_fn(unravel(flat - eps * v)))
        fd = (lp - lm) / (2 * eps)
        an = float(g @ v)
        assert abs(fd - an) <= tol * max(1.0, abs(an)), (fd, an)


def _int_batch(rng, b, t):
    return {
        "xt": jnp.asarray(rng.integers(0, N_TABLES, (b, t)), jnp.int32),
        "xr1": jnp.asarray(rng.integers(0, ROW_BUCKETS[0], (b, t)),
                           jnp.int32),
        "xr2": jnp.asarray(rng.integers(0, ROW_BUCKETS[1], (b, t)),
                           jnp.int32),
        "xn": jnp.asarray(rng.uniform(0, 1, (b, t))),
        "xf": jnp.asarray(rng.uniform(0, 1, (b, t))),
        "xrc": jnp.asarray(rng.uniform(0, 1, (b, t))),
    }


def test_bce_loss_gradient_matches_finite_differences():
    with enable_x64():
        cfg = CachingModelConfig(n_tables=N_TABLES, table_emb=4, row_emb=4,
                                 hidden=HIDDEN, in_len=IN_LEN)
        params = init_caching_model(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        batch = _int_batch(rng, 2, IN_LEN)
        batch["y"] = jnp.asarray(
            rng.integers(0, 2, (2, IN_LEN)).astype(np.float64))
        _fd_check(lambda p: bce_loss(p, batch), params)


def _prefetch_case(loss):
    cfg = PrefetchModelConfig(n_tables=N_TABLES, table_emb=4, row_emb=4,
                              hidden=HIDDEN, in_len=IN_LEN, out_len=OUT_LEN,
                              window=3 * OUT_LEN, loss=loss)
    params = init_prefetch_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    batch = _int_batch(rng, 2, IN_LEN)
    wlen = cfg.window
    w = _int_batch(rng, 2, wlen)
    batch.update(wt=w["xt"], wr1=w["xr1"], wr2=w["xr2"], wn=w["xn"])
    return cfg, params, batch


@pytest.mark.parametrize("loss", ["chamfer", "l2"])
def test_prefetch_loss_gradient_matches_detached_target_fd(loss):
    """grad of the real loss (targets stop-gradiented) == FD of the
    detached-target reference: the prediction branch's gradient is right
    AND the detach really cuts the target branch (if it leaked, the
    analytic grad would pick up the extra embedding-table terms and the
    comparison would blow past the f64 tolerance)."""
    with enable_x64():
        cfg, params, batch = _prefetch_case(loss)
        wlen = cfg.window if loss == "chamfer" else cfg.out_len
        w0 = jax.lax.stop_gradient(access_reps(
            params, cfg, batch["wt"][:, :wlen], batch["wr1"][:, :wlen],
            batch["wr2"][:, :wlen], batch["wn"][:, :wlen]))

        def loss_fixed(p):
            po = prefetch_predict_batch(
                p, cfg, batch["xt"], batch["xr1"], batch["xr2"],
                batch["xn"], batch["xf"], batch["xrc"])
            if loss == "l2":
                return l2_truncated_vec(po, w0).mean()
            out = chamfer_bidirectional_vec(po, w0, cfg.alpha).mean()
            d = po[:, :, None, :] - po[:, None, :, :]
            d2 = (d * d).sum(-1)
            P = po.shape[1]
            off = 1.0 - jnp.eye(P)
            rep = ((jnp.exp(-d2 / cfg.diversity_tau) * off).sum(-1).sum(-1)
                   / (P * (P - 1)))
            return out + cfg.diversity_weight * rep.mean()

        g_real = ravel_pytree(
            jax.grad(lambda p: prefetch_loss(p, cfg, batch))(params))[0]
        g_fix = ravel_pytree(jax.grad(loss_fixed)(params))[0]
        np.testing.assert_allclose(np.asarray(g_real), np.asarray(g_fix),
                                   rtol=1e-12, atol=1e-12)
        _fd_check(loss_fixed, params)


@pytest.mark.parametrize("term", ["chamfer", "l2", "diversity"])
def test_set_loss_terms_gradient_wrt_points(term):
    """The chamfer / truncated-L2 / diversity terms FD-checked directly
    with respect to the predicted point set (no model, no detach)."""
    with enable_x64():
        rng = np.random.default_rng(3)
        po0 = jnp.asarray(rng.normal(size=(2, OUT_LEN, 5)))
        w = jnp.asarray(rng.normal(size=(2, 3 * OUT_LEN, 5)))

        def f(po):
            if term == "chamfer":
                return chamfer_bidirectional_vec(po, w, 0.7).mean()
            if term == "l2":
                return l2_truncated_vec(po, w[:, :OUT_LEN]).mean()
            d = po[:, :, None, :] - po[:, None, :, :]
            d2 = (d * d).sum(-1)
            off = 1.0 - jnp.eye(OUT_LEN)
            return (jnp.exp(-d2 / 0.5) * off).sum(-1).sum(-1).mean()

        _fd_check(f, po0)


# ---------------------------------------------------------------------------
# Tiny end-to-end training: descent + bit-exact seeded reproducibility
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _train_trace():
    from repro.core.trace import TraceGenConfig, generate_trace

    return generate_trace(TraceGenConfig(
        n_tables=3, rows_per_table=64, n_accesses=2000, seed=0,
        drift_every=10**9))


def _train_caching():
    from repro.core.belady import belady_labels

    tr = _train_trace()
    labels, _, _ = belady_labels(tr.global_id, 48)
    data = make_windows(tr, labels=labels, stride=5)
    cfg = CachingModelConfig(n_tables=3, hidden=16)
    return train_caching_model(data, cfg, epochs=4, batch_size=64, lr=1e-2)


def _train_prefetch():
    tr = _train_trace()
    data = make_prefetch_data(tr, stride=5)
    cfg = PrefetchModelConfig(n_tables=3, hidden=16)
    return train_prefetch_model(data, cfg, epochs=2, batch_size=64, lr=3e-3)


@pytest.mark.parametrize("train", [_train_caching, _train_prefetch],
                         ids=["caching", "prefetch"])
def test_tiny_training_descends_and_reproduces(train):
    """~20 optimizer steps on a 2000-access trace: the loss goes down,
    and a second same-seed run reproduces every parameter byte (the
    guarantee the learned golden files and the drift fine-tune's
    determinism contract both sit on)."""
    p1, losses = train()
    assert len(losses) >= 10
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses
    assert np.all(np.isfinite(losses))
    p2, losses2 = train()
    assert losses == losses2
    f1 = np.asarray(ravel_pytree(p1)[0])
    f2 = np.asarray(ravel_pytree(p2)[0])
    assert np.array_equal(f1, f2)  # byte-identical, not just allclose
