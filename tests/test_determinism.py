"""Seed determinism: same seed => byte-identical artifacts, twice over.

Guards the reproducibility contract everything else leans on — the
golden-trace test pins values *across commits*, these tests pin them
*across runs*: the trace generator must emit byte-identical access
streams for a fixed :class:`TraceGenConfig`, and the serving runtime's
:class:`VirtualClock` timeline (telemetry, per-request latencies, store
counters) must replay byte-identically for a fixed workload.
"""
import json

import numpy as np

from repro.core.tiered import TieredEmbeddingStore
from repro.core.trace import TraceGenConfig, generate_trace
from repro.runtime import PipelinedRuntime, RuntimeConfig

CFG = TraceGenConfig(n_tables=4, rows_per_table=512, n_accesses=6000,
                     seed=7, drift_every=2000)


def test_generate_trace_seed_determinism():
    a, b = generate_trace(CFG), generate_trace(CFG)
    for f in ("table_id", "row_id", "query_id", "rows_per_table"):
        assert getattr(a, f).tobytes() == getattr(b, f).tobytes(), f
    # And a different seed genuinely changes the stream.
    c = generate_trace(TraceGenConfig(
        n_tables=4, rows_per_table=512, n_accesses=6000, seed=8,
        drift_every=2000))
    assert a.row_id.tobytes() != c.row_id.tobytes()


def _timeline_blob(seed=3):
    """One pipelined run on a VirtualClock, serialized without the
    wall-clock fields."""
    rng = np.random.default_rng(seed)
    host = rng.normal(size=(400, 8)).astype(np.float32)
    ranks = np.minimum(rng.zipf(1.2, size=3000), 400) - 1
    ids = rng.permutation(400)[ranks].astype(np.int64)
    store = TieredEmbeddingStore(host, 48, policy="recmg")
    rt = PipelinedRuntime(store, RuntimeConfig(
        max_batch=4, pipeline_depth=2, compute_us=500.0))
    pf_rng = np.random.default_rng(seed + 1)
    empty = np.empty(0, np.int64)

    def step(b, emb):
        pf = np.unique(pf_rng.integers(0, 400, size=6))
        return 0.0, [(empty, empty, pf)]

    n_req = len(ids) // 12
    rt.run((ids[i * 12: (i + 1) * 12] for i in range(n_req)), step)
    d = rt.results()
    d["latencies_us"] = list(rt.telemetry.latencies_us)
    st = store.stats.as_dict()
    for wall in ("fetch_s", "gather_s", "model_s"):
        st.pop(wall)
    d["store"] = st
    return json.dumps(d, sort_keys=True)


def test_virtual_clock_timeline_determinism():
    assert _timeline_blob() == _timeline_blob()


def test_sharded_serving_determinism():
    """Two sharded runs over the same plan/workload: identical aggregate
    stats and shard telemetry (the per-shard engine channels included)."""
    from repro.core.sharded_serving import ShardedTieredStore

    def run():
        rng = np.random.default_rng(11)
        host = rng.normal(size=(600, 8)).astype(np.float32)
        ids = rng.integers(0, 600, size=4000).astype(np.int64)
        st = ShardedTieredStore.build(
            host, [150, 150, 150, 150], 4, "freq", capacity=96,
            profile_ids=ids, policy="recmg")
        empty = np.empty(0, np.int64)
        for b in range(40):
            st.lookup(ids[b * 100: (b + 1) * 100])
            st.apply_model_outputs(
                empty, empty, np.unique(ids[b * 7: b * 7 + 5]))
        d = st.stats.as_dict()
        for wall in ("fetch_s", "gather_s", "model_s"):
            d.pop(wall)
        d["shard"] = st.shard_telemetry()
        return json.dumps(d, sort_keys=True)

    assert run() == run()
