"""§Perf optimization code paths: q-stationary attention, data-local MoE
dispatch, row-sharded DLRM lookup, sharding variants."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.sharding import partition as sp


def test_kv_stream_attention_matches_plain():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 200, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 200, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 200, 2, 32))
    ref = L.plain_attention(q, k, v, causal=True)
    out = L.kv_stream_attention(q, k, v, bk=64)
    np.testing.assert_allclose(out, ref, rtol=3e-4, atol=3e-4)
    ref_w = L.plain_attention(q, k, v, causal=True, window=50)
    out_w = L.kv_stream_attention(q, k, v, bk=64, window=50)
    np.testing.assert_allclose(out_w, ref_w, rtol=3e-4, atol=3e-4)


def test_moe_local_dispatch_matches_global():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=16,
                      d_ff=32, vocab=64, n_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=16.0, param_dtype="float32",
                      compute_dtype="float32")
    p = L.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    y_global, _ = L.moe_block(p, cfg, x)
    # Sharded dispatch with an explicit 2-shard split (droppless capacity ->
    # identical math regardless of dispatch grouping).
    xf = x.reshape(-1, 16)
    y_sharded, _ = L._moe_dispatch_ffn_sharded(p, cfg, xf, 2)
    np.testing.assert_allclose(y_sharded.reshape(x.shape), y_global,
                               rtol=1e-4, atol=1e-5)


def test_dlrm_rowsharded_lookup_matches_dense():
    from repro.models.dlrm import embedding_lookup, embedding_lookup_rowsharded

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    emb = jax.random.normal(jax.random.PRNGKey(0), (3, 16, 8))
    idx = jax.random.randint(jax.random.PRNGKey(1), (4, 3, 2), 0, 16)
    want = embedding_lookup(emb, idx)
    got = embedding_lookup_rowsharded(emb, idx, mesh)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_dlrm_forward_sharded_flag():
    cfg = get_config("dlrm-recmg").reduced()
    from repro.models.dlrm import dlrm_forward, init_dlrm

    params = init_dlrm(jax.random.PRNGKey(0), cfg)
    dense = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.dense_features))
    sparse = jax.random.randint(jax.random.PRNGKey(2),
                                (4, cfg.n_tables, cfg.multi_hot), 0,
                                cfg.rows_per_table)
    base = dlrm_forward(params, cfg, dense, sparse)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sp.activation_sharding(mesh):
        sharded = dlrm_forward(params, cfg, dense, sparse,
                               sharded_lookup=True)
    np.testing.assert_allclose(sharded, base, rtol=1e-4, atol=1e-4)


def test_fsdp_variant_param_specs():
    class FakeMesh:
        shape = {"data": 4, "model": 8}
        axis_names = ("data", "model")

    from repro.models.model_api import build

    bundle = build(get_config("qwen3-14b"))
    specs = sp.param_pspecs(bundle.param_struct(), FakeMesh, "fsdp")
    # No TP: the sharded dim carries both axes, nothing else is sharded.
    assert specs["embed"] == P(("data", "model"))
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for s in flat:
        for ent in s:
            # Full-axes FSDP, or its progressive prefix when a dim doesn't
            # divide the (data*model) product, or replicated.
            assert ent in (None, ("data", "model"), "data"), s


def test_seq_entry_and_batch_entry():
    class FakeMesh:
        shape = {"data": 4, "model": 8}
        axis_names = ("data", "model")

    assert sp.batch_entry(FakeMesh, "fsdp_tp") == ("data",)
    assert sp.batch_entry(FakeMesh, "fsdp") == ("data", "model")
    assert sp.seq_entry(FakeMesh, "fsdp_seq") == ("model",)
    assert sp.seq_entry(FakeMesh, "fsdp_tp") is None


def test_constrain_kv_gather_noop_outside_seq():
    x = jnp.ones((2, 8, 2, 4))
    assert sp.constrain_kv_gather(x) is x
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sp.activation_sharding(mesh, "fsdp_tp"):
        assert sp.constrain_kv_gather(x) is x  # seq variant not active
