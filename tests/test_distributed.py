"""Gradient compression + fault-tolerance utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress_tree, dequantize_int8,
                                           init_error,
                                           make_compressed_dp_grads,
                                           quantize_int8)
from repro.distributed.fault_tolerance import (ElasticMesh, Heartbeat,
                                               RetryDeadlineExceeded,
                                               StragglerMonitor, retry_step)


def test_int8_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.51 + 1e-6


def test_error_feedback_carries_residual():
    g = {"w": jnp.asarray([0.001, 1.0])}
    e = init_error(g)
    q, s, e2 = compress_tree(g, e)
    # Residual of the tiny coordinate is carried, not lost.
    assert float(jnp.abs(e2["w"][0])) > 0
    # Over repeated steps the residual average converges to the true grad.
    acc = jnp.zeros(2)
    err = init_error(g)
    for _ in range(50):
        q, s, err = compress_tree(g, err)
        acc = acc + dequantize_int8(q["w"], s["w"])
    np.testing.assert_allclose(acc / 50, g["w"], rtol=0.05, atol=1e-4)


def test_compressed_dp_grads_close_to_exact():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    w = {"w": jnp.asarray([1.0, -2.0, 3.0])}

    def loss_fn(p, batch):
        return jnp.mean((batch["x"] @ p["w"] - batch["y"]) ** 2)

    batch = {"x": jnp.eye(3), "y": jnp.asarray([0.0, 1.0, 2.0])}
    grads_fn = make_compressed_dp_grads(loss_fn, mesh)
    err = init_error(w)
    loss, g, err = grads_fn(w, err, batch)
    _, g_exact = jax.value_and_grad(loss_fn)(w, batch)
    np.testing.assert_allclose(g["w"], g_exact["w"], rtol=0.05, atol=0.05)


def test_retry_step():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return 42

    assert retry_step(flaky, retries=5, backoff_s=0.001) == 42
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError()),
                   retries=1, backoff_s=0.001)


def test_retry_step_injectable_clock_and_backoff():
    pauses = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 4:
            raise RuntimeError("transient")
        return "ok"

    assert retry_step(flaky, retries=5, backoff_s=1.0,
                      sleep=pauses.append, now=lambda: 0.0) == "ok"
    assert pauses == [1.0, 2.0, 4.0]  # exponential, no wall sleep


def test_retry_step_fatal_errors_are_not_retried():
    calls = {"n": 0}

    def broken():
        calls["n"] += 1
        raise ValueError("logic bug")

    with pytest.raises(ValueError):
        retry_step(broken, retries=5, backoff_s=1.0,
                   retryable=(RuntimeError,), sleep=lambda s: None)
    assert calls["n"] == 1  # first raise propagates, zero retries


def test_retry_step_deadline_bounds_the_episode():
    t = {"now": 0.0}

    def sleep(s):
        t["now"] += s

    def always_fails():
        raise RuntimeError("transient")

    with pytest.raises(RetryDeadlineExceeded) as ei:
        retry_step(always_fails, retries=100, backoff_s=1.0,
                   retryable=(RuntimeError,), sleep=sleep,
                   now=lambda: t["now"], deadline_s=5.0)
    # 1 + 2 slept; the next 4s backoff would land past 5s -> raise, and
    # the underlying error rides along as the cause.
    assert t["now"] == 3.0
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert isinstance(ei.value, TimeoutError)  # admission code catches this


def test_straggler_monitor():
    mon = StragglerMonitor(warmup=5)
    for i in range(30):
        slow = mon.record(i, 0.1)
        assert not slow
    assert mon.record(31, 5.0)  # 50x outlier flagged
    assert mon.summary()["stragglers"] == 1


def test_straggler_record_since_uses_injected_clock():
    ticks = iter([float(i) for i in range(20)] + [120.0])
    mon = StragglerMonitor(warmup=5, clock=lambda: next(ticks))
    assert not mon.record_since(0)  # first call only arms the clock
    assert mon.n == 0
    flagged = [mon.record_since(i) for i in range(1, 20)]
    assert not any(flagged)         # steady 1s cadence, no outliers
    assert mon.record_since(20)     # 100s gap -> flagged
    assert mon.summary()["stragglers"] == 1


def test_elastic_mesh_factors():
    m = ElasticMesh(model_parallel=8).make()  # 1 device -> mp shrinks to 1
    assert m.devices.size == len(jax.devices())


def test_heartbeat(tmp_path):
    hb = Heartbeat(tmp_path / "hb.json", every_s=0.0)
    hb.beat(5, loss=1.0)
    import json

    assert json.loads((tmp_path / "hb.json").read_text())["step"] == 5


def test_heartbeat_cadence_on_virtual_clock(tmp_path):
    import json

    t = {"now": 0.0}
    hb = Heartbeat(tmp_path / "hb.json", every_s=10.0,
                   clock=lambda: t["now"])
    hb.beat(0)  # first beat always writes, even with a long cadence
    assert json.loads((tmp_path / "hb.json").read_text())["step"] == 0
    t["now"] = 5.0
    hb.beat(1)  # inside the cadence window: suppressed
    assert json.loads((tmp_path / "hb.json").read_text())["step"] == 0
    t["now"] = 12.0
    hb.beat(2)
    assert json.loads((tmp_path / "hb.json").read_text())["step"] == 2
    # Atomic publish: the temp file never survives a completed beat.
    assert not (tmp_path / "hb.tmp").exists()
    assert list(tmp_path.iterdir()) == [tmp_path / "hb.json"]
