"""HLO static analysis: trip-count recovery and collective-byte accounting,
against both crafted text and a real compiled scan."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import (analyze, collective_stats,
                                       computation_multipliers,
                                       hlo_dot_flops, parse_computations)

CRAFTED = """
HloModule test

%body.1 (p: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p = (s32[], f32[4,8]) parameter(0)
  %ag = f32[16,8]{1,0} all-gather(%x), replica_groups={}
  ROOT %t = (s32[], f32[4,8]) tuple(%i, %y)
}

%cond.1 (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %c = s32[] constant(30)
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %cmp = pred[] compare(%i, %c), direction=LT
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %ar = f32[4,8]{1,0} all-reduce(%a), to_apply=%add
  %w = (s32[], f32[4,8]) while(%init), condition=%cond.1, body=%body.1
  ROOT %r = f32[4,8] get-tuple-element(%w), index=1
}
"""


def test_crafted_trip_scaling():
    stats = collective_stats(CRAFTED)
    # all-gather inside the 30-trip loop: 16*8*4 bytes * 30.
    assert stats["bytes_all-gather"] == 16 * 8 * 4 * 30
    # all-reduce at top level: 4*8*4 bytes * 2 (two ring phases).
    assert stats["bytes_all-reduce"] == 4 * 8 * 4 * 2


def test_real_scan_trip_count():
    def f(x):
        def body(c, _):
            return c @ c, None

        y, _ = jax.lax.scan(body, x, None, length=13)
        return y

    hlo = jax.jit(f).lower(jnp.eye(64)).compile().as_text()
    comps = parse_computations(hlo)
    mult = computation_multipliers(comps)
    assert max(mult.values()) == 13


def test_dot_flops_scaled_by_trips():
    n, L = 64, 13

    def f(x):
        def body(c, _):
            return c @ c, None

        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    hlo = jax.jit(f).lower(jnp.eye(n)).compile().as_text()
    flops = hlo_dot_flops(hlo)
    want = 2 * n**3 * L
    assert 0.9 * want <= flops <= 1.2 * want


def test_analyze_has_all_fields():
    out = analyze(CRAFTED)
    for k in ("collective_bytes", "hlo_dot_flops", "hlo_bytes_accessed"):
        assert k in out
