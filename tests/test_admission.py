"""SLO-aware admission control: config validation, EDF order, shed
accounting, degraded answers, backpressure, and the fate identity
``admitted == served + shed + degraded`` on every serving surface."""
import numpy as np
import pytest

from repro.core.serving import MultiTableTieredStore
from repro.core.sharded_serving import ShardedTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.obs import MetricsRegistry, reconcile
from repro.obs.reconcile import check_admission
from repro.runtime import (AdmissionConfig, AdmissionQueue, AdmissionStats,
                           PipelinedRuntime, Request, RuntimeConfig)
from repro.sharding.embedding_shard import make_plan
from repro.workloads import (degradation_ratio, make_spec, overload_sweep,
                             replay_overload)

EMPTY = np.empty(0, np.int64)


def _host(n=200, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _req(rid, pri=0, arrival=0.0, deadline=float("inf")):
    return Request(rid, np.array([rid % 50]), arrival_us=float(arrival),
                   priority=pri, deadline_us=float(deadline))


# ---------------- config validation ----------------


@pytest.mark.parametrize("kw", [
    dict(queue_bound=0),
    dict(class_deadline_us=()),
    dict(class_deadline_us=(float("nan"),)),
    dict(class_deadline_us=(-1.0,)),
    dict(backpressure_hi=1.5),
    dict(backpressure_lo=0.9, backpressure_hi=0.5),
    dict(backpressure_lo=float("nan")),
])
def test_admission_config_rejects_invalid(kw):
    with pytest.raises(ValueError):
        AdmissionConfig(**kw)


def test_admission_config_deadlines():
    cfg = AdmissionConfig(class_deadline_us=(10.0, 40.0))
    assert cfg.n_classes == 2
    assert cfg.class_name(0) == "gold" and cfg.class_name(1) == "silver"
    assert cfg.deadline_for(1, 100.0) == 140.0
    with pytest.raises(ValueError):
        cfg.deadline_for(2, 0.0)
    # inf budget is a legal "never degrade this class" knob
    assert AdmissionConfig(
        class_deadline_us=(float("inf"),)).deadline_for(0, 5.0) == float("inf")


# ---------------- stats + identity ----------------


def test_admission_stats_identity_and_publish():
    st = AdmissionStats(n_classes=3)
    st.admitted[0] += 4
    st.served[0] += 2
    st.shed[0] += 1
    st.degraded[0] += 1
    st.admitted[2] += 3
    st.shed[2] += 3
    st.check()  # holds
    d = st.as_dict()
    assert d["admitted"] == 7 and d["gold_served"] == 2
    assert d["bronze_shed"] == 3 and d["silver_admitted"] == 0

    reg = MetricsRegistry()
    st.publish(reg)
    flat = reg.as_dict()
    assert flat["adm.admitted"] == 7
    assert flat["adm.class.gold.degraded"] == 1
    assert check_admission(flat) == []

    st.served[0] += 1  # cook the books: served without admission
    with pytest.raises(AssertionError):
        st.check()


def test_admission_stats_merge_additive():
    a = AdmissionStats(n_classes=2)
    b = AdmissionStats(n_classes=2)
    a.admitted[0], a.served[0] = 3, 3
    b.admitted[0], b.shed[0] = 2, 2
    b.degraded_rows_default = 5
    a.merge(b)
    assert a.admitted[0] == 5 and a.served[0] == 3 and a.shed[0] == 2
    assert a.degraded_rows_default == 5
    a.check()


def test_check_admission_catches_per_class_drift():
    flat = {"adm.admitted": 10, "adm.served": 10, "adm.shed": 0,
            "adm.degraded": 0,
            "adm.class.gold.admitted": 6, "adm.class.gold.served": 6,
            "adm.class.gold.shed": 0, "adm.class.gold.degraded": 0}
    assert check_admission(flat)  # class sums != totals must be flagged


# ---------------- queue: EDF order + shedding ----------------


def test_queue_pops_in_edf_order_with_deterministic_ties():
    cfg = AdmissionConfig(queue_bound=8)
    aq = AdmissionQueue(cfg)
    # rid 0 late deadline, rid 1 early, rid 2 ties rid 1 on deadline but
    # arrived later, rid 3 ties rid 1 on deadline AND arrival (rid breaks)
    aq.offer(_req(0, arrival=0.0, deadline=90.0))
    aq.offer(_req(1, arrival=1.0, deadline=50.0))
    aq.offer(_req(2, arrival=2.0, deadline=50.0))
    aq.offer(_req(3, arrival=1.0, deadline=50.0))
    assert [r.rid for r in aq.pop(3)] == [1, 3, 2]
    assert [r.rid for r in aq.drain()] == [0]
    with pytest.raises(ValueError, match="empty admission queue"):
        aq.pop(4)


def test_queue_sheds_lowest_priority_first():
    cfg = AdmissionConfig(queue_bound=2, class_deadline_us=(10.0, 20.0, 40.0))
    aq = AdmissionQueue(cfg)
    st = aq.stats
    assert aq.offer(_req(0, pri=2, arrival=0.0, deadline=40.0))
    assert aq.offer(_req(1, pri=1, arrival=0.0, deadline=20.0))
    # Full queue + gold arrival: the queued bronze request is displaced.
    assert aq.offer(_req(2, pri=0, arrival=1.0, deadline=11.0))
    assert st.shed == [0, 0, 1]
    assert sorted(r.rid for r in aq.drain()) == [1, 2]
    # Full queue of gold + bronze arrival: the incoming request is shed.
    aq.offer(_req(3, pri=0, arrival=2.0, deadline=12.0))
    aq.offer(_req(4, pri=0, arrival=2.0, deadline=12.0))
    assert not aq.offer(_req(5, pri=2, arrival=3.0, deadline=43.0))
    assert st.shed == [0, 0, 2]
    assert st.total_admitted == 6
    st.served[0] += 3  # rids 2, 3, 4
    st.served[1] += 1  # rid 1
    # fate identity: 6 admitted == 4 served + 2 shed (both bronze)
    st.check()


def test_queue_shed_tie_prefers_least_urgent_within_class():
    cfg = AdmissionConfig(queue_bound=2)
    aq = AdmissionQueue(cfg)
    aq.offer(_req(0, pri=1, arrival=0.0, deadline=30.0))
    aq.offer(_req(1, pri=1, arrival=0.0, deadline=99.0))  # least urgent
    aq.offer(_req(2, pri=0, arrival=1.0, deadline=10.0))
    kept = sorted(r.rid for r in aq.drain())
    assert kept == [0, 2]  # rid 1 (latest deadline in worst class) shed


# ---------------- degraded reads on every store surface ----------------


def _assert_lookup_resident_contract(store, ids, cold_ids, atol=0.0):
    full = np.asarray(store.lookup(ids))          # makes ids resident
    before = store.stats.as_dict()
    rows, n_def = store.lookup_resident(ids)
    assert rows.shape == full.shape and n_def == 0
    np.testing.assert_allclose(rows, full, atol=atol)
    cold, n_def_cold = store.lookup_resident(cold_ids)
    assert n_def_cold == len(cold_ids)
    assert not cold.any()                          # pure zero defaults
    assert store.stats.as_dict() == before         # zero stats mutation


def test_lookup_resident_single_store():
    store = TieredEmbeddingStore(_host(120, seed=1), capacity=32)
    _assert_lookup_resident_contract(
        store, np.arange(8, dtype=np.int64), np.arange(100, 110))


def test_lookup_resident_single_store_quantized():
    store = TieredEmbeddingStore(_host(120, seed=2), capacity=32,
                                 quantize=True)
    _assert_lookup_resident_contract(
        store, np.arange(8, dtype=np.int64), np.arange(100, 110))


def test_lookup_resident_multi_table():
    tables = [_host(60, seed=3), _host(40, d=8, seed=4)]
    store = MultiTableTieredStore(tables, capacity=24)
    ids = np.array([0, 1, 2, 60, 61, 62], np.int64)  # both tables
    _assert_lookup_resident_contract(store, ids, np.array([50, 95]),
                                     atol=1e-6)


def test_lookup_resident_sharded():
    host = _host(100, seed=5)
    plan = make_plan([100], n_shards=2, capacity=32, placement="row")
    store = ShardedTieredStore(host, plan)
    ids = np.array([0, 1, 2, 3, 7, 11], np.int64)
    _assert_lookup_resident_contract(store, ids, np.array([80, 90, 99]),
                                     atol=1e-6)


# ---------------- runtime integration ----------------


def _overload_rt(store, deadline_us=(50.0, 200.0, 800.0), queue_bound=8,
                 degrade=True, **cfg_kw):
    adm = AdmissionConfig(queue_bound=queue_bound,
                          class_deadline_us=deadline_us, degrade=degrade)
    return PipelinedRuntime(store, RuntimeConfig(
        max_batch=4, pipeline_depth=2, interarrival_us=10.0,
        compute_us=400.0, admission=adm, **cfg_kw))


def test_admission_run_identity_and_full_shape():
    """Saturating arrivals: the identity closes, degraded requests occur,
    and every batch's embedding matrix keeps the full batch shape."""
    store = TieredEmbeddingStore(_host(200, seed=6), capacity=32,
                                 fetch_us_fixed=200.0, fetch_us_per_row=20.0)
    rt = _overload_rt(store)
    rng = np.random.default_rng(0)
    stream = [(rng.integers(0, 200, size=3).astype(np.int64), int(p))
              for p in rng.integers(0, 3, size=60)]
    shapes = []

    def step(b, emb):
        shapes.append(np.asarray(emb).shape)
        return 0.0, []

    rt.run(iter(stream), step)
    st = rt.admission_stats
    st.check()
    assert st.total_admitted == 60
    assert st.total_shed > 0          # queue bound 8 under 40x overload
    assert st.total_degraded > 0      # tight gold deadline
    # every emb row count is 3 ids x the number of requests in its batch
    assert all(s[0] % 3 == 0 and s[1] == 8 for s in shapes)
    served_reqs = sum(s[0] // 3 for s in shapes)
    assert served_reqs == st.total_served + st.total_degraded


def test_admission_degrade_off_serves_everything_admitted():
    store = TieredEmbeddingStore(_host(200, seed=7), capacity=32)
    rt = _overload_rt(store, degrade=False)
    stream = [(np.array([i % 200]), i % 3) for i in range(40)]
    rt.run(iter(stream), lambda b, emb: (0.0, []))
    st = rt.admission_stats
    st.check()
    assert st.total_degraded == 0
    assert st.total_served + st.total_shed == st.total_admitted


def test_admission_backpressure_suppresses_prefetch():
    """Queue saturation must flip the engine's backpressure bit: some
    submitted prefetch ids take the suppressed fate, and the extended
    prefetch identity still closes."""
    store = TieredEmbeddingStore(_host(200, seed=8), capacity=32,
                                 fetch_us_fixed=200.0)
    rt = _overload_rt(store, queue_bound=16)
    rng = np.random.default_rng(1)
    stream = [(rng.integers(0, 200, size=2).astype(np.int64), 2)
              for _ in range(120)]

    def step(b, emb):
        return 0.0, [(EMPTY, EMPTY, np.arange(b, b + 4) % 200)]

    rt.run(iter(stream), step)
    tel = rt.telemetry
    assert tel.pf_suppressed > 0
    assert tel.pf_submitted == (tel.pf_suppressed + tel.pf_deduped
                                + tel.pf_cancelled_resident + tel.pf_issued)
    reg = MetricsRegistry()
    rt.publish(reg)
    assert reconcile(metrics=reg.as_dict(), strict=False) == []


@pytest.mark.parametrize("kw", [
    dict(pipeline_depth=1, prefetch=False),   # synchronous surface
    dict(pipeline_depth=2),                   # pipelined surface
    dict(shards=2),                           # sharded surface
])
def test_overload_replay_reconciles_on_surface(kw):
    spec = make_spec("sustained_overload", n_accesses=4000)
    res = replay_overload(spec, load_x=4.0, **kw)  # check=True reconciles
    assert res["admitted"] == (res["served"] + res["shed"]
                               + res["degraded"])
    flat = {k: v for k, v in res["metrics"]["counters"].items()}
    assert flat["adm.admitted"] > 0
    assert check_admission(flat) == []


def test_overload_replay_deterministic():
    spec = make_spec("sustained_overload", n_accesses=4000)
    a = replay_overload(spec, load_x=2.0)
    b = replay_overload(spec, load_x=2.0)
    for k in ("admitted", "served", "shed", "degraded", "goodput_rps",
              "p99_ms", "modeled_s", "pf_suppressed"):
        assert a[k] == b[k], k


@pytest.mark.slow
def test_overload_sweep_degrades_gracefully():
    spec = make_spec("sustained_overload", n_accesses=12_000)
    sweep = overload_sweep(loads=(1.0, 2.0, 4.0), spec=spec)
    # shed monotonically non-decreasing in offered load
    sheds = [sweep[x]["shed"] for x in (1.0, 2.0, 4.0)]
    assert sheds == sorted(sheds)
    assert degradation_ratio(sweep, hi=4.0, lo=1.0) >= 0.7
