"""The two RecMG models: learnability, shapes, and the end-to-end policy
(Algorithms 1&2 driven by model outputs) beating plain LRU."""
import jax
import numpy as np
import pytest

from repro.core.belady import belady_labels
from repro.core.caching_model import (CachingModelConfig,
                                      evaluate_caching_model,
                                      init_caching_model, predict_bits,
                                      train_caching_model)
from repro.core.cache_sim import FALRU, simulate
from repro.core.features import make_windows, split_train_eval
from repro.core.lstm import n_params
from repro.core.prefetch_model import (
    PrefetchModelConfig, init_prefetch_model, make_prefetch_data,
    predict_sequences, train_prefetch_model)
from repro.core.recmg import precompute_outputs, run_recmg


@pytest.fixture(scope="module")
def trained(tiny_trace):
    tr = tiny_trace
    keys = tr.global_id
    cap = int(0.2 * tr.unique_count())
    labels, hits, miss = belady_labels(keys, cap)
    mcfg = CachingModelConfig(n_tables=tr.n_tables)
    data = make_windows(tr, labels=labels)
    trd, evd = split_train_eval(data)
    cparams, closs = train_caching_model(trd, mcfg, epochs=2, batch_size=256)
    return tr, cap, labels, mcfg, cparams, trd, evd, closs


def test_param_budgets():
    c = init_caching_model(jax.random.PRNGKey(0), CachingModelConfig())
    p = init_prefetch_model(jax.random.PRNGKey(0), PrefetchModelConfig())
    # Paper: ~37K caching, ~74K prefetch (1 and 2 LSTM stacks).
    assert 25_000 < n_params(c) < 50_000
    assert 50_000 < n_params(p) < 100_000


def test_caching_model_learns(trained):
    tr, cap, labels, mcfg, cparams, trd, evd, closs = trained
    assert closs[-1] < closs[0]
    train_acc = evaluate_caching_model(cparams, trd.batch(np.arange(0, len(trd), 5)))
    assert train_acc > 0.55  # clearly above chance on its own data


def test_predict_bits_shape(trained):
    tr, cap, labels, mcfg, cparams, trd, evd, _ = trained
    bits = predict_bits(cparams, evd)
    assert bits.shape == (len(evd), mcfg.in_len)
    assert bits.dtype == bool


def test_prefetch_model_trains(tiny_trace):
    tr = tiny_trace
    pcfg = PrefetchModelConfig(n_tables=tr.n_tables)
    pdata = make_prefetch_data(tr, stride=15)
    pparams, losses = train_prefetch_model(pdata, pcfg, epochs=2,
                                           batch_size=256)
    assert losses[-1] < losses[0]
    po = predict_sequences(pparams, pcfg, pdata)
    assert po.shape == (len(pdata), pcfg.out_len, pcfg.rep_dim)
    assert np.all(np.isfinite(po))


def test_chamfer_beats_l2_training(tiny_trace):
    """Paper Fig. 11: L2 + window==|PO| plateaus; Chamfer keeps improving."""
    tr = tiny_trace
    pdata = make_prefetch_data(tr, stride=15)
    losses = {}
    for loss in ("chamfer", "l2"):
        pcfg = PrefetchModelConfig(n_tables=tr.n_tables, loss=loss)
        _, ls = train_prefetch_model(pdata, pcfg, epochs=2, batch_size=256)
        losses[loss] = ls
    rel_drop = lambda ls: (ls[0] - np.mean(ls[-10:])) / abs(ls[0])
    assert rel_drop(losses["chamfer"]) > 0.2


def test_recmg_oracle_beats_lru(tiny_trace):
    """With oracle (Belady) keep-bits, the RecMG buffer must beat LRU."""
    tr = tiny_trace
    keys = tr.global_id
    cap = int(0.1 * tr.unique_count())
    labels, _, _ = belady_labels(keys, cap)
    outputs = precompute_outputs(tr)  # no models: bits come from oracle
    res = run_recmg(tr, cap, outputs, oracle_bits=labels, use_prefetch=False)
    lru = simulate(keys, FALRU(cap))
    assert res.hits > lru.hits
    assert res.accesses == lru.accesses


def test_recmg_learned_pipeline(trained):
    tr, cap, labels, mcfg, cparams, trd, evd, _ = trained
    outputs = precompute_outputs(tr, caching=(cparams, mcfg))
    res = run_recmg(tr, cap, outputs, use_prefetch=False)
    assert res.accesses == len(tr)
    assert res.hits + res.on_demand == res.accesses
