"""Pipelined serving runtime: determinism, sync/async counter equivalence,
micro-batcher triggers, prefetch-engine dedup/cancel/coalesce, telemetry."""
import numpy as np
import pytest

from repro.core.serving import MultiTableTieredStore
from repro.core.tiered import TieredEmbeddingStore
from repro.runtime import (MicroBatcher, PipelinedRuntime, PrefetchEngine,
                           Request, RuntimeConfig, RuntimeTelemetry,
                           VirtualClock, heuristic_prediction_stream)

EMPTY = np.empty(0, np.int64)


def _host(n=400, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def _trace(n_rows, n_acc, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.2, size=n_acc), n_rows) - 1
    return rng.permutation(n_rows)[ranks].astype(np.int64)


def _staged_fn(ids, batch, n_rows):
    """Deterministic model-output stream: rank the just-served chunk with
    pseudo-bits every other batch, oracle-prefetch the next batch's first
    keys every batch (gives real prefetch hits without training)."""
    rng = np.random.default_rng(7)
    bits_tbl = rng.random(4096) < 0.5

    def staged(b):
        items = []
        lo, hi = b * batch, (b + 1) * batch
        if b % 2 == 0:
            trunk = ids[lo: lo + 12]
            items.append((trunk, bits_tbl[:len(trunk)].astype(np.int64),
                          EMPTY))
        nxt = np.unique(ids[hi: hi + 8]) % n_rows
        items.append((EMPTY, EMPTY, nxt))
        return items

    return staged


def _run_sync(store, ids, batch, staged):
    n_b = len(ids) // batch
    for b in range(n_b):
        store.lookup(ids[b * batch: (b + 1) * batch])
        for item in staged(b):
            store.stage_model_outputs(*item)
        store.flush_staged()


def _run_async(store, ids, batch, staged, depth=2, compute_us=500.0,
               max_batch=1):
    rt = PipelinedRuntime(store, RuntimeConfig(
        max_batch=max_batch, pipeline_depth=depth, compute_us=compute_us))
    n_b = len(ids) // batch
    per_req = batch // max_batch
    stream = (ids[i * per_req: (i + 1) * per_req]
              for i in range(n_b * max_batch))
    rt.run(stream, lambda b, emb: (0.0, staged(b)))
    return rt


COUNTERS = ("batches", "lookups", "hits", "prefetch_hits", "on_demand_rows",
            "evictions")


@pytest.mark.parametrize("policy", ["lru", "recmg"])
@pytest.mark.parametrize("depth", [1, 2, 4])
def test_async_counters_match_sync(policy, depth):
    """The determinism contract: with the inline scheduler the pipelined
    runtime replays the exact synchronous operation sequence — identical
    hit/miss/eviction counters — while strictly less fetch time stays on
    the modeled critical path (depth >= 2)."""
    host = _host(400)
    ids = _trace(400, 6000)
    staged = _staged_fn(ids, 48, 400)
    sync = TieredEmbeddingStore(host, 64, policy=policy)
    _run_sync(sync, ids, 48, staged)
    anc = TieredEmbeddingStore(host, 64, policy=policy)
    rt = _run_async(anc, ids, 48, staged, depth=depth)
    for c in COUNTERS:
        assert getattr(anc.stats, c) == getattr(sync.stats, c), c
    assert anc.stats.prefetch_hits > 0  # the oracle stream really fired
    tel = rt.telemetry
    assert tel.demand_fetch_ms == pytest.approx(
        sync.stats.modeled_fetch_s * 1e3, rel=1e-9)
    if depth == 1:
        # Degenerate pipeline: everything stalls, like the sync runtime.
        assert tel.stall_ms == pytest.approx(tel.demand_fetch_ms)
    else:
        assert tel.stall_ms < tel.demand_fetch_ms  # strictly less

def test_async_counters_match_sync_multi_table():
    tables = [_host(160, seed=i) for i in range(3)]
    n = sum(t.shape[0] for t in tables)
    ids = _trace(n, 4000, seed=3)
    staged = _staged_fn(ids, 40, n)
    sync = MultiTableTieredStore(tables, capacity=72, policy="recmg")
    _run_sync(sync, ids, 40, staged)
    anc = MultiTableTieredStore(tables, capacity=72, policy="recmg")
    rt = _run_async(anc, ids, 40, staged)
    s_sync, s_anc = sync.stats, anc.stats
    for c in COUNTERS:
        assert getattr(s_anc, c) == getattr(s_sync, c), c
    assert rt.telemetry.stall_ms < rt.telemetry.demand_fetch_ms


def test_async_replay_is_deterministic():
    """Same trace + config => byte-for-byte identical telemetry."""
    host = _host(300, seed=2)
    ids = _trace(300, 3000, seed=2)
    staged = _staged_fn(ids, 30, 300)
    runs = []
    for _ in range(2):
        st = TieredEmbeddingStore(host, 48, policy="recmg")
        rt = _run_async(st, ids, 30, staged, depth=3)
        d = rt.results()
        d.update(st.stats.as_dict())
        d.pop("fetch_s"), d.pop("gather_s"), d.pop("model_s")  # wall clock
        runs.append(d)
    assert runs[0] == runs[1]


def test_requests_microbatched_like_monolithic():
    """Splitting each batch into per-query requests through the admission
    queue must form the very same batches (size trigger)."""
    host = _host(200, seed=5)
    ids = _trace(200, 2400, seed=5)
    staged = _staged_fn(ids, 24, 200)
    mono = TieredEmbeddingStore(host, 40)
    _run_async(mono, ids, 24, staged)
    split = TieredEmbeddingStore(host, 40)
    rt = _run_async(split, ids, 24, staged, max_batch=8)  # 8 requests/batch
    for c in COUNTERS:
        assert getattr(split.stats, c) == getattr(mono.stats, c), c
    assert rt.telemetry.requests == 8 * rt.telemetry.batches
    assert len(rt.telemetry.latencies_us) == rt.telemetry.requests


# ---------------- micro-batcher ----------------


def test_microbatcher_size_trigger():
    mb = MicroBatcher(max_batch=4)
    for i in range(4):
        assert not mb.ready(now_us=float(i))
        mb.push(Request(i, np.array([i]), arrival_us=float(i)))
    assert mb.ready(now_us=3.0)
    reqs, close = mb.pop()
    assert [r.rid for r in reqs] == [0, 1, 2, 3]
    assert close == 3.0  # a full batch closes when its last member arrived
    assert len(mb) == 0


def test_microbatcher_deadline_trigger():
    mb = MicroBatcher(max_batch=100, deadline_us=50.0)
    mb.push(Request(0, np.array([0]), arrival_us=10.0))
    mb.push(Request(1, np.array([1]), arrival_us=20.0))
    assert not mb.ready(now_us=59.0)
    assert mb.ready(now_us=60.0)  # oldest waited its deadline out
    reqs, close = mb.pop()
    assert len(reqs) == 2 and close == 60.0


def test_pipeline_deadline_closes_partial_batches():
    """Open-loop arrivals slower than the batch size: the deadline, not
    the size trigger, must close batches."""
    store = TieredEmbeddingStore(_host(100, seed=6), 32)
    rt = PipelinedRuntime(store, RuntimeConfig(
        max_batch=64, deadline_us=100.0, interarrival_us=80.0,
        compute_us=10.0))
    seen = []
    rt.run((np.array([i % 100]) for i in range(10)),
           lambda b, emb: (seen.append(np.asarray(emb).shape[0]), (0.0, []))[1])
    assert sum(seen) == 10
    assert max(seen) <= 2  # deadline 100us only spans ~2 arrivals at 80us
    assert rt.telemetry.batches >= 5


# ---------------- prefetch engine ----------------


def test_engine_populates_and_counts():
    store = TieredEmbeddingStore(_host(), 64)
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel)
    eng.submit(EMPTY, EMPTY, np.array([1, 2, 3]))
    assert store.n_resident == 0  # queued, not yet applied
    eng.drain()
    assert store.n_resident == 3
    assert tel.pf_submitted == 3 and tel.pf_issued == 3
    assert np.all(store.resident_mask(np.array([1, 2, 3])))
    out = np.asarray(store.lookup(np.array([1, 2, 3])))
    assert store.stats.prefetch_hits == 3
    np.testing.assert_allclose(out, store.host[[1, 2, 3]], rtol=1e-6)


def test_engine_dedups_inflight_and_cancels_resident():
    store = TieredEmbeddingStore(_host(), 64)
    store.lookup(np.array([5]))  # 5 resident via demand fetch
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel)
    eng.submit(EMPTY, EMPTY, np.array([7, 8]))
    eng.submit(EMPTY, EMPTY, np.array([8, 9, 5]))  # 8 in flight, 5 resident
    assert tel.pf_deduped == 1
    eng.drain()
    assert tel.pf_cancelled_resident == 1  # 5 cancelled before issue
    assert tel.pf_issued == 3  # 7, 8, 9
    assert store.n_resident == 4


def test_engine_coalesces_prefetch_only_items():
    store = TieredEmbeddingStore(_host(), 128)
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel)
    for lo in (0, 10, 20):
        eng.submit(EMPTY, EMPTY, np.arange(lo, lo + 5))
    eng.drain()
    assert tel.pf_populate_calls == 1  # one batched populate call
    assert tel.pf_issued == 15
    # Coalesced apply == sequential apply (ample capacity).
    ref = TieredEmbeddingStore(_host(), 128)
    for lo in (0, 10, 20):
        ref.apply_model_outputs(EMPTY, EMPTY, np.arange(lo, lo + 5))
    assert store.slot_of == ref.slot_of


def test_engine_timeliness_classification():
    """A prefetch completes at issue+cost on the modeled channel: demand
    before that is late, after is timely."""
    store = TieredEmbeddingStore(_host(), 64, fetch_us_per_row=10.0,
                                 fetch_us_fixed=30.0)
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel, fetch_us_per_row=10.0,
                         fetch_us_fixed=30.0)
    eng.submit(EMPTY, EMPTY, np.array([1, 2]), now_us=0.0)  # eta = 50us
    eng.drain()
    eng.observe_demand(np.array([1]), now_us=10.0)   # in flight: late
    eng.observe_demand(np.array([2]), now_us=60.0)   # completed: timely
    assert tel.pf_late == 1 and tel.pf_timely == 1
    assert tel.pf_late_ms == pytest.approx(0.04)     # 40us short
    eng.close()
    assert tel.pf_unused == 0


def test_engine_thread_scheduler_consistency():
    """Thread scheduler: worker applies under the shared lock; drain is a
    flush barrier and close() is idempotent."""
    store = TieredEmbeddingStore(_host(), 128)
    eng = PrefetchEngine(store, scheduler="thread", max_queue=8)
    for lo in range(0, 60, 5):
        eng.submit(EMPTY, EMPTY, np.arange(lo, lo + 5))
    eng.drain()
    assert store.n_resident == 60
    store.check_invariants()
    eng.close()
    eng.close()
    with pytest.raises(RuntimeError):
        eng.submit(EMPTY, EMPTY, np.array([1]))


def test_engine_rank_cancelled_evicted_counter():
    store = TieredEmbeddingStore(_host(), 16, policy="recmg")
    tel = RuntimeTelemetry()
    eng = PrefetchEngine(store, telemetry=tel)
    store.lookup(np.arange(10))
    # Rank a trunk that includes never-resident (evicted-before-issue) ids.
    eng.submit(np.array([0, 1, 200, 201]), np.array([1, 1, 1, 1]), EMPTY)
    eng.drain()
    assert tel.rank_cancelled_evicted == 2


def test_heuristic_prediction_stream_feeds_engine():
    """A rule-based prefetcher (BOP on a stride trace) packaged as a
    prediction stream produces real prefetch hits through the engine."""
    from repro.core.prefetchers import make_prefetcher

    n = 2000
    keys = np.arange(n, dtype=np.int64) % 1000
    outputs = heuristic_prediction_stream(keys, make_prefetcher("bop"),
                                          chunk=15, max_per_chunk=4)
    assert outputs.prefetch_ids is not None
    assert len(outputs.chunk_starts) == len(outputs.prefetch_ids)
    host = _host(1000, seed=9)
    store = TieredEmbeddingStore(host, 128)
    eng = PrefetchEngine(store)
    hits_before = store.stats.prefetch_hits
    lo = 0
    for ci, s in enumerate(outputs.chunk_starts.tolist()):
        store.lookup(keys[lo:s])
        lo = s
        eng.submit(EMPTY, EMPTY, outputs.prefetch_ids[ci])
        eng.drain()
    assert store.stats.prefetch_hits > hits_before


# ---------------- clock + telemetry ----------------


def test_virtual_clock_monotone():
    c = VirtualClock()
    assert c.now() == 0.0
    c.advance(5.0)
    c.advance_to(3.0)  # no-op: monotone
    assert c.now() == 5.0
    with pytest.raises(ValueError):
        c.advance(-1.0)


def test_telemetry_merge_additive():
    a = RuntimeTelemetry(batches=2, requests=10, pf_issued=5, stall_ms=1.5,
                         demand_fetch_ms=4.0, latencies_us=[100.0])
    b = RuntimeTelemetry(batches=3, requests=6, pf_issued=2, stall_ms=0.5,
                         demand_fetch_ms=1.0, latencies_us=[300.0])
    a.merge(b)
    assert a.batches == 5 and a.requests == 16 and a.pf_issued == 7
    assert a.stall_ms == pytest.approx(2.0)
    assert a.hidden_ms == pytest.approx(3.0)
    assert a.stall_reduction == pytest.approx(0.6)
    assert a.latencies_us == [100.0, 300.0]
    pcts = a.request_percentiles()
    assert pcts["req_p50_ms"] == pytest.approx(0.2)


def test_engine_thread_worker_failure_surfaces_not_hangs():
    """A poisoned work item must not kill the flush barrier: the worker
    records the failure, task_done()s everything, and drain() raises
    instead of deadlocking on q.join()."""
    store = TieredEmbeddingStore(_host(100, seed=11), 16)

    def poisoned_apply(trunk, bits, pf):
        raise IndexError("poisoned prediction stream")

    store.apply_model_outputs = poisoned_apply
    eng = PrefetchEngine(store, scheduler="thread", max_queue=8)
    eng.submit(EMPTY, EMPTY, np.array([1, 2]))
    with pytest.raises(RuntimeError, match="prefetch worker failed"):
        eng.drain()
    eng.close()  # still shuts down cleanly after the failure


# ---------------- micro-batcher / config edge cases (PR 8) ----------------


def test_microbatcher_flush_empty_returns_empty_and_now():
    """Flushing an empty batcher is a legitimate end-of-stream state
    (overload runs drain to empty), not an error."""
    mb = MicroBatcher(max_batch=4, deadline_us=50.0)
    reqs, close = mb.flush(now_us=123.5)
    assert reqs == [] and close == 123.5
    reqs, close = mb.flush()  # default now
    assert reqs == [] and close == 0.0


def test_microbatcher_pop_empty_raises():
    mb = MicroBatcher(max_batch=4)
    with pytest.raises(ValueError, match="empty micro-batcher"):
        mb.pop()


def test_microbatcher_exactly_full_close_is_last_arrival():
    """A batch that is exactly max_batch closes when its last member
    arrived — the deadline term must not leak into a full batch."""
    mb = MicroBatcher(max_batch=3, deadline_us=1000.0)
    for i, t in enumerate((5.0, 7.0, 9.0)):
        mb.push(Request(i, np.array([i]), arrival_us=t))
    reqs, close = mb.pop()
    assert len(reqs) == 3 and close == 9.0
    assert len(mb) == 0


def test_microbatcher_deadline_tie_between_oldest():
    """Two requests with identical arrival times: the deadline trigger
    fires once for both and FIFO order is preserved."""
    mb = MicroBatcher(max_batch=10, deadline_us=40.0)
    mb.push(Request(0, np.array([0]), arrival_us=10.0))
    mb.push(Request(1, np.array([1]), arrival_us=10.0))
    assert not mb.ready(now_us=49.0)
    assert mb.ready(now_us=50.0)
    reqs, close = mb.pop()
    assert [r.rid for r in reqs] == [0, 1]
    assert close == 50.0  # oldest arrival + deadline, finite


def test_microbatcher_inf_deadline_partial_close_clamps_finite():
    """deadline_us=inf + a forced partial pop must clamp the close time
    to the last arrival: an infinite close time would poison every
    latency percentile downstream."""
    mb = MicroBatcher(max_batch=8)  # default deadline inf
    mb.push(Request(0, np.array([0]), arrival_us=3.0))
    mb.push(Request(1, np.array([1]), arrival_us=11.0))
    reqs, close = mb.pop()
    assert len(reqs) == 2
    assert np.isfinite(close) and close == 11.0


def test_microbatcher_rejects_bad_deadline():
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=4, deadline_us=float("nan"))
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=4, deadline_us=-1.0)
    with pytest.raises(ValueError):
        MicroBatcher(max_batch=0)


@pytest.mark.parametrize("kw", [
    dict(max_batch=0),
    dict(pipeline_depth=0),
    dict(max_queue=0),
    dict(deadline_us=float("nan")),
    dict(deadline_us=-5.0),
    dict(interarrival_us=float("nan")),
    dict(interarrival_us=float("inf")),
    dict(interarrival_us=-1.0),
])
def test_runtime_config_rejects_invalid(kw):
    with pytest.raises(ValueError):
        RuntimeConfig(**kw)


def test_runtime_config_accepts_inf_deadline():
    cfg = RuntimeConfig(deadline_us=float("inf"))
    assert cfg.deadline_us == float("inf")
