"""Unified observability layer: typed metrics registry (merge additivity,
snapshot round-trip, NaN-safe percentiles), deterministic span tracing
(Chrome trace schema, monotone per-track spans, flight-recorder ring),
and the counter-reconciliation checker — identities on hand-built books,
violation detection, and the trace<->metrics cross-check on real runs.
"""
import json

import numpy as np
import pytest

from repro.obs import (Histogram, MetricsRegistry, Reservoir, check_all,
                       check_trace_vs_metrics, reconcile,
                       validate_chrome_trace)
from repro.obs.metrics import publish_all
from repro.obs.reconcile import (check_pipeline, check_prefetch,
                                 check_sharded, check_store)
from repro.obs.tracing import (NullTracer, SpanTracer, get_tracer,
                               install_tracer)


# ---------------- metrics registry ----------------

def test_counter_gauge_basics():
    reg = MetricsRegistry()
    reg.counter("store.fast.hits").inc(3)
    reg.counter("store.fast.hits").inc(2)
    reg.gauge("store.fast.hit_rate").set(0.6)
    assert reg.value("store.fast.hits") == 5
    assert reg.value("store.fast.hit_rate") == 0.6
    assert "store.fast.hits" in reg
    with pytest.raises(ValueError):
        reg.counter("store.fast.hits").inc(-1)  # counters only go up
    with pytest.raises(TypeError):
        reg.gauge("store.fast.hits")  # name already bound to a Counter
    with pytest.raises(ValueError):
        reg.counter("Bad Name!")


def test_registry_merge_is_additive():
    """Merging the registries of two half-runs equals the whole run."""
    whole, a, b = MetricsRegistry(), MetricsRegistry(), MetricsRegistry()
    rng = np.random.default_rng(0)
    xs = rng.normal(size=300)
    for i, x in enumerate(xs):
        dst = a if i < 150 else b
        dst.counter("rt.requests").inc(1)
        dst.histogram("rt.req_latency_us").append(float(x))
        whole.counter("rt.requests").inc(1)
        whole.histogram("rt.req_latency_us").append(float(x))
    a.gauge("rt.pf.queued").set(7)
    b.gauge("rt.pf.queued").set(3)
    a.merge(b)
    assert a.value("rt.requests") == whole.value("rt.requests") == 300
    assert a.value("rt.pf.queued") == 3  # gauge: last writer wins
    ha, hw = a.histogram("rt.req_latency_us"), whole.histogram(
        "rt.req_latency_us")
    assert ha.count == hw.count == 300
    assert ha.total == pytest.approx(hw.total)
    assert ha.mn == hw.mn and ha.mx == hw.mx


def test_histogram_empty_percentiles_are_nan_safe():
    h = Histogram("rt.req_latency_us")
    d = h.as_dict()
    assert d["count"] == 0
    for k in ("p50", "p95", "p99", "min", "max"):
        assert not np.isnan(d[k])  # empty sketch reports 0, never NaN
    assert h.percentile(50) == 0.0
    reg = MetricsRegistry()
    reg.histogram("rt.req_latency_us")
    flat = reg.as_dict()
    assert flat["rt.req_latency_us.p50"] == 0.0


def test_reservoir_bounded_and_list_compatible():
    r = Reservoir(cap=64, seed=0)
    r.extend(range(10_000))
    assert len(r) == 10_000  # streaming count survives the bound
    assert len(r.samples()) == 64  # retained memory stays fixed
    assert r.mn == 0 and r.mx == 9999
    assert r.total == sum(range(10_000))
    # percentile of the uniform stream stays near truth with 64 samples
    assert abs(r.percentile(50) - 5000) < 2500
    small = Reservoir(cap=64, items=[1.0, 2.0, 3.0])
    assert small == [1.0, 2.0, 3.0]  # under cap: exact, list-comparable
    assert list(small) == [1.0, 2.0, 3.0]


def test_snapshot_round_trip_exact():
    reg = MetricsRegistry()
    reg.counter("store.lookups").inc(1000)
    reg.gauge("shard.0.imbalance").set(1.25)
    reg.histogram("rt.req_latency_us", cap=32).extend(range(500))
    snap = json.loads(json.dumps(reg.snapshot()))  # through real JSON
    reg2 = MetricsRegistry.from_snapshot(snap)
    assert reg2.as_dict() == reg.as_dict()
    h = reg2.histogram("rt.req_latency_us")
    assert h.count == 500 and h.total == sum(range(500))
    assert h.mn == 0 and h.mx == 499  # exact past the retained samples


def test_publish_all_skips_none():
    class P:
        def publish(self, reg):
            reg.counter("x").inc(1)

    reg = publish_all(MetricsRegistry(), P(), None, P())
    assert reg.value("x") == 2


# ---------------- reconciliation identities ----------------

def _good_books():
    return {
        "store.batches": 10, "store.lookups": 100, "store.fast.hits": 60,
        "store.fast.misses": 40, "store.fast.prefetch_hits": 15,
        "store.fast.on_demand_rows": 30, "store.fast.evictions": 20,
        "rt.pf.submitted": 50, "rt.pf.deduped": 5,
        "rt.pf.cancelled_resident": 10, "rt.pf.issued": 30,
        "rt.pf.queued": 5, "rt.pf.channel_scheduled": 30,
        "rt.pf.timely": 12, "rt.pf.late": 8, "rt.pf.unused": 7,
        "rt.pf.eta_overwritten": 2, "rt.pf.eta_pending": 1,
        "rt.demand_fetch_ms": 40.0, "rt.stall_ms": 25.0,
        "rt.hidden_ms": 15.0,
    }


def test_identities_hold_on_consistent_books():
    assert check_all(_good_books()) == []


@pytest.mark.parametrize("key,delta,expect", [
    ("store.fast.hits", +1, "lookups"),          # hits+misses != lookups
    ("store.fast.prefetch_hits", +50, "prefetch_hits"),
    ("rt.pf.issued", -1, "submitted"),           # a prefetch id lost a fate
    ("rt.pf.timely", +2, "channel_scheduled"),   # channel over-accounted
    ("rt.stall_ms", +20.0, "stall_ms"),          # stall exceeds demand
])
def test_identity_violations_are_caught(key, delta, expect):
    books = _good_books()
    books[key] += delta
    problems = check_all(books)
    assert problems, f"perturbing {key} went unnoticed"
    assert any(expect in p for p in problems)
    with pytest.raises(AssertionError):
        reconcile(metrics=books, strict=True)


def test_sharded_aggregate_must_equal_sum():
    books = {"store.lookups": 30, "store.fast.hits": 18,
             "store.fast.misses": 12, "store.fast.prefetch_hits": 0,
             "store.fast.on_demand_rows": 6, "store.fast.evictions": 4}
    for s, (lk, h) in enumerate([(10, 6), (12, 7), (8, 5)]):
        books[f"shard.{s}.store.lookups"] = lk
        books[f"shard.{s}.store.fast.hits"] = h
        books[f"shard.{s}.store.fast.misses"] = lk - h
        books[f"shard.{s}.store.fast.prefetch_hits"] = 0
        books[f"shard.{s}.store.fast.on_demand_rows"] = 2
        books[f"shard.{s}.store.fast.evictions"] = s + 1
    # consistent: evictions 1+2+3 == 6? no — aggregate says 4: violation
    problems = check_sharded(books)
    assert any("fast.evictions" in p for p in problems)
    books["store.fast.evictions"] = 6
    assert check_sharded(books) == []


def test_vacuous_namespaces_pass():
    """A surface that never ran simply contributes no identities."""
    assert check_store({}) == []
    assert check_prefetch({"store.lookups": 5}) == []
    assert check_pipeline({}) == []


# ---------------- span tracer ----------------

def test_null_tracer_is_default_and_inert():
    tr = get_tracer()
    assert isinstance(tr, NullTracer) and not tr.enabled
    tr.add_span("store", "lookup", 0.0, 1.0)  # must not raise
    tr.set_batch(3)


def test_tracer_export_schema_and_ring():
    tr = SpanTracer(ring_batches=2)
    for b in range(5):
        tr.set_batch(b)
        tr.add_span("store", "lookup", ts=b * 100.0, dur=50.0,
                    track="store", args={"ids": 10})
        tr.add_instant("pf", "demand", ts=b * 100.0 + 10, track="pf")
    obj = tr.chrome_trace()
    assert validate_chrome_trace(obj) == []
    evs = obj["traceEvents"]
    assert any(e["ph"] == "M" for e in evs)  # track-name metadata present
    spans = [e for e in evs if e.get("ph") == "X"]
    assert len(spans) == 5
    assert all(e["args"]["batch"] == i for i, e in enumerate(spans))
    # flight recorder keeps only the last ring_batches batches
    ring = tr.flight_record()["traceEvents"]
    batches = {e["args"]["batch"] for e in ring if e.get("ph") != "M"}
    assert batches == {3, 4}


def test_validator_flags_regressing_spans():
    tr = SpanTracer()
    tr.add_span("store", "lookup", ts=100.0, dur=50.0, track="store")
    tr.add_span("store", "lookup", ts=10.0, dur=20.0, track="store")
    problems = validate_chrome_trace(tr.chrome_trace())
    assert any("regresses" in p for p in problems)
    # ... but parallel tracks are independent timelines
    tr2 = SpanTracer()
    tr2.add_span("pf", "channel", ts=100.0, dur=50.0, track="pf-shard-0")
    tr2.add_span("pf", "channel", ts=10.0, dur=20.0, track="pf-shard-1")
    assert validate_chrome_trace(tr2.chrome_trace()) == []


def test_install_tracer_round_trip():
    tr = SpanTracer()
    install_tracer(tr)
    try:
        assert get_tracer() is tr and get_tracer().enabled
    finally:
        install_tracer(None)
    assert not get_tracer().enabled


# ---------------- producers end to end ----------------

def _zipf_ids(n_rows, n_acc, seed=0):
    rng = np.random.default_rng(seed)
    ranks = np.minimum(rng.zipf(1.2, size=n_acc), n_rows) - 1
    return rng.permutation(n_rows)[ranks].astype(np.int64)


def test_store_trace_reconciles_with_metrics():
    """Per-batch lookup spans summed over the trace equal the TierStats
    counters exactly — the tentpole's acceptance identity."""
    from repro.core.tiered import TieredEmbeddingStore

    host = np.random.default_rng(0).normal(size=(400, 8)).astype(np.float32)
    ids = _zipf_ids(400, 1600)
    tr = SpanTracer()
    install_tracer(tr)
    try:
        store = TieredEmbeddingStore(host, 64, policy="lru")
        for b in range(16):
            tr.set_batch(b)
            store.lookup(ids[b * 100: (b + 1) * 100])
    finally:
        install_tracer(None)
    reg = store.publish_metrics(MetricsRegistry())
    flat = reg.as_dict()
    assert flat["store.fast.hits"] + flat["store.fast.misses"] \
        == flat["store.lookups"] == 1600
    trace = tr.chrome_trace()
    assert validate_chrome_trace(trace) == []
    assert check_trace_vs_metrics(trace, flat) == []
    assert reconcile(metrics=reg.snapshot(), trace=trace) == []


def test_stats_publish_matches_merge_additivity():
    """Publishing two split stats into one registry == publishing their
    merge: the registry is additive exactly where TierStats.merge is."""
    from repro.core.tiered import TieredEmbeddingStore

    host = np.random.default_rng(0).normal(size=(300, 4)).astype(np.float32)
    ids = _zipf_ids(300, 1200, seed=1)
    a = TieredEmbeddingStore(host, 48, policy="lru")
    b = TieredEmbeddingStore(host, 48, policy="lru")
    a.lookup(ids[:600])
    b.lookup(ids[600:])
    split = MetricsRegistry()
    a.stats.publish(split)
    b.stats.publish(split)
    merged_stats = a.stats.merge(b.stats)
    whole = merged_stats.publish(MetricsRegistry())
    for k in ("store.lookups", "store.fast.hits", "store.fast.misses",
              "store.fast.evictions", "store.fast.on_demand_rows"):
        assert split.value(k) == whole.value(k)


def test_pipelined_runtime_reconciles():
    """The full pipelined stack — store + prefetch engine + pipeline —
    publishes one registry whose identities all close, and whose spans
    cross-check against it."""
    from repro.core.tiered import TieredEmbeddingStore
    from repro.runtime import PipelinedRuntime, RuntimeConfig, VirtualClock

    host = np.random.default_rng(0).normal(size=(400, 8)).astype(np.float32)
    ids = _zipf_ids(400, 1200, seed=2)
    clock = VirtualClock()
    tr = SpanTracer(clock=clock)
    install_tracer(tr)
    try:
        store = TieredEmbeddingStore(host, 64, policy="lru")
        rt = PipelinedRuntime(store, RuntimeConfig(max_batch=64),
                              clock=clock)
        rt.run((ids[i * 100: (i + 1) * 100] for i in range(12)),
               lambda b, emb: (0.0, []))
    finally:
        install_tracer(None)
    reg = MetricsRegistry()
    store.publish_metrics(reg)
    rt.publish(reg)
    flat = reg.as_dict()
    assert flat["rt.requests"] == 12
    assert flat["rt.req_latency_us.count"] == 12
    trace = tr.chrome_trace()
    assert validate_chrome_trace(trace) == []
    assert reconcile(metrics=reg.snapshot(), trace=trace) == []


def test_sharded_replay_reconciles():
    """Sharded serving: aggregate == sum of shards, per-shard namespaces
    close, trace cross-check skips the span-count identity."""
    from repro.workloads import parse_workload
    from repro.workloads.harness import replay_scenario

    tr = SpanTracer(ring_batches=4)
    install_tracer(tr)
    try:
        res = replay_scenario(
            parse_workload("zipf_hot:n_accesses=4096,n_tables=4,"
                           "rows_per_table=256"),
            policy="recmg", shards=3, batch=256)
    finally:
        install_tracer(None)
    snap = res["metrics"]
    flat = MetricsRegistry.from_snapshot(snap).as_dict()
    assert flat["sharded.n_shards"] == 3
    shard_lookups = sum(v for k, v in flat.items()
                        if k.endswith(".store.lookups")
                        and k.startswith("shard."))
    assert shard_lookups == flat["store.lookups"]
    trace = tr.chrome_trace()
    assert validate_chrome_trace(trace) == []
    assert reconcile(metrics=snap, trace=trace) == []


def test_telemetry_latency_reservoir_is_bounded():
    from repro.runtime.telemetry import (LATENCY_RESERVOIR_CAP,
                                         RuntimeTelemetry)

    tel = RuntimeTelemetry()
    for i in range(LATENCY_RESERVOIR_CAP + 5000):
        tel.latencies_us.append(float(i))
    assert len(tel.latencies_us) == LATENCY_RESERVOIR_CAP + 5000
    assert len(tel.latencies_us.samples()) == LATENCY_RESERVOIR_CAP
    other = RuntimeTelemetry(latencies_us=[1.0, 2.0])
    merged = tel.merge(other)
    assert len(merged.latencies_us) == LATENCY_RESERVOIR_CAP + 5002
