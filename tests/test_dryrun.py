"""Multi-pod dry-run plumbing: a fast cell lowers+compiles on the production
meshes in a subprocess (512 placeholder devices must not leak into this
test process), and the roofline reader consumes its artifact."""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

ROOT = Path(__file__).resolve().parents[1]


def test_this_process_has_one_device():
    assert len(jax.devices()) >= 1  # and NOT 512: the flag must not leak
    assert len(jax.devices()) < 64


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "smollm-135m", "--shape", "decode_32k",
           "--mesh", "both", "--out", str(tmp_path), "--tag", "t"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for mesh in ("16x16", "2x16x16"):
        f = tmp_path / "t" / f"smollm-135m__decode_32k__{mesh}.json"
        cell = json.loads(f.read_text())
        assert cell["status"] == "ok"
        assert cell["devices"] == (256 if mesh == "16x16" else 512)
        assert "collectives" in cell and "cost_analysis" in cell

    from repro.launch.roofline import load_rows

    rows = load_rows(tmp_path / "t", "16x16")
    assert len(rows) == 1
    r = rows[0]
    assert r["dominant"] in ("compute", "memory", "collective")
    assert r["bound_step_s"] > 0


def test_mesh_factory_is_lazy():
    # Importing mesh.py must not create meshes or touch devices.
    import importlib

    import repro.launch.mesh as m

    importlib.reload(m)
    assert callable(m.make_production_mesh)


def test_input_specs_shapes():
    # input_specs uses ShapeDtypeStructs only — no allocation.
    sys.path.insert(0, str(ROOT / "src"))
    from repro.configs import get_config, shapes_for
    from repro.models.model_api import build

    for arch in ("qwen3-14b", "falcon-mamba-7b", "whisper-large-v3"):
        cfg = get_config(arch)
        b = build(cfg)
        for sname, shape in shapes_for(cfg).items():
            st = b.batch_struct(shape)
            assert all(hasattr(v, "shape") for v in st.values()), (arch, sname)
            if shape.kind == "train":
                assert st["tokens"].shape == (shape.global_batch, shape.seq_len)
