"""Multi-table facade: routing, shared budget split, model-output routing,
aggregated stats; plus the RecMGBuffer bulk API."""
import numpy as np
import pytest

from repro.core.buffer_manager import RecMGBuffer, SlowRecMGBuffer
from repro.core.serving import MultiTableTieredStore


@pytest.fixture
def tables():
    rng = np.random.default_rng(0)
    return [rng.normal(size=(n, 8)).astype(np.float32)
            for n in (100, 50, 200)]


def test_lookup_routes_global_ids(tables):
    ms = MultiTableTieredStore(tables, capacity=64)
    host = np.concatenate(tables)
    ids = np.array([3, 120, 149, 160, 3, 349])  # all three tables + dup
    out = np.asarray(ms.lookup(ids))
    np.testing.assert_allclose(out, host[ids], rtol=1e-6)
    assert ms.stats.lookups == len(ids)
    assert ms.stats.batches == 1


def test_budget_split_proportional(tables):
    ms = MultiTableTieredStore(tables, capacity=70)
    caps = [s.capacity for s in ms.stores]
    assert sum(caps) <= 70
    assert caps[2] > caps[0] > caps[1]  # proportional to 200/100/50 rows
    byte_ms = MultiTableTieredStore(tables, byte_budget=70 * 8 * 4)
    assert sum(s.capacity for s in byte_ms.stores) <= 70


def test_capacity_never_exceeds_table(tables):
    ms = MultiTableTieredStore(tables, capacity=10_000)
    for s, t in zip(ms.stores, tables):
        assert s.capacity <= t.shape[0]


def test_budget_is_hard_despite_min_capacity_floor():
    rng = np.random.default_rng(1)
    tables = [rng.normal(size=(n, 8)).astype(np.float32)
              for n in (500, 6, 6, 6, 6)]
    ms = MultiTableTieredStore(tables, capacity=30, min_capacity=4)
    assert sum(s.capacity for s in ms.stores) <= 30  # clawed back
    assert all(s.capacity >= 4 for s in ms.stores)


def test_lookup_matches_single_store_dtype(tables):
    """Facade output dtype == what a single store returns for that table
    (jax-canonicalized host dtype; f32 for the quantized tier)."""
    f64 = [t.astype(np.float64) for t in tables]
    ms = MultiTableTieredStore(f64, capacity=64)
    single = ms.stores[0].lookup(np.array([0]))
    assert np.asarray(ms.lookup(np.array([0, 120]))).dtype == single.dtype
    q = MultiTableTieredStore(tables, capacity=64, quantize=True)
    assert np.asarray(q.lookup(np.array([0]))).dtype == np.float32


def test_model_outputs_routed_per_table(tables):
    ms = MultiTableTieredStore(tables, capacity=64, policy="recmg")
    # Prefetch global ids landing in tables 0 and 2.
    ms.apply_model_outputs(np.empty(0, np.int64), np.empty(0, np.int64),
                           np.array([5, 151, 160]))
    assert ms.stores[0].n_resident == 1
    assert ms.stores[1].n_resident == 0
    assert ms.stores[2].n_resident == 2
    out = np.asarray(ms.lookup(np.array([5, 151, 160])))
    np.testing.assert_allclose(out, np.concatenate(tables)[[5, 151, 160]],
                               rtol=1e-6)
    assert ms.stats.prefetch_hits == 3


def test_staged_outputs_routed(tables):
    ms = MultiTableTieredStore(tables, capacity=64)
    ms.stage_model_outputs(np.empty(0, np.int64), np.empty(0, np.int64),
                           np.array([0, 149]))
    assert all(s.n_resident == 0 for s in ms.stores)  # not applied yet
    ms.lookup(np.array([0, 149]))
    assert ms.stats.prefetch_hits == 2


def test_per_table_hit_rates(tables):
    ms = MultiTableTieredStore(tables, capacity=64)
    ms.lookup(np.array([0, 1, 0, 1]))
    ms.lookup(np.array([0, 1]))
    rates = ms.per_table_hit_rates()
    assert rates[0] > 0 and rates[1] == 0 and rates[2] == 0


# ---------------- RecMGBuffer bulk API ----------------


def test_set_priorities_matches_sequential():
    a, b = RecMGBuffer(100), RecMGBuffer(100)
    keys = [3, 1, 4, 1, 5]
    for k in keys:
        a.set_priority(k, 4)
    b.set_priorities(keys, 4)
    assert a.score == b.score and a.seq == b.seq


def test_set_priorities_only_new():
    buf = RecMGBuffer(100)
    buf.set_priority(7, 0)
    buf.set_priorities([7, 8], 4, only_new=True)
    assert buf.score[7] - buf.epoch == 0  # existing entry untouched
    assert buf.score[8] - buf.epoch == 4


def test_fetch_many_populate_many_roundtrip():
    buf = RecMGBuffer(4, eviction_speed=2)
    buf.fetch_many(range(6), 2)  # overflows capacity 4 -> evicts 2
    assert len(buf) == 4
    victims = buf.populate_many(10)
    assert len(victims) == 4 and len(buf) == 0


def test_access_chunk_matches_per_access():
    keys = np.array([1, 2, 1, 3, 4, 2, 5, 1, 6, 3] * 5, np.int64)
    bulk = RecMGBuffer(4, eviction_speed=4)
    ref = SlowRecMGBuffer(4, eviction_speed=4, clamp=False)
    hits_bulk = bulk.access_chunk(keys, 4)
    hits_ref = []
    for k in keys.tolist():
        h = ref.contains(k)
        hits_ref.append(h)
        if not h:
            ref.fetch(k, 4)
    assert hits_bulk.tolist() == hits_ref
    assert set(bulk.score) == set(ref.priority)


def test_byte_budget_mixed_dtype_tables():
    """Regression: the byte->row conversion used table 0's itemsize for
    every table, so an fp32 + fp16 mix overran (or under-used) the shared
    budget.  The split now charges each table its own row footprint."""
    rng = np.random.default_rng(3)
    d = 8
    tables = [rng.normal(size=(100, d)).astype(np.float32),
              rng.normal(size=(100, d)).astype(np.float16)]
    byte_budget = 60 * d * 4  # 60 fp32 rows, or 120 fp16 rows
    ms = MultiTableTieredStore(tables, byte_budget=byte_budget)
    spent = sum(int(s.capacity) * int(rb)
                for s, rb in zip(ms.stores, ms.row_bytes_per_table))
    assert spent <= byte_budget
    assert list(ms.row_bytes_per_table) == [d * 4, d * 2]
    # The fp16 table's rows cost half as much, so the same weight buys it
    # more resident rows — the old shared-scalar conversion couldn't.
    assert ms.stores[1].capacity > ms.stores[0].capacity
    # With table-0's itemsize charged uniformly (the old bug) this mix
    # would have been priced at 32 B/row; the correct per-table spend
    # fits strictly more rows into the same bytes.
    assert ms.capacity > byte_budget // (d * 4)
    ids = np.concatenate((np.arange(8), 100 + np.arange(8)))
    out = np.asarray(ms.lookup(ids))
    assert out.shape == (16, d)


def test_byte_budget_quantized_holds_2x_rows():
    """At the same byte budget the quantized facade must hold >= 2x the
    resident rows (d=8: 32 B fp32 vs 12 B int8+scale)."""
    rng = np.random.default_rng(4)
    d = 8
    tables = [rng.normal(size=(200, d)).astype(np.float32)
              for _ in range(3)]
    byte_budget = 50 * d * 4
    fp32 = MultiTableTieredStore(tables, byte_budget=byte_budget)
    q = MultiTableTieredStore(tables, byte_budget=byte_budget,
                              quantize=True)
    assert q.capacity >= 2 * fp32.capacity
    spent = sum(int(s.capacity) * int(rb)
                for s, rb in zip(q.stores, q.row_bytes_per_table))
    assert spent <= byte_budget


def test_byte_budget_hard_with_many_tiny_tables():
    """Regression (min-capacity edge): lifting many tiny tables to
    ``min_capacity`` must never overrun the shared byte budget — the
    effective floor drops to an equal split when the budget is tight."""
    rng = np.random.default_rng(2)
    d = 8
    tables = [rng.normal(size=(6, d)).astype(np.float32) for _ in range(10)]
    row_bytes = d * 4
    byte_budget = 12 * row_bytes  # 12 rows for 10 tables; floor 4 wants 40
    ms = MultiTableTieredStore(tables, byte_budget=byte_budget,
                               min_capacity=4)
    assert sum(s.capacity for s in ms.stores) * ms.row_bytes <= byte_budget
    assert all(s.capacity >= 1 for s in ms.stores)
    # Sanity: lookups across every table still work at the tiny budget.
    ids = np.arange(0, 60, 6)
    out = np.asarray(ms.lookup(ids))
    np.testing.assert_allclose(out, np.concatenate(tables)[ids], rtol=1e-6)


def test_row_budget_hard_with_many_tiny_tables():
    rng = np.random.default_rng(3)
    tables = [rng.normal(size=(6, 8)).astype(np.float32) for _ in range(9)]
    ms = MultiTableTieredStore(tables, capacity=13, min_capacity=4)
    assert sum(s.capacity for s in ms.stores) <= 13
    assert all(s.capacity >= 1 for s in ms.stores)


def test_min_capacity_floor_honored_when_budget_allows():
    """With a roomy budget the configured floor still wins (no behavior
    change for the non-degenerate case)."""
    rng = np.random.default_rng(4)
    tables = [rng.normal(size=(n, 8)).astype(np.float32)
              for n in (500, 6, 6, 6, 6)]
    ms = MultiTableTieredStore(tables, capacity=30, min_capacity=4)
    assert sum(s.capacity for s in ms.stores) <= 30
    assert all(s.capacity >= 4 for s in ms.stores)


def test_facade_resident_mask_routes_tables(tables):
    ms = MultiTableTieredStore(tables, capacity=64)
    ms.lookup(np.array([3, 120, 160]))  # one id in each table
    mask = ms.resident_mask(np.array([3, 4, 120, 160, 200]))
    assert mask.tolist() == [True, False, True, True, False]


def test_budget_below_one_row_per_table_raises():
    rng = np.random.default_rng(5)
    tables = [rng.normal(size=(6, 8)).astype(np.float32) for _ in range(10)]
    with pytest.raises(ValueError, match="one row each"):
        MultiTableTieredStore(tables, capacity=5)
