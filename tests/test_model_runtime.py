"""Serving-runtime contracts of the learned RecMG duo
(:mod:`repro.core.model_runtime`):

* **Padding is invisible** — a batch of n and a batch of m >= n windows
  landing in the same shape bucket produce *bit-identical* outputs on
  the shared rows (the edge-repeat padding rows and the vmapped
  forwards' lack of cross-row ops make bucketing a pure compile-count
  optimization).
* **Buckets agree on decisions** — across *different* buckets XLA
  compiles per shape and the raw floats drift at rounding level
  (~1e-7), but the serving-visible outputs — thresholded keep bits and
  nearest-candidate prefetch ids — must be identical to feeding each
  window alone, for ragged batch sizes straddling every bucket boundary
  (fuzzed via the hypothesis shim plus a deterministic boundary sweep).
* **Batched ~ scalar** — the truly scalar (un-vmapped) forward agrees
  with the batched path to float tolerance, and the thresholded keep
  bits agree wherever the logit is not razor-thin.
* **Grid compatibility** — ``outputs_for`` emits exactly the chunk grid
  ``frequency_outputs`` emits, so every serving loop is interchangeable.
* **Drift fine-tune acceptance** (slow) — on the diurnal switch, the
  phase-1-trained model under :class:`LearnedController` recovers the
  post-switch steady hit rate to within 10% of pre-switch, beats its own
  frozen variant, matches-or-beats the PR-5 heuristic refresh, and
  reproduces byte-identically.
"""
from functools import lru_cache

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.caching_model import caching_logits
from repro.core.features import make_windows
from repro.core.model_runtime import (LearnedModelConfig, LearnedRecMGModel,
                                      _bucket)
from repro.core.recmg import frequency_outputs
from repro.core.trace import TraceGenConfig, generate_trace

CAP = 48


@lru_cache(maxsize=None)
def _setup():
    """One cheaply-trained model + its window set, shared by the whole
    module (the equivalence contract does not care how converged the
    weights are, only that inference reproduces)."""
    trace = generate_trace(TraceGenConfig(
        n_tables=3, rows_per_table=64, n_accesses=3000, seed=0,
        drift_every=10**9))
    cfg = LearnedModelConfig(hidden=16, caching_epochs=1, prefetch_epochs=1,
                             train_stride=8)
    model = LearnedRecMGModel.train_from_trace(trace, CAP, cfg)
    data = make_windows(trace, in_len=cfg.in_len, out_window=cfg.out_len,
                        stride=cfg.in_len)
    return trace, model, data


def _assert_batch_matches_per_window(idx: np.ndarray):
    """Cross-bucket contract: decisions (bits, decoded ids) identical to
    per-window calls; raw points within float rounding."""
    _, model, data = _setup()
    sub = data.batch(idx)
    bits = model.predict_bits(sub)
    pts = model.predict_points(sub)
    ids = model.decode_points(pts)
    for j, i in enumerate(idx):
        one = data.batch(np.array([i]))
        np.testing.assert_array_equal(bits[j], model.predict_bits(one)[0])
        p1 = model.predict_points(one)
        np.testing.assert_allclose(pts[j], p1[0], rtol=0, atol=2e-6)
        np.testing.assert_array_equal(ids[j], model.decode_points(p1)[0])


def test_bucket_helper():
    assert [_bucket(n) for n in (1, 2, 3, 4, 5, 8, 9, 4096)] == \
        [1, 2, 4, 4, 8, 8, 16, 4096]


@pytest.mark.parametrize("n", [1, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33])
def test_same_bucket_padding_bit_exact(n):
    """A batch of n and the full bucket batch of _bucket(n) windows go
    through the same compiled kernel — shared rows must be bit-identical
    (points included), i.e. the padding rows are truly invisible."""
    _, model, data = _setup()
    m = _bucket(n)
    assert len(data) >= m
    small, fullb = data.batch(np.arange(n)), data.batch(np.arange(m))
    np.testing.assert_array_equal(model.predict_bits(small),
                                  model.predict_bits(fullb)[:n])
    ps, pf = model.predict_points(small), model.predict_points(fullb)
    np.testing.assert_array_equal(ps, pf[:n])  # bit-exact, not close
    np.testing.assert_array_equal(model.decode_points(ps),
                                  model.decode_points(pf)[:n])


@pytest.mark.parametrize("n", [1, 2, 3, 4, 7, 8, 9, 15, 16, 17, 31, 32, 33])
def test_bucketed_inference_matches_per_window_at_boundaries(n):
    """Every bucket boundary (2^k - 1, 2^k, 2^k + 1): the bucketed batch
    makes the same decisions as feeding each window alone."""
    _, _, data = _setup()
    assert len(data) >= 33
    _assert_batch_matches_per_window(np.arange(n))


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(0, 130))
def test_bucketed_inference_matches_per_window_fuzz(n, off):
    """Ragged (offset, size) sub-batches — arbitrary serving slices hit
    arbitrary buckets and must all reproduce."""
    _, _, data = _setup()
    off = off % max(1, len(data) - 1)
    n = min(n, len(data) - off)
    _assert_batch_matches_per_window(np.arange(off, off + n))


def test_batched_close_to_scalar_forward():
    """The un-vmapped scalar forward is the semantic reference: batched
    logits match it to float tolerance, and the *decisions* (sign of the
    logit) match everywhere the logit is not within rounding of zero."""
    _, model, data = _setup()
    n = 24
    bits = model.predict_bits(data.batch(np.arange(n)))
    for i in range(n):
        b = data.batch(np.array([i]))
        logit = np.asarray(caching_logits(
            model.cparams, jnp.asarray(b.x_table[0]),
            jnp.asarray(b.x_row1[0]), jnp.asarray(b.x_row2[0]),
            jnp.asarray(b.x_norm[0]), jnp.asarray(b.x_freq[0]),
            jnp.asarray(b.x_rec[0])))
        sure = np.abs(logit) > 1e-5
        np.testing.assert_array_equal(bits[i][sure], (logit > 0)[sure])


def test_outputs_grid_matches_frequency_heuristic():
    """Interchangeability: the learned outputs sit on the exact chunk
    grid the heuristic emits, with the same shapes."""
    trace, model, _ = _setup()
    learned = model.outputs_for(trace)
    freq = frequency_outputs(trace, CAP, in_len=model.cfg.in_len,
                             out_len=model.cfg.out_len)
    np.testing.assert_array_equal(learned.chunk_starts, freq.chunk_starts)
    assert learned.caching_bits.shape == freq.caching_bits.shape
    assert learned.prefetch_ids.shape == freq.prefetch_ids.shape
    assert learned.prefetch_ids.dtype == np.int64


def test_finetune_bounded_and_deterministic():
    """A fine-tune pass is bounded by ``finetune_steps``, moves the
    caching params, leaves the prefetch params alone, and two models
    fine-tuned on the same window stay byte-identical."""
    from jax.flatten_util import ravel_pytree

    trace, _, _ = _setup()
    cfg = LearnedModelConfig(hidden=16, caching_epochs=1, prefetch_epochs=1,
                             train_stride=8)
    models = [LearnedRecMGModel.train_from_trace(trace, CAP, cfg)
              for _ in range(2)]
    window = trace.global_id[-1500:]
    before = np.asarray(ravel_pytree(models[0].cparams)[0]).copy()
    steps = [m.finetune(window) for m in models]
    assert steps[0] == steps[1]
    assert 1 <= steps[0] <= cfg.finetune_steps
    assert models[0].finetune_steps_run == steps[0]
    after = [np.asarray(ravel_pytree(m.cparams)[0]) for m in models]
    assert not np.array_equal(before, after[0])  # it actually trained
    assert np.array_equal(after[0], after[1])    # and deterministically
    p = [np.asarray(ravel_pytree(m.pparams)[0]) for m in models]
    assert np.array_equal(p[0], p[1])
    # Degenerate windows are a no-op (beyond the candidate refresh).
    assert models[0].finetune(window[:5]) == 0


# ---------------------------------------------------------------------------
# Drift fine-tune acceptance (slow lane)
# ---------------------------------------------------------------------------

_DRIFT_SCALE = dict(n_tables=4, rows_per_table=512, n_accesses=12_288,
                    seed=0, n_phases=2)


def _drift_cell(model: str, adapt: bool) -> dict:
    from repro.runtime.drift import DriftConfig
    from repro.workloads import replay_scenario, scenario

    return replay_scenario(
        scenario("diurnal", **_DRIFT_SCALE), policy="recmg", model=model,
        capacity_frac=0.12, batch=256, profile_frac=0.5, adapt=adapt,
        adapt_cfg=DriftConfig(window=1024, hot_k=128))


def _recovery(res: dict) -> float:
    from repro.workloads import phase_steady_hit_rates

    pre, post = phase_steady_hit_rates(res, _DRIFT_SCALE["n_phases"])
    return post / max(pre, 1e-9)


@pytest.mark.slow
def test_drift_finetune_recovers_steady_hit_rate():
    """The ISSUE's adaptation bar, end to end: diurnal switch, model
    trained on phase 1 only.  The online fine-tune must (a) actually fire
    through :class:`LearnedController`, (b) recover the post-switch
    steady hit rate to >= 0.9x pre-switch, (c) beat the frozen model,
    and (d) match or beat the PR-5 heuristic-only refresh."""
    frozen = _drift_cell("learned", adapt=False)
    adapt = _drift_cell("learned", adapt=True)
    heur = _drift_cell("frequency", adapt=True)

    assert adapt["drift"]["triggers"] >= 1
    assert adapt["drift"]["finetunes"] >= 1
    assert adapt["learned"]["finetune_steps"] >= 1
    r_adapt, r_frozen, r_heur = map(_recovery, (adapt, frozen, heur))
    assert r_adapt >= 0.9, (r_adapt, r_frozen)
    assert r_adapt > r_frozen
    assert r_adapt >= r_heur - 0.02, (r_adapt, r_heur)


@pytest.mark.slow
def test_drift_finetune_deterministic_double_run():
    """Online adaptation (fine-tune included) reproduces byte-identically
    — seeded numpy shuffles, jitted training steps, clock-free triggers."""
    a = _drift_cell("learned", adapt=True)
    b = _drift_cell("learned", adapt=True)
    assert a["batch_hit_rates"] == b["batch_hit_rates"]
    assert a["drift"] == b["drift"]
    assert a["learned"] == b["learned"]
